"""Resilience-vs-staleness curves on the asynchronous round runtime.

The regime the async subsystem exists to measure: how saddle escape and
convergence degrade when the center aggregates a shifting, stale subset
of the cluster while the saddle attack is live.  Every arm is one
:class:`repro.api.ExperimentSpec` cell of the ``staleness`` sweep preset
(identical hashes — a store produced by ``python -m repro.sweep run
--preset staleness`` serves these curves byte-for-byte), swept over

    staleness ∈ {0, 1, 4} × participation ∈ {1.0, 0.5}

with the saddle attack at α = 0.2 against staleness-weighted norm-trim,
plus the attack-free α = 0 reference.  The degenerate cell
(staleness 0, participation 1.0) doubles as the bit-exactness anchor
against the synchronous runtime.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.api import ExperimentSpec
from repro.sweep.grids import staleness_grid
from repro.sweep.grid import plan_grid


def run(T=8, participations=(1.0, 0.5), stalenesses=(0, 1, 4),
        alphas=(0.0, 0.2), seed=0):
    axes, base = staleness_grid(n_steps=T, participations=participations,
                                stalenesses=stalenesses, alphas=alphas,
                                seed=seed)
    plan = plan_grid(axes, base)

    out = {"cells": []}
    for entry in plan.entries:
        spec = entry.spec
        _, hist = spec.build().run(entry.n_steps)
        out["cells"].append({
            "hash": entry.hash,
            "staleness": spec.staleness,
            "participation": spec.participation,
            "alpha": spec.alpha,
            "loss": hist["loss"],
            "saddle_escape_step": hist["saddle_escape_step"],
            "uplink_bits": hist["uplink_bits"],
            "rounds": hist["rounds"],
            "mean_arrivals": (sum(hist["n_arrivals"]) /
                              len(hist["n_arrivals"])
                              if hist.get("n_arrivals") else None),
        })

    # bit-exactness anchor: the degenerate async cell vs runtime="paper",
    # reusing the planned (resolved) spec so the comparison covers the
    # exact cell the sweep store holds
    anchor = next((e for e in plan.entries
                   if e.spec.staleness == 0 and e.spec.participation == 1.0
                   and e.spec.drop == 0.0 and e.spec.duplicate == 0.0),
                  None)
    if anchor is not None:
        w_async, h_async = anchor.spec.build().run(T)
        w_sync, h_sync = anchor.spec.replace(runtime="paper") \
            .build().run(T)
        out["degenerate_bit_exact"] = bool(jnp.all(w_async == w_sync)) \
            and h_async["loss"] == h_sync["loss"]
    return out
