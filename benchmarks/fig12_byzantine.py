"""Figs. 1 & 2 — Byzantine experiments, an aggregator × attack grid.

Fig. 1: robust-regression training loss; Fig. 2: logistic test accuracy —
under the four §6 attacks at α ∈ {10%, 15%, 20%}, m=20, M=10, η=1 (the
paper's settings).  The paper's rule is ``norm_trim`` at β = α + 2/m;
``aggregators`` sweeps the registry rules against every attack (the
norm_trim-vs-krum-vs-trimmed_mean comparison).

A thin view over :mod:`repro.sweep`: the grid (shared with the
``fig12`` CLI preset, so a store produced by ``python -m repro.sweep
run --preset fig12`` has the same cell hashes and serves this benchmark
with zero new builds) is planned, run through the sweep engine, and
pivoted out of the result store.  Bare aggregator heads resolve to the
paper's per-α strengths inside the planner
(:func:`repro.sweep.paper_strengths`).
"""
from __future__ import annotations

from repro.sweep import ResultStore, fig12_grid, plan_grid, run_plan
from repro.sweep.grids import FIG12_ATTACKS

ATTACKS = FIG12_ATTACKS
ALPHAS = (0.10, 0.15, 0.20)
AGGREGATORS = ("norm_trim", "krum", "trimmed_mean")


def run(T=15, datasets=("a9a", "w8a"), attacks=ATTACKS, alphas=ALPHAS,
        aggregators=AGGREGATORS, seed=0, store_path=None):
    axes, base = fig12_grid(n_steps=T, datasets=datasets, attacks=attacks,
                            alphas=alphas, aggregators=aggregators,
                            seed=seed)
    store = ResultStore(store_path)
    plan = plan_grid(axes, base)
    # the figure's own grid must plan clean — a pruned cell here means the
    # caller asked for an un-coverable scenario (the old loud SpecError)
    if plan.skipped:
        raise RuntimeError(
            f"fig12 grid: {len(plan.skipped)} cells skipped at plan time: "
            + "; ".join(s["reason"] for s in plan.skipped[:3])
        )
    # retries: a transiently failed or budget-truncated cell cached in a
    # persistent store must not permanently brick the figure
    run_plan(plan, store, retry_failed=True, retry_truncated=True)
    results = {}
    # pivot only THIS plan's cells — a reused store may hold other grids —
    # and refuse to render a figure with holes (failed or truncated cells
    # cached by an earlier run against the same store)
    for rec in (store.get(h) for h in plan.hashes()):
        if rec["status"] != "ok" or rec["metrics"].get("truncated"):
            raise RuntimeError(
                f"fig12 sweep cell {rec['hash']} "
                f"{'truncated' if rec['status'] == 'ok' else rec['status']}: "
                f"{rec.get('error', 'rerun without --budget-s')}"
                + (f" (store: {store_path})" if store_path else "")
            )
        spec, metrics = rec["spec"], rec["metrics"]
        ds, _, kind = spec["problem"].partition("-")
        agg = spec["aggregator"].partition(":")[0]
        key = (f"{ds}/{spec['attack']}/alpha={spec['alpha']:g}/{agg}")
        if kind == "logistic":
            results[f"fig2/{key}"] = {"accuracy": metrics["eval"]}
        else:
            results[f"fig1/{key}"] = {"loss": metrics["loss"]}
    return results
