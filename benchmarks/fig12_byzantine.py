"""Figs. 1 & 2 — Byzantine experiments, now an aggregator × attack grid.

Fig. 1: robust-regression training loss; Fig. 2: logistic test accuracy —
under the four §6 attacks at α ∈ {10%, 15%, 20%}, m=20, M=10, η=1 (the
paper's settings).  The paper's rule is ``norm_trim`` at β = α + 2/m;
``aggregators`` sweeps the registry rules against every attack (the
norm_trim-vs-krum-vs-trimmed_mean comparison), each scenario built
through one :class:`repro.api.ExperimentSpec`.
"""
from __future__ import annotations

from repro.api import ExperimentSpec

ATTACKS = ("flipped_label", "negative", "gaussian", "random_label")
ALPHAS = (0.10, 0.15, 0.20)
# registry aggregators to pit against each attack; "norm_trim" is resolved
# per-α to the paper's β = α + 2/m
AGGREGATORS = ("norm_trim", "krum", "trimmed_mean")


def _aggregator_spec(agg: str, alpha: float, m: int) -> str:
    """Per-α registry spec for a sweep entry (paper-faithful strengths)."""
    if agg == "norm_trim":
        return f"norm_trim:{alpha + 2.0 / m}"
    if agg == "krum":
        return f"krum:{int(alpha * m)}"
    if agg == "trimmed_mean":
        return f"trimmed_mean:{alpha + 1.0 / m}"
    return agg   # "mean" / "coordinate_median" take no strength


def run(T=15, datasets=("a9a", "w8a"), attacks=ATTACKS, alphas=ALPHAS,
        aggregators=AGGREGATORS, seed=0):
    results = {}
    m = 20  # paper's cluster size (fixed by the workloads)
    for ds in datasets:
        for attack in attacks:
            for alpha in alphas:
                for agg in aggregators:
                    spec = _aggregator_spec(agg, alpha, m)
                    base = ExperimentSpec(
                        problem=f"{ds}-logistic", M=10.0, eta=1.0,
                        aggregator=spec, attack=attack, alpha=alpha,
                        seed=seed,
                    )
                    # Fig. 2: logistic accuracy
                    _, hist = base.build().run(T)
                    key = f"{ds}/{attack}/alpha={alpha:g}/{agg}"
                    results[f"fig2/{key}"] = {"accuracy": hist["eval"]}

                    # Fig. 1: robust-regression loss
                    _, hist = base.replace(
                        problem=f"{ds}-robust"
                    ).build().run(T)
                    results[f"fig1/{key}"] = {"loss": hist["loss"]}
    return results
