"""Figs. 1 & 2 — Byzantine experiments.

Fig. 1: robust-regression training loss; Fig. 2: logistic test accuracy —
under the four §6 attacks at α ∈ {10%, 15%, 20%}, β = α + 2/m, m=20,
M=10, η=1 (the paper's settings).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs import PAPER_WORKLOADS
from repro.core import AttackConfig, DistributedCubicNewton, NewtonConfig
from repro.data import paper_dataset

from .problems import accuracy, logistic_loss, robust_regression_loss

ATTACKS = ("flipped_label", "negative", "gaussian", "random_label")
ALPHAS = (0.10, 0.15, 0.20)


def run(T=15, datasets=("a9a", "w8a"), attacks=ATTACKS, alphas=ALPHAS, seed=0):
    results = {}
    for ds in datasets:
        for attack in attacks:
            for alpha in alphas:
                m = 20
                beta = alpha + 2.0 / m

                # Fig. 2: logistic accuracy
                wl = PAPER_WORKLOADS[f"{ds}-logistic"]
                data = paper_dataset(wl, seed)
                algo = DistributedCubicNewton(
                    logistic_loss,
                    NewtonConfig(M=10.0, eta=1.0, beta=beta),
                    AttackConfig(name=attack, alpha=alpha),
                )
                w, hist = algo.run(
                    jnp.zeros(wl.dim), data["X_workers"], data["y_workers"], T,
                    eval_fn=lambda w, d=data: accuracy(w, d["X_test"], d["y_test"]),
                )
                results[f"fig2/{ds}/{attack}/alpha={alpha:g}"] = {
                    "accuracy": hist["eval"]
                }

                # Fig. 1: robust-regression loss
                wl = PAPER_WORKLOADS[f"{ds}-robust"]
                data = paper_dataset(wl, seed)
                algo = DistributedCubicNewton(
                    robust_regression_loss,
                    NewtonConfig(M=10.0, eta=1.0, beta=beta),
                    AttackConfig(name=attack, alpha=alpha),
                )
                w, hist = algo.run(
                    jnp.zeros(wl.dim), data["X_workers"], data["y_workers"], T
                )
                results[f"fig1/{ds}/{attack}/alpha={alpha:g}"] = {
                    "loss": hist["loss"]
                }
    return results
