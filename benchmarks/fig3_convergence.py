"""Fig. 3 — non-Byzantine convergence (α = β = 0).

Top row: logistic-regression test accuracy on the a9a/w8a twins for
M ∈ {10, 15, 20}; bottom row: robust-regression training loss.
Paper protocol: m=20 workers, η=1, λ=1.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs import PAPER_WORKLOADS
from repro.core import DistributedCubicNewton, NewtonConfig
from repro.data import paper_dataset

from .problems import accuracy, logistic_loss, robust_regression_loss


def run(T=15, datasets=("a9a", "w8a"), Ms=(10.0, 15.0, 20.0), seed=0):
    results = {}
    for ds in datasets:
        for M in Ms:
            wl = PAPER_WORKLOADS[f"{ds}-logistic"]
            data = paper_dataset(wl, seed)
            algo = DistributedCubicNewton(
                logistic_loss, NewtonConfig(M=M, eta=wl.eta, beta=0.0)
            )
            w, hist = algo.run(
                jnp.zeros(wl.dim), data["X_workers"], data["y_workers"], T,
                eval_fn=lambda w, d=data: accuracy(w, d["X_test"], d["y_test"]),
            )
            results[f"logistic/{ds}/M={M:g}"] = {
                "accuracy": hist["eval"],
                "loss": hist["loss"],
            }

            wl = PAPER_WORKLOADS[f"{ds}-robust"]
            data = paper_dataset(wl, seed)
            algo = DistributedCubicNewton(
                robust_regression_loss, NewtonConfig(M=M, eta=wl.eta, beta=0.0)
            )
            w, hist = algo.run(
                jnp.zeros(wl.dim), data["X_workers"], data["y_workers"], T
            )
            results[f"robustreg/{ds}/M={M:g}"] = {"loss": hist["loss"]}
    return results
