"""Fig. 3 — non-Byzantine convergence (α = β = 0).

Top row: logistic-regression test accuracy on the a9a/w8a twins for
M ∈ {10, 15, 20}; bottom row: robust-regression training loss.
Paper protocol: m=20 workers, η=1, λ=1.

A thin view over :mod:`repro.sweep`: the (problem × M) grid is planned
once, run through the sweep engine (pass ``store_path`` to make the run
resumable — re-running skips every stored cell), and the figure series
are pivoted straight out of the result store.
"""
from __future__ import annotations

from repro.sweep import ResultStore, fig3_grid, plan_grid, run_plan


def run(T=15, datasets=("a9a", "w8a"), Ms=(10.0, 15.0, 20.0), seed=0,
        store_path=None):
    axes, base = fig3_grid(n_steps=T, datasets=datasets, Ms=Ms, seed=seed)
    store = ResultStore(store_path)
    plan = plan_grid(axes, base)
    # the figure's own grid must plan clean — a pruned cell here means the
    # caller asked for an un-coverable scenario (the old loud SpecError)
    if plan.skipped:
        raise RuntimeError(
            f"fig3 grid: {len(plan.skipped)} cells skipped at plan time: "
            + "; ".join(s["reason"] for s in plan.skipped[:3])
        )
    # retries: a transiently failed or budget-truncated cell cached in a
    # persistent store must not permanently brick the figure
    run_plan(plan, store, retry_failed=True, retry_truncated=True)
    results = {}
    # pivot only THIS plan's cells — a reused store may hold other grids —
    # and refuse to render a figure with holes (failed or truncated cells
    # cached by an earlier run against the same store)
    for rec in (store.get(h) for h in plan.hashes()):
        if rec["status"] != "ok" or rec["metrics"].get("truncated"):
            raise RuntimeError(
                f"fig3 sweep cell {rec['hash']} "
                f"{'truncated' if rec['status'] == 'ok' else rec['status']}: "
                f"{rec.get('error', 'rerun without --budget-s')}"
                + (f" (store: {store_path})" if store_path else "")
            )
        spec, metrics = rec["spec"], rec["metrics"]
        ds, _, kind = spec["problem"].partition("-")
        key = f"{ds}/M={spec['M']:g}"
        if kind == "logistic":
            results[f"logistic/{key}"] = {
                "accuracy": metrics["eval"],
                "loss": metrics["loss"],
            }
        else:
            results[f"robustreg/{key}"] = {"loss": metrics["loss"]}
    return results
