"""Fig. 3 — non-Byzantine convergence (α = β = 0).

Top row: logistic-regression test accuracy on the a9a/w8a twins for
M ∈ {10, 15, 20}; bottom row: robust-regression training loss.
Paper protocol: m=20 workers, η=1, λ=1.  Every run builds through the
:class:`repro.api.ExperimentSpec` facade.
"""
from __future__ import annotations

from repro.api import ExperimentSpec


def run(T=15, datasets=("a9a", "w8a"), Ms=(10.0, 15.0, 20.0), seed=0):
    results = {}
    for ds in datasets:
        for M in Ms:
            exp = ExperimentSpec(
                problem=f"{ds}-logistic", M=M, aggregator="mean", seed=seed
            ).build()
            _, hist = exp.run(T)
            results[f"logistic/{ds}/M={M:g}"] = {
                "accuracy": hist["eval"],
                "loss": hist["loss"],
            }

            exp = ExperimentSpec(
                problem=f"{ds}-robust", M=M, aggregator="mean", seed=seed
            ).build()
            _, hist = exp.run(T)
            results[f"robustreg/{ds}/M={M:g}"] = {"loss": hist["loss"]}
    return results
