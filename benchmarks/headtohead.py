"""Head-to-head — second-order vs first-order, per attack × aggregator.

The paper's headline: ~25% better iteration complexity than first-order
methods.  This benchmark regenerates the comparison from ONE sweep grid
(the ``headtohead`` CLI preset, same cell hashes): ``cubic_newton`` vs
``byzantine_pgd`` [Yin et al. 2019] vs ``compressed_sgd`` [Chen/Li/Chi
2023] on w8a robust regression at m=20, η=1, per attack × aggregator —
all three solvers transmitting through the same
:class:`~repro.comm.VectorChannel` stack, so every reported bit is an
exact :class:`~repro.comm.WireLedger` int (PGD escape-probe rounds
included) and rounds-to-ε / bits-to-ε are comparable across the solver
axis by construction.

A thin view over :mod:`repro.sweep`: plan → run (cached cells are free)
→ pivot the store through :func:`repro.sweep.headtohead_table`.
"""
from __future__ import annotations

from repro.sweep import (
    ResultStore,
    headtohead_grid,
    headtohead_table,
    plan_grid,
    run_plan,
)


def run(T=60, datasets=("w8a",), alphas=(0.2,), eps=0.05, seed=0,
        store_path=None):
    axes, base = headtohead_grid(n_steps=T, datasets=datasets,
                                 alphas=alphas, seed=seed)
    store = ResultStore(store_path)
    plan = plan_grid(axes, base)
    # the comparison's own grid must plan clean — a pruned cell means an
    # un-coverable scenario was requested (the loud SpecError)
    if plan.skipped:
        raise RuntimeError(
            f"headtohead grid: {len(plan.skipped)} cells skipped at plan "
            f"time: " + "; ".join(s["reason"] for s in plan.skipped[:3])
        )
    run_plan(plan, store, retry_failed=True, retry_truncated=True)
    recs = []
    for rec in (store.get(h) for h in plan.hashes()):
        # refuse to compare with holes: a failed or truncated cell would
        # silently bias the round/bit ratios
        if rec["status"] != "ok" or rec["metrics"].get("truncated"):
            raise RuntimeError(
                f"headtohead sweep cell {rec['hash']} "
                f"{'truncated' if rec['status'] == 'ok' else rec['status']}"
                f": {rec.get('error', 'rerun without --budget-s')}"
                + (f" (store: {store_path})" if store_path else "")
            )
        recs.append(rec)
    rows = headtohead_table(recs, eps=eps)
    # ledger-exactness invariant: every reported bit count is an exact
    # WireLedger int (integers end to end, no float estimate anywhere)
    for rec in recs:
        m = rec["metrics"]
        assert m["uplink_bits"] + m["downlink_bits"] == m["total_bits"]
    for row in rows:
        for col, val in row.items():
            if "_bits@" in col and val is not None:
                assert isinstance(val, int), (col, val)
    return rows
