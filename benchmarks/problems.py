"""Shared problem definitions for the paper-experiment benchmarks (§6)."""
import jax.numpy as jnp


def logistic_loss(w, X, y):
    """Eq. (8): regularized logistic regression (λ/2n scaling as in paper)."""
    z = X @ w
    yy = 2.0 * y - 1.0
    return jnp.mean(jnp.log1p(jnp.exp(-yy * z))) + 0.5 / X.shape[0] * (w @ w)


def robust_regression_loss(w, X, y):
    """Eq. (9): non-convex robust linear regression."""
    r = y - X @ w
    return jnp.mean(jnp.log(r * r / 2.0 + 1.0))


def accuracy(w, X, y):
    return float(((X @ w > 0) == (y > 0.5)).mean())
