"""Shared problem definitions for the paper-experiment benchmarks (§6).

The canonical loss functions moved to :mod:`repro.api.problems` (the
experiment facade's problem catalog); this module re-exports them so
older imports keep working.
"""
from repro.api.problems import (  # noqa: F401
    accuracy,
    factor_loss,
    logistic_loss,
    robust_regression_loss,
)
