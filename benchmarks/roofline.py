"""Roofline benchmark: aggregates the dry-run sweep (results/dryrun/*.jsonl)
into the per-(arch × shape × mesh) three-term table EXPERIMENTS.md §Roofline
publishes, plus micro-benchmarks of the Pallas kernels (interpret mode —
CPU wall time is NOT TPU time; the derived column is the roofline estimate).
"""
from __future__ import annotations

import glob
import json
import os
import time

import jax
import jax.numpy as jnp

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def load_records(results_dir=RESULTS_DIR):
    recs = []
    for f in sorted(glob.glob(os.path.join(results_dir, "dryrun*", "*.jsonl"))):
        with open(f) as fh:
            for ln in fh:
                try:
                    recs.append(json.loads(ln))
                except json.JSONDecodeError:
                    pass
    # newest record per (arch, shape, mesh) wins
    dedup = {}
    for r in recs:
        dedup[(r["arch"], r["shape"], r.get("mesh", "?"))] = r
    return list(dedup.values())


def roofline_table(records=None):
    records = records if records is not None else load_records()
    rows = []
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] != "ok":
            rows.append({
                "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "status": r["status"], "reason": r.get("reason", r.get("error", "")),
            })
            continue
        roof = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok",
            "compute_ms": roof["compute_s"] * 1e3,
            "memory_ms": roof["memory_s"] * 1e3,
            "collective_ms": roof["collective_s"] * 1e3,
            "dominant": roof["dominant"],
            "useful_flops_ratio": r["useful_flops_ratio"],
            "bytes_per_device_GB": r["bytes_per_device"] / 1e9,
        })
    return rows


def kernel_microbench(n_iter=3):
    """CPU interpret-mode wall time (correctness-path cost only) + the
    TPU-roofline-derived time for each kernel's benchmark shape."""
    from repro.kernels import cubic_step, flash_attention, rmsnorm
    from repro.launch.hlo import HBM_BW, PEAK_FLOPS

    out = []

    B, H, S, Dh = 1, 4, 512, 64
    q = jnp.ones((B, H, S, Dh), jnp.float32)
    f = lambda: flash_attention(q, q, q, causal=True).block_until_ready()
    f()
    t0 = time.time()
    for _ in range(n_iter):
        f()
    flops = 4 * B * H * S * S * Dh / 2  # causal
    out.append(("flash_attention_512", (time.time() - t0) / n_iter * 1e6,
                flops / PEAK_FLOPS * 1e6))

    d = 300
    Hm = jnp.eye(d)
    g = jnp.ones((d,))
    s = jnp.ones((d,))
    f = lambda: cubic_step(s, g, Hm, M=10.0, gamma=1.0, lr=1e-2).block_until_ready()
    f()
    t0 = time.time()
    for _ in range(n_iter):
        f()
    out.append(("cubic_step_d300", (time.time() - t0) / n_iter * 1e6,
                (d * d * 4) / HBM_BW * 1e6))

    x = jnp.ones((512, 1024), jnp.float32)
    w = jnp.ones((1024,), jnp.float32)
    f = lambda: rmsnorm(x, w).block_until_ready()
    f()
    t0 = time.time()
    for _ in range(n_iter):
        f()
    out.append(("rmsnorm_512x1024", (time.time() - t0) / n_iter * 1e6,
                (512 * 1024 * 8) / HBM_BW * 1e6))
    return out
