"""Benchmark harness — one entry per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall time of one
algorithm round / kernel call on this host; derived = the headline derived
metric for that artifact: final accuracy, loss, round-speedup, exact wire
bits, or dominant roofline term).  Full-protocol runs: pass --full; CI
smoke: ``--dryrun`` (seconds-scale budgets, every entry still executed).

    PYTHONPATH=src python -m benchmarks.run [--full|--dryrun] [--skip-roofline]
"""
from __future__ import annotations

import argparse
import json
import os
import time


def _emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale rounds")
    ap.add_argument("--dryrun", action="store_true",
                    help="seconds-scale smoke: tiny budgets, exercises every "
                         "benchmark entry (CI runs this so they can't rot)")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--out", default="results/benchmarks.json")
    ap.add_argument("--sweep-store-dir", default=None,
                    help="persist the fig3/fig12 sweeps as resumable "
                         "stores under this dir (re-runs skip stored "
                         "cells); default: in-memory")
    ap.add_argument("--trace-dir", default=None,
                    help="enable repro.telemetry: one span per benchmark "
                         "section (plus per-round/wire/compile events from "
                         "the runs underneath) into DIR/events.jsonl and a "
                         "Perfetto-loadable DIR/trace.json")
    ap.add_argument("--bench-ledger", default="results/bench",
                    help="append one fingerprinted BENCH_<name>.json "
                         "record per entry under this dir (compare with "
                         "`python -m repro.obsv bench-compare`); pass an "
                         "empty string to disable")
    args = ap.parse_args(argv)

    from repro.obsv import append_ledger, extract_scalars, fingerprint
    from repro.telemetry import get_telemetry

    tel = get_telemetry()
    if args.trace_dir is not None:
        tel.enable(args.trace_dir)

    meta = fingerprint()

    def _store(name):
        if args.sweep_store_dir is None:
            return None
        return os.path.join(args.sweep_store_dir, f"{name}.jsonl")

    from . import (
        async_staleness,
        fig3_convergence,
        fig12_byzantine,
        headtohead,
        saddle_escape,
        table1_communication,
        roofline,
    )

    T = 15 if args.full else (2 if args.dryrun else 6)
    datasets = ("a9a", "w8a") if args.full else ("a9a",)
    if args.dryrun:
        args.skip_roofline = True
    all_results = {}
    print("name,us_per_call,derived")

    # ---- Fig. 3: non-Byzantine convergence (sweep-engine backed) ---------
    t0 = time.time()
    with tel.span("bench.fig3"):
        r3 = fig3_convergence.run(T=T, datasets=datasets,
                                  Ms=(10.0, 15.0, 20.0) if args.full
                                  else (10.0,),
                                  store_path=_store("fig3"))
    n_rounds = sum(len(v.get("loss", [])) for v in r3.values())
    for k, v in r3.items():
        derived = (f"final_acc={v['accuracy'][-1]:.4f}" if "accuracy" in v
                   else f"final_loss={v['loss'][-1]:.4f}")
        _emit(f"fig3/{k}", (time.time() - t0) / max(n_rounds, 1) * 1e6, derived)
    all_results["fig3"] = r3

    # ---- Figs. 1 & 2: Byzantine attacks (sweep-engine backed) ------------
    t0 = time.time()
    with tel.span("bench.fig12"):
        r12 = fig12_byzantine.run(
            T=T, datasets=datasets,
            attacks=("flipped_label", "negative", "gaussian", "random_label")
            if args.full else (("gaussian",) if args.dryrun
                               else ("flipped_label", "gaussian")),
            alphas=(0.10, 0.15, 0.20) if args.full else (0.20,),
            store_path=_store("fig12"),
        )
    n_rounds = sum(len(v.get("loss", v.get("accuracy", []))) for v in r12.values())
    for k, v in r12.items():
        derived = (f"final_acc={v['accuracy'][-1]:.4f}" if "accuracy" in v
                   else f"final_loss={v['loss'][-1]:.4f}")
        _emit(k, (time.time() - t0) / max(n_rounds, 1) * 1e6, derived)
    all_results["fig12"] = r12

    # ---- Table 1: communication rounds vs ByzantinePGD --------------------
    t0 = time.time()
    with tel.span("bench.table1"):
        t1 = table1_communication.run(
            dataset="a9a" if args.dryrun else "w8a",
            attacks=("gaussian", "flipped_label", "negative", "random_label")
            if args.full else ("gaussian",),
            alphas=(0.10, 0.15, 0.20) if args.full else (0.15,),
            max_rounds=400 if args.full else (40 if args.dryrun else 250),
            newton_budget=60 if not args.dryrun else 4,
        )
    dt = time.time() - t0
    for row in t1:
        _emit(
            f"table1/{row['attack']}/alpha={row['alpha']:g}",
            dt / max(len(t1), 1) * 1e6 / 100,
            f"newton={row['newton_rounds']}r pgd={row['pgd_rounds']}r "
            f"speedup={row['speedup']:.1f}x "
            f"up_bits={row['newton_uplink_bits']} "
            f"down_bits={row['newton_downlink_bits']}",
        )
    all_results["table1"] = t1

    # ---- Head-to-head: solver axis (second- vs first-order) ---------------
    # one sweep grid, all three solvers through the same channel stack;
    # every bits@ε below is an exact WireLedger int
    t0 = time.time()
    with tel.span("bench.headtohead"):
        h2h = headtohead.run(
            T=60 if args.full else (2 if args.dryrun else 20),
            datasets=("a9a",) if args.dryrun else ("w8a",),
            eps=0.3 if args.dryrun else 0.05,
            store_path=_store("headtohead"),
        )
    dt = time.time() - t0
    for row in h2h:
        eps_cols = " ".join(
            f"{c}={'miss' if v is None else v}" for c, v in row.items()
            if "_rounds@" in c or "_bits@" in c
        )
        _emit(
            f"headtohead/{row['attack']}/{row['aggregator']}"
            f"/alpha={row['alpha']:g}",
            dt / max(len(h2h), 1) * 1e6 / 100,
            eps_cols,
        )
    all_results["headtohead"] = h2h

    # ---- Table 1 (compression axis): exact bits on the wire ---------------
    t0 = time.time()
    with tel.span("bench.table1_compression"):
        tc = table1_communication.run_compression(
            dataset="w8a" if args.full else "a9a",
            newton_budget=60 if not args.dryrun else 4,
        )
    dt = time.time() - t0
    for row in tc:
        _emit(
            f"table1_compression/{row['compressor']}",
            dt / max(len(tc), 1) * 1e6 / 100,
            f"rounds={row['rounds']} "
            f"up/round={row['uplink_bits_per_round']} "
            f"down/round={row['downlink_bits_per_round']} "
            f"up_total={row['uplink_bits']} down_total={row['downlink_bits']} "
            f"overhead={row['round_overhead']:.2f}x "
            f"bits_saving={row['bits_saving']:.1f}x",
        )
    all_results["table1_compression"] = tc

    # ---- bits-to-ε curve (total wire, uplink+downlink) --------------------
    t0 = time.time()
    with tel.span("bench.bits_to_eps"):
        te = table1_communication.run_bits_to_eps(
            dataset="w8a" if args.full else "a9a",
            newton_budget=25 if not args.dryrun else 4,
            eps_grid=(0.3, 0.1, 0.05, 0.02) if not args.dryrun else (0.3,),
        )
    dt = time.time() - t0
    for row in te:
        eps_str = " ".join(
            f"eps{eps:g}={bits if bits is not None else 'miss'}"
            for eps, bits in row["bits_to_eps"].items()
        )
        _emit(
            f"bits_to_eps/{row['compressor']}",
            dt / max(len(te), 1) * 1e6 / 100,
            eps_str,
        )
    all_results["bits_to_eps"] = te

    # ---- top-k kernel vs XLA at model scale -------------------------------
    # d sweep scales with the budget: dryrun proves the gridded launch at
    # CI speed; --full covers the ISSUE's 1.4k → 1M ladder (interpret mode
    # off-TPU, so the derived column carries the mode flag)
    kd = ((1408, 4096) if args.dryrun
          else table1_communication.KERNEL_TIMING_DS if args.full
          else (1408, 16_384, 131_072))
    with tel.span("bench.topk_kernel"):
        kt = table1_communication.run_kernel_timing(ds=kd)
    for row in kt:
        _emit(
            f"topk_kernel/d={row['d']}",
            row["kernel_us"],
            f"plan={row['plan']} k={row['k']} "
            f"xla_us={row['xla_topk_us']:.1f} "
            f"interpret={row['interpret_mode']}",
        )
    all_results["topk_kernel_timing"] = kt

    # ---- aggregation roofline: fused center kernels vs XLA dense ----------
    # same budget scaling as the top-k ladder; every row asserts parity
    # before it times, so bench-smoke exercises the kernels' semantics
    agg_ms = (4, 8) if args.dryrun else table1_communication.AGG_ROOFLINE_MS
    agg_ds = ((1408, 4096) if args.dryrun
              else table1_communication.KERNEL_TIMING_DS if args.full
              else (1408, 16_384, 131_072))
    with tel.span("bench.agg_roofline"):
        ar = table1_communication.run_agg_roofline(ms=agg_ms, ds=agg_ds)
    for row in ar:
        extra = (f"xla_us={row['xla_dense_us']:.1f}"
                 if "xla_dense_us" in row else "baseline=skipped")
        bytes_str = (f" center_bytes={row['center_bytes_sparse']}"
                     f"/{row['center_bytes_dense']}"
                     if "center_bytes_sparse" in row else "")
        _emit(
            f"agg_roofline/{row['rule']}/m={row['m']}/d={row['d']}",
            row["kernel_us"],
            f"plan={row['plan']} {extra}{bytes_str} "
            f"interpret={row['interpret_mode']}",
        )
    all_results["agg_roofline"] = ar

    # ---- Saddle escape (beyond-paper; Theorems 1-2 exercised directly) ----
    t0 = time.time()
    with tel.span("bench.saddle_escape"):
        se = saddle_escape.run(
            T=25 if args.full else (5 if args.dryrun else 15))
    dt = (time.time() - t0) * 1e6 / 45
    sv = se["newton"]["saddle_value"]
    _emit("saddle/newton", dt, f"final={se['newton']['loss'][-1]:.4f} "
          f"(saddle_value={sv:.2f})")
    _emit("saddle/first_order_gd", dt, f"final={se['gd']['loss'][-1]:.4f}")
    _emit("saddle/newton_under_saddle_attack", dt,
          f"final={se['newton_saddle_attack']['loss'][-1]:.4f}")
    all_results["saddle_escape"] = se

    # ---- Resilience vs staleness (async runtime; beyond-paper) ------------
    t0 = time.time()
    with tel.span("bench.async_staleness"):
        ast = async_staleness.run(
            T=8 if args.full else (2 if args.dryrun else 6),
            stalenesses=(0, 1, 4) if not args.dryrun else (0, 1),
            participations=(1.0, 0.5),
            alphas=(0.0, 0.2) if not args.dryrun else (0.2,),
        )
    dt = (time.time() - t0) * 1e6 / max(len(ast["cells"]), 1)
    for cell in ast["cells"]:
        esc = cell["saddle_escape_step"]
        _emit(
            f"async/stale={cell['staleness']}/p={cell['participation']:g}"
            f"/alpha={cell['alpha']:g}",
            dt,
            f"final={cell['loss'][-1]:.4f} "
            f"escape={'miss' if esc is None else esc} "
            f"up_bits={cell['uplink_bits']}",
        )
    if "degenerate_bit_exact" in ast:
        _emit("async/degenerate_bit_exact", 0.0,
              f"bit_exact={ast['degenerate_bit_exact']}")
        assert ast["degenerate_bit_exact"], \
            "degenerate async cell must be bit-exact with runtime='paper'"
    all_results["async_staleness"] = ast

    # ---- Roofline: dry-run aggregation + kernel micro-bench ---------------
    if not args.skip_roofline:
        with tel.span("bench.roofline"):
            rows = roofline.roofline_table()
        for r in rows:
            if r["status"] == "ok":
                _emit(
                    f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                    max(r["compute_ms"], r["memory_ms"], r["collective_ms"]) * 1e3,
                    f"dominant={r['dominant']} useful={r['useful_flops_ratio']:.3f}",
                )
            else:
                _emit(
                    f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                    0.0,
                    f"{r['status']}:{r.get('reason','')[:60]}",
                )
        all_results["roofline"] = rows
        for name, us, derived_us in roofline.kernel_microbench():
            _emit(f"kernel/{name}", us, f"tpu_roofline_us={derived_us:.2f}")

    all_results["meta"] = meta
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(all_results, f, indent=1, default=str)
    if args.bench_ledger:
        n_led = 0
        for name, entry in all_results.items():
            if name == "meta":
                continue
            scalars = extract_scalars(name, entry)
            if scalars:
                append_ledger(args.bench_ledger, name, scalars, meta)
                n_led += 1
        print(f"# bench ledger -> {args.bench_ledger} "
              f"({n_led} BENCH_<name>.json files)")
    if args.trace_dir is not None:
        tel.flush()
        print(f"# telemetry -> {args.trace_dir}")


if __name__ == "__main__":
    main()
