"""Saddle-escape experiment (beyond the paper's own §6 set).

The paper's headline theory — cubic-regularized Newton converges to
ε-SECOND-order stationary points (Theorems 1–2) — is exercised directly on
distributed low-rank matrix factorization:

    f_i(U) = ¼ ‖U Uᵀ − Σ_i‖²_F ,   Σ_i = worker i's sample covariance,

which has a strict saddle at U = 0 (λ_min(∇²f) = −λ_max(Σ) < 0) and global
minima at the top-r factors [BNS16, GJZ17 — the papers cited in §1].

Compared: cubic Newton vs first-order robust GD, both starting next to the
saddle; then cubic Newton under the SADDLE-POINT ATTACK (colluding Byzantine
workers send updates pulling the iterate back toward U = 0 — the fake-local-
minimum construction of §5).  Every Newton arm builds through the
:class:`repro.api.ExperimentSpec` facade; the problem itself comes from the
catalog's ``matrix-factor`` entry (:mod:`repro.api.problems`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api import ExperimentSpec, factor_loss
from repro.core.aggregation import norm_trim


def make_problem(key, d=10, r=2, n=400, m=10):
    """Worker datasets: samples with a rank-r planted covariance.

    (Kept for external callers/tests; the facade's ``matrix-factor``
    problem builds the same construction from the experiment seed.)
    """
    ku, kx = jax.random.split(key)
    U_star = jax.random.normal(ku, (d, r))
    X = jax.random.normal(kx, (m, n, r)) @ U_star.T  # (m, n, d) samples
    X = X + 0.01 * jax.random.normal(jax.random.fold_in(kx, 1), (m, n, d))
    return X, U_star


def min_hessian_eig(w, X):
    H = jax.hessian(factor_loss)(w, X, None)
    return float(jnp.linalg.eigvalsh(H)[0])


def run(T=25, d=10, r=2, m=10, seed=0):
    base = ExperimentSpec(
        problem=f"matrix-factor:{d}:{r}", m_workers=m, M=10.0, eta=1.0,
        aggregator="norm_trim:0.1", seed=seed,
    )

    out = {}

    # --- cubic Newton (ours) ---
    exp = base.build()
    prob = exp.problem   # one materialization; same seed ⇒ same data below
    _, h = exp.run(T)
    out["newton"] = {"loss": h["loss"], "saddle_value": prob.saddle_value}

    # --- first-order robust GD baseline (same data, same start) ---
    X, y, Xf, w0 = prob.X_workers, prob.y_workers, prob.X_full, prob.w0
    grad_fn = jax.jit(jax.vmap(jax.grad(factor_loss), in_axes=(None, 0, 0)))
    lossf = jax.jit(factor_loss)
    w = w0
    gd_losses = []
    for _ in range(T):
        g, _ = norm_trim(grad_fn(w, X, y), 0.1)
        w = w - 0.02 * g
        gd_losses.append(float(lossf(w, Xf, None)))
    out["gd"] = {"loss": gd_losses}

    # --- cubic Newton under the saddle-point attack ---
    attacked = base.replace(
        aggregator=f"norm_trim:{0.2 + 2.0 / m!r}", attack="saddle",
        alpha=0.2,
    ).build()
    _, h_atk = attacked.run(T)
    out["newton_saddle_attack"] = {"loss": h_atk["loss"]}

    # curvature certificates at the final iterates
    out["second_order"] = {
        "saddle_lambda_min": min_hessian_eig(jnp.zeros(d * r), Xf),
    }
    return out
