"""Table 1 + §6 comparison — communication rounds to the gradient stopping
criterion: our cubic Newton vs ByzantinePGD [YCKB19]
(R=10, r=5, Q=10, T_th=10, coordinate-wise trimmed mean — their settings).

Paper numbers: ByzantinePGD ≈ 198–212 rounds, ours ≈ 2–16 (w8a robust
regression); non-Byzantine §6: 257 vs 7 ⇒ the 36× claim.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs import PAPER_WORKLOADS
from repro.core import (
    AttackConfig,
    ByzantinePGD,
    DistributedCubicNewton,
    NewtonConfig,
    PGDConfig,
)
from repro.data import paper_dataset

from .problems import robust_regression_loss

ATTACKS = ("gaussian", "flipped_label", "negative", "random_label")


def run(dataset="w8a", attacks=ATTACKS, alphas=(0.10, 0.15, 0.20),
        grad_tol=0.02, max_rounds=400, newton_budget=60, seed=0):
    wl = PAPER_WORKLOADS[f"{dataset}-robust"]
    data = paper_dataset(wl, seed)
    m = wl.m_workers
    w0 = jnp.zeros(wl.dim)
    rows = []

    def one(attack, alpha):
        beta = alpha + 2.0 / m if alpha > 0 else 0.1
        newton = DistributedCubicNewton(
            robust_regression_loss,
            NewtonConfig(M=10.0, eta=1.0, beta=beta),
            AttackConfig(name=attack, alpha=alpha),
        )
        _, h_n = newton.run(
            w0, data["X_workers"], data["y_workers"], newton_budget,
            grad_tol=grad_tol,
        )
        pgd = ByzantinePGD(
            robust_regression_loss,
            PGDConfig(lr=1.0, R=10, r=5.0, Q=10, T_th=10, trim_frac=max(alpha, 0.1)),
            AttackConfig(name=attack, alpha=alpha),
        )
        _, h_p = pgd.run(
            w0, data["X_workers"], data["y_workers"],
            max_rounds=max_rounds, grad_tol=grad_tol,
        )
        return {
            "attack": attack,
            "alpha": alpha,
            "newton_rounds": h_n["rounds"],
            "pgd_rounds": h_p["rounds"],
            "speedup": h_p["rounds"] / max(h_n["rounds"], 1),
        }

    # non-Byzantine headline comparison (the 36× claim)
    rows.append(one("none", 0.0))
    for attack in attacks:
        for alpha in alphas:
            rows.append(one(attack, alpha))
    return rows
