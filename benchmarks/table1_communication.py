"""Table 1 + §6 comparison — communication cost to the gradient stopping
criterion, in ROUNDS *and* EXACT BITS ON THE WIRE (uplink + downlink).

Rounds: our cubic Newton vs ByzantinePGD [YCKB19] (R=10, r=5, Q=10,
T_th=10, coordinate-wise trimmed mean — their settings).  Paper numbers:
ByzantinePGD ≈ 198–212 rounds, ours ≈ 2–16 (w8a robust regression);
non-Byzantine §6: 257 vs 7 ⇒ the 36× claim.

Bits: every transmission — BOTH arms, Newton and PGD — routes through
:mod:`repro.comm` channels, so each row reports each run's exact integer
:class:`~repro.comm.WireLedger` totals per direction (m uplink payloads
+ one broadcast per round, PGD escape-probe rounds included — no
hand-rolled ``rounds · m · 32 · d`` estimate and no lossy float metric
anywhere; the PGD arm builds through ``ExperimentSpec(solver=
"byzantine_pgd")``, the channel-routed :mod:`repro.solvers` loop).  :func:`run_compression` sweeps
δ-approximate compressors on the same stopping criterion (top-k at
k/d = 0.1 pays ~7.8× fewer uplink bits per round on w8a and must stay
within 2× the uncompressed round count), optionally compressing the
downlink broadcast too; :func:`run_bits_to_eps` turns the same runs into
a total-bits(up+down)-to-ε curve — the budget question "how many bits
until ‖∇f‖ ≤ ε?" the rounds-only Table 1 cannot answer.

Kernel: :func:`run_kernel_timing` times the fused Pallas top-k payload
kernel (single-tile launch ≤ 1408, the sharded grid-over-blocks launch
beyond) against the XLA ``lax.top_k``+gather path at model-scale d ∈
{1.4k, 16k, 131k, 1M}, asserting bit-exact payload parity on every
shape — off-TPU the kernel runs in interpret mode, so rows carry an
``interpret_mode`` flag and the wall times answer "does it run at this
scale" rather than "is it faster" there.
"""
from __future__ import annotations

import time

import jax

from repro.api import ExperimentSpec, problem_dim

KERNEL_TIMING_DS = (1408, 16_384, 131_072, 1_000_000)

ATTACKS = ("gaussian", "flipped_label", "negative", "random_label")

# ≥3 compressors for the wire-cost sweep (acceptance floor: none/topk/sign)
COMPRESSOR_SWEEP = (None, "topk:0.1", "signnorm", "int8")


def _spec_name(spec):
    return "none" if spec is None else spec


def run(dataset="w8a", attacks=ATTACKS, alphas=(0.10, 0.15, 0.20),
        grad_tol=0.02, max_rounds=400, newton_budget=60, seed=0):
    m = 20  # the paper workloads partition over 20 machines
    rows = []

    def one(attack, alpha):
        beta = alpha + 2.0 / m if alpha > 0 else 0.1
        exp = ExperimentSpec(
            problem=f"{dataset}-robust", M=10.0, eta=1.0,
            aggregator=f"norm_trim:{beta!r}", attack=attack, alpha=alpha,
            seed=seed,
        ).build()
        _, h_n = exp.run(newton_budget, grad_tol=grad_tol)
        # the PGD arm builds through the same facade (solver axis), so
        # its wire cost is the run's own exact ledger — Yin et al.'s
        # settings (R=10, r=5, Q=10, coordinate-wise trimmed mean)
        pgd = ExperimentSpec(
            problem=f"{dataset}-robust", eta=1.0,
            solver="byzantine_pgd",
            aggregator=f"trimmed_mean:{max(alpha, 0.1)!r}",
            attack=attack, alpha=alpha, seed=seed,
        ).build()
        _, h_p = pgd.run(max_rounds, grad_tol=grad_tol)
        pgd_up = h_p["uplink_bits"]
        pgd_down = h_p["downlink_bits"]
        return {
            "attack": attack,
            "alpha": alpha,
            "newton_rounds": h_n["rounds"],
            "pgd_rounds": h_p["rounds"],
            "speedup": h_p["rounds"] / max(h_n["rounds"], 1),
            # exact ints from the run's WireLedger
            "newton_uplink_bits": h_n["uplink_bits"],
            "newton_downlink_bits": h_n["downlink_bits"],
            "newton_total_bits": h_n["total_bits"],
            "newton_bits_per_round": (
                h_n["total_bits"] // max(h_n["rounds"], 1)
            ),
            "pgd_uplink_bits": pgd_up,
            "pgd_downlink_bits": pgd_down,
            "pgd_total_bits": h_p["total_bits"],
            "bits_speedup": pgd_up / max(h_n["uplink_bits"], 1),
        }

    # non-Byzantine headline comparison (the 36× claim)
    rows.append(one("none", 0.0))
    for attack in attacks:
        for alpha in alphas:
            rows.append(one(attack, alpha))
    return rows


def run_compression(dataset="w8a", compressors=COMPRESSOR_SWEEP,
                    attack="none", alpha=0.0, grad_tol=0.02,
                    newton_budget=60, seed=0, downlink=None):
    """Rounds AND exact bits to the gradient stopping criterion, per
    compressor.

    Same workload/criterion as :func:`run`'s Newton arm; each row reports
    the channels' per-round uplink/downlink cost and the run's exact
    ledger totals.  ``downlink`` optionally compresses the broadcast too
    (e.g. ``"topk:0.1"``).  The acceptance bar is topk:0.1 within 2× of
    the uncompressed round count on w8a-robust at ≥4.7× fewer uplink
    bits.
    """
    d = problem_dim(f"{dataset}-robust")
    m = 20  # the paper workloads partition over 20 machines
    beta = alpha + 2.0 / m if alpha > 0 else 0.1
    rows = []
    for spec in compressors:
        exp = ExperimentSpec(
            problem=f"{dataset}-robust", M=10.0, eta=1.0,
            aggregator=f"norm_trim:{beta!r}", attack=attack, alpha=alpha,
            compressor=spec, downlink_compressor=downlink, seed=seed,
        ).build()
        _, h = exp.run(newton_budget, grad_tol=grad_tol)
        newton = exp.algo
        bps = newton.bits_per_step()
        comp = newton.uplink.compressor
        rows.append({
            "compressor": _spec_name(spec),
            "downlink": _spec_name(downlink),
            "rounds": h["rounds"],
            "reached_tol": h["grad_norm"][-1] <= grad_tol,
            "grad_norm": h["grad_norm"][-1],
            "uplink_bits_per_round": bps["uplink"],
            "downlink_bits_per_round": bps["downlink"],
            "payload_bits_per_worker": bps["uplink"] // m,
            "uplink_bits": h["uplink_bits"],
            "downlink_bits": h["downlink_bits"],
            "total_bits": h["total_bits"],
            "delta_bound": (
                comp.delta_bound(d) if comp is not None else 1.0
            ),
        })
    base = next((r for r in rows if r["compressor"] == "none"), None)
    for r in rows:
        # relative columns only exist when the sweep includes a baseline
        r["round_overhead"] = (
            r["rounds"] / max(base["rounds"], 1) if base else None
        )
        r["bits_saving"] = (
            base["uplink_bits"] / max(r["uplink_bits"], 1)
            if base else None
        )
        r["total_bits_saving"] = (
            base["total_bits"] / max(r["total_bits"], 1)
            if base else None
        )
    return rows


def run_kernel_timing(ds=KERNEL_TIMING_DS, ratio=0.1, repeats=3, seed=0):
    """Fused top-k kernel vs the XLA ``lax.top_k`` path: wall time per
    packed-payload call at model-scale d, with bit-exact parity asserted
    on every shape (same values, same int32 indices — so the timing can
    never drift away from the semantics it claims to speed up).

    Each row reports the auto-selected launch plan (``single_tile`` ≤
    1408, ``gridded`` beyond), the per-call microseconds for both paths,
    and whether the kernel executed in interpret mode (any backend other
    than TPU): interpret rows time the kernel's *semantics*, not its
    silicon performance.
    """
    import numpy as np

    from repro.kernels import kernel_plan, topk_compress
    from repro.kernels.ref import topk_compress_ref
    from repro.telemetry import get_telemetry

    tel = get_telemetry()
    rows = []
    for d in ds:
        k = max(1, int(round(ratio * d)))
        # each rung of the d ladder is one span (parity check + both
        # timed paths), so a --trace-dir run shows where the ladder's
        # wall time actually goes instead of ad-hoc prints
        with tel.span("bench.topk_kernel.d", d=d, k=k):
            x = jax.random.normal(jax.random.PRNGKey(seed), (d,))
            plan, tile = kernel_plan(d)
            kern = jax.jit(lambda z, kk=k: topk_compress(z, kk))
            xla = jax.jit(lambda z, kk=k: topk_compress_ref(z, kk))
            v1, i1 = kern(x)
            v2, i2 = xla(x)
            np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
            np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))

            def _time(f, z=x):
                f(z)[0].block_until_ready()      # compiled above; re-warm
                t0 = time.perf_counter()
                for _ in range(repeats):
                    f(z)[0].block_until_ready()
                return (time.perf_counter() - t0) / repeats * 1e6

            row = {
                "d": d,
                "k": k,
                "plan": plan,
                "tile": tile,
                "kernel_us": _time(kern),
                "xla_topk_us": _time(xla),
                "backend": jax.default_backend(),
                "interpret_mode": jax.default_backend() != "tpu",
            }
        if tel.enabled:
            tel.event("bench.topk_kernel.row", d=d, k=k, plan=plan,
                      kernel_us=row["kernel_us"],
                      xla_topk_us=row["xla_topk_us"],
                      interpret_mode=row["interpret_mode"])
        rows.append(row)
    return rows


AGG_ROOFLINE_MS = (8, 32, 128)

# registry krum materializes an (m, m, d) diff tensor — past this float32
# budget (~1 GB) the dense baseline is infeasible and its row is skipped
# loudly rather than silently downsized
KRUM_BASELINE_MAX_ELEMS = 2**28


def run_agg_roofline(ms=AGG_ROOFLINE_MS, ds=KERNEL_TIMING_DS, ratio=0.1,
                     repeats=3, seed=0, max_k=8192):
    """Aggregation roofline on the same 1.4k → 1M d ladder as
    :func:`run_kernel_timing`, swept over cluster sizes m: the fused
    robust-aggregation kernels vs their XLA dense baselines, parity
    asserted on every shape.

    Three rows per (m, d):

    * ``sparse_mean`` — :func:`repro.kernels.aggregate_sparse` summing m
      top-k payloads straight from the wire (O(m·k) center memory) vs
      the dense path (per-worker scatter to (m, d), then sum).  Payloads
      are integer-valued with distinct per-worker indices (the top-k
      wire format), so parity is exact equality.
    * ``trimmed_mean`` — the tiled bitonic-sort kernel vs the registry's
      ``jnp.sort``-based rule (bit-equal by construction).
    * ``krum`` — the blocked pairwise-distance kernel vs the registry
      ``krum_select``; the baseline's (m, m, d) diff tensor caps its
      feasible shapes (:data:`KRUM_BASELINE_MAX_ELEMS`) — infeasible
      rows keep the kernel timing and carry ``baseline_skipped=True``.

    Off-TPU every kernel runs in interpret mode (flagged per row): the
    numbers answer "does it run, bit-exactly, at this scale".
    """
    import numpy as np

    import jax.numpy as jnp

    from repro.core import aggregation as _agg
    from repro.kernels import (
        agg_kernel_plan,
        aggregate_sparse,
        krum_select_fused,
        trimmed_mean_fused,
    )
    from repro.telemetry import get_telemetry

    tel = get_telemetry()
    interpret = jax.default_backend() != "tpu"
    rng = np.random.default_rng(seed)
    rows = []

    def _time(f, *args):
        jax.block_until_ready(f(*args))          # warm (compile above)
        t0 = time.perf_counter()
        for _ in range(repeats):
            jax.block_until_ready(f(*args))
        return (time.perf_counter() - t0) / repeats * 1e6

    for m in ms:
        for d in ds:
            k = max(1, min(int(round(ratio * d)), max_k, d))
            with tel.span("bench.agg_roofline.md", m=m, d=d, k=k):
                # -- sparse-domain aggregation ------------------------
                vals = jnp.asarray(
                    rng.integers(-8, 9, size=(m, k)), jnp.float32)
                # distinct per-worker indices via strided sampling (the
                # top-k wire guarantee), index-ascending like the wire
                stride = d // k
                idx = jnp.asarray(
                    np.arange(k)[None, :] * stride
                    + rng.integers(0, stride, size=(m, k)),
                    jnp.int32)
                sparse_fn = jax.jit(lambda v, i: aggregate_sparse(v, i, d))
                dense_fn = jax.jit(lambda v, i: jax.vmap(
                    lambda vi, ii: jnp.zeros((d,), vi.dtype).at[ii].set(vi)
                )(v, i).sum(0))
                got = sparse_fn(vals, idx)
                want = dense_fn(vals, idx)
                np.testing.assert_array_equal(
                    np.asarray(got), np.asarray(want))
                plan, _ = agg_kernel_plan(m, d, k=k)
                rows.append({
                    "rule": "sparse_mean", "m": m, "d": d, "k": k,
                    "plan": plan,
                    "kernel_us": _time(sparse_fn, vals, idx),
                    "xla_dense_us": _time(dense_fn, vals, idx),
                    "center_bytes_sparse": m * k * 8 + 4 * d,
                    "center_bytes_dense": m * d * 4 + 4 * d,
                    "backend": jax.default_backend(),
                    "interpret_mode": interpret,
                })

                # -- fused dense rules: integer-valued (m, d) stack ---
                x = jnp.asarray(
                    rng.integers(-5, 6, size=(m, d)), jnp.float32)
                tm_kern = lambda z: trimmed_mean_fused(z, 0.2)
                tm_xla = jax.jit(lambda z: _agg.trimmed_mean(z, 0.2))
                np.testing.assert_array_equal(
                    np.asarray(tm_kern(x)), np.asarray(tm_xla(x)))
                rows.append({
                    "rule": "trimmed_mean", "m": m, "d": d,
                    "plan": agg_kernel_plan(m, d)[0],
                    "kernel_us": _time(tm_kern, x),
                    "xla_dense_us": _time(tm_xla, x),
                    "backend": jax.default_backend(),
                    "interpret_mode": interpret,
                })

                n_byz = max(1, m // 8)
                kr_kern = lambda z: krum_select_fused(z, n_byz)
                baseline_ok = m * m * d <= KRUM_BASELINE_MAX_ELEMS
                row = {
                    "rule": "krum", "m": m, "d": d, "n_byz": n_byz,
                    "plan": agg_kernel_plan(m, d)[0],
                    "kernel_us": _time(kr_kern, x),
                    "baseline_skipped": not baseline_ok,
                    "backend": jax.default_backend(),
                    "interpret_mode": interpret,
                }
                if baseline_ok:
                    kr_xla = jax.jit(
                        lambda z: _agg.krum_select(z, n_byz))
                    assert int(kr_kern(x)) == int(kr_xla(x))
                    row["xla_dense_us"] = _time(kr_xla, x)
                else:
                    print(f"agg_roofline: krum dense baseline skipped at "
                          f"m={m} d={d} (m²·d = {m * m * d} > "
                          f"{KRUM_BASELINE_MAX_ELEMS})")
                rows.append(row)
            if tel.enabled:
                for r in rows[-3:]:
                    tel.event("bench.agg_roofline.row", **{
                        kk: vv for kk, vv in r.items()
                        if isinstance(vv, (int, float, str, bool))})
    return rows


def run_bits_to_eps(dataset="a9a", compressors=COMPRESSOR_SWEEP,
                    eps_grid=(0.3, 0.1, 0.05, 0.02), newton_budget=60,
                    seed=0, downlink=None):
    """Total-bits-to-ε curves: cumulative exact wire bits (uplink +
    downlink) spent when ‖∇f‖ first drops below each ε.

    Returns one row per compressor with the full (bits, grad_norm)
    trajectory plus the bits-at-ε table (None where the budget never
    reached ε) — the x axis is the per-step ``bits_cumulative`` ledger
    series, so adaptive-k runs report their true varying per-step cost.
    """
    rows = []
    for spec in compressors:
        exp = ExperimentSpec(
            problem=f"{dataset}-robust", M=10.0, eta=1.0,
            aggregator="norm_trim:0.1", compressor=spec,
            downlink_compressor=downlink, seed=seed,
        ).build()
        _, h = exp.run(newton_budget)
        bits_at_eps = {}
        for eps in eps_grid:
            hit = next(
                (b for b, gn in zip(h["bits_cumulative"], h["grad_norm"])
                 if gn <= eps),
                None,
            )
            bits_at_eps[eps] = hit
        rows.append({
            "compressor": _spec_name(spec),
            "downlink": _spec_name(downlink),
            "bits_cumulative": h["bits_cumulative"],
            "grad_norm": h["grad_norm"],
            "bits_to_eps": bits_at_eps,
        })
    return rows
