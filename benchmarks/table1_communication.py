"""Table 1 + §6 comparison — communication cost to the gradient stopping
criterion, in ROUNDS *and* BITS ON THE WIRE.

Rounds: our cubic Newton vs ByzantinePGD [YCKB19] (R=10, r=5, Q=10,
T_th=10, coordinate-wise trimmed mean — their settings).  Paper numbers:
ByzantinePGD ≈ 198–212 rounds, ours ≈ 2–16 (w8a robust regression);
non-Byzantine §6: 257 vs 7 ⇒ the 36× claim.

Bits: every row also reports exact uplink wire cost (m workers × payload
bits × rounds; see repro.compression's per-compressor accounting), and
:func:`run_compression` sweeps δ-approximate compressors (none / top-k /
sign+norm / int8) on the same stopping criterion — the paper's
rounds-vs-accuracy story gains a compression-ratio axis: top-k at
k/d = 0.1 pays ~7.8× fewer bits per round on w8a (1230 vs 9600) and
must stay within 2× the uncompressed round count.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.compression import make_compressor
from repro.configs import PAPER_WORKLOADS
from repro.core import (
    AttackConfig,
    ByzantinePGD,
    DistributedCubicNewton,
    NewtonConfig,
    PGDConfig,
)
from repro.data import paper_dataset

from .problems import robust_regression_loss

ATTACKS = ("gaussian", "flipped_label", "negative", "random_label")

# ≥3 compressors for the wire-cost sweep (acceptance floor: none/topk/sign)
COMPRESSOR_SWEEP = (None, "topk:0.1", "signnorm", "int8")


def _spec_name(spec):
    return "none" if spec is None else spec


def run(dataset="w8a", attacks=ATTACKS, alphas=(0.10, 0.15, 0.20),
        grad_tol=0.02, max_rounds=400, newton_budget=60, seed=0):
    wl = PAPER_WORKLOADS[f"{dataset}-robust"]
    data = paper_dataset(wl, seed)
    m = wl.m_workers
    d = wl.dim
    w0 = jnp.zeros(wl.dim)
    rows = []

    def one(attack, alpha):
        beta = alpha + 2.0 / m if alpha > 0 else 0.1
        newton = DistributedCubicNewton(
            robust_regression_loss,
            NewtonConfig(M=10.0, eta=1.0, beta=beta),
            AttackConfig(name=attack, alpha=alpha),
        )
        _, h_n = newton.run(
            w0, data["X_workers"], data["y_workers"], newton_budget,
            grad_tol=grad_tol,
        )
        pgd = ByzantinePGD(
            robust_regression_loss,
            PGDConfig(lr=1.0, R=10, r=5.0, Q=10, T_th=10, trim_frac=max(alpha, 0.1)),
            AttackConfig(name=attack, alpha=alpha),
        )
        _, h_p = pgd.run(
            w0, data["X_workers"], data["y_workers"],
            max_rounds=max_rounds, grad_tol=grad_tol,
        )
        # PGD ships one full-precision d-gradient per worker per round
        pgd_bits = h_p["rounds"] * m * 32 * d
        return {
            "attack": attack,
            "alpha": alpha,
            "newton_rounds": h_n["rounds"],
            "pgd_rounds": h_p["rounds"],
            "speedup": h_p["rounds"] / max(h_n["rounds"], 1),
            "newton_wire_bits": h_n["wire_bits"],
            "newton_bits_per_round": h_n["wire_bits"] // max(h_n["rounds"], 1),
            "pgd_wire_bits": pgd_bits,
            "bits_speedup": pgd_bits / max(h_n["wire_bits"], 1),
        }

    # non-Byzantine headline comparison (the 36× claim)
    rows.append(one("none", 0.0))
    for attack in attacks:
        for alpha in alphas:
            rows.append(one(attack, alpha))
    return rows


def run_compression(dataset="w8a", compressors=COMPRESSOR_SWEEP,
                    attack="none", alpha=0.0, grad_tol=0.02,
                    newton_budget=60, seed=0):
    """Rounds AND bits to the gradient stopping criterion, per compressor.

    Same workload/criterion as :func:`run`'s Newton arm; each row reports
    the compressor's per-round uplink cost (m × payload bits), the total
    rounds×bits spend, and the round overhead vs the uncompressed run —
    the acceptance bar is topk:0.1 within 2× of none on w8a-robust.
    """
    wl = PAPER_WORKLOADS[f"{dataset}-robust"]
    data = paper_dataset(wl, seed)
    m, d = wl.m_workers, wl.dim
    w0 = jnp.zeros(d)
    beta = alpha + 2.0 / m if alpha > 0 else 0.1
    rows = []
    for spec in compressors:
        newton = DistributedCubicNewton(
            robust_regression_loss,
            NewtonConfig(M=10.0, eta=1.0, beta=beta, compressor=spec),
            AttackConfig(name=attack, alpha=alpha),
        )
        _, h = newton.run(
            w0, data["X_workers"], data["y_workers"], newton_budget,
            grad_tol=grad_tol,
        )
        comp = make_compressor(spec, d)
        rows.append({
            "compressor": _spec_name(spec),
            "rounds": h["rounds"],
            "reached_tol": h["grad_norm"][-1] <= grad_tol,
            "grad_norm": h["grad_norm"][-1],
            "bits_per_round": newton.wire_bits_per_step(d, m),
            "payload_bits_per_worker": (
                comp.wire_bits(d) if comp is not None else 32 * d
            ),
            "wire_bits_total": h["wire_bits"],
            "delta_bound": (
                comp.delta_bound(d) if comp is not None else 1.0
            ),
        })
    base = next((r for r in rows if r["compressor"] == "none"), None)
    for r in rows:
        # relative columns only exist when the sweep includes a baseline
        r["round_overhead"] = (
            r["rounds"] / max(base["rounds"], 1) if base else None
        )
        r["bits_saving"] = (
            base["wire_bits_total"] / max(r["wire_bits_total"], 1)
            if base else None
        )
    return rows
