"""Async saddle-escape under attack: Algorithm 1 on the asynchronous
round runtime — half the workers participate each round, updates land up
to 2 rounds stale, and 20% of the cluster mounts the saddle attack while
norm-trimming aggregation (staleness-weighted) escapes anyway.

Also demonstrates the degenerate-config guarantee: participation 1.0 /
staleness 0 / no faults runs the synchronous program and is bit-exact
with ``runtime="paper"``.

    PYTHONPATH=src python examples/async_rounds.py
"""
import jax.numpy as jnp

from repro.api import ExperimentSpec


def main():
    m, alpha = 10, 0.2
    base = dict(
        problem="matrix-factor:8:2",     # strict-saddle problem, known f*
        m_workers=m,
        M=10.0,
        aggregator=f"norm_trim:{alpha + 2.0 / m}",
        attack="saddle",                 # pin the cluster at the saddle
        alpha=alpha,
        seed=0,
    )

    # -- degenerate async == synchronous, bit for bit -------------------
    w_sync, h_sync = ExperimentSpec(runtime="paper", **base).build().run(10)
    w_deg, h_deg = ExperimentSpec(runtime="async", **base).build().run(10)
    assert bool(jnp.all(w_sync == w_deg)), \
        "degenerate async must be bit-exact with the synchronous runtime"
    assert h_deg["async_degenerate"] is True
    print(f"degenerate async: bit-exact with paper runtime "
          f"(final loss {h_deg['loss'][-1]:.4f})")

    # -- the actually-asynchronous run ----------------------------------
    spec = ExperimentSpec(
        runtime="async",
        participation=0.5,               # 5-worker cohorts per round
        staleness=2,                     # updates land up to 2 rounds late
        **base,
    )
    exp = spec.build()
    w, hist = exp.run(n_steps=20)

    saddle = exp.problem.saddle_value
    print(f"rounds={hist['rounds']}  final_loss={hist['loss'][-1]:.4f}  "
          f"saddle_value={saddle:.4f}  "
          f"escape_step={hist['saddle_escape_step']}")
    print("loss path:   ", " ".join(f"{l:.2f}" for l in hist["loss"]))
    print("cohort sizes:", hist["cohort_size"])
    print("arrivals:    ", hist["n_arrivals"])
    print("queue depth: ", hist["queue_depth"])
    print("spec:", spec.to_json())

    assert hist["saddle_escape_step"] is not None, \
        "staleness-weighted norm-trim should still escape the saddle"
    assert hist["loss"][-1] < saddle, "must end below the saddle value"
    assert all(c == 5 for c in hist["cohort_size"]), "p=0.5 of m=10"
    # exact wire accounting survives asynchrony: every sent packet billed
    assert hist["uplink_bits"] == 32 * exp.problem.dim * sum(
        hist["cohort_size"]
    )


if __name__ == "__main__":
    main()
