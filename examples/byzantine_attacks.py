"""The §6 attacks against the aggregator registry — naive mean vs the
paper's norm-trim vs krum vs trimmed-mean, the contrast that motivates the
paper — on the non-convex robust-regression objective (Eq. 9).

Each (attack × aggregator) cell is one declarative
:class:`repro.api.ExperimentSpec`; the sweep is literally a loop over the
registry spec strings.

    PYTHONPATH=src python examples/byzantine_attacks.py [--rounds N]
"""
import argparse

import jax.numpy as jnp

from repro.api import ExperimentSpec, SpecError

ATTACKS = ("gaussian:50.0", "negative", "flipped_label", "random_label")


def aggregator_sweep(m: int, alpha: float):
    """Registry spec strings swept per attack (strengths set from α)."""
    return (
        ("mean", "mean"),                                    # naive baseline
        ("norm_trim", f"norm_trim:{alpha + 2.0 / m}"),       # the paper
        ("krum", f"krum:{int(alpha * m)}"),
        ("trimmed_mean", f"trimmed_mean:{alpha + 1.0 / m}"),
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--alpha", type=float, default=0.2)
    args = ap.parse_args(argv)

    m, alpha, T = 20, args.alpha, args.rounds
    sweep = aggregator_sweep(m, alpha)
    base = ExperimentSpec(
        problem="synthetic-regression:8000:40", m_workers=m, M=10.0,
        alpha=alpha, seed=1,
    )

    header = " | ".join(f"{name:>12s}" for name, _ in sweep)
    print(f"{'attack':>15s} | {header} | norm-trim err")
    print("-" * (20 + 16 * len(sweep)))
    for attack in ATTACKS:
        cells, err = [], float("nan")
        for name, agg_spec in sweep:
            try:
                exp = base.replace(attack=attack, aggregator=agg_spec).build()
            except SpecError:
                # this rule can't cover the requested α at m=20 (e.g.
                # krum at α near the boundary) — report, keep sweeping
                cells.append(f"{'n/a':>12s}")
                continue
            w, hist = exp.run(T)
            cells.append(f"{hist['loss'][-1]:12.4f}")
            if name == "norm_trim":
                w_star = exp.problem.w_star
                err = float(jnp.linalg.norm(w - w_star)
                            / jnp.linalg.norm(w_star))
        print(f"{attack.partition(':')[0]:>15s} | {' | '.join(cells)} | "
              f"{err:.3f}")


if __name__ == "__main__":
    main()
