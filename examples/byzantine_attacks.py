"""All four §6 attacks, robust (norm-trim) vs naive (mean) aggregation —
the contrast that motivates the paper — on the non-convex robust-regression
objective (Eq. 9).

    PYTHONPATH=src python examples/byzantine_attacks.py
"""
import jax
import jax.numpy as jnp

from repro.core import AttackConfig, DistributedCubicNewton, NewtonConfig
from repro.data import make_regression, shard_to_workers


def robust_regression_loss(w, X, y):
    r = y - X @ w
    return jnp.mean(jnp.log(r * r / 2.0 + 1.0))


def main():
    m, alpha, T = 20, 0.2, 12
    X, y, w_star = make_regression(jax.random.PRNGKey(1), 8000, 40)
    Xw, yw = shard_to_workers(X, y, m)
    w0 = jnp.zeros(40)

    print(f"{'attack':>15s} | {'naive mean':>12s} | {'norm-trim':>12s} | param err")
    print("-" * 64)
    for attack in ("gaussian", "negative", "flipped_label", "random_label"):
        atk = AttackConfig(name=attack, alpha=alpha, sigma=50.0, num_classes=2)
        naive = DistributedCubicNewton(
            robust_regression_loss, NewtonConfig(M=10.0, beta=0.0), atk
        )
        robust = DistributedCubicNewton(
            robust_regression_loss,
            NewtonConfig(M=10.0, beta=alpha + 2.0 / m),
            atk,
        )
        _, h_naive = naive.run(w0, Xw, yw, T)
        w_r, h_rob = robust.run(w0, Xw, yw, T)
        err = float(jnp.linalg.norm(w_r - w_star) / jnp.linalg.norm(w_star))
        print(f"{attack:>15s} | {h_naive['loss'][-1]:12.4f} | "
              f"{h_rob['loss'][-1]:12.4f} | {err:.3f}")


if __name__ == "__main__":
    main()
