"""The §6 attacks against the aggregator registry — naive mean vs the
paper's norm-trim vs krum vs trimmed-mean, the contrast that motivates the
paper — on the non-convex robust-regression objective (Eq. 9).

The (attack × aggregator) grid is planned, executed, and pivoted by
:mod:`repro.sweep`: one ``plan_grid`` call replaces the hand-rolled
loop, per-α strengths come from the planner's ``paper_strengths``
resolve hook, combos a rule cannot cover are skipped at plan time with
a recorded reason (shown as ``n/a``), and the norm-trim parameter error
‖w − w*‖/‖w*‖ is the engine's stored ``w_err`` metric.

    PYTHONPATH=src python examples/byzantine_attacks.py [--rounds N]
"""
import argparse

from repro.sweep import ResultStore, plan_grid, run_plan

ATTACKS = ("gaussian:50.0", "negative", "flipped_label", "random_label")
AGGREGATORS = ("mean", "norm_trim", "krum", "trimmed_mean")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--alpha", type=float, default=0.2)
    args = ap.parse_args(argv)

    plan = plan_grid(
        axes={"attack": list(ATTACKS), "aggregator": list(AGGREGATORS)},
        base={"problem": "synthetic-regression:8000:40", "m_workers": 20,
              "M": 10.0, "alpha": args.alpha, "seed": 1,
              "n_steps": args.rounds},
    )
    store = ResultStore()
    run_plan(plan, store)

    cell = {}      # (attack head, aggregator head) -> record (any status)
    for rec in store.records():
        spec = rec["spec"]
        cell[(spec["attack"].partition(":")[0],
              spec["aggregator"].partition(":")[0])] = rec

    header = " | ".join(f"{name:>12s}" for name in AGGREGATORS)
    print(f"{'attack':>15s} | {header} | norm-trim err")
    print("-" * (20 + 16 * len(AGGREGATORS)))
    for attack in ATTACKS:
        head = attack.partition(":")[0]
        cells, err = [], float("nan")
        for agg in AGGREGATORS:
            rec = cell.get((head, agg))
            if rec is None:
                # skipped at plan time (rule can't cover this α at m=20)
                cells.append(f"{'n/a':>12s}")
                continue
            if rec["status"] != "ok":
                # built but died at run time — not the same thing as n/a
                cells.append(f"{'failed':>12s}")
                continue
            cells.append(f"{rec['metrics']['loss'][-1]:12.4f}")
            if agg == "norm_trim":
                err = rec["metrics"].get("w_err", float("nan"))
        print(f"{head:>15s} | {' | '.join(cells)} | {err:.3f}")


if __name__ == "__main__":
    main()
