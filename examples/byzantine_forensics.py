"""Per-worker Byzantine forensics end to end: plant two attacks, trace
them, and let the doctor name the culprits.

Two traced runs share one telemetry directory — a colluding **saddle**
attack on the matrix-factorization problem (the paper's escape setting)
and a **gaussian** attack on compressed logistic regression — each with
α = 0.2 planted Byzantine workers.  The schema-v4 round records carry
per-worker keep/norm/δ̂/suspicion, so the run-health doctor can recover
the planted worker set exactly:

    PYTHONPATH=src python examples/byzantine_forensics.py
    # (the script runs the doctor itself and asserts precision = recall = 1)

Inspect interactively afterwards:

    python -m repro.obsv doctor <printed telemetry dir> \\
        --trace <dir>/trace.json     # per-worker Perfetto tracks
"""
import os
import tempfile

M_WORKERS = 10
ALPHA = 0.2


def main():
    tel_dir = os.environ.get("REPRO_TELEMETRY_DIR") or tempfile.mkdtemp(
        prefix="forensics-")
    os.environ["REPRO_TELEMETRY_DIR"] = tel_dir

    from repro.api import ExperimentSpec
    from repro.obsv import analyze_events, load_events
    from repro.telemetry import get_telemetry, planted_byzantine_ids

    # β barely above α: norm_trim then rejects EXACTLY the ⌊α·m⌋ planted
    # workers each round, so suspicion concentrates on the true set.  (A
    # wider margin like the paper's α + 2/m also rejects the largest
    # honest norms every round — robust, but forensically blurrier.)
    beta = ALPHA + 0.02
    planted = planted_byzantine_ids(M_WORKERS, ALPHA)

    # run 1: colluding saddle-pushers against robust Newton at the strict
    # saddle — the aggregator must both escape AND expose the colluders
    ExperimentSpec(
        problem="matrix-factor:10:2", m_workers=M_WORKERS, M=10.0,
        aggregator=f"norm_trim:{beta}", attack="saddle", alpha=ALPHA,
        seed=0,
    ).build().run(n_steps=12)

    # run 2: gaussian blasters on the compressed wire (top-k + EF21)
    ExperimentSpec(
        problem="synthetic-logistic:1200:40", m_workers=M_WORKERS,
        aggregator=f"norm_trim:{beta}", attack="gaussian", alpha=ALPHA,
        compressor="topk:8", error_feedback="ef21", seed=0,
    ).build().run(n_steps=10)

    get_telemetry().flush()

    events, problems = load_events(tel_dir)
    assert not problems, f"schema-invalid stream: {problems[:3]}"
    report = analyze_events(events)
    assert report["n_runs"] == 2, report["n_runs"]
    for run in report["runs"]:
        det = run["detection"]
        print(f"{run['runtime']}/{run['attack']}: "
              f"flagged={run['flagged']} planted={run['byzantine_true']} "
              f"precision={det['precision']:.2f} "
              f"recall={det['recall']:.2f}")
        assert run["byzantine_true"] == planted
        assert det["precision"] == 1.0 and det["recall"] == 1.0, (
            f"forensics must recover the planted set exactly, "
            f"got {run['flagged']} vs {planted}"
        )
    assert not report["wire_ledger_mismatch"]
    print(f"telemetry -> {tel_dir}")


if __name__ == "__main__":
    main()
