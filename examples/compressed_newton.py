"""Compressed worker→center communication: the paper's third pillar.

Runs the same Byzantine logistic-regression workload as quickstart.py
under every δ-approximate compressor in the registry and prints the
wire-cost / rounds trade-off — top-k at k/d = 0.1 ships ~8× fewer
uplink bits per round and (with EF21 error feedback, the default) stays
within ~2× of the uncompressed round count.

    PYTHONPATH=src python examples/compressed_newton.py
"""
import jax
import jax.numpy as jnp

from repro.core import AttackConfig, DistributedCubicNewton, NewtonConfig
from repro.data import make_classification, shard_to_workers


def logistic_loss(w, X, y):
    z = X @ w
    yy = 2.0 * y - 1.0
    return jnp.mean(jnp.log1p(jnp.exp(-yy * z))) + 1e-3 * w @ w


def main():
    m, alpha, d = 20, 0.2, 60
    X, y, _ = make_classification(jax.random.PRNGKey(0), 8000, d, margin=3.0)
    Xw, yw = shard_to_workers(X, y, m)

    print(f"{'compressor':>10s} {'bits/round':>10s} {'rounds':>6s} "
          f"{'grad_norm':>9s} {'acc':>6s}")
    for spec in (None, "topk:0.1", "randk:0.1", "signnorm", "int8"):
        algo = DistributedCubicNewton(
            logistic_loss,
            NewtonConfig(M=10.0, eta=1.0, beta=alpha + 2.0 / m,
                         compressor=spec),
            AttackConfig(name="gaussian", alpha=alpha, sigma=50.0),
        )
        w, hist = algo.run(jnp.zeros(d), Xw, yw, n_steps=40, grad_tol=0.05)
        acc = float(((X @ w > 0) == (y > 0.5)).mean())
        print(f"{str(spec or 'none'):>10s} "
              f"{algo.wire_bits_per_step(d, m):>10d} {hist['rounds']:>6d} "
              f"{hist['grad_norm'][-1]:>9.4f} {acc:>6.3f}")


if __name__ == "__main__":
    main()
