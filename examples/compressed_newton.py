"""Compressed worker↔center communication: the paper's third pillar.

Runs the paper's w8a robust-regression workload under every δ-approximate
compressor in the registry and prints the exact-integer wire-cost /
rounds trade-off from the run's :class:`repro.comm.WireLedger` — top-k
at k/d = 0.1 ships ~7.8× fewer uplink bits per round and (with EF21
error feedback, the default) stays within ~2× of the uncompressed round
count.

Flags demonstrate the full channel layer on total wire (up + down):

    --downlink [SPEC]   compress the center→worker broadcast too
                        (default spec topk:0.1 when no value given)
    --adaptive-k        use the adaptive_topk schedule for the uplink
                        (k grows on gradient-norm plateaus, shrinks when
                        progress is cheap)

    PYTHONPATH=src python examples/compressed_newton.py --downlink --adaptive-k
"""
import argparse

from repro.api import ExperimentSpec, problem_dim


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="w8a", choices=["a9a", "w8a"])
    ap.add_argument("--downlink", nargs="?", const="topk:0.1", default=None,
                    help="compress the broadcast too (optional spec)")
    ap.add_argument("--adaptive-k", action="store_true",
                    help="adaptive_topk:0.05:0.5 uplink schedule")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--grad-tol", type=float, default=0.02)
    ap.add_argument("--alpha", type=float, default=0.15,
                    help="Byzantine fraction (gaussian attack)")
    args = ap.parse_args(argv)

    problem = f"{args.dataset}-robust"
    m, d = 20, problem_dim(problem)
    beta = args.alpha + 2.0 / m if args.alpha > 0 else 0.1
    base = ExperimentSpec(
        problem=problem, aggregator=f"norm_trim:{beta!r}",
        attack="gaussian" if args.alpha > 0 else "none", alpha=args.alpha,
    )

    specs = [None, "topk:0.1", "randk:0.1", "signnorm", "int8"]
    if args.adaptive_k:
        specs.append("adaptive_topk:0.05:0.5")

    print(f"# {problem}: m={m} d={d} downlink={args.downlink or 'fp32'} "
          f"attack=gaussian@{args.alpha}")
    print(f"{'uplink':>22s} {'rounds':>6s} {'up_bits':>12s} {'down_bits':>10s} "
          f"{'total_bits':>12s} {'saving':>7s} {'grad_norm':>9s}")
    base_total = None
    for spec in specs:
        # the baseline row stays fully uncompressed (fp32 broadcast), so
        # the saving column shows the DOWNLINK's contribution too
        downlink = args.downlink if spec is not None else None
        exp = base.replace(compressor=spec,
                           downlink_compressor=downlink).build()
        _, hist = exp.run(args.steps, grad_tol=args.grad_tol)
        if base_total is None:
            base_total = hist["total_bits"]
        saving = base_total / max(hist["total_bits"], 1)
        name = spec or "none"
        if args.adaptive_k and spec and spec.startswith("adaptive"):
            name += f"(k→{exp.algo.uplink.compressor.k})"
        print(f"{name:>22s} {hist['rounds']:>6d} {hist['uplink_bits']:>12d} "
              f"{hist['downlink_bits']:>10d} {hist['total_bits']:>12d} "
              f"{saving:>6.1f}x {hist['grad_norm'][-1]:>9.4f}")


if __name__ == "__main__":
    main()
