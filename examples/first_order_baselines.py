"""The solver axis: second-order vs first-order through one facade.

Builds the same Byzantine scenario (gaussian attack, trimmed-mean
center) three times — ``cubic_newton`` (the paper's Algorithm 1),
``byzantine_pgd`` (Yin et al. 2019, with the Escape probe rounds), and
``compressed_sgd`` (Chen/Li/Chi 2023) — and prints rounds and EXACT wire
bits to the same gradient tolerance.  All three transmit through the
same :class:`repro.comm.VectorChannel` stack, so the bits are ledger
ints, comparable by construction; the first-order solvers also take the
``compressor`` axis (here top-k with EF21) for the compressed-baseline
comparison.

Also demonstrates the degenerate-parity contract: ``compressed_sgd``
with no compressor, plain ``mean``, α = 0 IS plain robust SGD, bit for
bit.

    PYTHONPATH=src python examples/first_order_baselines.py
"""
import jax
import jax.numpy as jnp

from repro.api import ExperimentSpec


def main():
    base = dict(
        problem="synthetic-logistic:1000:20",
        m_workers=10,
        eta=1.0,
        aggregator="trimmed_mean:0.25",
        attack="gaussian:10.0",
        alpha=0.2,
        seed=0,
    )
    grad_tol = 0.05

    print(f"{'solver':<22} {'rounds':>6} {'uplink bits':>12} "
          f"{'downlink bits':>14} {'final ‖∇f‖':>11}")
    for solver in ("cubic_newton", "byzantine_pgd", "compressed_sgd"):
        spec = ExperimentSpec(solver=solver, M=10.0, **base)
        exp = spec.build()
        _, h = exp.run(200, grad_tol=grad_tol)
        # ledger exactness: totals are per-round static ints × rounds
        bps = exp.bits_per_step()
        assert h["uplink_bits"] == bps["uplink"] * h["rounds"]
        assert h["downlink_bits"] == bps["downlink"] * h["rounds"]
        print(f"{solver:<22} {h['rounds']:>6} {h['uplink_bits']:>12} "
              f"{h['downlink_bits']:>14} {h['grad_norm'][-1]:>11.4f}")

    # -- first-order + compression: same channel axes as Newton ---------
    spec = ExperimentSpec(solver="compressed_sgd", compressor="topk:0.25",
                          **base)
    exp = spec.build()
    _, h = exp.run(200, grad_tol=grad_tol)
    assert h["uplink_bits"] == exp.bits_per_step()["uplink"] * h["rounds"]
    print(f"{'compressed_sgd+topk':<22} {h['rounds']:>6} "
          f"{h['uplink_bits']:>12} {h['downlink_bits']:>14} "
          f"{h['grad_norm'][-1]:>11.4f}")

    # -- degenerate parity: compressed_sgd(mean, α=0, no wire) is SGD ---
    clean = ExperimentSpec(
        solver="compressed_sgd", problem=base["problem"],
        m_workers=base["m_workers"], eta=1.0, seed=0,
    ).build()
    w_sgd, _ = clean.run(5)
    prob = clean.problem
    grads = jax.vmap(jax.grad(prob.loss_fn), in_axes=(None, 0, 0))

    # reference round: data as jit ARGUMENTS, like the solver's round —
    # closure-constant data compiles to different float rounding
    @jax.jit
    def sgd_round(w, X, y):
        return w - 1.0 * jnp.mean(grads(w, X, y), axis=0)

    w_ref = prob.w0
    for _ in range(5):
        w_ref = sgd_round(w_ref, prob.X_workers, prob.y_workers)
    assert bool(jnp.all(w_sgd == w_ref)), \
        "degenerate compressed_sgd must be bit-exact with plain SGD"
    print("degenerate parity: compressed_sgd(mean, α=0, identity wire) "
          "== plain SGD, bit-exact")


if __name__ == "__main__":
    main()
