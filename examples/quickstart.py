"""Quickstart: Byzantine-robust distributed cubic-regularized Newton in a
dozen lines — one declarative :class:`repro.api.ExperimentSpec` describing
Algorithm 1 on a synthetic logistic-regression problem split over 20
workers, 20% of which mount a Gaussian attack.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import ExperimentSpec


def main():
    m, alpha = 20, 0.2
    spec = ExperimentSpec(
        problem="synthetic-logistic:8000:60",
        m_workers=m,
        M=10.0,
        eta=1.0,
        # β > α: trim a bit more than the Byzantine fraction (paper: α + 2/m)
        aggregator=f"norm_trim:{alpha + 2.0 / m}",
        attack="gaussian:50.0",
        alpha=alpha,
    )
    exp = spec.build()
    w, hist = exp.run(n_steps=12)

    acc = exp.problem.accuracy(w)
    print(f"rounds={hist['rounds']}  final_loss={hist['loss'][-1]:.4f}  "
          f"grad_norm={hist['grad_norm'][-1]:.4f}  train_acc={acc:.3f}")
    print("loss path:", " ".join(f"{l:.3f}" for l in hist["loss"]))
    print("spec:", spec.to_json())
    assert acc > 0.85, "robust Newton should shrug off 20% Byzantine workers"


if __name__ == "__main__":
    main()
