"""Quickstart: Byzantine-robust distributed cubic-regularized Newton in ~40
lines — Algorithm 1 on a synthetic logistic-regression problem split over
20 workers, 20% of which mount a Gaussian attack.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import AttackConfig, DistributedCubicNewton, NewtonConfig
from repro.data import make_classification, shard_to_workers


def logistic_loss(w, X, y):
    z = X @ w
    yy = 2.0 * y - 1.0
    return jnp.mean(jnp.log1p(jnp.exp(-yy * z))) + 1e-3 * w @ w


def main():
    m, alpha = 20, 0.2
    X, y, _ = make_classification(jax.random.PRNGKey(0), 8000, 60, margin=3.0)
    Xw, yw = shard_to_workers(X, y, m)

    algo = DistributedCubicNewton(
        logistic_loss,
        # β > α: trim a bit more than the Byzantine fraction (paper: α + 2/m)
        NewtonConfig(M=10.0, eta=1.0, beta=alpha + 2.0 / m),
        AttackConfig(name="gaussian", alpha=alpha, sigma=50.0),
    )
    w, hist = algo.run(jnp.zeros(60), Xw, yw, n_steps=12)

    acc = float(((X @ w > 0) == (y > 0.5)).mean())
    print(f"rounds={hist['rounds']}  final_loss={hist['loss'][-1]:.4f}  "
          f"grad_norm={hist['grad_norm'][-1]:.4f}  train_acc={acc:.3f}")
    print("loss path:", " ".join(f"{l:.3f}" for l in hist["loss"]))
    assert acc > 0.85, "robust Newton should shrug off 20% Byzantine workers"


if __name__ == "__main__":
    main()
