"""Batched serving example: prefill + greedy decode through the KV-cache
path (the decode_32k / long_500k dry-run shapes exercise this same code).
Param distribution rides the downlink TreeChannel — ``--downlink int8``
quantizes the broadcast and prints its exact ledger bits.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-27b --gen 48 \
        --downlink int8
"""
import argparse

from repro.launch.serve import run_serving


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--downlink", default="int8",
                    help="param-broadcast compressor spec (e.g. 'int8', "
                         "'topk:0.1'); pass '' for the full-precision wire")
    args = ap.parse_args()
    run_serving(args.arch, "smoke", args.batch, args.prompt_len, args.gen,
                downlink=args.downlink or None)


if __name__ == "__main__":
    main()
