"""End-to-end LM training driver: Byzantine-robust cubic Newton training a
language model from the assigned-architecture zoo on synthetic token
streams, with 25% of the data-parallel workers mounting a Gaussian attack.

Default is a CPU-friendly reduced model; pass --preset 100m --steps 300 for
the ~100M-parameter few-hundred-step run on real hardware.

    PYTHONPATH=src python examples/train_lm.py --arch deepseek-moe-16b --steps 40
"""
import argparse

from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-moe-16b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "100m", "full"])
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--m-workers", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--attack", default="gaussian")
    ap.add_argument("--alpha", type=float, default=0.25)
    args = ap.parse_args()

    _, hist = run_training(
        arch=args.arch,
        preset=args.preset,
        steps=args.steps,
        m_workers=args.m_workers,
        seq_len=args.seq_len,
        attack=args.attack,
        alpha=args.alpha,
        # β > α (build-time validated): trim strictly more than corrupted
        beta=max(args.alpha + 1.0 / args.m_workers, 0.25),
        solver_iters=2,
        ckpt_dir="results/train_lm_ckpt",
    )
    drop = (hist[0] - hist[-1]) / hist[0] * 100
    print(f"loss {hist[0]:.3f} → {hist[-1]:.3f}  ({drop:.1f}% drop under "
          f"{args.attack}@{args.alpha:.0%} attack)")


if __name__ == "__main__":
    main()
