"""Generate EXPERIMENTS.md from recorded results (dry-run JSONLs, perf
iterations, paper benchmarks).  Rerunnable: every number in the document
comes from a results file."""
import glob
import json
import os

R = "results"


def load_jsonl(pattern):
    recs = []
    for f in sorted(glob.glob(pattern)):
        for ln in open(f):
            try:
                recs.append(json.loads(ln))
            except json.JSONDecodeError:
                pass
    dedup = {}
    for r in recs:
        dedup[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return dedup


def fmt_ms(s):
    return f"{s*1e3:,.1f}"


def roof_row(r):
    ro = r["roofline"]
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_ms(ro['compute_s'])} | {fmt_ms(ro['memory_s'])} | "
            f"{fmt_ms(ro['collective_s'])} | {ro['dominant']} | "
            f"{r['useful_flops_ratio']:.3f} |")


def skip_row(r):
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
            f"SKIP | — |")


def mem_gib(r):
    m = r.get("memory", {})
    tot = (m.get("argument_bytes") or 0) + (m.get("temp_bytes") or 0)
    return tot / 2**30


def main():
    base = load_jsonl(f"{R}/dryrun/*.jsonl")
    opt = load_jsonl(f"{R}/dryrun_opt/*_single.jsonl")
    opt.update(load_jsonl(f"{R}/dryrun_opt/*_multi.jsonl"))
    extras = load_jsonl(f"{R}/dryrun_opt/extras.jsonl")
    bench = {}
    if os.path.exists(f"{R}/benchmarks_full.json"):
        bench = json.load(open(f"{R}/benchmarks_full.json"))
    if os.path.exists(f"{R}/benchmarks.json"):
        quick = json.load(open(f"{R}/benchmarks.json"))
        for k in ("saddle_escape",):
            if k in quick and k not in bench:
                bench[k] = quick[k]

    out = []
    w = out.append
    w(HEADER)

    # ---------------- paper validation ----------------
    w(PAPER_INTRO)
    if bench:
        w("### Fig. 3 twin — non-Byzantine convergence (α=β=0, m=20, η=1)\n")
        w("| problem / dataset / M | start | final (T=15) | final acc |")
        w("|---|---|---|---|")
        for k, v in sorted(bench.get("fig3", {}).items()):
            loss = v["loss"]
            acc = v.get("accuracy")
            w(f"| {k} | {loss[0]:.4f} | {loss[-1]:.4f} | "
              f"{(f'{acc[-1]:.4f}' if acc else '—')} |")
        w("")
        w("### Figs. 1–2 twins — four §6 attacks × α ∈ {10,15,20}%, β=α+2/m\n")
        w("| experiment | metric start → final (T=15) |")
        w("|---|---|")
        for k, v in sorted(bench.get("fig12", {}).items()):
            if "accuracy" in v:
                w(f"| {k} | acc {v['accuracy'][0]:.3f} → {v['accuracy'][-1]:.3f} |")
            else:
                w(f"| {k} | loss {v['loss'][0]:.3f} → {v['loss'][-1]:.3f} |")
        w("")
        w("### Table 1 twin — communication rounds to ‖∇f‖ ≤ 0.02 "
          "(w8a robust regression)\n")
        w("| attack | α | cubic Newton (ours) | ByzantinePGD | speedup |")
        w("|---|---|---|---|---|")
        for row in bench.get("table1", []):
            w(f"| {row['attack']} | {row['alpha']:g} | {row['newton_rounds']} "
              f"| {row['pgd_rounds']} | {row['speedup']:.1f}× |")
        w("")
        se = bench.get("saddle_escape")
        if se:
            w("### Saddle escape (beyond-paper; Theorems 1–2 exercised "
              "directly)\n")
            w("Distributed rank-2 matrix factorization, strict saddle at "
              f"U=0 (λ_min(∇²f) = {se['second_order']['saddle_lambda_min']:.1f}, "
              f"f_saddle = {se['newton']['saddle_value']:.1f}); all methods "
              "start 1e-3 from the saddle.  Harness: "
              "``benchmarks/saddle_escape.py``.\n")
            w("| method | final loss | escaped? |")
            w("|---|---|---|")
            sv = se["newton"]["saddle_value"]
            for name, key in [("cubic Newton (ours)", "newton"),
                              ("first-order robust GD", "gd"),
                              ("cubic Newton + saddle-point attack (α=20%)",
                               "newton_saddle_attack")]:
                fl = se[key]["loss"][-1]
                w(f"| {name} | {fl:.4f} | "
                  f"{'✓' if fl < 0.05*sv else '✗ (stuck near saddle)'} |")
            w("")
    w(PAPER_DISCUSSION)

    # ---------------- dry run ----------------
    n_ok = sum(1 for r in base.values() if r["status"] == "ok")
    n_skip = sum(1 for r in base.values() if r["status"] == "skipped")
    w(DRYRUN_INTRO.format(n_ok=n_ok, n_skip=n_skip))
    w("| arch | shape | mesh | bytes/device (args+temp, GiB) | fits 16 GB v5e? |")
    w("|---|---|---|---|---|")
    for key in sorted(k for k, r in opt.items() if r["status"] == "ok"):
        r = opt[key]
        g = mem_gib(r)
        w(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {g:,.1f} | "
          f"{'✓' if g <= 16 else '✗ (needs more chips / two-round / lower precision)'} |")
    w("")
    w(DRYRUN_NOTES)

    # ---------------- roofline (baseline, single-pod) ----------------
    w(ROOFLINE_INTRO)
    w("| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
      "| dominant | MODEL_FLOPS/HLO_FLOPS |")
    w("|---|---|---|---|---|---|---|---|")
    for key in sorted(base):
        r = base[key]
        if r["mesh"] != "16x16":
            continue
        w(roof_row(r) if r["status"] == "ok" else skip_row(r))
    w("")
    w(ROOFLINE_NOTES)

    # ---------------- perf ----------------
    w(PERF_LOG)

    # optimized table
    w("### Post-hillclimb roofline (single-pod, same analyzer)\n")
    w("| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
      "dominant | useful | memory ↓ vs baseline | collective ↓ |")
    w("|---|---|---|---|---|---|---|---|---|")
    for key in sorted(opt):
        r = opt[key]
        if r.get("mesh") != "16x16" or r["status"] != "ok":
            continue
        b = base.get(key)
        ro = r["roofline"]
        if b and b["status"] == "ok":
            bm = b["roofline"]["memory_s"]
            bc = b["roofline"]["collective_s"]
            dm = f"{(1 - ro['memory_s']/bm)*100:+.0f}%" if bm else "—"
            dc = f"{(1 - ro['collective_s']/bc)*100:+.0f}%" if bc else "—"
        else:
            dm = dc = "—"
        w(f"| {r['arch']} | {r['shape']} | {fmt_ms(ro['compute_s'])} | "
          f"{fmt_ms(ro['memory_s'])} | {fmt_ms(ro['collective_s'])} | "
          f"{ro['dominant']} | {r['useful_flops_ratio']:.3f} | {dm} | {dc} |")
    w("")

    if extras:
        w("### Beyond-paper variants (dry-run, single-pod)\n")
        w("| variant | shape | compute (ms) | memory (ms) | collective (ms) | note |")
        w("|---|---|---|---|---|---|")
        for key in sorted(extras):
            r = extras[key]
            if r["status"] != "ok":
                continue
            ro = r["roofline"]
            note = ("Remark-5 two-round (ε_g=0, exact gradient)"
                    if r["shape"] == "train_4k" else
                    "sliding-window dense variant unlocking long_500k")
            w(f"| {r['arch']} | {r['shape']} | {fmt_ms(ro['compute_s'])} | "
              f"{fmt_ms(ro['memory_s'])} | {fmt_ms(ro['collective_s'])} | {note} |")
        w("")

    w(FOOTER)
    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(out))
    print(f"wrote EXPERIMENTS.md ({len(out)} blocks)")


HEADER = """# EXPERIMENTS

Every number in this file is regenerated from ``results/`` by
``python scripts_experiments_md.py``; the provenance of each table is the
harness named next to it.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  This container is CPU-only — all per-chip quantities are
**derived from compiled artifacts** (lower().compile() on 512 forced host
devices), not wall-clock measurements, per the brief.

---

## §Paper-validation — the faithful reproduction
"""

PAPER_INTRO = """
Protocol is §6 of the paper: m=20 workers, η=1, M=10 (and {10,15,20} for
Fig. 3), β = α + 2/m, four attacks, LIBSVM a9a/w8a **synthetic twins**
(offline container — same d/n/split; see DESIGN.md §6/§8).  Harnesses:
``benchmarks/fig3_convergence.py``, ``benchmarks/fig12_byzantine.py``,
``benchmarks/table1_communication.py``; run via
``python -m benchmarks.run --full``.
"""

PAPER_DISCUSSION = """
**Validation against the paper's claims**

1. *Convergence without Byzantine workers* (Fig. 3): monotone loss decrease
   and high test accuracy on both twins for all M ∈ {10,15,20} ✓.
2. *Robustness* (Figs. 1–2): across all four attacks and α ∈ {10,15,20}%,
   norm-trimmed cubic Newton recovers essentially the attack-free accuracy /
   loss, while the naive-mean ablation (examples/byzantine_attacks.py)
   diverges or stalls under the Gaussian attack ✓.
3. *Communication efficiency* (Table 1 / §6): the paper reports 2–16 Newton
   rounds vs ~200 ByzantinePGD rounds (36× in their non-Byzantine w8a run).
   Our twin reproduces the ordering and magnitude: tens-of-× fewer rounds
   (exact factors in the table above — they vary with the synthetic twin's
   conditioning, as expected; the paper's own numbers vary 12×–100× across
   attacks too).
4. *Second-order escape*: tests/test_cubic.py::test_negative_curvature_escape
   verifies the sub-problem solution moves along negative curvature with
   ‖s‖ = 2|λ_min|/(Mγ) — the mechanism Theorems 1–2 rely on; the saddle
   attack test (tests/test_attacks.py) shows colluding fake-minimum updates
   get trimmed.

---

## §Dry-run — multi-pod lower + compile
"""

DRYRUN_INTRO = """
``src/repro/launch/dryrun.py`` (512 forced host devices, set before any jax
import) lowers + compiles **every (architecture × input-shape) pair on both
meshes** — 16×16 = 256 chips ("data","model") and 2×16×16 = 512 chips
("pod","data","model").

**Result: {n_ok} ok / {n_skip} policy-skips / 0 failures** (the skips are the
long_500k full-attention exclusions of DESIGN.md §4, plus whisper; the
llama3-405b-swa variant covers the dense-arch long-context case separately).
``compiled.memory_analysis()`` per-device totals (post-hillclimb code):
"""

DRYRUN_NOTES = """
Notes:

* Memory analysis is XLA's CPU-host estimate of the partitioned program —
  useful for *relative* sizing and catching catastrophes (it caught a 250
  GB/device SSD materialization and an unconstrained per-worker-state
  replication during §Perf; both fixed).
* The biggest configs (llama3-405b, internvl2-76b train) do NOT fit 16
  GB/chip at these pod sizes with the one-shot cubic step — per-worker update
  state is the paper's fundamental memory cost (m × d floats).  The
  Remark-5 two-round mode and larger meshes are the production answers;
  recorded under beyond-paper variants.
* The multi-pod (512-chip) pass proves the "pod" axis shards: worker count
  doubles to 32, per-device terms drop ~2× on train shapes (table in
  results/dryrun*/…_multi.jsonl).

---

## §Roofline — three terms per (arch × shape), single-pod baseline

Terms from the **loop-aware HLO analyzer** (``repro/launch/hlo.py``):
XLA's ``cost_analysis()`` visits while bodies once, undercounting a
126-layer scanned stack ~126×, so we parse the compiled module, multiply
through ``known_trip_count`` backend configs, count dot FLOPs from
contraction dims, fusion-granularity bytes, and collective operand bytes.
Validated against analytic counts on sharded matmul chains (exact) and scan
programs (tests/test_substrates.py).

    compute_s    = HLO_FLOPs_per_device / 197e12
    memory_s     = HLO_bytes_per_device / 819e9
    collective_s = collective_operand_bytes_per_device / 50e9

**Baseline = paper-faithful implementation, before hillclimbing** (the table
the three hillclimbs start from; regenerate with ``benchmarks/roofline.py``
over ``results/dryrun``):
"""

ROOFLINE_INTRO = ""

ROOFLINE_NOTES = """
Reading the baseline table:

* **Every pair is memory-dominated** at baseline.  Two causes, separated by
  the hillclimbs: (i) real algorithmic traffic (attention chunk logits,
  fp32 logits/CE path, SSD dual-form buffers), and (ii) CPU-HLO fusion
  granularity — the analyzer charges HBM for buffers a TPU pass would keep
  fused/in-VMEM, so absolute memory terms are pessimistic upper bounds;
  *deltas* between iterations are meaningful.
* ``MODEL_FLOPS/HLO_FLOPS`` uses MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D
  (MoE), × (1 + 2·(solver_iters+1)) backprop-equivalents for the cubic-Newton
  train step (1 grad + solver_iters+1 HVPs ≈ 2 backprops each).  Decode
  pairs hit 0.4–1.0 (mamba2 0.993 — near-perfect); train pairs sit at
  0.3–0.45 (remat recompute is the main gap — a deliberate memory/compute
  trade); prefill started at 0.03–0.16 because of the dense causal-grid
  attention — fixed in §Perf iteration 4 (0.056 → 0.670 on codeqwen).
* What would move the dominant term per family: dense/VLM — attention tile
  traffic (flash kernel, iter 4) and fp32 CE logits; MoE — same + capacity
  dispatch buffers; SSM/hybrid — conv/SSD layout (iters 5–6); all train
  shapes — fewer backprop-equivalents via Remark-5 two-round.

---

## §Perf — hypothesis → change → measure log

Three pairs hillclimbed (per brief): **mamba2-780m×train_4k** (paper's
technique), **codeqwen1.5-7b×prefill_32k** (worst useful-FLOPs ratio),
**gemma3-27b×train_4k** (most collective-bound).  All numbers are
per-device from the dry-run analyzer; baselines from ``results/dryrun``,
iterations logged in ``results/perf/*.jsonl``.
"""

PERF_LOG = """
### Iteration log

**Iter 1 — fuse the monitoring loss into the gradient pass** (all train pairs)
*Hypothesis*: ``loss_fn`` ran as a separate full forward besides
``vmap(grad)``; ~1 of 11 forward-equivalents ⇒ ~9% flops/bytes.
*Change*: ``vmap(value_and_grad)``, loss = mean of per-worker values.
*Measured (mamba2 train)*: flops 2.653e14→2.483e14 (−6%), bytes
1.791e14→1.566e14 (−13%), collective 2.566e12→1.866e12 (−27%).
**Confirmed** — collective win larger than predicted (the dropped forward
carried embedding all-reduces).

**Iter 2 — vocab path resharding** (gemma3, all big-vocab archs)
*Hypothesis*: embed (V,d) P(model,fsdp) / lm_head (d,V) P(fsdp,model) make
GSPMD all-reduce full (B,S,V/16) partial logits (d is contraction-sharded):
predicted ~10× collective cut.
*Change*: embed/lm_head → P(None,"model") (vocab on model, d replicated).
*Measured (gemma3 train)*: bytes 3.600e14→3.047e14 (−15%), collective
9.95e12→1.01e13 (±0).
**Partially refuted** — memory win real, but the big all-reduces persisted ⇒
they weren't the logits path.  Kept (strict memory improvement), hypothesis
revised → iter 3.

**Iter 3 — ZeRO-3 per-layer gather constraint**
*Diagnosis* (collective attribution by op): the 8–17 GB all-reduces are
``…d,df->…f`` MLP matmuls inside the HVP scan — GSPMD resolves
FSDP(d)-sharded weights × activations by ALL-REDUCING (B,S,f) partial
products instead of all-gathering the (d,f/16) weight shard.
*Change*: ``runtime.layer_param_constraint`` hook — every scanned
superblock's param slice is constrained to TP-only sharding inside the scan
body (= per-layer ZeRO-3 all-gather), installed by the launch layer.
*Measured (gemma3 train)*: collective 9.95e12→7.69e12 (−23%), bytes
3.05e14→2.14e14 (−30%).
**Confirmed** (remaining all-reduces attributed to the fundamental
Megatron-TP 2×(B,S,d)-per-layer pattern + ‖s‖ reductions — the floor).

**Iter 4 — triangle-scan causal attention** (codeqwen prefill, all
attention archs)
*Hypothesis*: the dense (n_q × n_kv) chunk grid issues ~2× causal-masked
FLOPs and chunk-logits traffic.
*Change*: statically enumerate only visible (q-chunk, kv-chunk) pairs —
n(n+1)/2 tiles, masks only on the diagonal; online-softmax state carried
for all q-chunks.
*Measured (codeqwen prefill_32k)*: flops 1.200e15→1.001e14 (**−92%**),
bytes 3.858e14→3.113e13 (−92%), useful ratio 0.056→0.670.
**Confirmed, far beyond prediction** — the pair-indexed formulation also
propagates head/batch sharding through the attention tiles that the old
dense grid caused GSPMD to partially replicate.

**Iter 5 — analyzer fix: in-place slice-update accounting** (measurement)
*Hypothesis*: remaining prefill "memory" was dominated by
dynamic-update-slice ops charged at full-buffer size; XLA updates in place.
*Change*: cost model charges 2×slice for DUS/gather/dynamic-slice and
detects DUS-root fusions.
*Measured*: codeqwen prefill bytes 3.11e13→2.22e13; mamba2 train bytes
1.57e14→6.01e13.  **Confirmed** (tooling accuracy, applied everywhere).

**Iter 6 — shard-aligned SSD projections** (mamba2)
*Diagnosis*: 31,584 collective-permutes — slicing the fused in_proj output
(z|x|B|C|dt) at channel offsets (3072, 3328, …) that don't align with the
16-way model sharding ⇒ a halo exchange per split per layer per pass.
(A first hypothesis — sequence-axis sharding in the causal conv — was
**refuted**: a channels-last activation constraint changed nothing.)
*Change*: separate z/x/B/C/dt projections + per-component depthwise convs
(identical math); small B/C/dt weights replicated so SSD einsums need no
contraction collectives.
*Measured (mamba2 train)*: collective 1.088e12→7.05e11 (−35%), bytes
6.01e13→4.50e13 (−25%), collective-permutes 31,584 → 0.
**Confirmed.**

**Iter 7 — bf16 SSD dual-form buffers** (mamba2)
*Hypothesis*: the (b,H,Q,Q) decay×score buffers in fp32 dominate remaining
SSD bytes; bf16 with fp32 accumulation halves them.
*Measured*: bytes 4.50e13→5.10e13 (**+13%**) — the inserted converts
materialize at the CPU-HLO fusion granularity the analyzer sees.
**Refuted by measurement → reverted** (kept as a note: on real TPU the
converts fuse and this is likely a win; re-evaluate with a hardware
profile).

**Iter 8 — sort-based MoE position-in-expert** (deepseek/phi MoE pairs)
*Hypothesis*: the classic one-hot-cumsum rank computation in the capacity
dispatch is O(T·k·E) compute and memory (~1.6 GB/layer/pass at 1M tokens,
64 experts); a stable argsort + segment-start scan is O(T·k·log T·k).
*Change*: ``models/moe.py`` ranking via argsort/associative-scan-max.
*Measured (deepseek prefill_32k)*: bytes 2.04e13→1.65e13 (−19%), collective
unchanged.  **Confirmed.**

**Iter 9 — worker grouping for 405B memory** (llama3-405b train)
*Hypothesis*: the algorithm's fundamental memory cost is m·d floats of
per-worker update state; coalescing 4 data rows per worker (m: 16 → 4,
per-worker trees regain FSDP sharding) should cut per-device state 4×.
*Change*: ``--worker-groups`` knob (sharding.worker_tree_specs grouped
mode + row-sharded per-worker batches).
*Measured (llama3-405b train_4k, m=4)*: temp 2.9 TB → 8.3 TB/device.
**Refuted as implemented** — when one worker's tokens span all data rows,
XLA materializes each worker's full (unsharded) gradient transiently
before re-sharding; needs explicit reduce-scatter scheduling to pay off.
Knob retained with the caveat documented; future work.

**Stopping**: on each pair the last candidates were < 5% or refuted:
mamba2 (iter 7 refuted; remaining collectives = fundamental ‖s‖ psums +
TP all-reduce), gemma3 (remaining = Megatron-TP floor), codeqwen prefill
(remaining memory = attention tile state at analyzer granularity; the
Pallas flash kernel keeps those in VMEM on hardware — kernels/ is the
mechanism, validated in interpret mode).

### Headline before → after (per-device, single-pod)

| pair | metric | paper-faithful baseline | optimized | Δ |
|---|---|---|---|---|
| mamba2-780m×train_4k | collective bytes | 2.57e12 | 7.05e11 | **−73%** |
| mamba2-780m×train_4k | bytes accessed | 1.79e14 | 4.50e13 | **−75%** |
| mamba2-780m×train_4k | useful-FLOPs ratio | 0.397 | 0.445 | +12% |
| codeqwen1.5-7b×prefill_32k | HLO FLOPs | 1.20e15 | 1.00e14 | **−92%** |
| codeqwen1.5-7b×prefill_32k | bytes accessed | 3.86e14 | 2.22e13 | **−94%** |
| codeqwen1.5-7b×prefill_32k | useful-FLOPs ratio | 0.056 | 0.670 | **12×** |
| gemma3-27b×train_4k | collective bytes | 1.10e13 | 7.69e12 | **−30%** |
| gemma3-27b×train_4k | bytes accessed | 3.84e14 | 2.14e14 | **−44%** |
"""

FOOTER = """
---

## Reproduction commands

```bash
PYTHONPATH=src pytest tests/                         # full suite
PYTHONPATH=src python -m benchmarks.run [--full]     # paper tables/figures
PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all [--multi-pod]
PYTHONPATH=src python examples/quickstart.py
PYTHONPATH=src python examples/byzantine_attacks.py
PYTHONPATH=src python examples/train_lm.py --arch deepseek-moe-16b
PYTHONPATH=src python examples/serve_lm.py --arch gemma3-27b
python scripts_experiments_md.py                     # regenerate this file
```
"""


if __name__ == "__main__":
    main()
