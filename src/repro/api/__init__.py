"""`repro.api` — the declarative experiment facade.

Three spec-string registries (mirroring
:mod:`repro.compression.registry`) plus one serializable record tie the
whole system together:

* :mod:`repro.api.aggregators` — ``"norm_trim:0.25"``, ``"krum:2"``,
  ``"trimmed_mean:0.1"``, ``"coordinate_median"``, ``"mean"`` → a
  resolved :class:`Aggregator` both runtimes call at the center;
* :mod:`repro.api.attacks` — ``"gaussian:10.0"``, ``"saddle:5.0"``,
  ``"negative:0.9"``, ``"flipped_label"``, … → a :class:`ResolvedAttack`
  owning the Byzantine mask, channel hooks, and label corruption;
* :mod:`repro.api.problems` — ``"w8a-robust"``,
  ``"synthetic-logistic:<n>:<d>"``, ``"matrix-factor:<d>:<r>"``, … →
  worker-sharded data + the canonical loss functions;
* :mod:`repro.solvers` — the ``solver:`` axis (``"cubic_newton"``,
  ``"byzantine_pgd[:R:Q]"``, ``"compressed_sgd[:radius:gtol]"``): the
  first-order Byzantine baselines, channel-routed with exact ledger
  billing (re-exported here as ``SOLVER_SPECS`` / ``parse_solver_spec``);
* :mod:`repro.api.experiment` — :class:`ExperimentSpec`, the frozen
  JSON-round-trippable record every entry point builds through, with
  build-time validation (:class:`SpecError`) and a ``build()`` →
  :class:`Experiment` runner over both runtimes.

The registries resolve specs ONCE at build time — nothing here runs
inside a trace.
"""
from .aggregators import (
    AGGREGATOR_SPECS,
    Aggregator,
    default_aggregator_spec,
    make_aggregator,
)
from .attacks import (
    ATTACK_SPECS,
    ResolvedAttack,
    make_attack,
    resolve_attack,
    to_attack_config,
)
from .errors import SpecError
from .experiment import Experiment, ExperimentSpec
from ..solvers import SOLVER_SPECS, parse_solver_spec
from .problems import (
    PROBLEM_SPECS,
    Problem,
    accuracy,
    factor_loss,
    fixed_workers,
    logistic_loss,
    make_problem,
    problem_dim,
    robust_regression_loss,
)

__all__ = [
    "AGGREGATOR_SPECS",
    "ATTACK_SPECS",
    "Aggregator",
    "Experiment",
    "ExperimentSpec",
    "PROBLEM_SPECS",
    "Problem",
    "ResolvedAttack",
    "SOLVER_SPECS",
    "SpecError",
    "accuracy",
    "default_aggregator_spec",
    "factor_loss",
    "fixed_workers",
    "logistic_loss",
    "make_aggregator",
    "make_attack",
    "make_problem",
    "parse_solver_spec",
    "problem_dim",
    "resolve_attack",
    "robust_regression_loss",
    "to_attack_config",
]
