"""Aggregator registry: spec strings → resolved :class:`Aggregator`.

Mirrors :mod:`repro.compression.registry` for the center's robust
aggregation rule (Algorithm 1, step 6, and its baselines):

    "mean"                  plain average (non-robust reference)
    "norm_trim:0.25"        paper's rule — drop the β·m largest-norm
                            updates, average the rest (β ∈ (0, 1))
    "krum:2"                Krum [BMGS17] assuming n_byz Byzantine workers
    "trimmed_mean:0.1"      coordinate-wise trimmed mean (ByzantinePGD's
                            default), trim_frac per side
    "coordinate_median"     coordinate-wise median

and the fused-kernel variants (``repro.kernels.robust_agg``, identical
math on the paper runtime's flat stack, registry path on the mesh):

    "krum_kernel:2"             blocked pairwise distances + on-chip scores
    "trimmed_mean_kernel:0.1"   tiled per-coordinate bitonic sort
    "coordinate_median_kernel"  same sort, median epilogue

``make_aggregator(spec)`` resolves the string ONCE (never inside a
trace); the returned object serves BOTH runtimes:

* ``agg(updates)``           — flat ``(m, d)`` stacked vectors (the
  paper-faithful runtime) → ``(aggregate, keep_mask)``;
* ``agg.tree(updates_tree)`` — worker-stacked pytree (the mesh runtime,
  every leaf ``(m, …)``) → ``(aggregate_tree, keep_mask)``.

``keep_mask`` is an ``(m,)`` float mask of the workers whose update
contributed — 0/1 by rank for norm_trim, one-hot for krum, and a SOFT
fraction-of-coordinates-contributed for the coordinate-wise rules
(trimmed_mean / coordinate_median, flat path; 0 means trimmed away in
every coordinate) — the forensic signal the schema-v4 round records
carry per worker.  The mask never feeds back into the aggregate (the
async staleness weighting binarizes it), so soft values change no
trajectory.  ``check_resilience(alpha, m)``
returns None when the rule provably tolerates a Byzantine fraction α at
cluster size m, else the reason it does not —
:meth:`ExperimentSpec.validate` turns that into a build-time
:class:`SpecError`.

Rules whose math is a weighted scatter-sum of the worker payloads —
mean and norm_trim — additionally expose the **sparse-domain path**
(``supports_sparse`` / ``agg.sparse(vals, idx, d)``): they aggregate
top-k wire payloads directly via :func:`repro.kernels.aggregate_sparse`
without ever materializing the m dense (d,) vectors.  The paper runtime
auto-routes through it when every uplink is payload-shaped (top-k
family, no error feedback, no update attack) — see
``repro.core.newton``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import aggregation as _agg
from ..kernels import (
    agg_kernel_plan,
    aggregate_sparse,
    coordinate_median_fused,
    krum_select_fused,
    trimmed_mean_fused,
)
from .errors import SpecError

AGGREGATOR_SPECS = ("mean", "norm_trim:<beta>", "krum:<n_byz>",
                    "trimmed_mean:<frac>", "coordinate_median",
                    "krum_kernel:<n_byz>", "trimmed_mean_kernel:<frac>",
                    "coordinate_median_kernel")


class Aggregator:
    """A resolved aggregation rule, usable from both runtimes."""

    spec: str
    name: str

    def __call__(self, updates):
        """(m, d) stacked updates → (aggregate (d,), keep mask (m,))."""
        raise NotImplementedError

    def tree(self, updates_tree):
        """Worker-stacked pytree → (aggregate tree, keep mask (m,))."""
        raise NotImplementedError

    def check_resilience(self, alpha: float, m: int):
        """None when the rule tolerates Byzantine fraction ``alpha`` at
        cluster size ``m``; otherwise the reason + fix (a build error)."""
        return None

    #: True when :meth:`sparse` aggregates wire payloads directly
    supports_sparse = False

    def sparse(self, vals, idx, d: int):
        """(m, k) payload values + (m, k) int32 indices (index-ascending,
        distinct within each worker — the top-k wire format) → the same
        (aggregate (d,), keep mask (m,)) as ``__call__`` on the densified
        stack, computed without materializing any (m, d) array."""
        raise NotImplementedError(
            f"{self.name!r} has no sparse-domain path — densify first"
        )

    @staticmethod
    def _m(updates_tree) -> int:
        return jax.tree_util.tree_leaves(updates_tree)[0].shape[0]

    @staticmethod
    def _ones(m, dtype=jnp.float32):
        return jnp.ones((m,), dtype)

    def __repr__(self):
        return f"{type(self).__name__}({self.spec!r})"


class Mean(Aggregator):
    """Plain average — the non-robust contrast the paper draws."""

    def __init__(self):
        self.spec = self.name = "mean"

    def __call__(self, updates):
        return updates.mean(0), self._ones(updates.shape[0], updates.dtype)

    supports_sparse = True

    def sparse(self, vals, idx, d):
        m = vals.shape[0]
        agg = aggregate_sparse(vals, idx, d) / m
        return agg, self._ones(m, agg.dtype)

    def tree(self, updates_tree):
        m = self._m(updates_tree)
        return _agg.mean_tree(updates_tree), self._ones(m)

    def check_resilience(self, alpha, m):
        return (f"'mean' has no Byzantine tolerance — it is the "
                f"deliberate non-robust baseline")


class NormTrim(Aggregator):
    """Paper's norm-based thresholding; resilient for α < β."""

    def __init__(self, beta: float):
        if not 0.0 < beta < 1.0:
            raise SpecError(
                f"norm_trim needs a trim fraction β in (0, 1), got {beta!r}; "
                f"use e.g. 'norm_trim:0.25' (β = 0 is just 'mean')"
            )
        self.beta = float(beta)
        self.spec = f"norm_trim:{self.beta!r}"
        self.name = "norm_trim"

    def __call__(self, updates):
        return _agg.norm_trim(updates, self.beta)

    supports_sparse = True

    def sparse(self, vals, idx, d):
        # with distinct indices per worker (the top-k wire format) the
        # payload norm IS the dense-update norm, summed in the same
        # coordinate order — the keep mask matches _agg.norm_trim
        # bit-for-bit; the kept payloads then scatter-sum directly
        m = vals.shape[0]
        v32 = vals.astype(jnp.float32)
        norms = jnp.linalg.norm(v32, axis=1)
        n_keep = max(1, int(round((1.0 - self.beta) * m)))
        order = jnp.argsort(norms)
        ranks = jnp.argsort(order)
        keep = (ranks < n_keep).astype(jnp.float32)
        agg = aggregate_sparse(v32, idx, d, weights=keep) / n_keep
        return agg, keep.astype(vals.dtype)

    def tree(self, updates_tree):
        return _agg.norm_trim_tree(updates_tree, self.beta)

    def check_resilience(self, alpha, m):
        # β > α precondition: strictly more must be trimmed than corrupted
        if self.beta <= alpha:
            return (f"norm_trim β={self.beta!r} ≤ α={alpha!r}: the "
                    f"resilience precondition needs β > α — raise β (the "
                    f"paper uses β = α + 2/m = {alpha + 2 / m:.4g})")
        return None


class Krum(Aggregator):
    """Krum [BMGS17]: forward the single most-central update.

    ``use_kernel=True`` (spec head ``krum_kernel``) routes the flat-stack
    selection through :func:`repro.kernels.krum_select_fused` whenever
    :func:`repro.kernels.agg_kernel_plan` serves m, falling back to the
    registry past its on-chip (P, P) budget; the mesh ``tree`` path
    always uses the registry."""

    def __init__(self, n_byz: int, use_kernel: bool = False):
        if n_byz < 0:
            raise SpecError(f"krum needs n_byz ≥ 0, got {n_byz}")
        self.n_byz = int(n_byz)
        self.use_kernel = bool(use_kernel)
        self.name = "krum_kernel" if use_kernel else "krum"
        self.spec = f"{self.name}:{self.n_byz}"

    def __call__(self, updates):
        m = updates.shape[0]
        flat = updates.reshape(m, -1).astype(jnp.float32)
        if self.use_kernel and agg_kernel_plan(m, flat.shape[1])[0] == "fused":
            j = krum_select_fused(flat, self.n_byz)
        else:
            j = _agg.krum_select(flat, self.n_byz)
        keep = (jnp.arange(m) == j).astype(updates.dtype)
        return updates[j], keep

    def tree(self, updates_tree):
        m = self._m(updates_tree)
        agg, j = _agg.krum_tree(updates_tree, self.n_byz)
        return agg, (jnp.arange(m) == j).astype(jnp.float32)

    def check_resilience(self, alpha, m):
        f = int(alpha * m)  # byzantine_mask's worker count
        if self.n_byz < f:
            return (f"krum:{self.n_byz} assumes fewer Byzantine workers "
                    f"than α={alpha!r} implies at m={m} — raise n_byz "
                    f"to ≥ {f}")
        if m < 2 * self.n_byz + 3:
            return (f"krum needs m ≥ 2·n_byz + 3 = {2 * self.n_byz + 3} "
                    f"workers to score n_byz={self.n_byz}, got m={m}")
        return None


class TrimmedMean(Aggregator):
    """Coordinate-wise trimmed mean (ByzantinePGD's default).

    ``use_kernel=True`` (spec head ``trimmed_mean_kernel``) runs the
    per-coordinate sort as the tiled bitonic kernel
    (:func:`repro.kernels.trimmed_mean_fused`, bit-identical epilogue)
    whenever ``agg_kernel_plan`` serves m; mesh ``tree`` path stays on
    the registry."""

    def __init__(self, trim_frac: float, use_kernel: bool = False):
        if not 0.0 < trim_frac < 0.5:
            raise SpecError(
                f"trimmed_mean needs a per-side trim fraction in (0, 0.5), "
                f"got {trim_frac!r}; use e.g. 'trimmed_mean:0.1'"
            )
        self.trim_frac = float(trim_frac)
        self.use_kernel = bool(use_kernel)
        self.name = "trimmed_mean_kernel" if use_kernel else "trimmed_mean"
        self.spec = f"{self.name}:{self.trim_frac!r}"

    def __call__(self, updates):
        m = updates.shape[0]
        if (self.use_kernel and updates.ndim == 2
                and agg_kernel_plan(m, updates.shape[1])[0] == "fused"):
            agg = trimmed_mean_fused(updates, self.trim_frac).astype(
                updates.dtype)
        else:
            agg = _agg.trimmed_mean(updates, self.trim_frac)
        # soft keep: the fraction of coordinates each worker actually
        # contributed to (0 = trimmed away everywhere — the forensic
        # rejection signal; kernel and registry paths share this exact
        # rank math, so the mask is layout-independent)
        k = min(int(round(self.trim_frac * m)), (m - 1) // 2)
        keep = (self._ones(m, updates.dtype) if k == 0 else
                _agg.contribution_keep(updates, k, m - k)
                .astype(updates.dtype))
        return agg, keep

    def tree(self, updates_tree):
        m = self._m(updates_tree)
        return _agg.trimmed_mean_tree(updates_tree, self.trim_frac), self._ones(m)

    def check_resilience(self, alpha, m):
        # per-coordinate: the k = round(trim_frac·m) values cut per side
        # must cover every corrupted worker
        k = min(int(round(self.trim_frac * m)), (m - 1) // 2)
        f = int(alpha * m)
        if k < f:
            return (f"trimmed_mean:{self.trim_frac!r} cuts {k}/side at "
                    f"m={m} but α={alpha!r} corrupts {f} workers — raise "
                    f"the trim fraction to ≥ {f / m:.4g}")
        return None


class CoordinateMedian(Aggregator):
    """Coordinate-wise median; resilient up to α < 1/2.

    ``use_kernel=True`` (spec ``coordinate_median_kernel``) routes the
    flat stack through :func:`repro.kernels.coordinate_median_fused`
    (bit-identical to ``jnp.median``) whenever ``agg_kernel_plan``
    serves m; mesh ``tree`` path stays on the registry."""

    def __init__(self, use_kernel: bool = False):
        self.use_kernel = bool(use_kernel)
        self.spec = self.name = (
            "coordinate_median_kernel" if use_kernel else "coordinate_median"
        )

    def __call__(self, updates):
        m = updates.shape[0]
        if (self.use_kernel and updates.ndim == 2
                and agg_kernel_plan(m, updates.shape[1])[0] == "fused"):
            agg = coordinate_median_fused(updates).astype(updates.dtype)
        else:
            agg = _agg.coordinate_median(updates)
        # soft keep: fraction of coordinates where the worker's value was
        # a median contributor (the middle rank, or both for even m)
        keep = _agg.contribution_keep(
            updates, (m - 1) // 2, m // 2 + 1
        ).astype(updates.dtype)
        return agg, keep

    def tree(self, updates_tree):
        m = self._m(updates_tree)
        return _agg.coordinate_median_tree(updates_tree), self._ones(m)

    def check_resilience(self, alpha, m):
        if int(alpha * m) > (m - 1) // 2:
            return (f"coordinate_median needs an honest majority: "
                    f"α={alpha!r} corrupts {int(alpha * m)} of m={m}")
        return None


def _num(head: str, arg: str, cast, what: str):
    try:
        return cast(arg)
    except ValueError:
        raise SpecError(
            f"aggregator spec {head!r} takes {what}, got {arg!r}"
        ) from None


def make_aggregator(spec) -> Aggregator:
    """Resolve a spec string (or pass through an Aggregator instance)."""
    if isinstance(spec, Aggregator):
        return spec
    if not isinstance(spec, str):
        raise SpecError(f"aggregator spec must be a string, got {spec!r}")
    head, _, arg = spec.partition(":")
    if head == "mean":
        return Mean()
    if head == "norm_trim":
        return NormTrim(_num(head, arg or "0.2", float, "a β fraction"))
    if head == "krum":
        return Krum(_num(head, arg or "2", int, "an integer n_byz"))
    if head == "trimmed_mean":
        return TrimmedMean(_num(head, arg or "0.2", float, "a trim fraction"))
    if head == "coordinate_median":
        return CoordinateMedian()
    if head == "krum_kernel":
        return Krum(_num(head, arg or "2", int, "an integer n_byz"),
                    use_kernel=True)
    if head == "trimmed_mean_kernel":
        return TrimmedMean(_num(head, arg or "0.2", float, "a trim fraction"),
                           use_kernel=True)
    if head == "coordinate_median_kernel":
        return CoordinateMedian(use_kernel=True)
    raise SpecError(
        f"unknown aggregator spec {spec!r}; expected one of {AGGREGATOR_SPECS}"
    )


def default_aggregator_spec(beta: float) -> str:
    """The legacy β-field behaviour as a spec: norm_trim(β) when β > 0,
    plain mean otherwise (what both runtimes hardcoded before)."""
    return f"norm_trim:{float(beta)!r}" if beta > 0 else "mean"
