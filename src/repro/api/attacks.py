"""Attack registry: spec strings → resolved :class:`ResolvedAttack`.

Unifies the free functions of :mod:`repro.core.attacks` behind the same
spec-string pattern as the compressor and aggregator registries:

    "none"                no corruption
    "gaussian:10.0"       s_i + N(0, σ²) on Byzantine updates
    "negative:0.9"        −c · s_i  (norm-preserving sign flip)
    "saddle:5.0"          colluding fake descent direction toward a
                          saddle (scale · random unit vector)
    "random_label"        Byzantine workers train on random labels
    "flipped_label"       … on flipped labels ("flip" is an alias)

``make_attack(spec, alpha)`` resolves the string ONCE.  The resolved
object owns the Byzantine mask, the channel injection hooks for both
runtime layouts, and the label-corruption entry point, so neither
runtime dispatches on name strings any more:

* ``update_hook(m)`` — ``(key, (m, d) stacked) → corrupted`` for a
  :class:`~repro.comm.VectorChannel` (None for label/none attacks);
* ``tree_hook(m)``   — same over a worker-stacked pytree for a
  :class:`~repro.comm.TreeChannel`;
* ``corrupt_labels(key, y)`` — data-level corruption before the local
  solve (label attacks only).

``to_attack_config`` bridges to the legacy frozen
:class:`~repro.core.newton.AttackConfig` for the Newton runtimes'
constructors; ``resolve_attack`` goes the other way.  The first-order
solvers (:mod:`repro.solvers`) take a :class:`ResolvedAttack` directly —
since this PR there is no name-dispatch on the legacy ``core.attacks``
tables left outside this module.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax

from ..core import attacks as attacks_lib
from .errors import SpecError

# head → (family, scale-parameter name, default scale)
_UPDATE = {
    "gaussian": ("sigma", 10.0),
    "negative": ("c", 0.9),
    "saddle": ("scale", 5.0),
}
_LABEL = ("random_label", "flipped_label")
_ALIASES = {"flip": "flipped_label", "label_flip": "flipped_label"}

ATTACK_SPECS = ("none", "gaussian:<sigma>", "negative:<c>", "saddle:<scale>",
                "random_label", "flipped_label")


class ResolvedAttack:
    """One attack scenario: rule + strength + Byzantine fraction."""

    def __init__(self, name: str, alpha: float, *,
                 param: Optional[float] = None, num_classes: int = 2):
        self.name = name
        self.alpha = float(alpha)
        self.num_classes = int(num_classes)
        if name == "none" or self.alpha <= 0:
            self.kind = "none"
            self.kwargs: dict = {}
            self.spec = "none"
            return
        if name in _UPDATE:
            self.kind = "update"
            pname, default = _UPDATE[name]
            value = default if param is None else float(param)
            self.kwargs = {pname: value}
            self.spec = f"{name}:{value!r}"
        elif name in _LABEL:
            self.kind = "label"
            self.kwargs = {"num_classes": self.num_classes}
            self.spec = name
        else:
            raise SpecError(
                f"unknown attack {name!r}; expected one of {ATTACK_SPECS}"
            )

    # -- mask + hooks ----------------------------------------------------
    def mask(self, m: int):
        return attacks_lib.byzantine_mask(m, self.alpha)

    def update_hook(self, m: int) -> Optional[Callable]:
        """Channel injection hook over (m, d) stacked vectors."""
        if self.kind != "update":
            return None
        fn = attacks_lib.UPDATE_ATTACKS[self.name]
        mask = self.mask(m)
        kw = self.kwargs

        def hook(key, s):
            return fn(key, s, mask, **kw)

        return hook

    def tree_hook(self, m: int) -> Optional[Callable]:
        """Channel injection hook over a worker-stacked pytree."""
        if self.kind != "update":
            return None
        fn = attacks_lib.UPDATE_ATTACKS[self.name]
        mask = self.mask(m)
        kw = self.kwargs

        def hook(key, tree):
            return jax.tree_util.tree_map(
                lambda x: fn(key, x, mask, **kw), tree
            )

        return hook

    def corrupt_labels(self, key, y):
        """Data-level corruption of the (m, n) label block (no-op unless
        this is a label attack)."""
        if self.kind != "label":
            return y
        return attacks_lib.LABEL_ATTACKS[self.name](
            key, y, self.mask(y.shape[0]), num_classes=self.num_classes
        )

    def __repr__(self):
        return f"ResolvedAttack({self.spec!r}, alpha={self.alpha!r})"


def make_attack(spec, alpha: float = 0.0, *,
                num_classes: int = 2) -> ResolvedAttack:
    """Resolve an attack spec string at the given Byzantine fraction α."""
    if isinstance(spec, ResolvedAttack):
        return spec
    if spec is None:
        spec = "none"
    if not isinstance(spec, str):
        raise SpecError(f"attack spec must be a string, got {spec!r}")
    head, _, arg = spec.partition(":")
    head = _ALIASES.get(head, head)
    if head != "none" and head not in _UPDATE and head not in _LABEL:
        raise SpecError(
            f"unknown attack spec {spec!r}; expected one of {ATTACK_SPECS}"
        )
    if arg and head not in _UPDATE:
        raise SpecError(f"attack {head!r} takes no parameter, got {spec!r}")
    param = None
    if arg:
        try:
            param = float(arg)
        except ValueError:
            raise SpecError(
                f"attack spec {spec!r}: parameter must be a number"
            ) from None
    return ResolvedAttack(head, alpha, param=param, num_classes=num_classes)


def resolve_attack(cfg) -> ResolvedAttack:
    """Legacy bridge: an :class:`~repro.core.newton.AttackConfig` (name +
    per-attack fields) → the resolved form the runtimes consume."""
    param = {"gaussian": cfg.sigma, "negative": cfg.c,
             "saddle": getattr(cfg, "scale", None)}.get(cfg.name)
    return ResolvedAttack(cfg.name, cfg.alpha, param=param,
                          num_classes=cfg.num_classes)


def to_attack_config(spec, alpha: float = 0.0, *, num_classes: int = 2):
    """Spec string → legacy :class:`~repro.core.newton.AttackConfig`
    (the form the Newton runtimes' constructors take; the channel-routed
    :class:`~repro.core.ByzantinePGD` shim accepts either form and
    resolves it back through this registry)."""
    make_attack(spec, alpha, num_classes=num_classes)  # validate grammar
    from ..core.newton import AttackConfig  # runtime import: no cycle

    head, _, arg = (spec or "none").partition(":")
    head = _ALIASES.get(head, head)
    kw = {}
    if arg and head in _UPDATE:
        kw[_UPDATE[head][0]] = float(arg)
    return AttackConfig(name=head, alpha=alpha, num_classes=num_classes, **kw)
