"""Build-time validation errors for the experiment facade.

Every mis-specification surfaces *before* anything traces or allocates,
as a :class:`SpecError` whose message names the offending field, the
offending value, and the fix — the actionable-messages contract of the
``repro.api`` layer.
"""
from __future__ import annotations


class SpecError(ValueError):
    """A spec string or :class:`~repro.api.ExperimentSpec` field is
    invalid; the message says which one and how to fix it."""
