"""`ExperimentSpec` — the one declarative description every entry point
builds through.

A frozen, JSON-round-trippable record of a full experiment: problem +
runtime selector, solver hyper-parameters, the three channel compressor
specs, the aggregator spec, the attack spec, and the seed.  All fields
are plain JSON scalars, so

    ExperimentSpec.from_dict(spec.to_dict()) == spec      (exactly)

and a sweep is just a list of dicts.  ``validate()`` runs every
build-time check (β > α resilience precondition, spec-string grammar
against the three registries, EF-vs-compressor compatibility, the
top-k kernel's launch-plan/tile sanity) and raises
:class:`~repro.api.errors.SpecError` with an actionable message;
``build()`` validates and returns a ready :class:`Experiment` runner
covering both the paper-faithful and mesh runtimes.

Entry points that drive an external model through the mesh runtime
(``repro.launch.train`` / ``repro.launch.dryrun``) use ``problem =
"external"`` and take only the validated configs
(:meth:`to_distributed_config`), keeping all config construction inside
this module.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

from ..compression.registry import make_compressor
from .aggregators import default_aggregator_spec, make_aggregator
from .attacks import make_attack, to_attack_config
from .errors import SpecError
from .problems import fixed_workers, make_problem, problem_dim

_PAPER_SOLVER_ITERS = 500   # Algorithm 2 while-loop cap (paper runtime)
_MESH_SOLVER_ITERS = 4      # fixed inner iterations (static mesh program)

#: async-runtime axes and their degenerate-synchronous defaults.  At
#: these values the async runtime runs the synchronous program (bit-
#: exact), so ``to_dict`` omits default-valued axes — pre-async spec
#: dicts (and their sweep-store spec hashes) are unchanged byte for
#: byte, and every existing store entry stays addressable.
_ASYNC_AXIS_DEFAULTS = {
    "participation": 1.0,
    "staleness": 0,
    "drop": 0.0,
    "duplicate": 0.0,
    "staleness_decay": 0.5,
}

#: the default solver is likewise omitted from ``to_dict`` — every spec
#: dict (and sweep-store hash) minted before the solver axis existed
#: stays byte-identical, so pre-existing store entries remain addressable
_SOLVER_DEFAULT = "cubic_newton"


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """Declarative experiment description (all fields JSON scalars)."""

    # -- problem / runtime selector --------------------------------------
    problem: str = "synthetic-logistic:4000:40"
    runtime: str = "paper"          # "paper" | "mesh" | "async"
    m_workers: int = 20
    # -- solver (Algorithm 1 / 2) ----------------------------------------
    M: float = 10.0
    gamma: float = 1.0
    eta: float = 1.0
    solver_tol: float = 1e-6
    solver_iters: Optional[int] = None   # None → 500 (paper) / 4 (mesh)
    exact_gradient: bool = False         # Remark 5: two-round, ε_g = 0
    momentum: float = 0.0
    # -- the three wire segments (repro.compression spec strings) --------
    compressor: Optional[str] = None           # uplink: worker updates
    downlink_compressor: Optional[str] = None  # center→worker broadcast
    grad_compressor: Optional[str] = None      # Remark-5 gradient round
    error_feedback: Optional[str] = None       # None → auto (see below)
    ef_damping: float = 0.75
    # -- solver axis (repro.solvers spec string; see _SOLVER_DEFAULT) -----
    solver: str = "cubic_newton"    # | "byzantine_pgd[:R:Q]"
    #                                 | "compressed_sgd[:radius:gtol]"
    # -- resilience scenario ---------------------------------------------
    aggregator: str = "mean"        # repro.api.aggregators spec string
    attack: str = "none"            # repro.api.attacks spec string
    alpha: float = 0.0              # Byzantine fraction
    num_classes: int = 2
    seed: int = 0
    # -- async-runtime axes (runtime="async"; see _ASYNC_AXIS_DEFAULTS) --
    participation: float = 1.0      # per-round cohort fraction ∈ (0, 1]
    staleness: int = 0              # max rounds a packet lags (uniform)
    drop: float = 0.0               # P(packet never arrives) ∈ [0, 1]
    duplicate: float = 0.0          # P(packet delivered twice) ∈ [0, 1]
    staleness_decay: float = 0.5    # arrival weight decay**age ∈ (0, 1]

    # ------------------------------------------------------------ serde
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        # default-valued async axes (and the default solver) are omitted:
        # pre-existing spec dicts (and their sweep-store hashes) stay
        # byte-identical
        for key, default in _ASYNC_AXIS_DEFAULTS.items():
            if d[key] == default:
                del d[key]
        if d["solver"] == _SOLVER_DEFAULT:
            del d["solver"]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise SpecError(
                f"unknown ExperimentSpec fields {sorted(unknown)}; "
                f"known fields: {sorted(known)}"
            )
        return cls(**d)

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))

    def replace(self, **kw) -> "ExperimentSpec":
        return dataclasses.replace(self, **kw)

    # --------------------------------------------------------- resolution
    @property
    def any_compressor(self) -> bool:
        return any((self.compressor, self.downlink_compressor,
                    self.grad_compressor))

    def resolved_error_feedback(self) -> str:
        """``None`` means auto: EF21 on the compressed paper runtime (the
        NewtonConfig default that the round-count results rely on), off
        on the mesh runtime (stateful steps are opt-in at scale)."""
        if self.error_feedback is not None:
            return self.error_feedback
        if self.runtime in ("paper", "async") and self.any_compressor:
            return "ef21"
        return "none"

    def _beta(self) -> float:
        """β mirrored into the legacy config field (norm_trim only)."""
        agg = make_aggregator(self.aggregator)
        return getattr(agg, "beta", 0.0)

    # --------------------------------------------------------- validation
    def validate(self) -> "ExperimentSpec":
        if self.runtime not in ("paper", "mesh", "async"):
            raise SpecError(
                f"runtime must be 'paper', 'mesh', or 'async', "
                f"got {self.runtime!r}"
            )
        # async axes: range checks always, non-defaults only on async
        if not 0.0 < self.participation <= 1.0:
            raise SpecError(
                f"participation={self.participation!r}: the per-round "
                f"cohort fraction must lie in (0, 1]"
            )
        if not isinstance(self.staleness, int) or self.staleness < 0:
            raise SpecError(
                f"staleness={self.staleness!r}: the max packet lag must "
                f"be an int ≥ 0 (rounds)"
            )
        for field in ("drop", "duplicate"):
            if not 0.0 <= getattr(self, field) <= 1.0:
                raise SpecError(
                    f"{field}={getattr(self, field)!r}: packet-fault "
                    f"probabilities must lie in [0, 1]"
                )
        if not 0.0 < self.staleness_decay <= 1.0:
            raise SpecError(
                f"staleness_decay={self.staleness_decay!r}: the arrival "
                f"weight decay must lie in (0, 1]"
            )
        if self.runtime != "async":
            for field, default in _ASYNC_AXIS_DEFAULTS.items():
                if getattr(self, field) != default:
                    raise SpecError(
                        f"{field}={getattr(self, field)!r} is an async-"
                        f"runtime axis, but runtime={self.runtime!r} — "
                        f"set runtime='async' (or drop the override)"
                    )
        if self.runtime == "async" and self.exact_gradient:
            raise SpecError(
                "exact_gradient=True (the Remark-5 two-round mode) needs "
                "a per-round global barrier for the gradient round, which "
                "the async runtime removes — use runtime='paper' for the "
                "two-round experiments"
            )
        if self.m_workers < 2:
            raise SpecError(
                f"m_workers={self.m_workers}: need ≥ 2 workers for "
                f"aggregation to mean anything"
            )

        # solver axis (grammar first — pure, no registries); the
        # first-order baselines ship flat-vector gradient rounds, so they
        # run on the paper runtime only, and the Newton-only axes are
        # rejected rather than silently ignored
        from ..solvers import FIRST_ORDER_SOLVERS, parse_solver_spec

        solver_head, _ = parse_solver_spec(self.solver)
        if solver_head in FIRST_ORDER_SOLVERS:
            if self.runtime != "paper":
                raise SpecError(
                    f"solver {self.solver!r} is a first-order baseline "
                    f"over flat-vector gradient rounds — it runs on "
                    f"runtime='paper' only, got runtime={self.runtime!r}"
                )
            if self.exact_gradient:
                raise SpecError(
                    f"exact_gradient=True is the Newton Remark-5 two-"
                    f"round mode; solver {self.solver!r} already ships "
                    f"gradients every round — drop exact_gradient"
                )
            if solver_head == "byzantine_pgd" and self.momentum != 0.0:
                raise SpecError(
                    f"momentum={self.momentum!r}: ByzantinePGD [Yin et "
                    f"al. 2019] has no momentum term — use "
                    f"solver='compressed_sgd' for momentum-SGD, or drop "
                    f"the momentum override"
                )
        if not 0.0 <= self.alpha < 0.5:
            raise SpecError(
                f"alpha={self.alpha!r}: the Byzantine fraction must lie in "
                f"[0, 0.5) — no aggregator survives a corrupted majority"
            )
        for field in ("M", "gamma", "eta"):
            if getattr(self, field) <= 0:
                raise SpecError(f"{field} must be positive, "
                                f"got {getattr(self, field)!r}")
        if not 0.0 <= self.momentum < 1.0:
            raise SpecError(f"momentum must be in [0, 1), "
                            f"got {self.momentum!r}")

        # problem spec (grammar + dim for the kernel-tile check); problems
        # that pin the cluster size must agree with m_workers, or the
        # resilience checks below would run against the wrong m
        dim = None if self.problem == "external" else problem_dim(self.problem)
        fixed_m = (None if self.problem == "external"
                   else fixed_workers(self.problem))
        if fixed_m is not None and self.m_workers != fixed_m:
            raise SpecError(
                f"problem {self.problem!r} partitions over a fixed "
                f"m={fixed_m} machines, but the spec says "
                f"m_workers={self.m_workers} — set m_workers={fixed_m}, or "
                f"use a synthetic problem to vary the cluster size"
            )

        # aggregator spec + resilience precondition
        agg = make_aggregator(self.aggregator)
        atk = make_attack(self.attack, self.alpha,
                          num_classes=self.num_classes)
        if atk.kind == "label" and self.runtime == "mesh":
            raise SpecError(
                f"attack {self.attack!r} corrupts worker labels, but the "
                f"mesh runtime's batches carry no label channel — use an "
                f"update-level attack (gaussian/negative/saddle)"
            )
        if self.alpha > 0 and agg.name != "mean":
            # ("mean" under attack is the deliberate non-robust baseline
            # every comparison plots against, so it is exempt)
            reason = agg.check_resilience(self.alpha, self.m_workers)
            if reason is not None:
                raise SpecError(
                    f"aggregator {agg.spec!r} cannot resist the configured "
                    f"attack: {reason}"
                )

        # channel specs
        if self.grad_compressor is not None and not self.exact_gradient:
            raise SpecError(
                "grad_compressor compresses the Remark-5 gradient round, "
                "which only exists with exact_gradient=True — enable it or "
                "drop grad_compressor"
            )
        for field in ("compressor", "downlink_compressor", "grad_compressor"):
            spec = getattr(self, field)
            if spec is None:
                continue
            try:
                make_compressor(spec, dim or 1024)
            except ValueError as e:
                raise SpecError(f"{field}={spec!r}: {e}") from None
            if spec.partition(":")[0].endswith("_kernel"):
                # the kernel path serves any d (single-tile launch up to
                # 1408, the sharded grid beyond).  The remaining build-time
                # check guards the kernel module's CONFIGURED tiling, not
                # d: if DEFAULT_BLOCK ever drifts to something the TPU
                # cannot serve (non-128-lane multiple, VMEM-oversized
                # tiles), the spec fails here with an actionable message
                # instead of deep inside a trace at run time.
                from ..kernels import kernel_plan

                try:
                    kernel_plan(dim or 1024)
                except ValueError as e:
                    raise SpecError(f"{field}={spec!r}: {e}") from None

        # error feedback
        ef = self.resolved_error_feedback()
        if ef not in ("none", "ef", "ef21"):
            raise SpecError(
                f"error_feedback={self.error_feedback!r}: expected "
                f"'none', 'ef', or 'ef21'"
            )
        if ef != "none" and self.error_feedback is not None \
                and not self.any_compressor:
            raise SpecError(
                f"error_feedback={self.error_feedback!r} tracks a "
                f"compressor's residual, but all three channel compressors "
                f"are None — set compressor=... (e.g. 'topk:0.1') or drop "
                f"the error_feedback override"
            )

        # runtime/problem compatibility
        if self.runtime == "mesh" and self.problem != "external" \
                and not self.problem.startswith("quadratic"):
            raise SpecError(
                f"mesh-runtime builds need a pytree problem "
                f"('quadratic:<d>') or problem='external' (supply your own "
                f"loss through to_distributed_config), got {self.problem!r}"
            )
        if self.runtime in ("paper", "async") and (
                self.problem.startswith("quadratic")
                or self.problem == "external"):
            raise SpecError(
                f"problem {self.problem!r} is mesh-only; the "
                f"{self.runtime} runtime takes a flat-vector problem "
                f"from the catalog"
            )
        return self

    # --------------------------------------------------------- config gen
    def to_newton_config(self):
        """Validated spec → :class:`repro.core.NewtonConfig`."""
        self.validate()
        from ..core.newton import NewtonConfig  # runtime import: no cycle

        return NewtonConfig(
            M=self.M, gamma=self.gamma, eta=self.eta, beta=self._beta(),
            solver_tol=self.solver_tol,
            solver_iters=self.solver_iters or _PAPER_SOLVER_ITERS,
            exact_gradient=self.exact_gradient, momentum=self.momentum,
            compressor=self.compressor,
            downlink_compressor=self.downlink_compressor,
            grad_compressor=self.grad_compressor,
            error_feedback=self.resolved_error_feedback(),
            ef_damping=self.ef_damping,
            aggregator=self.aggregator,
        )

    def to_attack_config(self):
        """Validated spec → :class:`repro.core.AttackConfig`."""
        return to_attack_config(self.attack, self.alpha,
                                num_classes=self.num_classes)

    def to_distributed_config(self):
        """Validated spec → :class:`repro.core.DistributedNewtonConfig`."""
        self.validate()
        from ..core.distributed import DistributedNewtonConfig

        return DistributedNewtonConfig(
            M=self.M, gamma=self.gamma, eta=self.eta, beta=self._beta(),
            solver_iters=self.solver_iters or _MESH_SOLVER_ITERS,
            two_round=self.exact_gradient,
            compressor=self.compressor,
            downlink_compressor=self.downlink_compressor,
            error_feedback=self.resolved_error_feedback(),
            ef_damping=self.ef_damping,
            aggregator=self.aggregator,
        )

    # ------------------------------------------------------------- build
    def build(self) -> "Experiment":
        """Validate, materialize the problem, and wire up the runtime."""
        self.validate()
        return Experiment(self)


class Experiment:
    """A built, ready-to-run experiment (both runtimes, one interface).

    ``run(n_steps, grad_tol=...)`` returns ``(iterate, history)``; the
    history always carries ``loss`` plus the exact-int wire-ledger
    totals.  The resolved pieces stay inspectable: ``.problem`` (data),
    ``.algo`` (paper runtime's :class:`DistributedCubicNewton`), or
    ``.step``/``.config`` (mesh runtime).
    """

    def __init__(self, spec: ExperimentSpec):
        self.spec = spec
        self.problem = make_problem(spec.problem, spec.m_workers, spec.seed)
        if spec.solver != _SOLVER_DEFAULT:
            from ..solvers import make_solver

            # first-order solver on the paper runtime: same .algo duck
            # type (run / bits_per_step / _ensure_channels) as the
            # Newton runtimes, channels and registries included
            self.config = None
            self.algo = make_solver(spec, self.problem.loss_fn)
            self.step = None
        elif spec.runtime in ("paper", "async"):
            self.config = spec.to_newton_config()
            if spec.runtime == "async":
                from ..async_rt import AsyncConfig, AsyncCubicNewton

                self.algo = AsyncCubicNewton(
                    self.problem.loss_fn, self.config,
                    spec.to_attack_config(),
                    AsyncConfig(
                        participation=spec.participation,
                        staleness=spec.staleness,
                        drop=spec.drop, duplicate=spec.duplicate,
                        staleness_decay=spec.staleness_decay,
                        seed=spec.seed,
                    ),
                )
            else:
                from ..core.newton import DistributedCubicNewton

                self.algo = DistributedCubicNewton(
                    self.problem.loss_fn, self.config,
                    spec.to_attack_config()
                )
            self.step = None
        else:
            import jax

            from ..core.distributed import (
                make_stateful_train_step,
                make_train_step,
            )

            self.config = spec.to_distributed_config()
            self.algo = None
            self._stateful = self.config.error_feedback != "none"
            maker = (make_stateful_train_step if self._stateful
                     else make_train_step)
            built = maker(
                self.problem.loss_fn, self.config, spec.m_workers,
                attack_name=spec.attack, attack_alpha=spec.alpha,
            )
            if self._stateful:
                raw_step, self._init_comm_state = built
                self.step = jax.jit(raw_step, donate_argnums=(3,))
            else:
                raw_step, self._init_comm_state = built, None
                self.step = jax.jit(raw_step)
            self._raw_step = raw_step

    # -- running ---------------------------------------------------------
    def run(self, n_steps: int = 10, *, grad_tol: Optional[float] = None,
            eval_fn=None, key=None, deadline: Optional[float] = None):
        """Run the experiment; returns ``(iterate, history)``.

        ``deadline`` is a ``time.monotonic()`` timestamp: the run loop
        cooperatively stops at the first round boundary past it (the
        sweep runner's per-cell wall-time budget), recording
        ``history["truncated"] = True``.
        """
        if self.algo is not None:
            return self.algo.run(
                self.problem.w0, self.problem.X_workers,
                self.problem.y_workers, n_steps, key=key,
                eval_fn=eval_fn if eval_fn is not None
                else self.problem.eval_fn,
                grad_tol=grad_tol, deadline=deadline,
                saddle_value=self.problem.saddle_value,
            )
        return self._run_mesh(n_steps, key=key, deadline=deadline)

    def _run_mesh(self, n_steps: int, key=None,
                  deadline: Optional[float] = None):
        import time as _time

        import jax

        from ..comm import WireLedger
        from ..telemetry import (RoundRecord, SuspicionTracker,
                                 compile_scope, get_telemetry,
                                 planted_byzantine_ids, rejected_from_keep)

        params = self.problem.w0
        batch = self.problem.batch
        key = key if key is not None else jax.random.PRNGKey(self.spec.seed)
        ledger = WireLedger()
        wire = self._raw_step.wire_bits(params)
        state = (self._init_comm_state(params) if self._stateful else None)
        hist = {"loss": [], "bits_cumulative": [], "uplink_delta": [],
                "truncated": False}
        tel = get_telemetry()
        prev_loss = None
        m = self.spec.m_workers
        tracker = SuspicionTracker(m) if tel.enabled else None
        for t in range(n_steps):
            if deadline is not None and hist["loss"] \
                    and _time.monotonic() >= deadline:
                hist["truncated"] = True
                if tel.enabled:
                    tel.event("mesh.truncated", step=t)
                break
            key, sub = jax.random.split(key)
            # (re)compiles of the mesh step are attributed to this scope
            # by the telemetry compile-counter (host-side contextvar)
            with compile_scope("mesh.step"):
                if self._stateful:
                    params, metrics, state = self.step(params, batch, sub,
                                                       state)
                else:
                    params, metrics = self.step(params, batch, sub)
            ledger.record(uplink=wire["uplink"], downlink=wire["downlink"],
                          rounds=2 if self.config.two_round else 1,
                          label="round")
            loss = float(metrics["loss"])
            hist["loss"].append(loss)
            hist["uplink_delta"].append(float(metrics["uplink_delta"]))
            hist["bits_cumulative"].append(ledger.total_bits)
            if tel.enabled:
                # schema-v4 forensics from the metrics the mesh step
                # already surfaces host-side (no new traced outputs; the
                # tree-stacked wire has no per-worker δ̂ view, so
                # worker_delta stays absent on runtime="mesh")
                keep_l = [float(k) for k in metrics["kept"]]
                norms_l = [float(n) for n in metrics["update_norms"]]
                attacked = (self.spec.attack != "none"
                            and self.spec.alpha > 0)
                tel.round(RoundRecord(
                    step=t, runtime="mesh", loss=loss,
                    model_decrease=(None if prev_loss is None
                                    else prev_loss - loss),
                    uplink_delta=float(metrics["uplink_delta"]),
                    rejected=rejected_from_keep(metrics["kept"]),
                    attack=self.spec.attack, alpha=self.spec.alpha,
                    wire_uplink_bits=wire["uplink"],
                    wire_downlink_bits=wire["downlink"],
                    worker_bits=[wire["uplink"] // m] * m,
                    worker_keep=keep_l,
                    worker_norms=norms_l,
                    suspicion=tracker.update(keep=keep_l, norms=norms_l),
                    byzantine_true=(planted_byzantine_ids(
                        m, self.spec.alpha) if attacked else None),
                ), name="mesh.round")
                prev_loss = loss
        hist["rounds"] = ledger.rounds
        hist.update(ledger.snapshot())
        self._last_metrics = metrics
        return params, hist

    # -- introspection ---------------------------------------------------
    def bits_per_step(self) -> dict:
        if self.algo is not None:
            self.algo._ensure_channels(self.problem.dim,
                                       self.problem.m_workers)
            return self.algo.bits_per_step()
        return self._raw_step.wire_bits(self.problem.w0)
