"""Problem catalog: spec strings → ready-to-run distributed problems.

The third registry of the facade.  A problem spec names the objective,
its data, and (optionally) its size; :func:`make_problem` materializes
worker-sharded data deterministically from the experiment seed:

    "a9a-logistic" / "w8a-logistic"      paper §6 logistic regression
    "a9a-robust"   / "w8a-robust"        paper §6 robust regression
    "synthetic-logistic:<n>:<d>"         separable classification twin
    "synthetic-regression:<n>:<d>"       heavy-tailed robust regression
    "matrix-factor:<d>:<r>"              low-rank factorization with a
                                         strict saddle at U = 0 (the
                                         saddle-escape testbed)
    "quadratic:<d>"                      tiny least-squares pytree
                                         problem for the MESH runtime

The canonical loss functions live here (they were previously duplicated
across benchmarks, examples, and tests).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..configs import PAPER_WORKLOADS
from ..data import (
    make_classification,
    make_regression,
    paper_dataset,
    shard_to_workers,
)
from .errors import SpecError

PROBLEM_SPECS = tuple(PAPER_WORKLOADS) + (
    "synthetic-logistic:<n>:<d>", "synthetic-regression:<n>:<d>",
    "matrix-factor:<d>:<r>", "quadratic:<d>",
)


# ---------------------------------------------------------------- losses
def logistic_loss(w, X, y):
    """Eq. (8): regularized logistic regression (λ/2n scaling as in paper)."""
    z = X @ w
    yy = 2.0 * y - 1.0
    return jnp.mean(jnp.log1p(jnp.exp(-yy * z))) + 0.5 / X.shape[0] * (w @ w)


def robust_regression_loss(w, X, y):
    """Eq. (9): non-convex robust linear regression."""
    r = y - X @ w
    return jnp.mean(jnp.log(r * r / 2.0 + 1.0))


def factor_loss(w, X, y):
    """¼‖UUᵀ − Σ‖²_F with w = flat U (d·r); strict saddle at U = 0."""
    del y
    n, d = X.shape
    r = w.shape[0] // d
    U = w.reshape(d, r)
    Sigma = X.T @ X / n
    R = U @ U.T - Sigma
    return 0.25 * jnp.sum(R * R)


def accuracy(w, X, y):
    return float(((X @ w > 0) == (y > 0.5)).mean())


# ---------------------------------------------------------------- catalog
@dataclasses.dataclass
class Problem:
    """Materialized problem: loss + worker-sharded data + metadata."""

    spec: str
    kind: str                 # "logistic" | "robust_regression" | ...
    loss_fn: Callable
    dim: int
    m_workers: int
    X_workers: jnp.ndarray = None
    y_workers: jnp.ndarray = None
    w0: jnp.ndarray = None
    X_full: jnp.ndarray = None
    y_full: jnp.ndarray = None
    X_test: Optional[jnp.ndarray] = None
    y_test: Optional[jnp.ndarray] = None
    w_star: Optional[jnp.ndarray] = None
    saddle_value: Optional[float] = None   # matrix-factor only
    batch: Optional[dict] = None           # mesh problems: worker batches

    @property
    def eval_fn(self) -> Optional[Callable]:
        """Test accuracy for classification problems, else None."""
        if self.kind == "logistic" and self.X_test is not None:
            return lambda w: accuracy(w, self.X_test, self.y_test)
        return None

    def accuracy(self, w) -> float:
        X = self.X_test if self.X_test is not None else self.X_full
        y = self.y_test if self.y_test is not None else self.y_full
        return accuracy(w, X, y)


def _ints(spec: str, arg: str, defaults: tuple) -> tuple:
    parts = [p for p in arg.split(":") if p]
    try:
        vals = tuple(int(p) for p in parts)
    except ValueError:
        raise SpecError(
            f"problem spec {spec!r}: size parameters must be integers"
        ) from None
    if len(vals) > len(defaults):
        raise SpecError(
            f"problem spec {spec!r}: at most {len(defaults)} parameters"
        )
    return vals + defaults[len(vals):]


def fixed_workers(spec: str) -> Optional[int]:
    """Cluster size a problem pins (the paper workloads partition over a
    fixed 20 machines); None when m_workers is free."""
    if spec in PAPER_WORKLOADS:
        return PAPER_WORKLOADS[spec].m_workers
    return None


def problem_dim(spec: str) -> Optional[int]:
    """The flat iterate dimension a spec implies (None for mesh problems
    whose params come from an external model)."""
    if spec in PAPER_WORKLOADS:
        return PAPER_WORKLOADS[spec].dim
    head, _, arg = spec.partition(":")
    if head in ("synthetic-logistic", "synthetic-regression"):
        return _ints(spec, arg, (4000, 40))[1]
    if head == "matrix-factor":
        d, r = _ints(spec, arg, (10, 2))
        return d * r
    if head == "quadratic":
        return _ints(spec, arg, (8,))[0] + 1    # w plus bias
    raise SpecError(
        f"unknown problem spec {spec!r}; expected one of {PROBLEM_SPECS}"
    )


def make_problem(spec: str, m_workers: int, seed: int = 0) -> Problem:
    """Materialize a problem's data deterministically from the seed.

    Memoized on ``(spec, m_workers, seed)``: sweeps (aggregator × attack
    grids share one dataset per cell row) reuse the same
    :class:`Problem` instead of regenerating identical arrays — safe
    because the jax arrays are immutable and the seed fully determines
    the data.
    """
    return _materialize(spec, int(m_workers), int(seed))


@functools.lru_cache(maxsize=4)
def _materialize(spec: str, m_workers: int, seed: int) -> Problem:
    if spec in PAPER_WORKLOADS:
        wl = PAPER_WORKLOADS[spec]
        data = paper_dataset(wl, seed)
        loss = logistic_loss if wl.problem == "logistic" else robust_regression_loss
        return Problem(
            spec=spec, kind=wl.problem, loss_fn=loss, dim=wl.dim,
            m_workers=wl.m_workers,
            X_workers=data["X_workers"], y_workers=data["y_workers"],
            w0=jnp.zeros(wl.dim),
            X_full=data["X_train"], y_full=data["y_train"],
            X_test=data["X_test"], y_test=data["y_test"],
        )

    head, _, arg = spec.partition(":")
    key = jax.random.PRNGKey(seed)

    if head == "synthetic-logistic":
        n, d = _ints(spec, arg, (4000, 40))
        X, y, w_star = make_classification(key, n, d, margin=3.0)
        Xw, yw = shard_to_workers(X, y, m_workers)
        return Problem(spec=spec, kind="logistic", loss_fn=logistic_loss,
                       dim=d, m_workers=m_workers, X_workers=Xw, y_workers=yw,
                       w0=jnp.zeros(d), X_full=X, y_full=y, w_star=w_star)

    if head == "synthetic-regression":
        n, d = _ints(spec, arg, (4000, 40))
        X, y, w_star = make_regression(key, n, d)
        Xw, yw = shard_to_workers(X, y, m_workers)
        return Problem(spec=spec, kind="robust_regression",
                       loss_fn=robust_regression_loss, dim=d,
                       m_workers=m_workers, X_workers=Xw, y_workers=yw,
                       w0=jnp.zeros(d), X_full=X, y_full=y, w_star=w_star)

    if head == "matrix-factor":
        d, r = _ints(spec, arg, (10, 2))
        n = 400
        ku, kx = jax.random.split(key)
        U_star = jax.random.normal(ku, (d, r))
        X = jax.random.normal(kx, (m_workers, n, r)) @ U_star.T
        X = X + 0.01 * jax.random.normal(
            jax.random.fold_in(kx, 1), (m_workers, n, d)
        )
        y = jnp.zeros(X.shape[:2])
        Xf = X.reshape(-1, d)
        # start NEXT to the strict saddle U = 0
        w0 = 1e-3 * jax.random.normal(jax.random.fold_in(key, 2), (d * r,))
        return Problem(
            spec=spec, kind="matrix_factor", loss_fn=factor_loss, dim=d * r,
            m_workers=m_workers, X_workers=X, y_workers=y, w0=w0,
            X_full=Xf, y_full=y.reshape(-1),
            saddle_value=float(factor_loss(jnp.zeros(d * r), Xf, None)),
        )

    if head == "quadratic":
        # mesh-runtime problem: params are a {"w", "b"} pytree, batches
        # carry a leading worker axis — the facade's both-runtimes testbed.
        (din,) = _ints(spec, arg, (8,))
        n = 32
        wstar = jax.random.normal(key, (din,))
        X = jax.random.normal(jax.random.fold_in(key, 1), (m_workers, n, din))
        Y = X @ wstar + 0.01 * jax.random.normal(
            jax.random.fold_in(key, 2), (m_workers, n)
        )

        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"] + params["b"]
            return jnp.mean((pred - batch["y"]) ** 2)

        return Problem(spec=spec, kind="quadratic", loss_fn=loss_fn,
                       dim=din + 1, m_workers=m_workers,
                       w0={"w": jnp.zeros(din), "b": jnp.zeros(())},
                       w_star=wstar, batch={"x": X, "y": Y})

    raise SpecError(
        f"unknown problem spec {spec!r}; expected one of {PROBLEM_SPECS}"
    )
