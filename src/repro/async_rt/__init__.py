"""repro.async_rt — the asynchronous round runtime.

Algorithm 1 under partial participation, staleness, and packet faults:
a deterministic seeded event scheduler drives per-round cohort sampling
and per-packet lag/drop/duplicate decisions, per-node message buffers
deliver EF-compressed updates (channel state versioned per arrival) into
a staleness-weighted registry aggregation, and exact WireLedger bit
accounting is preserved packet by packet.  Degenerate configs
(participation 1.0, staleness 0, no faults) delegate to the synchronous
runtime's jitted step and are bit-exact with it.

Spec surface: ``runtime: async`` plus the ``participation:`` /
``staleness:`` / ``drop:`` / ``duplicate:`` / ``staleness_decay:`` axes
on :class:`repro.api.ExperimentSpec`.
"""
from .aggregate import StalenessWeighted
from .runtime import AsyncConfig, AsyncCubicNewton
from .scheduler import (
    EventScheduler,
    Message,
    MessageQueue,
    cohort_size,
    sample_cohort,
)

__all__ = [
    "AsyncConfig",
    "AsyncCubicNewton",
    "EventScheduler",
    "Message",
    "MessageQueue",
    "StalenessWeighted",
    "cohort_size",
    "sample_cohort",
]
