"""Staleness-weighted wrapper over the aggregator registry.

The async center aggregates a *variable-size stack of arrivals* — each
carrying an age (rounds spent in flight) — instead of the synchronous
fixed ``(m, d)`` stack.  :class:`StalenessWeighted` lifts ANY resolved
:class:`repro.api.aggregators.Aggregator` to that setting:

1. the base rule screens the arrival stack exactly as it screens the
   synchronous stack (norm-trim drops the largest norms, krum picks the
   most central, …), producing its keep mask;
2. the kept arrivals are combined with weights ``decay ** age`` — a
   fresh update counts fully, a k-round-stale one is discounted
   geometrically (the standard staleness-aware FedAsync-style weighting;
   ``decay = 1.0`` recovers the unweighted rule over kept arrivals).

Cohorts too small for the base rule to screen (n < 2) are NOT waved
through unconditionally: under low ``participation`` a round where only
a Byzantine packet lands would otherwise become the entire center update
at full weight.  The wrapper therefore carries one screen statistic
across rounds — the norm of the last aggregate produced by a *screened*
(n ≥ 2, non-empty-keep) round — and rejects a lone arrival whose norm
exceeds ``norm_guard`` times it.  Before any screened round has
established a reference the lone arrival is accepted (there is genuinely
nothing to screen against yet), preserving the degenerate bit-exactness
with the synchronous runtimes.

The wrapper is eager (host-driven, unjitted): the arrival count changes
every round, and re-tracing a jitted aggregate per distinct count would
compile once per cohort size for no measurable win at simulation scale.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


class StalenessWeighted:
    """``agg(arrivals, ages) -> (aggregate, keep)`` over an arrival stack.

    ``arrivals`` is ``(n, d)`` (n = this round's deliveries, any n ≥ 1),
    ``ages`` is ``(n,)`` integer rounds-in-flight.  ``keep`` is the base
    rule's mask over the arrival stack; for n < 2 it is the norm-guard's
    verdict against the last screened aggregate (see module docstring).
    """

    def __init__(self, base, decay: float = 0.5, norm_guard: float = 3.0):
        if not 0.0 < float(decay) <= 1.0:
            raise ValueError(f"staleness decay must be in (0, 1], "
                             f"got {decay!r}")
        if float(norm_guard) <= 0.0:
            raise ValueError(f"norm_guard must be positive, "
                             f"got {norm_guard!r}")
        self.base = base
        self.decay = float(decay)
        self.norm_guard = float(norm_guard)
        self._ref_norm: Optional[float] = None  # last screened ‖aggregate‖
        self.name = f"staleness_weighted({base.name})"
        self.spec = f"staleness_weighted:{self.decay}:{base.spec}"

    def __call__(self, arrivals, ages):
        n = arrivals.shape[0]
        screened = n >= 2
        if screened:
            _, keep = self.base(arrivals)
        elif (self._ref_norm is not None
              and float(jnp.linalg.norm(arrivals[0]))
              > self.norm_guard * max(self._ref_norm, 1e-12)):
            # lone arrival far outside the scale every screened round
            # has produced — the single-Byzantine-packet round
            keep = jnp.zeros((n,), jnp.float32)
        else:
            keep = jnp.ones((n,), jnp.float32)
        ages = jnp.asarray(ages, jnp.float32)
        # binarize: a soft keep (trimmed_mean's per-coordinate fraction)
        # is a forensic signal, not an aggregation weight — only fully
        # rejected arrivals (keep == 0) are excluded, so 0/1 and one-hot
        # base rules behave exactly as before
        wts = (keep > 0).astype(jnp.float32) * (self.decay ** ages)
        total = jnp.sum(wts)
        # all-rejected stacks (a paranoid base rule on a tiny cohort, or
        # a norm-guarded lone arrival) contribute nothing rather than NaN
        agg = jnp.where(
            total > 0,
            jnp.sum(wts[:, None] * arrivals, axis=0)
            / jnp.maximum(total, 1e-30),
            jnp.zeros(arrivals.shape[-1], arrivals.dtype),
        )
        if screened and float(total) > 0:
            self._ref_norm = float(jnp.linalg.norm(agg))
        return agg, keep

    def __repr__(self):
        return (f"StalenessWeighted({self.base!r}, decay={self.decay}, "
                f"norm_guard={self.norm_guard})")
