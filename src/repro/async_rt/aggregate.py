"""Staleness-weighted wrapper over the aggregator registry.

The async center aggregates a *variable-size stack of arrivals* — each
carrying an age (rounds spent in flight) — instead of the synchronous
fixed ``(m, d)`` stack.  :class:`StalenessWeighted` lifts ANY resolved
:class:`repro.api.aggregators.Aggregator` to that setting:

1. the base rule screens the arrival stack exactly as it screens the
   synchronous stack (norm-trim drops the largest norms, krum picks the
   most central, …), producing its keep mask;
2. the kept arrivals are combined with weights ``decay ** age`` — a
   fresh update counts fully, a k-round-stale one is discounted
   geometrically (the standard staleness-aware FedAsync-style weighting;
   ``decay = 1.0`` recovers the unweighted rule over kept arrivals).

The wrapper is eager (host-driven, unjitted): the arrival count changes
every round, and re-tracing a jitted aggregate per distinct count would
compile once per cohort size for no measurable win at simulation scale.
"""
from __future__ import annotations

import jax.numpy as jnp


class StalenessWeighted:
    """``agg(arrivals, ages) -> (aggregate, keep)`` over an arrival stack.

    ``arrivals`` is ``(n, d)`` (n = this round's deliveries, any n ≥ 1),
    ``ages`` is ``(n,)`` integer rounds-in-flight.  ``keep`` is the base
    rule's mask over the arrival stack (all-ones when n < 2 — a single
    arrival is nothing to screen against).
    """

    def __init__(self, base, decay: float = 0.5):
        if not 0.0 < float(decay) <= 1.0:
            raise ValueError(f"staleness decay must be in (0, 1], "
                             f"got {decay!r}")
        self.base = base
        self.decay = float(decay)
        self.name = f"staleness_weighted({base.name})"
        self.spec = f"staleness_weighted:{self.decay}:{base.spec}"

    def __call__(self, arrivals, ages):
        n = arrivals.shape[0]
        if n >= 2:
            _, keep = self.base(arrivals)
        else:
            keep = jnp.ones((n,), jnp.float32)
        ages = jnp.asarray(ages, jnp.float32)
        wts = keep.astype(jnp.float32) * (self.decay ** ages)
        total = jnp.sum(wts)
        # all-rejected stacks (a paranoid base rule on a tiny cohort)
        # contribute nothing rather than NaN
        agg = jnp.where(
            total > 0,
            jnp.sum(wts[:, None] * arrivals, axis=0)
            / jnp.maximum(total, 1e-30),
            jnp.zeros(arrivals.shape[-1], arrivals.dtype),
        )
        return agg, keep

    def __repr__(self):
        return f"StalenessWeighted({self.base!r}, decay={self.decay})"
