"""Asynchronous round runtime: Algorithm 1 under partial participation,
staleness, and packet faults.

:class:`AsyncCubicNewton` extends the paper-faithful synchronous runtime
(:class:`repro.core.newton.DistributedCubicNewton`) with an event-driven
round loop:

* each round a seeded **cohort** of workers computes and sends its
  EF-compressed update (``participation:<p>`` of m, sampled without
  replacement per round by the :class:`~repro.async_rt.EventScheduler`);
* a sent packet lands ``lag ∈ {0, …, staleness}`` rounds later in the
  center's :class:`~repro.async_rt.MessageQueue`; it may be **dropped**
  (paid on the wire, never delivered) or **duplicated** (paid twice,
  delivered twice, EF-committed once);
* the center's per-worker Channel/EF21 state is **versioned per
  arrival**: a packet carries the candidate state row its send produced,
  and the center commits it the first time that send arrives — so a
  straggler's next update is compressed against the state the center
  actually believes, and dropped packets never advance it;
* arrivals are aggregated by a :class:`~repro.async_rt.StalenessWeighted`
  wrapper over the configured registry rule (base rule's keep mask, then
  ``decay**age`` weighting), momentum/downlink/iterate update as in the
  synchronous step;
* exact wire accounting is preserved: every packet (including drops and
  duplicates) records its payload bits on the :class:`WireLedger` at
  send time, every executed round records one round + the downlink
  broadcast when anything arrived.

**Degenerate configs run the synchronous program.**  When
``participation == 1.0, staleness == 0, drop == duplicate == 0`` the
round semantics are exactly Algorithm 1, so :meth:`run` delegates to the
parent's jitted step — the identical jaxpr, hence *bit-exact* with
``runtime="paper"`` (two differently-structured XLA programs would not
be; sharing the trace is what makes the acceptance test exact).  This
also keeps the sparse-domain center available in degenerate mode; the
buffered path forces the dense center (arrival stacks re-order workers,
which the payload-domain receive cannot represent).

Device-side randomness (compressors, attacks) keeps the synchronous
runtime's per-round key-split structure; all scheduling randomness is
host-side numpy Philox (see :mod:`~repro.async_rt.scheduler`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core.newton import AttackConfig, DistributedCubicNewton, NewtonConfig
from ..telemetry import (
    RoundRecord,
    SuspicionTracker,
    compile_scope,
    get_telemetry,
    planted_byzantine_ids,
    rejected_from_keep,
)
from .aggregate import StalenessWeighted
from .scheduler import EventScheduler, Message, MessageQueue


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """The async runtime's scheduling axes (all host-side semantics)."""

    participation: float = 1.0   # per-round cohort fraction p ∈ (0, 1]
    staleness: int = 0           # max rounds a packet lags (uniform lag)
    drop: float = 0.0            # P(a sent packet never arrives)
    duplicate: float = 0.0       # P(a sent packet is delivered twice)
    staleness_decay: float = 0.5  # arrival weight decay**age ∈ (0, 1]
    seed: int = 0                # the event scheduler's seed

    @property
    def degenerate(self) -> bool:
        """True when async semantics collapse to the synchronous round
        (full participation, no lag, no faults) — the config the
        bit-exactness guarantee covers."""
        return (self.participation >= 1.0 and self.staleness == 0
                and self.drop == 0.0 and self.duplicate == 0.0)


class AsyncCubicNewton(DistributedCubicNewton):
    """Algorithm 1 on the asynchronous round runtime (see module doc)."""

    runtime_label = "async"

    def __init__(
        self,
        loss_fn,
        config: NewtonConfig = NewtonConfig(),
        attack: AttackConfig = AttackConfig(),
        async_config: AsyncConfig = AsyncConfig(),
    ):
        self.async_config = async_config
        super().__init__(loss_fn, config, attack)
        if config.exact_gradient:
            raise ValueError(
                "the async runtime has no two-round (Remark 5) mode: the "
                "gradient round's global barrier is exactly what "
                "asynchrony removes — set exact_gradient=False"
            )
        self.staleness_agg = StalenessWeighted(
            self.aggregator, async_config.staleness_decay
        )

    # -- jitted pieces ---------------------------------------------------
    def _rebuild_jit(self):
        super()._rebuild_jit()
        # the async loop splits the synchronous step into two fixed-shape
        # jitted halves (compute+uplink over all m; downlink apply) with
        # the host-side buffer/aggregation seam between them
        self._ct = jax.jit(self._compute_transmit_impl)
        self._down = jax.jit(self._downlink_impl)

    def _compute_transmit_impl(self, w, uplink_state, X, y, key):
        """All m workers' cubic solves + uplink transmit, one trace.

        Mirrors the synchronous step's key-split structure exactly; the
        host selects the cohort's rows from the full (m, d) result, so
        the trace never depends on the (varying) cohort size.  Returns
        the reconstructed updates, the CANDIDATE uplink state (committed
        per arrival, not here), and the measured δ̂.
        """
        k_label, k_update, k_comp, _k_grad, _k_down = jax.random.split(key, 5)
        y_used = self._attack_rule.corrupt_labels(k_label, y)
        s = jax.vmap(
            lambda Xi, yi: self._worker_solve(w, Xi, yi, None)
        )(X, y_used)
        if get_telemetry().enabled:
            # forensics: also stage the per-sender δ̂ (trace-time gate —
            # the disabled program is the exact pre-forensics HLO)
            s_hat, new_state, delta, worker_delta = self.uplink.transmit(
                s, uplink_state, key=k_comp, attack_key=k_update,
                measure=True, per_sender=True,
            )
        else:
            s_hat, new_state, delta = self.uplink.transmit(
                s, uplink_state, key=k_comp, attack_key=k_update,
                measure=True,
            )
            worker_delta = None
        return s_hat, new_state, delta, worker_delta

    def _downlink_impl(self, v_new, downlink_state, key):
        """Center broadcast of the aggregated step (η·v), own channel."""
        *_rest, k_down = jax.random.split(key, 5)
        delta, new_state = self.downlink.transmit(
            self.config.eta * v_new, downlink_state, key=k_down
        )
        return delta, new_state

    # -- the round loop --------------------------------------------------
    def run(self, w0, X, y, n_steps, key=None, eval_fn=None, grad_tol=None,
            full_data=None, deadline=None, saddle_value=None):
        if self.async_config.degenerate:
            # the synchronous program IS the degenerate async program:
            # delegating to the parent's jitted step shares the jaxpr,
            # which is the only way "bit-exact with runtime='paper'" is
            # guaranteed (structurally different XLA programs are not)
            w, hist = super().run(
                w0, X, y, n_steps, key=key, eval_fn=eval_fn,
                grad_tol=grad_tol, full_data=full_data, deadline=deadline,
                saddle_value=saddle_value,
            )
            hist["async_degenerate"] = True
            return w, hist
        return self._run_async(
            w0, X, y, n_steps, key=key, eval_fn=eval_fn, grad_tol=grad_tol,
            full_data=full_data, deadline=deadline,
            saddle_value=saddle_value,
        )

    def _run_async(self, w0, X, y, n_steps, *, key, eval_fn, grad_tol,
                   full_data, deadline, saddle_value):
        import time as _time

        acfg = self.async_config
        key = key if key is not None else jax.random.PRNGKey(0)
        if full_data is None:
            full_data = (X.reshape(-1, X.shape[-1]), y.reshape(-1))
        Xf, yf = full_data
        gradf = jax.jit(jax.grad(self.loss_fn))
        lossf = jax.jit(self.loss_fn)
        m = X.shape[0]
        self._ensure_channels(w0.shape[0], m)
        if self._use_sparse_center:
            if self.config.sparse_center:
                raise ValueError(
                    "sparse_center=True needs the degenerate async config "
                    "(participation=1.0, staleness=0, no faults): the "
                    "buffered path aggregates re-ordered arrival stacks, "
                    "which the payload-domain center cannot represent"
                )
            self._use_sparse_center = False   # auto resolved: dense center

        sched = EventScheduler(
            acfg.seed, m, participation=acfg.participation,
            staleness=acfg.staleness, drop=acfg.drop,
            duplicate=acfg.duplicate,
        )
        queue = MessageQueue()
        ledger = self.ledger
        ledger.reset()
        hist = {"loss": [], "grad_norm": [], "eval": [], "rounds": 0,
                "bits_cumulative": [], "uplink_delta": [],
                "k_trajectory": [], "saddle_escape_step": None,
                "truncated": False, "async_degenerate": False,
                "cohort_size": [], "n_arrivals": [], "queue_depth": [],
                "staleness_mean": []}
        tel = get_telemetry()
        prev_loss = float(lossf(w0, Xf, yf)) if tel.enabled else None
        tracker = SuspicionTracker(m) if tel.enabled else None
        w = w0
        v = jnp.zeros_like(w0)
        state = self.init_comm_state()
        stateful_uplink = self.uplink.feedback is not None
        committed_version = [-1] * m

        for t in range(n_steps):
            if deadline is not None and hist["loss"] \
                    and _time.monotonic() >= deadline:
                hist["truncated"] = True
                if tel.enabled:
                    tel.event("newton.truncated", step=t)
                break
            key, sub = jax.random.split(key)
            k_live = self._uplink_k()
            cohort = sched.cohort(t)
            with compile_scope("async.compute"):
                s_hat, cand_state, delta_hat, worker_delta = self._ct(
                    w, state["uplink"], X, y, sub
                )
            # wire accounting at SEND time: every packet pays its payload
            # bits (drops included — the sender transmitted; duplicates
            # pay twice), re-read per round so an adaptive k bills each
            # send at the size it actually shipped
            bps = self.bits_per_step()
            msg_bits = bps["uplink"] // m
            paid_bits = [0] * m   # exact per-worker bits paid this round
            for i in cohort:
                i = int(i)
                copies = 2 if sched.duplicated(t, i) else 1
                paid_bits[i] = msg_bits * copies
                for c in range(copies):
                    ledger.record(uplink=msg_bits, rounds=0, label="uplink")
                    if sched.dropped(t, i, copy=c):
                        continue
                    queue.push(t + sched.lag(t, i, copy=c), Message(
                        worker=i, send_round=t, version=t, copy=c,
                        payload=s_hat[i],
                        ef_row=(cand_state[i] if stateful_uplink else None),
                    ))

            arrivals = queue.pop_due(t)
            ages = [t - msg.send_round for msg in arrivals]
            # commit the channel/EF state rows carried by first arrivals:
            # the center's belief of each worker's compressor state only
            # advances when that worker's send actually lands
            uplink_state = state["uplink"]
            for msg in arrivals:
                if msg.version > committed_version[msg.worker]:
                    if stateful_uplink:
                        uplink_state = uplink_state.at[msg.worker].set(
                            msg.ef_row
                        )
                    committed_version[msg.worker] = msg.version
            state["uplink"] = uplink_state

            rejected_workers = []
            # per-worker forensic view of this round (schema v4): None
            # entries are workers whose send did not arrive this round
            worker_keep = [None] * m
            worker_staleness = [None] * m
            worker_norms = [None] * m
            if arrivals:
                stack = jnp.stack([msg.payload for msg in arrivals])
                agg, keep = self.staleness_agg(stack, ages)
                # the keep mask indexes the ARRIVAL stack; map rejects
                # back to worker ids for the round record
                rejected_workers = sorted({
                    arrivals[i].worker for i in rejected_from_keep(keep)
                })
                if tel.enabled:
                    arrival_norms = jnp.linalg.norm(
                        stack.reshape(stack.shape[0], -1), axis=-1
                    )
                    for idx, msg in enumerate(arrivals):
                        i, age = msg.worker, t - msg.send_round
                        k_i, n_i = float(keep[idx]), float(arrival_norms[idx])
                        # duplicates: keep the freshest / most-kept view
                        if worker_keep[i] is None or k_i > worker_keep[i]:
                            worker_keep[i] = k_i
                            worker_norms[i] = n_i
                        if (worker_staleness[i] is None
                                or age < worker_staleness[i]):
                            worker_staleness[i] = age
                v = self.config.momentum * v + agg
                with compile_scope("async.downlink"):
                    delta, state["downlink"] = self._down(
                        v, state["downlink"], sub
                    )
                w = w + delta
                ledger.record(downlink=bps["downlink"], rounds=1,
                              label="round")
            else:
                # an empty round still happened on the clock (and in the
                # ledger's round count) but broadcasts nothing
                ledger.record(rounds=1, label="round")

            hist["bits_cumulative"].append(ledger.total_bits)
            delta_hat = float(delta_hat)
            hist["uplink_delta"].append(delta_hat)
            hist["k_trajectory"].append(k_live)
            hist["cohort_size"].append(len(cohort))
            hist["n_arrivals"].append(len(arrivals))
            hist["queue_depth"].append(queue.depth)
            hist["staleness_mean"].append(
                sum(ages) / len(ages) if ages else None
            )
            gn = float(jnp.linalg.norm(gradf(w, Xf, yf)))
            loss = float(lossf(w, Xf, yf))
            hist["loss"].append(loss)
            hist["grad_norm"].append(gn)
            if eval_fn is not None:
                hist["eval"].append(float(eval_fn(w)))
            hit_tol = grad_tol is not None and gn <= grad_tol
            k_changed = False
            if not hit_tol:
                k_changed = self._maybe_adapt(gn, measured_delta=delta_hat)
            escaped = (saddle_value is not None
                       and hist["saddle_escape_step"] is None
                       and loss < saddle_value)
            if escaped:
                hist["saddle_escape_step"] = t
            if tel.enabled:
                cohort_set = {int(i) for i in cohort}
                wdelta = [
                    (float(worker_delta[i]) if i in cohort_set else None)
                    for i in range(m)
                ] if worker_delta is not None else None
                suspicion = tracker.update(keep=worker_keep,
                                           norms=worker_norms)
                tel.round(RoundRecord(
                    step=t, runtime=self.runtime_label, loss=loss,
                    grad_norm=gn,
                    model_decrease=(None if prev_loss is None
                                    else prev_loss - loss),
                    uplink_delta=delta_hat, k=k_live, k_changed=k_changed,
                    saddle_escape=escaped,
                    rejected=rejected_workers,
                    attack=self.attack.name, alpha=self.attack.alpha,
                    wire_uplink_bits=msg_bits * len(cohort),
                    wire_downlink_bits=(bps["downlink"] if arrivals else 0),
                    center_bytes=self.center_bytes_per_round(),
                    agg_kernel=self._agg_kernel_label(),
                    cohort_size=len(cohort), n_arrivals=len(arrivals),
                    queue_depth=queue.depth,
                    participation=acfg.participation,
                    arrival_staleness=ages,
                    worker_bits=paid_bits,
                    worker_delta=wdelta,
                    worker_keep=worker_keep,
                    worker_norms=worker_norms,
                    worker_staleness=worker_staleness,
                    suspicion=suspicion,
                    byzantine_true=(
                        planted_byzantine_ids(m, self._attack_rule.alpha)
                        if self._attack_rule.kind != "none" else None
                    ),
                ), name="newton.round")
                tel.observe("async.queue_depth", queue.depth)
                for age in ages:
                    tel.observe("async.staleness", age)
                prev_loss = loss
            if hit_tol:
                break
        hist.update(ledger.snapshot())
        return w, hist
