"""Deterministic seeded event scheduler for the asynchronous runtime.

One scheduler instance owns every host-side random decision the async
round loop makes — which workers participate this round (cohort
sampling), how many rounds a sent message lags (staleness), and the
packet faults (drop / duplicate).  All of it is derived from counter-mode
RNG streams keyed on ``(seed, decision-kind, round, worker, copy)`` via
numpy's Philox bit generator, so

* the same ``(seed, round)`` always yields the same cohort — on any
  host, in any process, regardless of what was sampled before
  (reproducibility is a pure function of the key, not of call order);
* distinct decision kinds never share a stream (a different staleness
  cap cannot change who participates);
* nothing here touches JAX PRNG keys — the device-side randomness
  (compressors, attacks) keeps the synchronous runtime's exact key
  structure, which is what makes the degenerate async config bit-exact
  with it.

The message-buffer half (:class:`Message` / :class:`MessageQueue`) is
the per-node mailbox: sends are pushed with an absolute arrival round,
and ``pop_due(t)`` drains that round's arrivals in a deterministic
order — ``(send_round, worker, copy)`` — so aggregation over the
arrival stack is reproducible even when lags interleave workers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# stream salts: one per decision kind, so streams never collide
_SALT_COHORT = 0x11
_SALT_LAG = 0x22
_SALT_DROP = 0x33
_SALT_DUP = 0x44


def _rng(seed: int, salt: int, *key: int) -> np.random.Generator:
    """Counter-mode generator for one decision: keyed, never sequential."""
    ss = np.random.SeedSequence(entropy=(int(seed), int(salt), *map(int, key)))
    return np.random.Generator(np.random.Philox(ss))


def cohort_size(m: int, participation: float) -> int:
    """Per-round cohort size: ⌈nothing⌉ — round(p·m), floored at 1 so a
    round is never a guaranteed no-op."""
    return max(1, int(round(float(participation) * m)))


def sample_cohort(seed: int, round_idx: int, m: int,
                  participation: float) -> np.ndarray:
    """The sorted worker ids participating in round ``round_idx``.

    Sampled without replacement from ``range(m)``; a pure function of
    ``(seed, round_idx, m, participation)``.  ``participation=1.0``
    returns every worker.
    """
    c = cohort_size(m, participation)
    if c >= m:
        return np.arange(m)
    rng = _rng(seed, _SALT_COHORT, round_idx)
    return np.sort(rng.choice(m, size=c, replace=False))


@dataclasses.dataclass
class Message:
    """One in-flight uplink packet: a worker's EF-compressed update.

    ``payload`` is the reconstructed update the center will aggregate;
    ``ef_row`` is the candidate per-worker channel/EF21 state row
    produced by the send — the center commits it on the packet's FIRST
    arrival (``version`` guards re-commits: duplicates and out-of-order
    older sends never roll the committed state back).
    """

    worker: int
    send_round: int
    version: int          # the worker's send counter (== send_round here)
    copy: int             # 0 = original, 1 = the duplicated packet
    payload: object       # jax (d,) array
    ef_row: Optional[object] = None   # candidate EF state row, or None

    def sort_key(self):
        return (self.send_round, self.worker, self.copy)


class MessageQueue:
    """Per-round arrival mailbox over all simulated nodes.

    Host-side and deterministic: messages are pushed with an absolute
    arrival round and drained with :meth:`pop_due`, which returns the
    round's arrivals sorted by ``(send_round, worker, copy)``.
    """

    def __init__(self):
        self._pending: list[tuple[int, Message]] = []

    def push(self, arrival_round: int, msg: Message) -> None:
        self._pending.append((int(arrival_round), msg))

    def pop_due(self, round_idx: int) -> list[Message]:
        due = [m for (arr, m) in self._pending if arr <= round_idx]
        self._pending = [(arr, m) for (arr, m) in self._pending
                         if arr > round_idx]
        return sorted(due, key=Message.sort_key)

    @property
    def depth(self) -> int:
        """In-flight messages still buffered (the worker-queue depth the
        telemetry histogram tracks)."""
        return len(self._pending)


class EventScheduler:
    """All per-round scheduling decisions, derived from one seed.

    ``cohort(t)`` — who computes/sends in round t;
    ``lag(t, i, copy)`` — rounds message (t, i, copy) spends in flight,
    uniform over ``{0, …, staleness}``;
    ``dropped(t, i, copy)`` / ``duplicated(t, i)`` — packet faults with
    the configured probabilities.  Every decision is independent and
    reproducible (see module doc).
    """

    def __init__(self, seed: int, m: int, *, participation: float = 1.0,
                 staleness: int = 0, drop: float = 0.0,
                 duplicate: float = 0.0):
        self.seed = int(seed)
        self.m = int(m)
        self.participation = float(participation)
        self.staleness = int(staleness)
        self.drop = float(drop)
        self.duplicate = float(duplicate)

    def cohort(self, t: int) -> np.ndarray:
        return sample_cohort(self.seed, t, self.m, self.participation)

    def lag(self, t: int, worker: int, copy: int = 0) -> int:
        if self.staleness <= 0:
            return 0
        rng = _rng(self.seed, _SALT_LAG, t, worker, copy)
        return int(rng.integers(0, self.staleness + 1))

    def dropped(self, t: int, worker: int, copy: int = 0) -> bool:
        if self.drop <= 0.0:
            return False
        if self.drop >= 1.0:
            return True
        rng = _rng(self.seed, _SALT_DROP, t, worker, copy)
        return bool(rng.random() < self.drop)

    def duplicated(self, t: int, worker: int) -> bool:
        if self.duplicate <= 0.0:
            return False
        if self.duplicate >= 1.0:
            return True
        rng = _rng(self.seed, _SALT_DUP, t, worker)
        return bool(rng.random() < self.duplicate)
