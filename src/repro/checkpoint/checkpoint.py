"""Pytree checkpointing: flat-key .npz + json metadata.

Device-agnostic (arrays are pulled to host), restartable mid-run, and
round-trips arbitrary nested dict pytrees — enough substrate for the train
driver without an external dependency.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

_SEP = "/"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}__{i}{_SEP}"))
    else:
        arr = np.asarray(tree)
        if arr.dtype.kind not in "fiub" or str(arr.dtype) == "bfloat16":
            # numpy .npz can't round-trip ml_dtypes (bfloat16 etc.) — store
            # as fp32 (lossless for bf16); load_checkpoint casts back.
            arr = arr.astype(np.float32)
        out[prefix.rstrip(_SEP)] = arr
    return out


def save_checkpoint(path: str, params: Any, step: int, extra: dict | None = None):
    os.makedirs(path, exist_ok=True)
    flat = _flatten(jax.device_get(params))
    np.savez(os.path.join(path, f"step_{step:08d}.npz"), **flat)
    meta = {"step": step, **(extra or {})}
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [
        int(f[len("step_") : -len(".npz")])
        for f in os.listdir(path)
        if f.startswith("step_") and f.endswith(".npz")
    ]
    return max(steps) if steps else None


def load_checkpoint(path: str, like: Any, step: int | None = None):
    """Restore into the structure of ``like`` (a template pytree)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    data = np.load(os.path.join(path, f"step_{step:08d}.npz"))

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}{_SEP}") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            vals = [rebuild(v, f"{prefix}__{i}{_SEP}") for i, v in enumerate(tree)]
            return type(tree)(vals)
        arr = data[prefix.rstrip(_SEP)]
        return jax.numpy.asarray(arr, dtype=tree.dtype)

    return rebuild(like), step
