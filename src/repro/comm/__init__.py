"""Comm — the unified worker↔center communication layer.

Every transmission in both runtimes routes through a
:class:`~repro.comm.channel.Channel`: compressor (resolved once),
per-sender EF/EF21 state as an explicit pytree, the Byzantine-injection
hook, and exact integer wire accounting via
:class:`~repro.comm.ledger.WireLedger`.

* :mod:`repro.comm.channel` — :class:`VectorChannel` (flat ``(m, d)``
  senders, the paper-faithful runtime) and :class:`TreeChannel`
  (worker-stacked / parameter pytrees, the mesh runtime).
* :mod:`repro.comm.ledger` — host-side exact-int uplink/downlink totals.

See ``src/repro/comm/README.md`` for the channel diagram.
"""
from .channel import DOWNLINK, UPLINK, Channel, TreeChannel, VectorChannel
from .ledger import WireLedger

__all__ = [
    "Channel",
    "DOWNLINK",
    "TreeChannel",
    "UPLINK",
    "VectorChannel",
    "WireLedger",
]
