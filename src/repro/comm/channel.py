"""The unified communication-channel layer (worker↔center wire).

Every transmission in both runtimes — the worker→center update uplink,
the Remark-5 gradient round, and the center→worker broadcast downlink —
goes through a :class:`Channel`.  A channel owns, in one place, what the
seed code hand-rolled twice with diverging semantics:

* **direction** — ``"uplink"`` (m senders → center) or ``"downlink"``
  (center → workers, broadcast);
* **compressor** — a :mod:`repro.compression` spec, resolved ONCE at
  construction (never per trace);
* **error-feedback state** — per-sender EF / EF21 memory as an explicit
  pytree threaded through ``transmit`` (state in, state out), so it
  jits, vmaps, donates, and takes sharding constraints like any other
  carry;
* **Byzantine-injection hook** — update-level attacks corrupt the
  *reconstructed* payloads (Byzantine workers send arbitrary bytes, so
  compression grants them no protection);
* **exact wire accounting** — ``bits_per_round`` is a static Python int
  the driver feeds a :class:`repro.comm.WireLedger`.  The int comes from
  the compressor's ``wire_bits``, which describes the PAYLOAD (k values +
  k indices for sparsifiers), not the producing implementation — the
  gridded Pallas top-k kernel's blocked slice layout re-arranges how the
  payload is produced, never what crosses the wire, so ``topk_kernel``
  and ``topk`` account identically for the same (d, k).

Two layouts mirror the two runtimes:

* :class:`VectorChannel` — senders hold flat ``(d,)`` vectors, stacked
  ``(n_senders, d)`` (the paper-faithful LIBSVM runtime,
  :mod:`repro.core.newton`);
* :class:`TreeChannel`  — senders hold parameter pytrees; uplink leaves
  carry a leading worker axis of size m, downlink leaves are the param
  shapes (the mesh runtime, :mod:`repro.core.distributed`).  An optional
  ``constrain`` callable re-applies GSPMD sharding constraints to the
  reconstructed tree *and* the feedback state.

``transmit`` is pure and jit-safe; channels hold no traced state.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..compression import TreeCompressor, make_compressor, make_error_feedback

UPLINK = "uplink"
DOWNLINK = "downlink"


def _tree_size(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def _measured_delta(sent, received):
    """Achieved contraction δ̂ = 1 − ‖x − C(x)‖²/‖x‖² over all senders'
    payloads (pytrees or arrays) — the per-round norm ratio the adaptive
    schedule consumes.  1 where nothing was sent (zero signal)."""
    num = 0.0
    den = 0.0
    for x, r in zip(jax.tree_util.tree_leaves(sent),
                    jax.tree_util.tree_leaves(received)):
        x32 = x.astype(jnp.float32)
        r32 = r.astype(jnp.float32)
        num = num + jnp.sum((x32 - r32) ** 2)
        den = den + jnp.sum(x32 * x32)
    return jnp.where(den > 0, 1.0 - num / jnp.maximum(den, 1e-30), 1.0)


def _per_sender_delta(sent, received):
    """Per-sender achieved contraction δ̂_i over an (m, d) stack — one
    norm ratio per row, same definition as :func:`_measured_delta` but
    never summed across senders (the forensic per-worker view; the
    global δ̂ stays its own reduction so existing trajectories are
    bit-identical)."""
    x32 = sent.astype(jnp.float32)
    r32 = received.astype(jnp.float32)
    num = jnp.sum((x32 - r32) ** 2, axis=-1)
    den = jnp.sum(x32 * x32, axis=-1)
    return jnp.where(den > 0, 1.0 - num / jnp.maximum(den, 1e-30), 1.0)


class Channel:
    """Shared direction/feedback bookkeeping for both layouts."""

    def __init__(self, direction: str, n_senders: int, *,
                 error_feedback: str = "none", damping: float = 1.0,
                 attack_hook: Optional[Callable] = None):
        if direction not in (UPLINK, DOWNLINK):
            raise ValueError(f"direction must be uplink/downlink, got {direction!r}")
        self.direction = direction
        self.n_senders = int(n_senders)
        self.error_feedback = error_feedback
        self.damping = damping
        self.attack_hook = attack_hook

    @property
    def is_uplink(self) -> bool:
        return self.direction == UPLINK

    def _ledger_kwargs(self, bits: int) -> dict:
        return {"uplink" if self.is_uplink else "downlink": bits}


class VectorChannel(Channel):
    """Flat-vector senders: ``x`` is ``(n_senders, d)`` (or ``(d,)`` when
    ``n_senders == 1``) — the :class:`DistributedCubicNewton` layout.

    ``spec`` is resolved against ``d`` once, here; ``None`` means a
    full-precision wire (identity passthrough, 32 bits/coordinate).
    """

    def __init__(self, direction: str, spec, d: int, n_senders: int = 1, *,
                 error_feedback: str = "none", damping: float = 1.0,
                 attack_hook: Optional[Callable] = None,
                 value_bits: int = 32):
        super().__init__(direction, n_senders, error_feedback=error_feedback,
                         damping=damping, attack_hook=attack_hook)
        self.d = int(d)
        self.value_bits = value_bits
        self.compressor = make_compressor(spec, d)
        self.feedback = (
            make_error_feedback(error_feedback, self.compressor, damping)
            if self.compressor is not None else None
        )

    # -- state ----------------------------------------------------------
    def init_state(self):
        """Fresh per-sender EF memory; a zero-width array when the channel
        carries no feedback (keeps the carry pytree structure stable)."""
        width = self.d if self.feedback is not None else 0
        shape = (self.n_senders, width) if self.n_senders > 1 else (width,)
        return jnp.zeros(shape, jnp.float32)

    # -- the wire -------------------------------------------------------
    def transmit(self, x, state, *, key=None, attack_key=None,
                 measure: bool = False, per_sender: bool = False):
        """One round: compress/EF every sender's vector, reconstruct at
        the receiver, inject Byzantine payloads.  Returns ``(x̂, state')``
        — or ``(x̂, state', δ̂)`` with ``measure=True``, where δ̂ is the
        achieved contraction measured BEFORE Byzantine injection (so the
        adaptive schedule sees the compressor, not the attacker).

        ``per_sender=True`` (requires ``measure``) appends a fourth
        output: the (n_senders,) per-sender δ̂ — the forensic per-worker
        view.  The global δ̂ is still computed by its own reduction
        (:func:`_measured_delta`), so trajectories that only consume it
        stay bit-identical whether or not per-sender measurement is on.
        """
        x_sent = x
        comp, fb = self.compressor, self.feedback
        if comp is not None:
            if self.n_senders > 1:
                keys = (jax.random.split(key, self.n_senders)
                        if key is not None else None)
                if fb is not None:
                    x, state = jax.vmap(
                        lambda xi, ei, ki: fb.apply(xi, ei, key=ki)
                    )(x, state, keys)
                else:
                    x = jax.vmap(lambda xi, ki: comp.roundtrip(xi, key=ki))(
                        x, keys
                    )
            else:
                if fb is not None:
                    x, state = fb.apply(x, state, key=key)
                else:
                    x = comp.roundtrip(x, key=key)
        delta = _measured_delta(x_sent, x) if measure else None
        worker_delta = (_per_sender_delta(
            x_sent.reshape(self.n_senders, -1), x.reshape(self.n_senders, -1)
        ) if measure and per_sender else None)
        if self.attack_hook is not None and attack_key is not None:
            x = self.attack_hook(attack_key, x)
        if measure:
            if per_sender:
                return x, state, delta, worker_delta
            return x, state, delta
        return x, state

    # -- sparse receive path --------------------------------------------
    @property
    def supports_sparse_receive(self) -> bool:
        """True when :meth:`transmit_sparse` carries this channel's full
        semantics: an uplink whose compressor ships (value, index)
        payloads, with no error-feedback state to densify against and no
        update attack to apply to reconstructed vectors."""
        from ..compression.sparsify import _SparseCompressor

        return (self.is_uplink
                and isinstance(self.compressor, _SparseCompressor)
                and self.feedback is None
                and self.attack_hook is None)

    def transmit_sparse(self, x, state, *, key=None, measure: bool = False,
                        per_sender: bool = False):
        """Payload-shaped receive: compress every sender's vector but hand
        the receiver the wire payloads themselves — values ``(m, k)`` and
        int32 indices ``(m, k)`` — instead of reconstructing m dense
        ``(d,)`` vectors.  Returns ``((vals, idx), state')`` (or with δ̂
        appended under ``measure=True``, computed from the payload norms:
        for the distinct-index wire format ‖C(x)‖² = Σ vals², so
        δ̂ = 1 − (‖x‖² − Σ vals²)/‖x‖² without densifying).

        Exactly what crosses the wire is unchanged — same payload, same
        ``bits_per_round`` — only the receiver-side representation
        differs, so :class:`WireLedger` accounting is identical to
        :meth:`transmit`.  Only valid when
        :attr:`supports_sparse_receive` (asserted)."""
        assert self.supports_sparse_receive, (
            "transmit_sparse needs an uplink sparse compressor with no "
            "error feedback and no attack hook — use transmit"
        )
        comp = self.compressor
        if self.n_senders > 1:
            keys = (jax.random.split(key, self.n_senders)
                    if key is not None else None)
            vals, idx = jax.vmap(lambda xi, ki: comp.compress(xi, key=ki))(
                x, keys
            )
        else:
            vals, idx = comp.compress(x, key=key)
            vals, idx = vals[None], idx[None]
        idx = idx.astype(jnp.int32)
        if measure:
            x32 = x.astype(jnp.float32)
            den = jnp.sum(x32 * x32)
            num = den - jnp.sum(vals.astype(jnp.float32) ** 2)
            delta = jnp.where(den > 0, 1.0 - num / jnp.maximum(den, 1e-30),
                              1.0)
            if per_sender:
                # same payload-norm identity, one ratio per sender
                xw = x32.reshape(self.n_senders, -1)
                den_w = jnp.sum(xw * xw, axis=-1)
                num_w = den_w - jnp.sum(
                    vals.astype(jnp.float32) ** 2, axis=-1
                )
                worker_delta = jnp.where(
                    den_w > 0, 1.0 - num_w / jnp.maximum(den_w, 1e-30), 1.0
                )
                return (vals, idx), state, delta, worker_delta
            return (vals, idx), state, delta
        return (vals, idx), state

    # -- accounting -----------------------------------------------------
    def bits_per_round(self) -> int:
        """Exact bits one round costs on this channel (static Python int):
        m payloads uplink, ONE broadcast payload downlink."""
        payload = (self.compressor.wire_bits(self.d)
                   if self.compressor is not None
                   else self.value_bits * self.d)
        return payload * (self.n_senders if self.is_uplink else 1)

    def record(self, ledger, rounds: int = 1) -> None:
        ledger.record(rounds=rounds, label=self.direction,
                      **self._ledger_kwargs(self.bits_per_round() * rounds))


class TreeChannel(Channel):
    """Pytree senders — the mesh runtime layout.

    Uplink trees are worker-stacked (every leaf ``(m, …)``); downlink
    trees are parameter-shaped.  The per-leaf compressor comes from a
    :class:`repro.compression.TreeCompressor` (static k per leaf), and
    ``constrain`` re-applies the caller's sharding constraints to the
    reconstructed tree and the EF state so GSPMD sees the same layout as
    the uncompressed step.
    """

    def __init__(self, direction: str, spec, n_senders: int = 1, *,
                 error_feedback: str = "none", damping: float = 1.0,
                 attack_hook: Optional[Callable] = None,
                 constrain: Optional[Callable] = None,
                 value_bits: int = 32):
        super().__init__(direction, n_senders, error_feedback=error_feedback,
                         damping=damping, attack_hook=attack_hook)
        self.value_bits = value_bits
        if spec is None or isinstance(spec, TreeCompressor):
            self.tree_compressor = spec
        else:
            self.tree_compressor = TreeCompressor(spec)
        self.constrain = constrain or (lambda t: t)
        self._ef_cache: dict[int, object] = {}
        self.stateful = (self.tree_compressor is not None
                         and error_feedback not in (None, False, "none"))

    def _ef(self, d: int):
        if d not in self._ef_cache:
            self._ef_cache[d] = make_error_feedback(
                self.error_feedback,
                self.tree_compressor.leaf_compressor(d),
                self.damping,
            )
        return self._ef_cache[d]

    # -- state ----------------------------------------------------------
    def init_state(self, params):
        """Per-sender EF memory mirroring the transmitted tree (float32;
        uplink leaves gain the leading worker axis).  ``()`` when the
        channel is stateless — stable carry structure either way."""
        if not self.stateful:
            return ()
        lead = (self.n_senders,) if self.n_senders > 1 else ()
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(lead + p.shape, jnp.float32), params
        )

    # -- the wire -------------------------------------------------------
    def transmit(self, tree, state, *, key=None, attack_key=None,
                 measure: bool = False):
        """Like :meth:`VectorChannel.transmit`, over pytrees; with
        ``measure=True`` also returns the pre-attack achieved δ̂."""
        tree_sent = tree
        tc = self.tree_compressor
        if tc is not None:
            # a stateful channel's init_state is never empty, so the None
            # check alone distinguishes the stateless wrapper's carry
            if self.stateful and state is not None:
                tree, state = self._feedback_roundtrip(tree, state, key)
                state = self.constrain(state)
            elif self.n_senders > 1:
                tree = tc.roundtrip_worker_tree(tree, key, self.n_senders)
            else:
                tree = tc.roundtrip_tree(tree, key)
            tree = self.constrain(tree)
        delta = _measured_delta(tree_sent, tree) if measure else None
        if self.attack_hook is not None and attack_key is not None:
            tree = self.constrain(self.attack_hook(attack_key, tree))
        if measure:
            return tree, state, delta
        return tree, state

    def _feedback_roundtrip(self, tree, state, key):
        """EF/EF21 per leaf per sender; state leaves keep the transmitted
        leaf shapes (so one sharding constraint covers both)."""
        n = self.n_senders
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        st_leaves = jax.tree_util.tree_leaves(state)
        assert len(st_leaves) == len(leaves), "feedback state/tree mismatch"
        keys = jax.random.split(key, n) if (key is not None and n > 1) else None
        out, new_st = [], []
        for i, (x, e) in enumerate(zip(leaves, st_leaves)):
            d = x.size // n
            ef = self._ef(d)
            if n > 1:
                leaf_keys = (jax.vmap(lambda kk: jax.random.fold_in(kk, i))(keys)
                             if keys is not None else None)
                xhat, e_new = jax.vmap(
                    lambda xi, ei, ki: ef.apply(xi, ei, key=ki)
                )(x.reshape(n, d), e.reshape(n, d), leaf_keys)
            else:
                ki = jax.random.fold_in(key, i) if key is not None else None
                xhat, e_new = ef.apply(x.reshape(d), e.reshape(d), key=ki)
            out.append(xhat.reshape(x.shape).astype(x.dtype))
            new_st.append(e_new.reshape(e.shape).astype(jnp.float32))
        return (jax.tree_util.tree_unflatten(treedef, out),
                jax.tree_util.tree_unflatten(
                    jax.tree_util.tree_structure(state), new_st))

    # -- accounting -----------------------------------------------------
    def bits_per_round(self, params) -> int:
        """Exact bits one round costs, given the (unstacked) param tree."""
        if self.tree_compressor is not None:
            payload = self.tree_compressor.wire_bits_tree(params, 1)
        else:
            payload = self.value_bits * _tree_size(params)
        return payload * (self.n_senders if self.is_uplink else 1)

    def record(self, ledger, params, rounds: int = 1) -> None:
        ledger.record(rounds=rounds, label=self.direction,
                      **self._ledger_kwargs(self.bits_per_round(params) * rounds))
