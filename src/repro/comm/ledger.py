"""Exact integer wire-cost accounting for the worker↔center channels.

A :class:`WireLedger` is a *host-side* accumulator: plain Python ints
(arbitrary precision, so an int64-and-beyond accumulator with no float32
mantissa loss), never traced.  Channels know their static bits-per-round
(payload shapes are fixed at trace time), and the run driver records one
ledger entry per *executed* step — the jit-traced program never carries a
wire-bit value, so nothing lossy (the old ``jnp.float32(bits)`` metric)
or overflow-prone (int32 constants) enters the computation.

Conventions
-----------
* **uplink** — worker→center payloads; m senders pay m payloads per round.
* **downlink** — center→worker broadcast; the payload is counted ONCE per
  round (broadcast medium), not once per receiver.
* ``rounds`` counts communication rounds (a Remark-5 step is two).
* payload bits are implementation-independent: a compressor that
  assembles its payload in blocks (the sharded top-k kernel) records the
  same exact int as the single-tile/XLA path for the same (d, k).

Telemetry: every ``record`` call doubles as a per-transmit ``wire``
event and ``snapshot`` as a ``ledger`` event on the global
:mod:`repro.telemetry` stream when it is enabled (exact same ints, so
the stream's wire events sum to the ledger totals by construction —
``python -m repro.telemetry validate --check-wire`` asserts it).  Each
ledger generation carries a ``(pid, ledger_id)`` pair identifying its
events — ``ledger_id`` alone is only process-unique, and a parallel
sweep pool's workers each start their own ``itertools.count``, so the
validator must (and does) group by the pair.  Every wire event also
carries a per-ledger sequence id ``seq`` and the snapshot the total
record count, making validation **order-insensitive**: events may be
interleaved, buffered, or merged out of order across async channels and
pool workers — the sums and the seq-completeness check
(``sorted(seqs) == range(n_records)``) are invariant to ordering.
"""
from __future__ import annotations

import itertools
import os

from ..telemetry import get_telemetry

_LEDGER_IDS = itertools.count()


class WireLedger:
    """Exact integer uplink/downlink bit totals, accumulated host-side."""

    __slots__ = ("uplink_bits", "downlink_bits", "rounds", "ledger_id",
                 "pid", "_seq")

    def __init__(self) -> None:
        self.reset()

    def reset(self) -> None:
        """Zero the totals and start a FRESH ledger generation: a new
        ``ledger_id`` and seq stream, so back-to-back runs reusing one
        ledger object never mix their events under a shared id (which
        would break the validator's per-generation seq completeness)."""
        self.ledger_id: int = next(_LEDGER_IDS)
        self.pid: int = os.getpid()
        self._seq: int = 0
        self.uplink_bits: int = 0
        self.downlink_bits: int = 0
        self.rounds: int = 0

    def record(self, *, uplink: int = 0, downlink: int = 0,
               rounds: int = 1, label: str = None) -> None:
        """Add one (or ``rounds``) communication rounds' exact bit cost.
        ``label`` only annotates the telemetry wire event (e.g. which
        channel paid), never the accounting."""
        self.uplink_bits += int(uplink)
        self.downlink_bits += int(downlink)
        self.rounds += int(rounds)
        seq = self._seq
        self._seq = seq + 1
        tel = get_telemetry()
        if tel.enabled:
            tel.wire(ledger_id=self.ledger_id, uplink=int(uplink),
                     downlink=int(downlink), rounds=int(rounds),
                     label=label, seq=seq, pid=self.pid)

    @property
    def total_bits(self) -> int:
        return self.uplink_bits + self.downlink_bits

    def snapshot(self) -> dict:
        """Plain-dict view (exact ints) for histories / JSON; also the
        end-of-run ``ledger`` telemetry event the wire events must sum
        to (run drivers call this exactly once per finished run)."""
        snap = {
            "uplink_bits": self.uplink_bits,
            "downlink_bits": self.downlink_bits,
            "total_bits": self.total_bits,
            "rounds": self.rounds,
        }
        tel = get_telemetry()
        if tel.enabled:
            tel.ledger_snapshot(ledger_id=self.ledger_id, snapshot=snap,
                                n_records=self._seq, pid=self.pid)
        return snap

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (f"WireLedger(uplink={self.uplink_bits}, "
                f"downlink={self.downlink_bits}, rounds={self.rounds})")
