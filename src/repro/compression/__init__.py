"""Compression — δ-approximate worker→center communication (paper §1's
third pillar; COMRADE's compressed second-order updates).

* :mod:`repro.compression.base` — the δ-approximate :class:`Compressor`
  protocol, identity compressor, wire-format bit accounting.
* :mod:`repro.compression.sparsify` — top-k / random-k sparsification
  (static k, jit/vmap-safe; optional fused Pallas top-k kernel path).
* :mod:`repro.compression.sign` — scaled-sign (sign+norm), 1 bit/coord.
* :mod:`repro.compression.quant` — block-wise int8 quantization.
* :mod:`repro.compression.error_feedback` — EF / EF21 memory wrappers so
  biased compressors retain convergence.
* :mod:`repro.compression.tree` — pytree-aware per-leaf compression for
  the mesh runtime (static shapes per leaf, worker-stacked vmap layout).
* :mod:`repro.compression.adaptive` — adaptive top-k (host-side k
  schedule driven by the gradient-norm plateau / measured δ).
* :mod:`repro.compression.registry` — spec strings ("topk:0.1", …) →
  compressors, the form configs carry.
"""
from .adaptive import AdaptiveTopK
from .base import Compressor, Identity, index_bits
from .error_feedback import EF21, ErrorFeedback, make_error_feedback
from .quant import BlockInt8
from .registry import COMPRESSORS, make_compressor
from .sign import SignNorm
from .sparsify import RandomK, TopK
from .tree import TreeCompressor

__all__ = [
    "AdaptiveTopK",
    "BlockInt8",
    "COMPRESSORS",
    "Compressor",
    "EF21",
    "ErrorFeedback",
    "Identity",
    "RandomK",
    "SignNorm",
    "TopK",
    "TreeCompressor",
    "index_bits",
    "make_compressor",
    "make_error_feedback",
]
