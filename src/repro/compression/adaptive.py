"""Adaptive top-k: spend bits only when the iterate needs them.

:class:`AdaptiveTopK` is a top-k compressor whose k follows a *host-side*
schedule between steps.  Under jit every shape must be static, so k is a
plain Python int; the schedule mutates it between executions and the
owning runtime re-traces its step (a handful of retraces over a run —
see ``DistributedCubicNewton.run``).

Policy (the ROADMAP's "grow/shrink with the measured δ or the
gradient-norm plateau"):

* **measured δ̂ below target ⇒ grow immediately** — the channel reports
  its achieved per-round contraction (one norm ratio, see
  ``Channel.transmit(measure=True)``); δ̂ < ``delta_target`` means the
  wire is starving the iterate *right now*, so k doubles toward
  ``k_max`` without waiting out the plateau window.
* **plateau ⇒ grow** — if the gradient norm improved by less than
  ``plateau_tol`` (relative) over the last ``patience`` steps, the
  compression error is what is stalling progress (near saddles the true
  update is small and top-k truncation dominates): double k toward
  ``k_max``.
* **fast progress ⇒ shrink** — if the iterate is moving well (relative
  improvement above ``shrink_tol`` over the window) *and* the measured δ
  comfortably exceeds the target, halve k back toward ``k_min``: the
  cheap payload was already enough.

``schedule_update`` returns True when k changed, which is the caller's
signal to rebuild its jitted step.  ``wire_bits`` always reflects the
*current* k, so per-step ledger entries stay exact; ``delta_bound`` is
the conservative k_min/d floor that holds for every phase of the run.
"""
from __future__ import annotations

from collections import deque

from .sparsify import TopK


class AdaptiveTopK(TopK):
    """Top-k with a host-side k schedule in [k_min, k_max]."""

    def __init__(self, d: int, k_min: int, k_max: int, *,
                 value_bits: int = 32, plateau_tol: float = 0.05,
                 shrink_tol: float = 0.5, patience: int = 3,
                 delta_target: float = 0.5, use_kernel: bool = False):
        assert 1 <= k_min <= k_max <= d
        # use_kernel routes every compress through the fused Pallas path;
        # k is a static argument of the (single-tile OR sharded) launch,
        # so each schedule move re-traces the kernel at the new k — the
        # owning runtime's rebuild-on-change contract covers both paths
        super().__init__(k_min, value_bits=value_bits, use_kernel=use_kernel)
        self.d = int(d)
        self.k_min = int(k_min)
        self.k_max = int(k_max)
        self.plateau_tol = plateau_tol
        self.shrink_tol = shrink_tol
        self.patience = int(patience)
        self.delta_target = delta_target
        self._grad_norms: deque = deque(maxlen=self.patience + 1)
        self.name = f"adaptive_topk[{self.k_min},{self.k_max}]"

    # -- schedule (host-side; call between executed steps) --------------
    def schedule_update(self, *, grad_norm: float | None = None,
                        measured_delta: float | None = None) -> bool:
        """Feed the measured signals; returns True when k changed (the
        caller must then re-trace anything that baked the old k in).
        Every k move is a telemetry ``adaptive_k`` event carrying the
        old/new k and the reason — the shape key of the re-trace the
        caller is about to pay."""
        old_k = self.k
        reason = None
        if grad_norm is not None:
            self._grad_norms.append(float(grad_norm))
        # δ-targeted control: the channel's measured contraction fell below
        # the target — grow NOW (no patience window; the wire is the
        # bottleneck this very round).
        if (measured_delta is not None
                and measured_delta < self.delta_target
                and self.k < self.k_max):
            self.k = min(self.k_max, 2 * self.k)
            self._grad_norms.clear()
            self._emit_move(old_k, "delta_below_target", measured_delta)
            return True
        if len(self._grad_norms) == self._grad_norms.maxlen:
            first, last = self._grad_norms[0], self._grad_norms[-1]
            rel = (first - last) / max(first, 1e-30)
            if rel < self.plateau_tol and self.k < self.k_max:
                self.k = min(self.k_max, 2 * self.k)
                reason = "plateau"
            elif (rel > self.shrink_tol and self.k > self.k_min
                  and (measured_delta is None
                       or measured_delta >= self.delta_target)):
                self.k = max(self.k_min, self.k // 2)
                reason = "fast_progress"
            if self.k != old_k:
                self._grad_norms.clear()
                self._emit_move(old_k, reason, measured_delta)
        return self.k != old_k

    def _emit_move(self, old_k: int, reason: str,
                   measured_delta: float | None) -> None:
        """One ``adaptive_k`` telemetry event per schedule move (no-op
        when telemetry is disabled — one attribute check)."""
        from ..telemetry import get_telemetry

        tel = get_telemetry()
        if tel.enabled:
            tel.event("adaptive_k", k_from=int(old_k), k_to=int(self.k),
                      reason=reason,
                      **({} if measured_delta is None
                         else {"measured_delta": float(measured_delta)}))

    # -- δ accounting: the guarantee must hold for the whole run --------
    def delta_bound(self, d):
        return min(self.k_min, d) / d
