"""δ-approximate compressor protocol (Definition 2 of the paper / COMRADE).

An operator ``C : R^d → R^d`` is a *δ-approximate compressor* if

    ‖C(x) − x‖² ≤ (1 − δ)‖x‖²     for all x, some δ ∈ (0, 1].

Every compressor here factors ``C`` into an explicit wire format:
``compress`` produces the *payload* a worker would actually transmit
(values+indices, sign bits+scale, int8 blocks+scales, …) and
``decompress`` is the center's reconstruction.  This split is what makes
exact wire-cost accounting possible: :meth:`Compressor.wire_bits` is the
payload size in bits under the natural encoding, a static Python int the
benchmarks can sum without running anything.

All array methods are pure jnp with static output shapes, so they are
safe under ``jit``/``vmap`` (workers are a vmapped leading axis in both
runtimes).  Randomized compressors take a PRNG ``key``; deterministic
ones ignore it.
"""
from __future__ import annotations

import jax.numpy as jnp


class Compressor:
    """Base class: subclasses implement compress/decompress/wire_bits.

    ``delta_bound(d)`` is the *guaranteed* contraction factor δ (a lower
    bound that holds for every input, or in expectation for randomized
    compressors — see each subclass); ``delta(x)`` measures the achieved
    contraction on a concrete vector.
    """

    name: str = "identity"

    # -- wire format ---------------------------------------------------
    def compress(self, x, *, key=None):
        """x: (d,) → payload pytree of arrays (static shapes)."""
        raise NotImplementedError

    def decompress(self, payload, d: int):
        """payload → dense (d,) reconstruction C(x)."""
        raise NotImplementedError

    def wire_bits(self, d: int) -> int:
        """Exact uplink payload size in bits for a d-vector (static)."""
        raise NotImplementedError

    # -- δ accounting --------------------------------------------------
    def delta_bound(self, d: int) -> float:
        """Guaranteed δ with ‖C(x) − x‖² ≤ (1 − δ)‖x‖²."""
        raise NotImplementedError

    def roundtrip(self, x, *, key=None):
        """C(x) = decompress(compress(x)) — what the center sees."""
        return self.decompress(self.compress(x, key=key), x.shape[-1])

    def delta(self, x, *, key=None):
        """Measured contraction 1 − ‖x − C(x)‖²/‖x‖² (1 where x = 0)."""
        x32 = x.astype(jnp.float32)
        r = self.roundtrip(x, key=key).astype(jnp.float32)
        sq = jnp.sum(x32 * x32)
        err = jnp.sum((x32 - r) ** 2)
        return jnp.where(sq > 0, 1.0 - err / jnp.maximum(sq, 1e-30), 1.0)


class Identity(Compressor):
    """No compression — full-precision d-vector on the wire (δ = 1)."""

    name = "none"

    def __init__(self, value_bits: int = 32):
        self.value_bits = value_bits

    def compress(self, x, *, key=None):
        return (x,)

    def decompress(self, payload, d):
        return payload[0]

    def wire_bits(self, d):
        return d * self.value_bits

    def delta_bound(self, d):
        return 1.0


def index_bits(d: int) -> int:
    """Bits for one coordinate index in [0, d)."""
    return max(1, (d - 1).bit_length())
