"""Error feedback (memory) for biased compressors.

Biased operators (top-k, random-k, scaled sign) do not average out their
compression error, so some form of memory is needed for convergence.
Two schemes, both functional (state in, state out) so they jit/vmap over
the worker axis like everything else, and both degenerating to the exact
update when the compressor is lossless (parity at k = d):

* :class:`ErrorFeedback` — classic EF [Seide et al. 2014 / Stich et al.
  2018 / Karimireddy et al. 2019]: transmit x̂ = C(x + e), carry
  e ← θ·(x + e − x̂).  The residual is re-injected next round; θ < 1
  damps the stale-direction momentum the raw scheme exhibits on
  fast-moving (Newton) iterates.

* :class:`EF21` — markers-style tracking [Richtárik et al. 2021]:
  every sender keeps an estimate h of its own signal and transmits only
  the compressed *innovation* c = C(x − θ·h); both ends update
  h ← θ·h + c, and the center aggregates the h's.  On deterministic
  second-order updates this tracks far better than classic EF (the
  innovation shrinks as the iterate converges); θ slightly below 1
  keeps the tracker contractive when x moves superlinearly.  Measured
  on the w8a robust-regression workload (top-k, k/d = 0.1): classic EF
  ≈ 3.5× the uncompressed round count, EF21(θ=0.75) ≈ 1.7×.

Wire cost is the base compressor's payload in both schemes — the memory
never ships (the center mirrors h from the received innovations).
"""
from __future__ import annotations

import jax.numpy as jnp

from .base import Compressor


class _FeedbackBase:
    """Shared shape: wrap a compressor, keep one (d,) memory per sender."""

    def __init__(self, base: Compressor, damping: float = 1.0):
        assert 0.0 < damping <= 1.0
        self.base = base
        self.damping = damping

    def init(self, d: int):
        """Fresh memory for one d-dimensional sender."""
        return jnp.zeros((d,), jnp.float32)

    def apply(self, x, e, *, key=None):
        """One round: (signal, memory) → (x̂ seen by the center, memory')."""
        raise NotImplementedError

    def wire_bits(self, d: int) -> int:
        return self.base.wire_bits(d)

    def delta_bound(self, d: int) -> float:
        return self.base.delta_bound(d)


class ErrorFeedback(_FeedbackBase):
    """Classic EF: x̂ = C(x + e), e ← θ(x + e − x̂)."""

    def __init__(self, base: Compressor, damping: float = 1.0):
        super().__init__(base, damping)
        self.name = f"ef({base.name})"

    def apply(self, x, e, *, key=None):
        xc = x.astype(jnp.float32) + e
        xhat = self.base.roundtrip(xc, key=key).astype(jnp.float32)
        return xhat.astype(x.dtype), self.damping * (xc - xhat)


class EF21(_FeedbackBase):
    """EF21 tracking: x̂ = θh + C(x − θh), h ← x̂ (memory IS the estimate)."""

    def __init__(self, base: Compressor, damping: float = 1.0):
        super().__init__(base, damping)
        self.name = f"ef21({base.name})"

    def apply(self, x, e, *, key=None):
        c = self.base.roundtrip(
            x.astype(jnp.float32) - self.damping * e, key=key
        ).astype(jnp.float32)
        xhat = self.damping * e + c
        return xhat.astype(x.dtype), xhat


def make_error_feedback(
    variant, base: Compressor, damping: float = 1.0
) -> _FeedbackBase | None:
    """"none"/False → None, "ef" → classic, "ef21"/True → tracking."""
    if variant in (None, False, "none"):
        return None
    if variant == "ef":
        return ErrorFeedback(base, damping)
    if variant in (True, "ef21"):
        return EF21(base, damping)
    raise ValueError(f"unknown error-feedback variant {variant!r}")
