"""Block-wise int8 quantization — 8 bits/coordinate + one fp32 scale per block.

Each block of ``block`` coordinates is scaled by its max-|x| and rounded
to int8 in [−127, 127].  Per-coordinate error ≤ scale/2 = max|x_b|/254,
and max|x_b|² ≤ ‖x_b‖², so per block

    ‖x_b − C(x_b)‖² ≤ block · ‖x_b‖² / 4·127²

giving the uniform bound δ ≥ 1 − block/64516 (≈ 0.998 at block = 128).
Tail blocks are zero-padded; padded zeros quantize exactly, so padding
adds no error (and is not counted on the wire).
"""
from __future__ import annotations

import jax.numpy as jnp

from .base import Compressor


class BlockInt8(Compressor):
    def __init__(self, block: int = 128, scale_bits: int = 32):
        assert 1 <= block <= 64516, "block too large for a nontrivial δ"
        self.block = int(block)
        self.scale_bits = scale_bits
        self.name = f"int8({self.block})"

    def _nblocks(self, d):
        return -(-d // self.block)

    def compress(self, x, *, key=None):
        d = x.shape[-1]
        nb = self._nblocks(d)
        xb = jnp.pad(x.astype(jnp.float32), (0, nb * self.block - d))
        xb = xb.reshape(nb, self.block)
        amax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
        return q, scale[:, 0]

    def decompress(self, payload, d):
        q, scale = payload
        xb = q.astype(jnp.float32) * scale[:, None]
        return xb.reshape(-1)[:d]

    def wire_bits(self, d):
        return d * 8 + self._nblocks(d) * self.scale_bits

    def delta_bound(self, d):
        return 1.0 - min(self.block, d) / (4.0 * 127.0**2)
