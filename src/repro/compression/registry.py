"""Compressor registry: spec strings → Compressor instances.

Configs carry compressors as frozen-dataclass-friendly *spec strings*:

    "none"          identity (full precision)
    "topk:0.1"      top-k, k = max(1, round(0.1·d))   (ratio form)
    "topk:32"       top-k, k = 32                     (absolute form)
    "topk_kernel:r" top-k via the fused Pallas kernel (single-tile
                    launch for d ≤ 1408, sharded grid-over-blocks launch
                    beyond — auto-selected by d, any model scale;
                    bit-exact with "topk", identical wire bits)
    "randk:0.1"     random-k (same k grammar)
    "signnorm"      scaled sign, 1 bit/coordinate
    "int8"          block-wise int8, block = 128
    "int8:64"       block-wise int8, block = 64
    "adaptive_topk:0.05:0.5"
                    top-k whose k follows a host-side schedule between
                    k_min = 0.05·d and k_max = 0.5·d (grad-norm plateau
                    grows k, fast progress shrinks it — see adaptive.py);
                    both bounds take the same ratio/absolute k grammar
    "adaptive_topk_kernel:0.05:0.5"
                    the same schedule over the fused Pallas kernel path
                    (each k move re-traces the kernel launch)

``make_compressor(spec, d)`` resolves the string against the vector
dimension d (needed to turn ratios into static k); passing an already-
constructed :class:`Compressor` returns it unchanged.
"""
from __future__ import annotations

from typing import Optional, Union

from .adaptive import AdaptiveTopK
from .base import Compressor, Identity
from .quant import BlockInt8
from .sign import SignNorm
from .sparsify import RandomK, TopK

COMPRESSORS = ("none", "topk", "topk_kernel", "randk", "signnorm", "int8",
               "adaptive_topk", "adaptive_topk_kernel")


def _resolve_k(arg: str, d: int) -> int:
    v = float(arg)
    # ratio form needs a decimal point ("1.0" → k = d, "1" → k = 1)
    if "." in arg and 0 < v <= 1:
        return max(1, min(d, int(round(v * d))))
    return max(1, min(d, int(v)))


def make_compressor(
    spec: Optional[Union[str, Compressor]], d: int
) -> Optional[Compressor]:
    """Resolve a spec string (or pass through a Compressor / None)."""
    if spec is None or isinstance(spec, Compressor):
        return spec
    head, _, arg = spec.partition(":")
    if head == "none":
        return Identity()
    if head in ("topk", "topk_kernel"):
        k = _resolve_k(arg or "0.1", d)
        return TopK(k, use_kernel=head == "topk_kernel")
    if head == "randk":
        return RandomK(_resolve_k(arg or "0.1", d))
    if head in ("adaptive_topk", "adaptive_topk_kernel"):
        lo, _, hi = arg.partition(":")
        k_min = _resolve_k(lo or "0.05", d)
        k_max = _resolve_k(hi or "0.5", d)
        return AdaptiveTopK(d, min(k_min, k_max), max(k_min, k_max),
                            use_kernel=head == "adaptive_topk_kernel")
    if head == "signnorm":
        return SignNorm()
    if head == "int8":
        return BlockInt8(int(arg) if arg else 128)
    raise ValueError(
        f"unknown compressor spec {spec!r}; expected one of {COMPRESSORS}"
    )
