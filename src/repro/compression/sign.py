"""Scaled-sign (sign+norm) compression — 1 bit per coordinate + one scale.

    C(x) = (‖x‖₁ / d) · sign(x)

the ℓ₁-scaled signSGD operator [Karimireddy et al. 2019, cited by
COMRADE].  Error identity (sign(0) := 0 only shrinks the error):

    ‖x − C(x)‖² ≤ ‖x‖² − ‖x‖₁²/d   ⇒   δ = ‖x‖₁² / (d‖x‖²) ≥ 1/d,

with δ → 1 for dense, equal-magnitude vectors.  The measured
:meth:`delta` is the quantity to report; 1/d is only the adversarial
floor.
"""
from __future__ import annotations

import jax.numpy as jnp

from .base import Compressor


class SignNorm(Compressor):
    name = "signnorm"

    def __init__(self, scale_bits: int = 32):
        self.scale_bits = scale_bits

    def compress(self, x, *, key=None):
        x32 = x.astype(jnp.float32)
        scale = jnp.sum(jnp.abs(x32)) / x.shape[-1]
        return jnp.sign(x32).astype(jnp.int8), scale

    def decompress(self, payload, d):
        signs, scale = payload
        return scale * signs.astype(jnp.float32)

    def wire_bits(self, d):
        return d + self.scale_bits

    def delta_bound(self, d):
        return 1.0 / d
