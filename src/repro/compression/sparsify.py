"""Sparsifying compressors: top-k and random-k coordinate selection.

Both transmit k (value, index) pairs and reconstruct a dense vector with
zeros elsewhere.  ``k`` is a static Python int, so ``jax.lax.top_k`` and
the scatter keep fixed shapes under jit/vmap.

* Top-k is a deterministic δ-approximate compressor with the tight
  worst-case bound δ = k/d (the k largest magnitudes carry at least a
  k/d fraction of the energy).
* Random-k (no rescaling) satisfies the same δ = k/d *in expectation
  over the key*; individual draws can do worse, which is exactly why the
  error-feedback wrapper exists.  Its wire advantage: the index set is
  derivable from a shared 32-bit seed, so only the k values ship.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import Compressor, index_bits


class _SparseCompressor(Compressor):
    """Shared wire format: k (value, index) pairs → dense-with-zeros."""

    def decompress(self, payload, d):
        vals, idx = payload
        return jnp.zeros((d,), vals.dtype).at[idx].set(vals)

    def delta_bound(self, d):
        return min(self.k, d) / d


class TopK(_SparseCompressor):
    """Keep the k largest-magnitude coordinates (ties → lowest index).

    ``use_kernel=True`` routes compression through the fused Pallas
    kernel :func:`repro.kernels.topk_compress`, which auto-selects its
    launch by d: the single-tile threshold-select + pack up to
    d = 1408, the sharded grid-over-coordinate-blocks launch (two-pass
    radix-select global threshold) for model-scale vectors.  Both are
    bit-exact with the default ``jax.lax.top_k`` path — same selected
    support, same payload, same :meth:`wire_bits` — so the kernel flag
    never changes accounted wire cost.  The default is the XLA path,
    which is what XLA fuses best off-TPU.
    """

    def __init__(self, k: int, value_bits: int = 32, use_kernel: bool = False):
        assert k >= 1, "top-k needs k ≥ 1"
        self.k = int(k)
        self.value_bits = value_bits
        self.use_kernel = use_kernel
        self.name = f"topk({self.k})"

    def compress(self, x, *, key=None):
        k = min(self.k, x.shape[-1])
        if self.use_kernel:
            from ..kernels import topk_compress

            return topk_compress(x, k)
        _, idx = jax.lax.top_k(jnp.abs(x), k)
        # canonical index-ascending order (matches the kernel's packing)
        idx = jnp.sort(idx)
        return x[idx], idx

    def wire_bits(self, d):
        k = min(self.k, d)
        return k * (self.value_bits + index_bits(d))


class RandomK(_SparseCompressor):
    """Transmit k uniformly-chosen coordinates (index set from the key).

    Biased and only δ = k/d in expectation — pair with
    :class:`repro.compression.ErrorFeedback` for convergence.
    """

    def __init__(self, k: int, value_bits: int = 32):
        assert k >= 1, "random-k needs k ≥ 1"
        self.k = int(k)
        self.value_bits = value_bits
        self.name = f"randk({self.k})"

    def compress(self, x, *, key=None):
        assert key is not None, "RandomK.compress needs a PRNG key"
        d = x.shape[-1]
        k = min(self.k, d)
        idx = jax.random.choice(key, d, (k,), replace=False)
        idx = jnp.sort(idx)
        return x[idx], idx

    def wire_bits(self, d):
        # indices are re-derivable from a shared 32-bit seed
        return min(self.k, d) * self.value_bits + 32
