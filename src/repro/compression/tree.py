"""Pytree-aware compression (the mesh runtime's parameter trees).

A :class:`TreeCompressor` applies a per-leaf compressor with a *static*
k per leaf (ratio resolved against each leaf's flattened size), so every
shape stays fixed under jit — ``jax.lax.top_k`` with a Python-int k, a
fixed scatter, fixed int8 block counts.  Two layouts:

* ``roundtrip_tree``  — the whole tree is one sender (leaves are the
  parameter shapes);
* ``roundtrip_worker_tree`` — every leaf carries a leading worker axis
  of size m (the shape :func:`repro.core.make_train_step` produces) and
  each worker's slice is compressed independently via ``vmap``.

Wire accounting mirrors the layouts: ``wire_bits_tree`` is bits per
sender per round, summed over leaves.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import Compressor
from .registry import make_compressor


class TreeCompressor:
    """Per-leaf δ-approximate compression over arbitrary pytrees."""

    def __init__(self, spec):
        """``spec``: a registry string ("topk:0.1", "signnorm", …) or a
        factory ``d -> Compressor`` for custom per-leaf construction."""
        self.spec = spec
        self._cache: dict[int, Compressor] = {}
        self.name = spec if isinstance(spec, str) else getattr(spec, "name", "custom")

    def leaf_compressor(self, d: int) -> Compressor:
        if d not in self._cache:
            if callable(self.spec) and not isinstance(self.spec, str):
                self._cache[d] = self.spec(d)
            else:
                self._cache[d] = make_compressor(self.spec, d)
        return self._cache[d]

    # -- single-sender layout ------------------------------------------
    def roundtrip_tree(self, tree, key):
        """C(x) leaf-by-leaf; one sender, leaves flattened to vectors."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        out = []
        for i, x in enumerate(leaves):
            comp = self.leaf_compressor(x.size)
            r = comp.roundtrip(
                x.reshape(-1), key=jax.random.fold_in(key, i)
            )
            out.append(r.reshape(x.shape).astype(x.dtype))
        return jax.tree_util.tree_unflatten(treedef, out)

    # -- worker-stacked layout -----------------------------------------
    def roundtrip_worker_tree(self, tree, key, m: int):
        """Leaves are (m, …); compress each worker's slice independently."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        keys = jax.random.split(key, m)
        out = []
        for i, x in enumerate(leaves):
            d = x.size // m
            comp = self.leaf_compressor(d)
            leaf_keys = jax.vmap(lambda kk: jax.random.fold_in(kk, i))(keys)
            r = jax.vmap(lambda xi, ki: comp.roundtrip(xi, key=ki))(
                x.reshape(m, d), leaf_keys
            )
            out.append(r.reshape(x.shape).astype(x.dtype))
        return jax.tree_util.tree_unflatten(treedef, out)

    # -- wire accounting -----------------------------------------------
    def wire_bits_tree(self, tree, m: int = 1) -> int:
        """Uplink bits one sender pays per round (static Python int).

        ``m > 1``: leaves are worker-stacked and the per-sender vector is
        each leaf's trailing dims."""
        bits = 0
        for x in jax.tree_util.tree_leaves(tree):
            d = x.size // m
            bits += self.leaf_compressor(d).wire_bits(d)
        return bits

    def delta_bound_tree(self, tree, m: int = 1) -> float:
        """Worst leaf δ — the contraction the whole-tree roundtrip obeys."""
        return min(
            self.leaf_compressor(x.size // m).delta_bound(x.size // m)
            for x in jax.tree_util.tree_leaves(tree)
        )
