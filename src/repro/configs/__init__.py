"""Config registry: ``get_config("<arch>")`` / ``--arch <id>``."""
from .base import INPUT_SHAPES, InputShape, ModelConfig
from .codeqwen15_7b import CONFIG as CODEQWEN15_7B
from .deepseek_moe_16b import CONFIG as DEEPSEEK_MOE_16B
from .gemma3_27b import CONFIG as GEMMA3_27B
from .internlm2_20b import CONFIG as INTERNLM2_20B
from .internvl2_76b import CONFIG as INTERNVL2_76B
from .llama3_405b import CONFIG as LLAMA3_405B, VARIANT_SWA as LLAMA3_405B_SWA
from .mamba2_780m import CONFIG as MAMBA2_780M
from .paper_workloads import PAPER_WORKLOADS, PaperWorkload
from .phi35_moe import CONFIG as PHI35_MOE
from .recurrentgemma_9b import CONFIG as RECURRENTGEMMA_9B
from .whisper_medium import CONFIG as WHISPER_MEDIUM

ARCHS = {
    c.name: c
    for c in (
        MAMBA2_780M,
        INTERNVL2_76B,
        LLAMA3_405B,
        CODEQWEN15_7B,
        INTERNLM2_20B,
        WHISPER_MEDIUM,
        RECURRENTGEMMA_9B,
        DEEPSEEK_MOE_16B,
        GEMMA3_27B,
        PHI35_MOE,
    )
}
VARIANTS = {LLAMA3_405B_SWA.name: LLAMA3_405B_SWA}


def get_config(name: str) -> ModelConfig:
    if name in ARCHS:
        return ARCHS[name]
    if name in VARIANTS:
        return VARIANTS[name]
    raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")


__all__ = [
    "ARCHS",
    "INPUT_SHAPES",
    "InputShape",
    "ModelConfig",
    "PAPER_WORKLOADS",
    "PaperWorkload",
    "VARIANTS",
    "get_config",
]
