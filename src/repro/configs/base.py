"""ModelConfig — the single config record every architecture instantiates.

One ``<arch>.py`` per assigned architecture fills this in with the exact
published numbers (source cited in each file).  ``reduced()`` produces the
CPU smoke-test variant mandated by the brief (≤2 layers, d_model ≤ 512,
≤4 experts).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads

    # attention pattern ------------------------------------------------
    window: int = 0                     # >0 ⇒ sliding-window on local layers
    local_global_pattern: Tuple[int, int] = (0, 1)  # (n_local, n_global) per group
    rope_theta: float = 1e4

    # MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    router_z_weight: float = 1e-3

    # SSM (mamba2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    conv_width: int = 4

    # hybrid (recurrentgemma): block pattern over layer types -------------
    # 'R' = RG-LRU recurrent block, 'A' = local-attention block
    hybrid_pattern: str = ""

    # enc-dec (whisper) ----------------------------------------------------
    encoder_layers: int = 0
    encoder_len: int = 1500   # stub frame-embedding length

    # vlm -----------------------------------------------------------------
    num_prefix_tokens: int = 0  # stub patch/frame embeddings prepended

    # numerics / memory -----------------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    q_chunk: int = 512
    kv_chunk: int = 512
    logits_dtype: str = "float32"

    # citation for the config numbers
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding/lm_head can
        shard evenly over the 16-way model axis (MaxText-style padding).
        Targets always stay < vocab_size; padded logits are harmless."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic? (DESIGN.md §4 skip policy for long_500k)."""
        if self.family in ("ssm", "hybrid"):
            return True
        # dense archs qualify only with a sliding-window component
        return self.window > 0 and self.local_global_pattern[0] > 0

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs are decoders (whisper = enc-dec)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family/wiring, tiny sizes."""
        hd = min(self.resolved_head_dim, 64)
        nh = min(self.num_heads, 4)
        nkv = max(1, min(self.num_kv_heads, nh))
        nkv = nh // max(1, nh // nkv)  # keep divisibility
        pat = self.hybrid_pattern[:3] if self.hybrid_pattern else ""
        if pat:
            n_layers = len(pat)
        elif self.local_global_pattern[0] > 0:
            # keep one full local:global unit so the smoke test exercises both
            n_layers = sum(self.local_global_pattern)
        else:
            n_layers = 2
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=n_layers,
            d_model=min(self.d_model, 256),
            num_heads=nh,
            num_kv_heads=nkv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            num_shared_experts=min(self.num_shared_experts, 1),
            top_k=min(self.top_k, 2) if self.top_k else 0,
            # generous capacity so smoke decode == smoke forward (no drops)
            capacity_factor=4.0,
            expert_d_ff=min(self.expert_d_ff, 128) if self.expert_d_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=min(self.ssm_head_dim, 16),
            ssm_chunk=16,
            window=min(self.window, 16) if self.window else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_len=16 if self.encoder_layers else self.encoder_len,
            num_prefix_tokens=min(self.num_prefix_tokens, 8),
            hybrid_pattern=pat,
            q_chunk=16,
            kv_chunk=16,
            dtype="float32",
            remat=False,
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
