"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B].

32L, d_model=4096, 32 heads with kv=32 (full MHA — qwen1.5 arch),
d_ff=13440, vocab=92416.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    rope_theta=1e6,
    source="CodeQwen1.5 [hf:Qwen/CodeQwen1.5-7B]",
)
