"""deepseek-moe-16b — fine-grained MoE [arXiv:2401.06066].

28L, d_model=2048, 16 heads (kv=16), vocab=102400.  Experts: 64 routed
(top-6) + 2 shared, expert d_ff=1408 (fine-grained segmentation).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,             # routed-expert hidden size (assignment spec)
    expert_d_ff=1408,
    vocab_size=102400,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    source="DeepSeekMoE [arXiv:2401.06066]",
)
