"""gemma3-27b [hf:google/gemma-3-1b-pt family].

62L in 5:1 local:global superblocks (local window 1024), d_model=5376,
32 heads (GQA kv=16, head_dim=128), d_ff=21504, vocab=262144, 128k context.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    window=1024,
    local_global_pattern=(5, 1),
    rope_theta=1e6,
    source="Gemma 3 [hf:google/gemma-3-1b-pt]",
)
