"""internlm2-20b [arXiv:2403.17297].

48L, d_model=6144, 48 heads (GQA kv=8, head_dim=128), d_ff=16384,
vocab=92544.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
    rope_theta=1e6,
    source="InternLM2 [arXiv:2403.17297]",
)
