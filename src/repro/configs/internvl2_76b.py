"""internvl2-76b — InternViT-6B (stub) + LLaMA-backbone LM [arXiv:2404.16821].

Backbone: 80L, d_model=8192, 64 heads (GQA kv=8), d_ff=28672,
vocab=128256.  The vision tower + MLP projector are a STUB per the brief:
``input_specs`` supplies 1024 precomputed patch embeddings at d_model (one
high-res tile's worth after pixel-shuffle), consumed through a learned
projector inside the model.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    num_prefix_tokens=1024,
    source="InternVL2 [arXiv:2404.16821]",
)
