"""llama3-405b [arXiv:2407.21783].

126L, d_model=16384, 128 heads (GQA kv=8, head_dim=128), d_ff=53248,
vocab=128256, RoPE θ=500000.

``VARIANT_SWA`` adds a 4096 sliding window on every layer — the optional
dense-arch sub-quadratic variant that unlocks the ``long_500k`` shape
(DESIGN.md §4); recorded separately in EXPERIMENTS.md.
"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=5e5,
    source="Llama 3 [arXiv:2407.21783]",
)

VARIANT_SWA = dataclasses.replace(
    CONFIG, name="llama3-405b-swa", window=4096, local_global_pattern=(1, 0)
)
