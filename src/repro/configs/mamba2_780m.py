"""mamba2-780m — SSD (state-space duality) [arXiv:2405.21060].

48L, d_model=1536, attention-free (d_ff=0: the SSD mixer is the whole
block), vocab=50280 (GPT-NeoX tokenizer), ssm_state=128, expand=2,
head_dim=64 ⇒ 48 SSD heads.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=1,       # unused: attention-free
    num_kv_heads=1,
    d_ff=0,            # no MLP — SSD mixer only (per assignment: d_ff=0)
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    source="SSD / Mamba-2 [arXiv:2405.21060]",
)
