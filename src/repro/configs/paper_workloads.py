"""The paper's own workloads (§6): logistic regression and non-convex
robust linear regression on LIBSVM-shaped data.

Offline container ⇒ synthetic twins of a9a (d=123, n≈32k, 70/30 split) and
w8a (d=300, n_train≈50k, n_test≈15k); see repro.data.synthetic.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperWorkload:
    name: str
    problem: str          # "logistic" | "robust_regression"
    dim: int
    n_train: int
    n_test: int
    m_workers: int = 20   # paper partitions data over 20 machines
    reg_lambda: float = 1.0
    M: float = 10.0
    eta: float = 1.0


A9A_LOGISTIC = PaperWorkload("a9a-logistic", "logistic", 123, 22400, 9600)
A9A_ROBUST = PaperWorkload("a9a-robust", "robust_regression", 123, 22400, 9600)
W8A_LOGISTIC = PaperWorkload("w8a-logistic", "logistic", 300, 49749, 14951)
W8A_ROBUST = PaperWorkload("w8a-robust", "robust_regression", 300, 49749, 14951)

PAPER_WORKLOADS = {
    w.name: w for w in (A9A_LOGISTIC, A9A_ROBUST, W8A_LOGISTIC, W8A_ROBUST)
}
