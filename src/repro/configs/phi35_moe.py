"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct].

32L, d_model=4096, 32 heads (GQA kv=8), vocab=32064.  16 experts, top-2,
expert d_ff=6400.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    expert_d_ff=6400,
    vocab_size=32064,
    num_experts=16,
    num_shared_experts=0,
    top_k=2,
    source="Phi-3.5-MoE [hf:microsoft/Phi-3.5-MoE-instruct]",
)
