"""recurrentgemma-9b — Griffin: RG-LRU + local attention, 2:1 [arXiv:2402.19427].

38L in repeating (recurrent, recurrent, local-attention) superblocks,
d_model=4096, attention blocks: 16 heads MQA (kv=1, head_dim=256),
window=2048, d_ff=12288, vocab=256000.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    window=2048,
    hybrid_pattern="RRL",   # 2 RG-LRU : 1 local-attn (L uses cfg.window)
    source="RecurrentGemma / Griffin [arXiv:2402.19427]",
)
