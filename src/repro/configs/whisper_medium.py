"""whisper-medium [arXiv:2212.04356] — encoder-decoder, conv frontend STUB.

24 encoder + 24 decoder layers, d_model=1024, 16 heads (MHA, kv=16),
d_ff=4096, vocab=51865.  The mel-spectrogram + 2×conv frontend is a stub:
``input_specs`` supplies 1500 precomputed frame embeddings (30 s of audio
after 2× conv downsampling) at d_model.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,          # decoder layers
    encoder_layers=24,
    encoder_len=1500,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    source="Whisper [arXiv:2212.04356]",
)
