"""Core — the paper's contribution.

* :mod:`repro.core.cubic` — cubic sub-problem solvers (exact / Algorithm 2 /
  matrix-free HVP).
* :mod:`repro.core.aggregation` — norm-trim (the paper) + robust baselines.
* :mod:`repro.core.attacks` — the four Byzantine attacks of §6 + saddle attack.
* :mod:`repro.core.newton` — Algorithm 1, paper-faithful simulated cluster.
* :mod:`repro.core.distributed` — Algorithm 1 at TPU-pod scale (vmap-of-grad
  workers, HVP cubic solves, masked-all-reduce trimming).
* :mod:`repro.core.byzantine_pgd` — ByzantinePGD [YCKB19] baseline.

Both runtimes transmit exclusively through :mod:`repro.comm` channels —
uplink (``NewtonConfig.compressor`` / ``DistributedNewtonConfig.compressor``
or ``make_train_step(compressor=…)``), downlink broadcast
(``downlink_compressor``), and the Remark-5 gradient round
(``NewtonConfig.grad_compressor``) — with exact integer wire accounting
on a :class:`repro.comm.WireLedger`.  Error feedback at mesh scale comes
from :func:`make_stateful_train_step`.
"""
from .aggregation import (
    AGGREGATORS,
    contribution_keep,
    coordinate_median,
    coordinate_median_tree,
    krum,
    krum_tree,
    mean,
    mean_tree,
    norm_trim,
    norm_trim_tree,
    trimmed_mean,
    trimmed_mean_tree,
)
from .attacks import ALL_ATTACKS, LABEL_ATTACKS, UPDATE_ATTACKS, byzantine_mask
from .byzantine_pgd import ByzantinePGD, PGDConfig
from .cubic import (
    CubicParams,
    cubic_model_value,
    cubic_residual,
    make_hvp,
    solve_cubic_exact,
    solve_cubic_gd,
    solve_cubic_hvp,
)
from .distributed import (
    DistributedNewtonConfig,
    build_channels,
    make_robust_sgd_step,
    make_stateful_train_step,
    make_train_step,
)
from .newton import AttackConfig, DistributedCubicNewton, NewtonConfig

__all__ = [
    "AGGREGATORS",
    "ALL_ATTACKS",
    "AttackConfig",
    "ByzantinePGD",
    "CubicParams",
    "DistributedCubicNewton",
    "DistributedNewtonConfig",
    "LABEL_ATTACKS",
    "NewtonConfig",
    "PGDConfig",
    "UPDATE_ATTACKS",
    "build_channels",
    "byzantine_mask",
    "coordinate_median",
    "coordinate_median_tree",
    "cubic_model_value",
    "cubic_residual",
    "krum",
    "krum_tree",
    "make_hvp",
    "make_robust_sgd_step",
    "make_stateful_train_step",
    "make_train_step",
    "mean",
    "mean_tree",
    "norm_trim",
    "norm_trim_tree",
    "trimmed_mean_tree",
    "solve_cubic_exact",
    "solve_cubic_gd",
    "solve_cubic_hvp",
    "trimmed_mean",
]
