"""Core — the paper's contribution.

* :mod:`repro.core.cubic` — cubic sub-problem solvers (exact / Algorithm 2 /
  matrix-free HVP).
* :mod:`repro.core.aggregation` — norm-trim (the paper) + robust baselines.
* :mod:`repro.core.attacks` — the four Byzantine attacks of §6 + saddle attack.
* :mod:`repro.core.newton` — Algorithm 1, paper-faithful simulated cluster.
* :mod:`repro.core.distributed` — Algorithm 1 at TPU-pod scale (vmap-of-grad
  workers, HVP cubic solves, masked-all-reduce trimming).
* :mod:`repro.core.byzantine_pgd` — ByzantinePGD [YCKB19] baseline.

Both runtimes accept a δ-approximate compressor for the worker→center
uplink (``NewtonConfig.compressor`` / ``DistributedNewtonConfig.compressor``
or ``make_train_step(compressor=…)``) — see :mod:`repro.compression`.
"""
from .aggregation import (
    AGGREGATORS,
    coordinate_median,
    krum,
    mean,
    norm_trim,
    norm_trim_tree,
    trimmed_mean,
)
from .attacks import ALL_ATTACKS, LABEL_ATTACKS, UPDATE_ATTACKS, byzantine_mask
from .byzantine_pgd import ByzantinePGD, PGDConfig
from .cubic import (
    CubicParams,
    cubic_model_value,
    cubic_residual,
    make_hvp,
    solve_cubic_exact,
    solve_cubic_gd,
    solve_cubic_hvp,
)
from .distributed import (
    DistributedNewtonConfig,
    make_robust_sgd_step,
    make_train_step,
    wire_bits_per_step,
)
from .newton import AttackConfig, DistributedCubicNewton, NewtonConfig

__all__ = [
    "AGGREGATORS",
    "ALL_ATTACKS",
    "AttackConfig",
    "ByzantinePGD",
    "CubicParams",
    "DistributedCubicNewton",
    "DistributedNewtonConfig",
    "LABEL_ATTACKS",
    "NewtonConfig",
    "PGDConfig",
    "UPDATE_ATTACKS",
    "byzantine_mask",
    "coordinate_median",
    "cubic_model_value",
    "cubic_residual",
    "krum",
    "make_hvp",
    "make_robust_sgd_step",
    "make_train_step",
    "mean",
    "norm_trim",
    "norm_trim_tree",
    "solve_cubic_exact",
    "solve_cubic_gd",
    "solve_cubic_hvp",
    "trimmed_mean",
    "wire_bits_per_step",
]
