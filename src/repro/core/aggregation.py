"""Robust aggregation rules at the center machine.

The paper's rule (Algorithm 1, step 6) is **norm-based thresholding**: sort
workers by ‖s_i‖, keep the smallest ``(1−β)m``, average the survivors.  We
also implement the aggregators ByzantinePGD [YCKB19] uses (coordinate-wise
median / trimmed mean) both as baselines and for the comparison harness, plus
plain mean (non-robust reference).

All aggregators take updates stacked on a leading worker axis:
``updates: (m, d)`` (or a pytree whose leaves have a leading ``m`` axis for
the tree variants) and return the aggregated ``(d,)`` update.

Runtimes do not call these functions directly any more: they resolve an
:class:`repro.api.aggregators.Aggregator` from a spec string
(``"norm_trim:0.25"``, ``"krum:2"``, …) once at build time and call it at
both aggregation sites.  This module stays the pure math layer.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _stack_tree(tree, m):
    """Worker-stacked pytree → (m, D) float32 matrix (concat of leaves)."""
    return jnp.concatenate(
        [x.reshape(m, -1).astype(jnp.float32)
         for x in jax.tree_util.tree_leaves(tree)],
        axis=1,
    )


def mean(updates):
    return jnp.mean(updates, axis=0)


def mean_tree(updates_tree):
    return jax.tree_util.tree_map(lambda x: jnp.mean(x, axis=0), updates_tree)


@partial(jax.jit, static_argnames=("beta",))
def norm_trim(updates, beta: float):
    """Paper's rule: keep the ``(1-beta)m`` smallest-norm updates, average.

    Returns (aggregate, keep_mask).  Implemented with a rank threshold so it
    jits with static shapes (no boolean gathering).
    """
    m = updates.shape[0]
    n_keep = max(1, int(round((1.0 - beta) * m)))
    norms = jnp.linalg.norm(updates.reshape(m, -1), axis=1)
    # rank of each worker's norm (0 = smallest); ties broken by index order.
    order = jnp.argsort(norms)
    ranks = jnp.argsort(order)
    keep = (ranks < n_keep).astype(updates.dtype)
    agg = (keep[:, None] * updates.reshape(m, -1)).sum(0) / n_keep
    return agg.reshape(updates.shape[1:]), keep


def norm_trim_tree(updates_tree, beta: float):
    """norm_trim on a pytree with a leading worker axis on every leaf."""
    m = jax.tree_util.tree_leaves(updates_tree)[0].shape[0]
    n_keep = max(1, int(round((1.0 - beta) * m)))
    sq = jax.tree_util.tree_map(
        lambda x: jnp.sum(x.reshape(m, -1).astype(jnp.float32) ** 2, axis=1),
        updates_tree,
    )
    norms = jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq))
    order = jnp.argsort(norms)
    ranks = jnp.argsort(order)
    keep = (ranks < n_keep).astype(jnp.float32)

    def agg_leaf(x):
        w = keep.reshape((m,) + (1,) * (x.ndim - 1)).astype(x.dtype)
        return (w * x).sum(0) / n_keep

    return jax.tree_util.tree_map(agg_leaf, updates_tree), keep


def contribution_keep(updates, lo: int, hi: int):
    """Soft keep mask for the coordinate-wise rules: the fraction of
    coordinates where each worker's value ranked inside ``[lo, hi)`` —
    i.e. actually entered the trimmed-mean / median epilogue.  1 means
    every coordinate contributed, 0 means the worker was trimmed away
    everywhere (``rejected_from_keep`` rejects exactly those).  Ties are
    broken by worker index, matching ``jnp.sort``'s stable order."""
    m = updates.shape[0]
    flat = updates.reshape(m, -1)
    order = jnp.argsort(flat, axis=0)
    ranks = jnp.argsort(order, axis=0)
    kept = (ranks >= lo) & (ranks < hi)
    return kept.mean(axis=1).astype(jnp.float32)


def coordinate_median(updates):
    """Coordinate-wise median (ByzantinePGD option)."""
    return jnp.median(updates, axis=0)


def coordinate_median_tree(updates_tree):
    """Coordinate-wise median per leaf of a worker-stacked pytree."""
    return jax.tree_util.tree_map(
        lambda x: jnp.median(x.astype(jnp.float32), axis=0).astype(x.dtype),
        updates_tree,
    )


@partial(jax.jit, static_argnames=("trim_frac",))
def trimmed_mean(updates, trim_frac: float):
    """Coordinate-wise trimmed mean: drop the top/bottom ``trim_frac``·m
    values per coordinate, average the rest (ByzantinePGD's default)."""
    m = updates.shape[0]
    k = int(round(trim_frac * m))
    k = min(k, (m - 1) // 2)
    srt = jnp.sort(updates, axis=0)
    if k == 0:
        return srt.mean(0)
    return srt[k : m - k].mean(0)


def trimmed_mean_tree(updates_tree, trim_frac: float):
    """Coordinate-wise trimmed mean per leaf of a worker-stacked pytree."""
    m = jax.tree_util.tree_leaves(updates_tree)[0].shape[0]
    k = min(int(round(trim_frac * m)), (m - 1) // 2)

    def agg_leaf(x):
        srt = jnp.sort(x.astype(jnp.float32), axis=0)
        kept = srt if k == 0 else srt[k : m - k]
        return kept.mean(0).astype(x.dtype)

    return jax.tree_util.tree_map(agg_leaf, updates_tree)


def krum_select(flat, n_byz: int):
    """Krum's selected worker index for an (m, D) matrix: the update whose
    summed squared distance to its m−f−2 nearest neighbours is smallest."""
    m = flat.shape[0]
    d2 = jnp.sum((flat[:, None, :] - flat[None, :, :]) ** 2, axis=-1)
    k = max(m - n_byz - 2, 1)
    # distance to k nearest others (exclude self-distance 0 via large diag)
    d2 = d2 + jnp.eye(m) * 1e30
    nearest = jnp.sort(d2, axis=1)[:, :k]
    return jnp.argmin(nearest.sum(1))


@partial(jax.jit, static_argnames=("n_byz",))
def krum(updates, n_byz: int):
    """Krum [BMGS17].  Quadratic in m — included as the classic baseline
    the paper's O(m log m) norm sort improves on."""
    m = updates.shape[0]
    return updates[krum_select(updates.reshape(m, -1), n_byz)]


def krum_tree(updates_tree, n_byz: int):
    """Krum over a worker-stacked pytree: score on the concatenated flat
    view, then gather the selected worker's whole tree."""
    m = jax.tree_util.tree_leaves(updates_tree)[0].shape[0]
    j = krum_select(_stack_tree(updates_tree, m), n_byz)
    return jax.tree_util.tree_map(lambda x: x[j], updates_tree), j


AGGREGATORS = {
    "mean": lambda u, **kw: mean(u),
    "norm_trim": lambda u, beta=0.2, **kw: norm_trim(u, beta)[0],
    "coordinate_median": lambda u, **kw: coordinate_median(u),
    "trimmed_mean": lambda u, trim_frac=0.2, **kw: trimmed_mean(u, trim_frac),
    "krum": lambda u, n_byz=2, **kw: krum(u, n_byz),
}
