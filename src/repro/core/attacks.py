"""Byzantine attack library (the four attacks of the paper's §6).

Two families:

* **update-level** — corrupt the update ``s_i`` a Byzantine worker sends:
  - ``gaussian``:  s_i + N(0, σ²)            (Gaussian-noise attack)
  - ``negative``:  −c · s_i, c ∈ (0,1)        (negative-update attack)
* **data-level** — corrupt the worker's *labels* before it computes its
  gradient/Hessian and solves the sub-problem:
  - ``random_label``: train on uniformly random labels
  - ``flipped_label``: train on 1−y (binary) / permuted labels

Attacked worker indices are a static boolean mask so experiments are
reproducible and the distributed step stays shape-static.  A fifth,
``saddle``, implements the *saddle-point attack* the paper is designed to
resist: colluding workers send a common vector that pulls the iterate toward
a saddle direction (the negative-curvature eigenvector scaled up).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def byzantine_mask(m: int, alpha: float) -> jnp.ndarray:
    """First ⌊αm⌋ workers are Byzantine (deterministic, as in the paper's
    experiments where the fraction — not the identity — matters)."""
    n_byz = int(alpha * m)
    return jnp.arange(m) < n_byz


# -------------------- update-level attacks: (m,d) -> (m,d) -----------------


def gaussian_attack(key, updates, mask, sigma=10.0):
    noise = sigma * jax.random.normal(key, updates.shape, updates.dtype)
    return jnp.where(mask.reshape((-1,) + (1,) * (updates.ndim - 1)), updates + noise, updates)


def negative_update_attack(key, updates, mask, c=0.9):
    del key
    return jnp.where(
        mask.reshape((-1,) + (1,) * (updates.ndim - 1)), -c * updates, updates
    )


def saddle_attack(key, updates, mask, direction=None, scale=5.0):
    """Colluding workers all send ``scale · direction`` — a fake descent
    direction toward a saddle (fake-local-minimum construction of §5)."""
    m = updates.shape[0]
    if direction is None:
        direction = jax.random.normal(key, updates.shape[1:], updates.dtype)
        direction = direction / (jnp.linalg.norm(direction) + 1e-12)
    fake = jnp.broadcast_to(scale * direction, updates.shape)
    return jnp.where(mask.reshape((-1,) + (1,) * (updates.ndim - 1)), fake, updates)


UPDATE_ATTACKS: dict[str, Callable] = {
    "none": lambda key, u, mask, **kw: u,
    "gaussian": gaussian_attack,
    "negative": negative_update_attack,
    "saddle": saddle_attack,
}


# -------------------- data-level attacks: labels (m, n) -> (m, n) ----------


def random_label_attack(key, labels, mask, num_classes=2):
    rnd = jax.random.randint(key, labels.shape, 0, num_classes).astype(labels.dtype)
    return jnp.where(mask.reshape((-1,) + (1,) * (labels.ndim - 1)), rnd, labels)


def flipped_label_attack(key, labels, mask, num_classes=2):
    del key
    flipped = (num_classes - 1) - labels
    return jnp.where(
        mask.reshape((-1,) + (1,) * (labels.ndim - 1)), flipped, labels
    )


LABEL_ATTACKS: dict[str, Callable] = {
    "none": lambda key, y, mask, **kw: y,
    "random_label": random_label_attack,
    "flipped_label": flipped_label_attack,
}

ALL_ATTACKS = ("gaussian", "negative", "random_label", "flipped_label")
