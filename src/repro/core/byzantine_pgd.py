"""ByzantinePGD [Yin et al., ICML 2019] — compatibility shim.

The real implementation moved to
:class:`repro.solvers.pgd.ChannelByzantinePGD`: the loop now transmits
every exchange (main rounds AND the R×Q Escape probe rounds) through the
:class:`~repro.comm.VectorChannel` stack with exact
:class:`~repro.comm.WireLedger` billing, and resolves its aggregator and
attack from the :mod:`repro.api` registries — so a spec-named attack
means the same thing here as in both Newton runtimes, closing the old
gap where this class name-dispatched on the legacy ``core.attacks``
config tables.

This module keeps the historical constructor/run surface
(``ByzantinePGD(loss_fn, PGDConfig(...), AttackConfig(...))`` →
``run(w0, X, y, max_rounds=, grad_tol=)`` → ``(w, hist)`` with
``hist["rounds"]``, the Table-1 metric) for existing callers and tests.
New code should go through the facade: ``ExperimentSpec(solver=
"byzantine_pgd", ...)``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional


@dataclasses.dataclass(frozen=True)
class PGDConfig:
    lr: float = 1.0
    R: int = 10           # escape attempts
    r: float = 5.0        # perturbation radius
    Q: int = 10           # GD rounds per escape attempt
    T_th: int = 10        # (kept for config compatibility; unused)
    f_th: float = 1e-3    # function-decrease threshold to accept an escape
    grad_th: float = 1e-4 # "gradient is small" trigger
    aggregator: str = "trimmed_mean"  # legacy name or a registry spec
    trim_frac: float = 0.2
    # channel axes (full-precision wire by default, like the legacy loop)
    compressor: Optional[str] = None
    downlink_compressor: Optional[str] = None
    error_feedback: str = "none"
    ef_damping: float = 0.75

    def aggregator_spec(self) -> str:
        """Map the legacy ``(aggregator, trim_frac)`` pair onto a
        :mod:`repro.api.aggregators` registry spec string."""
        if self.aggregator in ("trimmed_mean", "norm_trim"):
            return f"{self.aggregator}:{self.trim_frac!r}"
        return self.aggregator  # "coordinate_median", "mean", or a spec


class ByzantinePGD:
    """Thin adapter over :class:`repro.solvers.pgd.ChannelByzantinePGD`."""

    def __init__(
        self,
        loss_fn: Callable,
        config: PGDConfig = PGDConfig(),
        attack=None,
    ):
        from ..solvers.pgd import ChannelByzantinePGD, PGDParams

        self.loss_fn = loss_fn
        self.cfg = config
        self.attack = attack
        self.solver = ChannelByzantinePGD(
            loss_fn,
            PGDParams(
                lr=config.lr,
                compressor=config.compressor,
                downlink_compressor=config.downlink_compressor,
                error_feedback=config.error_feedback,
                ef_damping=config.ef_damping,
                R=config.R, r=config.r, Q=config.Q,
                f_th=config.f_th, grad_th=config.grad_th,
            ),
            aggregator=config.aggregator_spec(),
            attack=attack,  # legacy AttackConfig → registry resolve_attack
        )

    @property
    def ledger(self):
        return self.solver.ledger

    def run(self, w0, X, y, max_rounds: int = 2000, grad_tol: float = 1e-3,
            key=None, full_data=None):
        """Run until Escape certifies a second-order stationary point or
        the round budget is exhausted (probe rounds count).  Returns
        ``(w, history)``; ``history["rounds"]`` is the exact number of
        worker↔center communication rounds consumed, and the wire-bit
        totals are the ledger's exact ints."""
        return self.solver.run(
            w0, X, y, n_steps=max_rounds, key=key,
            grad_tol=grad_tol, full_data=full_data,
        )
