"""ByzantinePGD [Yin et al., ICML 2019] — the baseline the paper beats.

Perturbed robust gradient descent: every round each worker ships its local
gradient; the center aggregates with a robust rule (coordinate-wise trimmed
mean / median) and takes a GD step.  Whenever the aggregated gradient is
small (a stationary point — true local minimum *or* saddle / fake minimum),
the ``Escape`` sub-routine probes: up to ``R`` random perturbations in an
r-ball, each followed by ``Q`` robust-GD rounds; if the function value drops
by more than ``f_th`` the point was a saddle and the main loop resumes from
the escaped iterate, otherwise it is declared (second-order) stationary.

Every worker→center exchange counts as one communication round — this is the
quantity Table 1 compares (their experiment: R=10, r=5, Q=10, T_th=10,
coordinate-wise trimmed mean).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from . import attacks as attacks_lib
from .aggregation import coordinate_median, trimmed_mean


@dataclasses.dataclass(frozen=True)
class PGDConfig:
    lr: float = 1.0
    R: int = 10           # escape attempts
    r: float = 5.0        # perturbation radius
    Q: int = 10           # GD rounds per escape attempt
    T_th: int = 10        # patience between escape triggers
    f_th: float = 1e-3    # function-decrease threshold to accept an escape
    grad_th: float = 1e-4 # "gradient is small" trigger
    aggregator: str = "trimmed_mean"  # or "coordinate_median"
    trim_frac: float = 0.2


class ByzantinePGD:
    def __init__(
        self,
        loss_fn: Callable,
        config: PGDConfig = PGDConfig(),
        attack: "attacks_lib.AttackConfig | None" = None,
    ):
        from .newton import AttackConfig  # avoid cycle

        self.loss_fn = loss_fn
        self.cfg = config
        self.attack = attack if attack is not None else AttackConfig()
        self._per_worker_grads = jax.jit(
            jax.vmap(jax.grad(loss_fn), in_axes=(None, 0, 0))
        )
        self._loss = jax.jit(loss_fn)

    # ------------------------------------------------------------------
    def _aggregate(self, grads):
        if self.cfg.aggregator == "coordinate_median":
            return coordinate_median(grads)
        return trimmed_mean(grads, self.cfg.trim_frac)

    def _robust_grad(self, w, X, y, key):
        """One communication round: workers send gradients, center aggregates."""
        atk = self.attack
        m = X.shape[0]
        mask = attacks_lib.byzantine_mask(m, atk.alpha)
        k_label, k_update = jax.random.split(key)
        y_used = y
        if atk.name in attacks_lib.LABEL_ATTACKS and atk.name != "none":
            y_used = attacks_lib.LABEL_ATTACKS[atk.name](
                k_label, y, mask, num_classes=atk.num_classes
            )
        g = self._per_worker_grads(w, X, y_used)
        if atk.name in attacks_lib.UPDATE_ATTACKS and atk.name != "none":
            kw = {}
            if atk.name == "gaussian":
                kw = {"sigma": atk.sigma}
            elif atk.name == "negative":
                kw = {"c": atk.c}
            g = attacks_lib.UPDATE_ATTACKS[atk.name](k_update, g, mask, **kw)
        return self._aggregate(g)

    # ------------------------------------------------------------------
    def run(self, w0, X, y, max_rounds: int = 2000, grad_tol: float = 1e-3,
            key=None, full_data=None):
        """Run until pooled ‖∇f‖ ≤ grad_tol (same stopping rule as the
        paper's §6 comparison) or the round budget is exhausted.

        Returns (w, history) where history['rounds'] is the number of
        worker↔center communication rounds consumed — the Table-1 metric.
        """
        cfg = self.cfg
        key = key if key is not None else jax.random.PRNGKey(0)
        if full_data is None:
            full_data = (X.reshape(-1, X.shape[-1]), y.reshape(-1))
        Xf, yf = full_data
        gradf = jax.jit(jax.grad(self.loss_fn))

        w = w0
        rounds = 0
        hist = {"loss": [], "grad_norm": [], "rounds": 0}

        def record(w):
            hist["loss"].append(float(self._loss(w, Xf, yf)))
            hist["grad_norm"].append(float(jnp.linalg.norm(gradf(w, Xf, yf))))

        while rounds < max_rounds:
            key, sub = jax.random.split(key)
            g = self._robust_grad(w, X, y, sub)
            rounds += 1
            w = w - cfg.lr * g
            record(w)
            if hist["grad_norm"][-1] <= grad_tol:
                # Candidate stationary point: run Escape to certify it is not
                # a saddle / fake local minimum.
                escaped, w, used = self._escape(w, X, y, key)
                rounds += used
                if not escaped:
                    break  # certified: no descent found in R perturbations
        hist["rounds"] = rounds
        return w, hist

    def _escape(self, w, X, y, key):
        """The Escape sub-routine.  Returns (escaped?, iterate, rounds_used)."""
        cfg = self.cfg
        f0 = float(self._loss(w, X.reshape(-1, X.shape[-1]), y.reshape(-1)))
        used = 0
        for _ in range(cfg.R):
            key, kp, kg = jax.random.split(key, 3)
            u = jax.random.normal(kp, w.shape)
            u = u / (jnp.linalg.norm(u) + 1e-12) * cfg.r * jax.random.uniform(kp)
            w_try = w + u
            for _q in range(cfg.Q):
                kg, sub = jax.random.split(kg)
                g = self._robust_grad(w_try, X, y, sub)
                used += 1
                w_try = w_try - cfg.lr * g
            f_try = float(
                self._loss(w_try, X.reshape(-1, X.shape[-1]), y.reshape(-1))
            )
            if f0 - f_try > cfg.f_th:
                return True, w_try, used  # decreased ⇒ was a saddle, escaped
        return False, w, used
