"""Cubic sub-problem solvers (the inner problem of the paper's Algorithm 1).

Every worker solves, on its *local* gradient g and Hessian H (Eq. (2)):

    s* = argmin_s  gᵀs + (γ/2) sᵀHs + (M γ²/6) ‖s‖³

Three solvers are provided:

* :func:`solve_cubic_exact` — eigendecomposition + 1-D root finding on the
  Nesterov–Polyak secular equation.  Only feasible for small d (the paper's
  LIBSVM regime, d ≤ 300).  Used as the test oracle.
* :func:`solve_cubic_gd` — the paper's Algorithm 2: plain gradient descent on
  the sub-problem with explicit H, run as a ``lax.while_loop`` on ‖G‖ > τ
  (iteration-capped so it always terminates under jit).
* :func:`solve_cubic_hvp` — matrix-free Algorithm 2 for pytree parameters:
  H·s is a Hessian-vector product closure (two backprops), the loop is a
  ``lax.fori_loop`` with a fixed iteration count so the distributed train
  step lowers to a static program.  This is the TPU-scale adaptation noted
  in DESIGN.md §3.

First-order optimality (Lemma 4, Eq. 16):  g + γHs + (Mγ²/2)‖s‖ s = 0.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .tree_util import (
    tree_axpy,
    tree_norm,
    tree_zeros_like,
)


class CubicParams(NamedTuple):
    """Hyper-parameters of the sub-problem (paper's M, γ)."""

    M: float = 10.0
    gamma: float = 1.0


# ---------------------------------------------------------------------------
# Exact solver (small d) — the oracle
# ---------------------------------------------------------------------------


def _secular_norm(r, evals, u, M, gamma):
    """‖ (γΛ + (Mγ²/2) r)^{-1} u ‖ for the secular equation."""
    denom = gamma * evals + 0.5 * M * gamma**2 * r
    return jnp.sqrt(jnp.sum((u / denom) ** 2))


@partial(jax.jit, static_argnames=("n_bisect",))
def solve_cubic_exact(g, H, M=10.0, gamma=1.0, n_bisect=100):
    """Nesterov–Polyak exact solution via eigendecomposition + bisection.

    The stationarity condition gives ``s = -(γH + (Mγ²/2) r I)^{-1} g`` where
    ``r = ‖s‖`` must satisfy the secular equation
    ``φ(r) := ‖(γH + (Mγ²/2) r I)^{-1} g‖ − r = 0`` on
    ``r > max(0, −2λ_min(H)/(Mγ))`` (where the shifted matrix is PD).  φ is
    strictly decreasing there, so bisection converges.
    """
    evals, evecs = jnp.linalg.eigh(H)
    u = evecs.T @ g
    lam_min = evals[0]
    r_lo = jnp.maximum(0.0, -2.0 * lam_min / (M * gamma)) + 1e-12
    # Upper bound: ‖s‖ ≤ r_lo + sqrt(2‖g‖/(Mγ²)) + 2‖g‖/(γ|λ|) slack.
    gnorm = jnp.linalg.norm(g)
    r_hi = r_lo + jnp.sqrt(2.0 * gnorm / (M * gamma**2) + 1e-12) + gnorm / (
        0.5 * M * gamma**2 * (r_lo + 1e-6)
    )

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        phi = _secular_norm(mid, evals, u, M, gamma) - mid
        lo = jnp.where(phi > 0, mid, lo)
        hi = jnp.where(phi > 0, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, n_bisect, body, (r_lo, r_hi))
    r = 0.5 * (lo + hi)
    denom = gamma * evals + 0.5 * M * gamma**2 * r
    s = -(evecs @ (u / denom))
    return s


# ---------------------------------------------------------------------------
# Algorithm 2 — gradient-based cubic solver (explicit Hessian)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("max_iters",))
def solve_cubic_gd(g, H, M=10.0, gamma=1.0, lr=None, tol=1e-6, max_iters=2000):
    """The paper's Algorithm 2, verbatim (with an iteration cap for jit).

        s ← 0;  G ← g
        while ‖G‖ > τ:
            s ← s − ξ G
            G ← g + γ H s + (Mγ²/2) ‖s‖ s
    """
    if lr is None:
        # 1/(γ(‖H‖+Mγ)) is a safe step for the smooth part of the sub-problem.
        lr = 1.0 / (gamma * (jnp.linalg.norm(H, ord="fro") + M * gamma) + 1e-8)

    def cond(state):
        it, s, G = state
        return jnp.logical_and(jnp.linalg.norm(G) > tol, it < max_iters)

    def body(state):
        it, s, G = state
        s = s - lr * G
        G = g + gamma * (H @ s) + 0.5 * M * gamma**2 * jnp.linalg.norm(s) * s
        return it + 1, s, G

    _, s, _ = jax.lax.while_loop(cond, body, (0, jnp.zeros_like(g), g))
    return s


# ---------------------------------------------------------------------------
# Matrix-free Algorithm 2 (pytrees, HVP closure) — the at-scale path
# ---------------------------------------------------------------------------


def make_hvp(loss_fn: Callable, params, *batch):
    """Return ``hvp(v) = ∇²f(params)·v`` as a pytree→pytree closure.

    Forward-over-reverse: jvp of grad — two backprop-equivalents per call,
    exact (no finite differences).  ``loss_fn(params, *batch) -> scalar``.
    """
    grad_fn = lambda p: jax.grad(loss_fn)(p, *batch)

    def hvp(v):
        return jax.jvp(grad_fn, (params,), (v,))[1]

    return hvp


def solve_cubic_hvp(
    g,
    hvp: Callable,
    M: float = 10.0,
    gamma: float = 1.0,
    lr: float | None = None,
    n_iters: int = 8,
    norm_fn: Callable = tree_norm,
):
    """Algorithm 2 on a pytree with matrix-free H·s.

    ``norm_fn`` computes the *global* ‖s‖ — in the sharded setting it must
    psum partial squares over the model axis (see core/newton.py), which is
    why it is injectable.  A fixed ``fori_loop`` keeps the lowered program
    static (DESIGN.md §8.2); n_iters plays the role of τ.
    """
    if lr is None:
        # Scale-free default: trust Algorithm 2 with a conservative step.
        gn = norm_fn(g)
        lr = 1.0 / (gamma * (gn + M * gamma) + 1e-8)

    def body(_, s):
        Hs = hvp(s)
        sn = norm_fn(s)
        # s ← s − ξ G,  G = g + γ H s + (Mγ²/2)‖s‖ s    (kept in leaf dtype so
        # the fori_loop carry matches bf16 params exactly)
        return jax.tree_util.tree_map(
            lambda gi, hsi, si: (
                si.astype(jnp.float32)
                - lr
                * (
                    gi.astype(jnp.float32)
                    + gamma * hsi.astype(jnp.float32)
                    + 0.5 * M * gamma**2 * sn * si.astype(jnp.float32)
                )
            ).astype(si.dtype),
            g,
            Hs,
            s,
        )

    return jax.lax.fori_loop(0, n_iters, body, tree_zeros_like(g))


def cubic_model_value(s, g, H, M=10.0, gamma=1.0):
    """Sub-problem objective value m(s) — used by tests & property checks."""
    return (
        g @ s
        + 0.5 * gamma * s @ (H @ s)
        + M / 6.0 * gamma**2 * jnp.linalg.norm(s) ** 3
    )


def cubic_residual(s, g, H, M=10.0, gamma=1.0):
    """‖g + γHs + (Mγ²/2)‖s‖s‖ — first-order stationarity residual (Eq. 16)."""
    G = g + gamma * (H @ s) + 0.5 * M * gamma**2 * jnp.linalg.norm(s) * s
    return jnp.linalg.norm(G)
