"""Mesh-scale Byzantine-robust cubic-Newton train step (the paper at size).

Adaptation of Algorithm 1 to TPU pods (DESIGN.md §3/§5):

* a *worker machine* = one index along the ``data`` (and ``pod``) mesh axes;
  its tensor shards live on the ``model`` axis;
* per-worker gradients come from ``vmap(grad)`` over a leading worker axis on
  the batch — XLA keeps each worker's gradient on its own data-row;
* the cubic sub-problem (Eq. 2) is solved matrix-free with Hessian-vector
  products on the worker's *local* batch (exactly the sub-sampled-Hessian
  regime of Assumption 4).  The Algorithm-2 iteration runs as ONE
  ``fori_loop`` over the full ``(m, …)`` worker-stacked tree so per-worker
  state can carry explicit sharding constraints (worker→data, TP dims→model)
  — without them GSPMD replicates m full-model buffers per device;
* the center is virtual: the configured :mod:`repro.api.aggregators`
  rule runs on the worker-stacked update tree.  The default norm-trim
  reduces per-worker norms to ``m`` scalars, ranks them, and averages the
  smallest ``(1−β)m`` — a masked all-reduce, i.e. the same collective a
  data-parallel step already pays; krum / trimmed-mean /
  coordinate-median run through the same tree-aware interface.

Two gradient modes (paper's Remark 5):
* ``two_round=False`` — one communication phase, workers use local g_i
  (ε_g > 0 floor);
* ``two_round=True``  — a first all-reduce produces the exact global
  gradient (ε_g = 0) and, as a bonus at scale, removes the m-fold gradient
  memory: only s_i is per-worker.

Communication (§1's third pillar) routes through :mod:`repro.comm`
:class:`~repro.comm.TreeChannel` instances: the **uplink** channel
δ-compresses every worker's update tree before the masked all-reduce and
owns the Byzantine-injection hook; an optional **downlink** channel
compresses the center→worker broadcast of the aggregated update.
:func:`make_stateful_train_step` additionally threads the channels'
``(m, …)`` error-feedback pytree through the step (sharding constraints
re-applied), so long mesh runs get EF/EF21.  Exact integer wire costs
come from ``step.wire_bits(params)`` (static ints; feed them to a
host-side :class:`~repro.comm.WireLedger` per executed step — the traced
program never carries a lossy bit count).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .tree_util import tree_axpy, tree_size, tree_sqnorm
from ..comm import TreeChannel
from ..telemetry import device_event


@dataclasses.dataclass(frozen=True)
class DistributedNewtonConfig:
    M: float = 10.0
    gamma: float = 1.0
    eta: float = 1.0
    beta: float = 0.125          # trim fraction (β > α); 2/16 on a 16-row mesh
    solver_iters: int = 4        # fixed inner iterations (static program)
    solver_lr: Optional[float] = None
    two_round: bool = False      # Remark 5: exact global gradient
    # δ-approximate compression (repro.compression spec strings resolved
    # per leaf — None ⇒ full precision) for the two wire segments:
    compressor: Optional[str] = None           # worker→center update trees
    downlink_compressor: Optional[str] = None  # center→worker broadcast
    # error feedback ("none" | "ef" | "ef21") — only the *stateful* step
    # variant threads the (m, d)-tree memory; make_train_step ignores it.
    error_feedback: str = "none"
    ef_damping: float = 0.75
    # center aggregation rule as a repro.api.aggregators spec string
    # (tree-aware variants run here); None keeps the legacy β-field
    # behaviour (norm_trim(β) when β > 0, plain mean otherwise)
    aggregator: Optional[str] = None


def _per_worker_norms(s_tree, m):
    sq = jax.tree_util.tree_map(
        lambda x: jnp.sum(x.reshape(m, -1).astype(jnp.float32) ** 2, axis=1),
        s_tree,
    )
    return jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq))


def _bcast(v, leaf, m):
    """(m,) vector broadcast against an (m, …) leaf."""
    return v.reshape((m,) + (1,) * (leaf.ndim - 1)).astype(jnp.float32)


def _merge_workers(batch):
    return jax.tree_util.tree_map(
        lambda x: x.reshape((-1,) + x.shape[2:]), batch
    )


def _tree_attack_hook(attack_name: str, attack_alpha: float, m: int):
    """Update-level Byzantine injection over a worker-stacked tree.

    ``attack_name`` is a :mod:`repro.api.attacks` spec string (bare names
    like ``"gaussian"`` keep their registry defaults; ``"gaussian:50.0"``
    parametrizes).  Label attacks are rejected at build time — the mesh
    batches carry no label channel.
    """
    from ..api.attacks import make_attack
    from ..api.errors import SpecError

    rule = make_attack(attack_name, attack_alpha)
    if rule.kind == "label":
        raise SpecError(
            f"attack {attack_name!r} corrupts worker labels, but the mesh "
            f"runtime's batches have no label channel — use an update-level "
            f"attack (gaussian/negative/saddle)"
        )
    return rule.tree_hook(m)


def build_channels(
    cfg: DistributedNewtonConfig,
    m_workers: int,
    attack_name: str = "none",
    attack_alpha: float = 0.0,
    constrain_worker: Optional[Callable] = None,
    constrain_update: Optional[Callable] = None,
    compressor=None,
    stateful: bool = False,
):
    """Resolve the mesh step's channels once (shared by both step builders).

    Returns ``{"uplink": TreeChannel, "downlink": TreeChannel}``.
    """
    ef = cfg.error_feedback if stateful else "none"
    uplink = TreeChannel(
        "uplink",
        compressor if compressor is not None else cfg.compressor,
        m_workers,
        error_feedback=ef,
        damping=cfg.ef_damping,
        attack_hook=_tree_attack_hook(attack_name, attack_alpha, m_workers),
        constrain=constrain_worker,
    )
    downlink = TreeChannel(
        "downlink",
        cfg.downlink_compressor,
        1,
        error_feedback=ef,
        damping=cfg.ef_damping,
        constrain=constrain_update,
    )
    return {"uplink": uplink, "downlink": downlink}


def _make_step(
    loss_fn: Callable,
    cfg: DistributedNewtonConfig,
    m_workers: int,
    channels: dict,
    constrain_worker: Optional[Callable],
    constrain_update: Optional[Callable],
    stateful: bool,
):
    """The shared step body; see make_train_step / make_stateful_train_step."""
    from ..api.aggregators import default_aggregator_spec, make_aggregator

    m = m_workers
    # resolved ONCE at build time, like the channels — the registry rule
    # replaces the formerly hardcoded norm-trim at the virtual center
    aggregator = make_aggregator(
        cfg.aggregator if cfg.aggregator is not None
        else default_aggregator_spec(cfg.beta)
    )
    grad_fn = jax.grad(loss_fn)
    cw = constrain_worker or (lambda t: t)
    cu = constrain_update or (lambda t: t)
    uplink: TreeChannel = channels["uplink"]
    downlink: TreeChannel = channels["downlink"]
    # measured δ̂ costs two O(m·d) tree reductions per step; only pay for
    # it when an adaptive schedule could consume the signal
    _up_spec = getattr(uplink.tree_compressor, "spec", None)
    measure_delta = isinstance(_up_spec, str) and _up_spec.startswith("adaptive")

    def hvp_all(params, batch, s):
        """Per-worker H_i·s_i on each worker's local batch (m-stacked)."""

        def one(b_i, s_i):
            g_of = lambda p: grad_fn(p, b_i)
            return jax.jvp(g_of, (params,), (s_i,))[1]

        return jax.vmap(one, in_axes=(0, 0))(batch, s)

    def _solver_lr(params, batch, g_tree, gnorms, g_is_global):
        """Safe Algorithm-2 step size from a one-shot curvature estimate.

        The sub-problem gradient is (γ‖H‖ + (3/2)Mγ²r)-Lipschitz on the ball
        ‖s‖ ≤ r; GD needs ξ < 1/L_sub.  ‖H_i‖ is estimated by the Rayleigh
        quotient along ĝ_i (one extra HVP — counted in the roofline's
        backprop-equivalents)."""
        if g_is_global:
            ghat = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(
                    (x / (gnorms[0] + 1e-20)).astype(x.dtype)[None],
                    (m,) + x.shape,
                ),
                g_tree,
            )
        else:
            ghat = jax.tree_util.tree_map(
                lambda x: (
                    x.astype(jnp.float32) / _bcast(gnorms + 1e-20, x, m)
                ).astype(x.dtype),
                g_tree,
            )
        lam = _per_worker_norms(cw(hvp_all(params, batch, cw(ghat))), m)
        # solution-scale bound: r* ≤ sqrt(2‖g‖/(Mγ²)) + 2‖H‖/(Mγ)
        r_max = jnp.sqrt(2.0 * gnorms / (cfg.M * cfg.gamma**2) + 1e-12) + (
            2.0 * lam / (cfg.M * cfg.gamma)
        )
        L_sub = cfg.gamma * lam + 1.5 * cfg.M * cfg.gamma**2 * r_max
        return 1.0 / (1.5 * L_sub + 1e-8)

    def step_body(params, batch, key, comm_state):
        # loss is a by-product of the gradient pass (value_and_grad) — a
        # separate monitoring forward would cost ~9% of the whole step
        # (§Perf iteration 1).
        if cfg.two_round:
            # Round 1: exact global gradient (Remark 5, ε_g = 0); only s_i is
            # per-worker state.
            loss_val, g_global = jax.value_and_grad(loss_fn)(
                params, _merge_workers(batch)
            )
            gnorm = jnp.sqrt(tree_sqnorm(g_global))
            gnorms = jnp.full((m,), gnorm)
            g_tree = g_global  # broadcast over workers inside `upd`
            g_is_global = True
        else:
            losses, g_tree = jax.vmap(
                lambda b: jax.value_and_grad(loss_fn)(params, b)
            )(batch)
            g_tree = cw(g_tree)
            loss_val = losses.mean()
            gnorms = _per_worker_norms(g_tree, m)
            g_is_global = False
        if cfg.solver_lr is not None:
            lr_vec = jnp.full((m,), cfg.solver_lr)
        else:
            lr_vec = _solver_lr(params, batch, g_tree, gnorms, g_is_global)

        # ---- Algorithm 2, matrix-free, all workers at once ----
        def body(_, s):
            Hs = cw(hvp_all(params, batch, s))
            sn = _per_worker_norms(s, m)  # ‖s_i‖, m scalars

            def upd(si, gi, hsi):
                si32 = si.astype(jnp.float32)
                gi32 = gi.astype(jnp.float32)
                if g_is_global:
                    gi32 = gi32[None]
                G = (
                    gi32
                    + cfg.gamma * hsi.astype(jnp.float32)
                    + 0.5 * cfg.M * cfg.gamma**2 * _bcast(sn, si, m) * si32
                )
                return (si32 - _bcast(lr_vec, si, m) * G).astype(si.dtype)

            return cw(jax.tree_util.tree_map(upd, s, g_tree, Hs))

        s0 = cw(
            jax.tree_util.tree_map(
                lambda p: jnp.zeros((m,) + p.shape, p.dtype), params
            )
        )
        s = jax.lax.fori_loop(0, cfg.solver_iters, body, s0)

        # ---- uplink channel: δ-compress (+EF) then Byzantine-inject ----
        # (attacks corrupt the reconstructed tree — Byzantine workers send
        # arbitrary payloads, so compression grants them no protection;
        # δ̂ is measured before injection so the metric sees the wire)
        k_atk, k_comp, k_down = jax.random.split(key, 3)
        up_state = comm_state["uplink"] if stateful else None
        if measure_delta:
            s, up_state, uplink_delta = uplink.transmit(
                s, up_state, key=k_comp, attack_key=k_atk, measure=True
            )
        else:
            s, up_state = uplink.transmit(
                s, up_state, key=k_comp, attack_key=k_atk
            )
            uplink_delta = jnp.float32(1.0)  # stable metrics structure

        # ---- Center: the resolved registry aggregation rule ----
        # (Algorithm 1 step 6 is norm_trim; krum / trimmed_mean /
        # coordinate_median / mean run here through the same interface)
        norms = _per_worker_norms(s, m)
        update, keep = aggregator.tree(s)
        update = cu(update)
        # The keep mask and per-worker norms live on the device; when
        # telemetry is enabled at TRACE time this stages one host
        # callback shipping them out.  Disabled, device_event stages
        # nothing — the lowered HLO is bit-identical (pinned by the
        # HLO-identity test).
        device_event("mesh.aggregate", keep=keep, update_norms=norms)

        # ---- downlink channel: compressed broadcast of the step ----
        down_state = comm_state["downlink"] if stateful else None
        update, down_state = downlink.transmit(
            update, down_state, key=k_down
        )
        new_params = jax.tree_util.tree_map(
            lambda p, u: (
                p.astype(jnp.float32) + cfg.eta * u.astype(jnp.float32)
            ).astype(p.dtype),
            params,
            update,
        )
        # wire accounting lives OUTSIDE the trace: bits are static ints —
        # read step.wire_bits(params) and feed a repro.comm.WireLedger per
        # executed step (no lossy float32 / overflowing int32 in metrics).
        metrics = {
            "loss": loss_val,
            "update_norms": norms,
            "kept": keep,
            "update_norm": jnp.sqrt(tree_sqnorm(update)),
            "uplink_delta": uplink_delta,
        }
        return new_params, metrics, {"uplink": up_state, "downlink": down_state}

    def wire_bits(params) -> dict:
        """Exact bits one step costs per direction (static Python ints).
        ``two_round`` adds the full-precision gradient all-reduce (m
        uplink payloads) and the averaged-gradient broadcast."""
        d = tree_size(params)
        up = uplink.bits_per_round(params)
        down = downlink.bits_per_round(params)
        if cfg.two_round:
            up += m * 32 * d
            down += 32 * d
        return {"uplink": up, "downlink": down}

    return step_body, wire_bits


def make_train_step(
    loss_fn: Callable,
    cfg: DistributedNewtonConfig,
    m_workers: int,
    attack_name: str = "none",
    attack_alpha: float = 0.0,
    constrain_worker: Optional[Callable] = None,
    constrain_update: Optional[Callable] = None,
    compressor=None,
):
    """Build the stateless ``train_step(params, batch, key) -> (params,
    metrics)``.

    ``loss_fn(params, batch) -> scalar``; every leaf of ``batch`` carries a
    leading worker axis of size ``m_workers`` (sharded over data(+pod)).
    ``constrain_worker`` / ``constrain_update`` apply sharding constraints to
    worker-stacked / aggregated update trees (supplied by repro.launch).

    All transmissions route through :class:`repro.comm.TreeChannel`
    (``cfg.compressor`` / ``compressor=`` for the uplink,
    ``cfg.downlink_compressor`` for the broadcast); this variant carries
    no error-feedback state — use :func:`make_stateful_train_step` for
    EF/EF21 at mesh scale.  The channels are exposed as
    ``train_step.channels`` and the exact static wire cost as
    ``train_step.wire_bits(params)``.
    """
    channels = build_channels(
        cfg, m_workers, attack_name, attack_alpha,
        constrain_worker, constrain_update, compressor, stateful=False,
    )
    step_body, wire_bits = _make_step(
        loss_fn, cfg, m_workers, channels,
        constrain_worker, constrain_update, stateful=False,
    )

    def train_step(params, batch, key):
        new_params, metrics, _ = step_body(params, batch, key, None)
        return new_params, metrics

    train_step.channels = channels
    train_step.wire_bits = wire_bits
    return train_step


def make_stateful_train_step(
    loss_fn: Callable,
    cfg: DistributedNewtonConfig,
    m_workers: int,
    attack_name: str = "none",
    attack_alpha: float = 0.0,
    constrain_worker: Optional[Callable] = None,
    constrain_update: Optional[Callable] = None,
    compressor=None,
):
    """Stateful variant: error feedback at mesh scale.

    Returns ``(train_step, init_comm_state)`` with

        train_step(params, batch, key, comm_state)
            -> (params, metrics, comm_state)
        init_comm_state(params) -> {"uplink": (m, …)-tree, "downlink": tree}

    The comm state is the channels' EF/EF21 memory — an ``(m, d)``-tree
    for the uplink, a param-tree for the downlink broadcast — threaded
    explicitly so it jits, donates, and (via ``constrain_worker`` /
    ``constrain_update``, re-applied inside ``transmit``) keeps the same
    GSPMD layout as the update trees on long mesh runs.  With
    ``cfg.error_feedback = "none"`` the state is ``()`` and the step
    degenerates to :func:`make_train_step` plus a trivial carry.
    """
    channels = build_channels(
        cfg, m_workers, attack_name, attack_alpha,
        constrain_worker, constrain_update, compressor, stateful=True,
    )
    step_body, wire_bits = _make_step(
        loss_fn, cfg, m_workers, channels,
        constrain_worker, constrain_update, stateful=True,
    )

    def init_comm_state(params):
        return {
            "uplink": channels["uplink"].init_state(params),
            "downlink": channels["downlink"].init_state(params),
        }

    step_body.channels = channels
    step_body.wire_bits = wire_bits
    return step_body, init_comm_state


def make_robust_sgd_step(
    loss_fn: Callable,
    lr: float,
    m_workers: int,
    beta: float = 0.125,
    constrain_worker: Optional[Callable] = None,
):
    """First-order robust baseline: per-worker gradients + norm-trim + SGD.

    Used by the communication benchmark to contrast against first-order
    methods the paper outperforms on rounds-to-accuracy.
    """
    m = m_workers
    n_keep = max(1, int(round((1.0 - beta) * m)))
    grad_fn = jax.grad(loss_fn)
    cw = constrain_worker or (lambda t: t)

    def step(params, batch, key):
        del key
        loss_val = loss_fn(params, _merge_workers(batch))
        g = cw(jax.vmap(lambda b: grad_fn(params, b))(batch))
        norms = _per_worker_norms(g, m)
        ranks = jnp.argsort(jnp.argsort(norms))
        keep = (ranks < n_keep).astype(jnp.float32)

        def masked_mean(x):
            w = keep.reshape((m,) + (1,) * (x.ndim - 1)).astype(x.dtype)
            return (w * x).sum(0) / jnp.asarray(n_keep, x.dtype)

        update = jax.tree_util.tree_map(masked_mean, g)
        new_params = tree_axpy(-lr, update, params)
        return new_params, {"loss": loss_val, "update_norms": norms}

    return step
