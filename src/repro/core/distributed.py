"""Mesh-scale Byzantine-robust cubic-Newton train step (the paper at size).

Adaptation of Algorithm 1 to TPU pods (DESIGN.md §3/§5):

* a *worker machine* = one index along the ``data`` (and ``pod``) mesh axes;
  its tensor shards live on the ``model`` axis;
* per-worker gradients come from ``vmap(grad)`` over a leading worker axis on
  the batch — XLA keeps each worker's gradient on its own data-row;
* the cubic sub-problem (Eq. 2) is solved matrix-free with Hessian-vector
  products on the worker's *local* batch (exactly the sub-sampled-Hessian
  regime of Assumption 4).  The Algorithm-2 iteration runs as ONE
  ``fori_loop`` over the full ``(m, …)`` worker-stacked tree so per-worker
  state can carry explicit sharding constraints (worker→data, TP dims→model)
  — without them GSPMD replicates m full-model buffers per device;
* the center is virtual: per-worker update norms are reduced to ``m``
  scalars, ranked, and the smallest ``(1−β)m`` averaged — a masked
  all-reduce, i.e. the same collective a data-parallel step already pays.

Two gradient modes (paper's Remark 5):
* ``two_round=False`` — one communication phase, workers use local g_i
  (ε_g > 0 floor);
* ``two_round=True``  — a first all-reduce produces the exact global
  gradient (ε_g = 0) and, as a bonus at scale, removes the m-fold gradient
  memory: only s_i is per-worker.

Communication efficiency (§1's third pillar): ``compressor=`` applies a
δ-approximate compressor (:mod:`repro.compression`) to every worker's
update tree before the masked all-reduce, with exact per-worker wire-bit
accounting surfaced in the step metrics.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import attacks as attacks_lib
from .tree_util import tree_axpy, tree_size, tree_sqnorm
from ..compression import TreeCompressor


@dataclasses.dataclass(frozen=True)
class DistributedNewtonConfig:
    M: float = 10.0
    gamma: float = 1.0
    eta: float = 1.0
    beta: float = 0.125          # trim fraction (β > α); 2/16 on a 16-row mesh
    solver_iters: int = 4        # fixed inner iterations (static program)
    solver_lr: Optional[float] = None
    two_round: bool = False      # Remark 5: exact global gradient
    # δ-approximate compression of each worker's update tree before the
    # masked all-reduce: a repro.compression spec string ("topk:0.1",
    # "signnorm", "int8", …) resolved per leaf — None ⇒ full precision.
    compressor: Optional[str] = None


def wire_bits_per_step(params, cfg: DistributedNewtonConfig, compressor=None) -> int:
    """Exact uplink bits ONE worker pays per train step (static Python int;
    the mesh mirror of ``DistributedCubicNewton.wire_bits_per_step``).

    Counts the (possibly compressed) update-tree payload, plus the
    full-precision local gradient in ``two_round`` mode.  Use this for
    accounting at scale — the per-step ``wire_bits_per_worker`` metric is
    a float32 convenience and loses integer exactness above 2²⁴ bits.
    """
    d = tree_size(params)
    spec = compressor if compressor is not None else cfg.compressor
    if spec is None:
        bits = 32 * d
    else:
        if not isinstance(spec, TreeCompressor):
            spec = TreeCompressor(spec)
        bits = spec.wire_bits_tree(params, 1)
    if cfg.two_round:
        bits += 32 * d
    return bits


def _per_worker_norms(s_tree, m):
    sq = jax.tree_util.tree_map(
        lambda x: jnp.sum(x.reshape(m, -1).astype(jnp.float32) ** 2, axis=1),
        s_tree,
    )
    return jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq))


def _bcast(v, leaf, m):
    """(m,) vector broadcast against an (m, …) leaf."""
    return v.reshape((m,) + (1,) * (leaf.ndim - 1)).astype(jnp.float32)


def _merge_workers(batch):
    return jax.tree_util.tree_map(
        lambda x: x.reshape((-1,) + x.shape[2:]), batch
    )


def make_train_step(
    loss_fn: Callable,
    cfg: DistributedNewtonConfig,
    m_workers: int,
    attack_name: str = "none",
    attack_alpha: float = 0.0,
    constrain_worker: Optional[Callable] = None,
    constrain_update: Optional[Callable] = None,
    compressor=None,
):
    """Build ``train_step(params, batch, key) -> (params, metrics)``.

    ``loss_fn(params, batch) -> scalar``; every leaf of ``batch`` carries a
    leading worker axis of size ``m_workers`` (sharded over data(+pod)).
    ``constrain_worker`` / ``constrain_update`` apply sharding constraints to
    worker-stacked / aggregated update trees (supplied by repro.launch).

    ``compressor`` (or ``cfg.compressor``) turns on δ-approximate
    compression of each worker's update tree *before* the masked
    all-reduce — a :class:`repro.compression.TreeCompressor`, or a spec
    string ("topk:0.1", …).  Per-leaf shapes stay static and the worker
    sharding constraint is re-applied to the reconstructed tree, so
    GSPMD sees the same layout as the uncompressed step.  Error
    feedback at mesh scale would thread (m, d) state through the step
    signature — left as a ROADMAP follow-on.
    """
    m = m_workers
    n_keep = max(1, int(round((1.0 - cfg.beta) * m)))
    grad_fn = jax.grad(loss_fn)
    cw = constrain_worker or (lambda t: t)
    cu = constrain_update or (lambda t: t)
    spec = compressor if compressor is not None else cfg.compressor
    if spec is not None and not isinstance(spec, TreeCompressor):
        spec = TreeCompressor(spec)
    tc: Optional[TreeCompressor] = spec

    def hvp_all(params, batch, s):
        """Per-worker H_i·s_i on each worker's local batch (m-stacked)."""

        def one(b_i, s_i):
            g_of = lambda p: grad_fn(p, b_i)
            return jax.jvp(g_of, (params,), (s_i,))[1]

        return jax.vmap(one, in_axes=(0, 0))(batch, s)

    def _solver_lr(params, batch, g_tree, gnorms, g_is_global):
        """Safe Algorithm-2 step size from a one-shot curvature estimate.

        The sub-problem gradient is (γ‖H‖ + (3/2)Mγ²r)-Lipschitz on the ball
        ‖s‖ ≤ r; GD needs ξ < 1/L_sub.  ‖H_i‖ is estimated by the Rayleigh
        quotient along ĝ_i (one extra HVP — counted in the roofline's
        backprop-equivalents)."""
        if g_is_global:
            ghat = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(
                    (x / (gnorms[0] + 1e-20)).astype(x.dtype)[None],
                    (m,) + x.shape,
                ),
                g_tree,
            )
        else:
            ghat = jax.tree_util.tree_map(
                lambda x: (
                    x.astype(jnp.float32) / _bcast(gnorms + 1e-20, x, m)
                ).astype(x.dtype),
                g_tree,
            )
        lam = _per_worker_norms(cw(hvp_all(params, batch, cw(ghat))), m)
        # solution-scale bound: r* ≤ sqrt(2‖g‖/(Mγ²)) + 2‖H‖/(Mγ)
        r_max = jnp.sqrt(2.0 * gnorms / (cfg.M * cfg.gamma**2) + 1e-12) + (
            2.0 * lam / (cfg.M * cfg.gamma)
        )
        L_sub = cfg.gamma * lam + 1.5 * cfg.M * cfg.gamma**2 * r_max
        return 1.0 / (1.5 * L_sub + 1e-8)

    def train_step(params, batch, key):
        # loss is a by-product of the gradient pass (value_and_grad) — a
        # separate monitoring forward would cost ~9% of the whole step
        # (§Perf iteration 1).
        if cfg.two_round:
            # Round 1: exact global gradient (Remark 5, ε_g = 0); only s_i is
            # per-worker state.
            loss_val, g_global = jax.value_and_grad(loss_fn)(
                params, _merge_workers(batch)
            )
            gnorm = jnp.sqrt(tree_sqnorm(g_global))
            gnorms = jnp.full((m,), gnorm)
            g_tree = g_global  # broadcast over workers inside `upd`
            g_is_global = True
        else:
            losses, g_tree = jax.vmap(
                lambda b: jax.value_and_grad(loss_fn)(params, b)
            )(batch)
            g_tree = cw(g_tree)
            loss_val = losses.mean()
            gnorms = _per_worker_norms(g_tree, m)
            g_is_global = False
        if cfg.solver_lr is not None:
            lr_vec = jnp.full((m,), cfg.solver_lr)
        else:
            lr_vec = _solver_lr(params, batch, g_tree, gnorms, g_is_global)

        # ---- Algorithm 2, matrix-free, all workers at once ----
        def body(_, s):
            Hs = cw(hvp_all(params, batch, s))
            sn = _per_worker_norms(s, m)  # ‖s_i‖, m scalars

            def upd(si, gi, hsi):
                si32 = si.astype(jnp.float32)
                gi32 = gi.astype(jnp.float32)
                if g_is_global:
                    gi32 = gi32[None]
                G = (
                    gi32
                    + cfg.gamma * hsi.astype(jnp.float32)
                    + 0.5 * cfg.M * cfg.gamma**2 * _bcast(sn, si, m) * si32
                )
                return (si32 - _bcast(lr_vec, si, m) * G).astype(si.dtype)

            return cw(jax.tree_util.tree_map(upd, s, g_tree, Hs))

        s0 = cw(
            jax.tree_util.tree_map(
                lambda p: jnp.zeros((m,) + p.shape, p.dtype), params
            )
        )
        s = jax.lax.fori_loop(0, cfg.solver_iters, body, s0)

        # ---- δ-compress honest worker→center payloads ----
        # (before injection: Byzantine workers send arbitrary vectors, so
        # the attacks corrupt the reconstructed tree, as in repro.core.newton)
        k_atk, k_comp = jax.random.split(key)
        if tc is not None:
            s = cw(tc.roundtrip_worker_tree(s, k_comp, m))

        # ---- Byzantine injection (update-level attacks at scale) ----
        if attack_name != "none" and attack_alpha > 0:
            mask = attacks_lib.byzantine_mask(m, attack_alpha)
            kw = {"sigma": 10.0} if attack_name == "gaussian" else {}
            s = jax.tree_util.tree_map(
                lambda x: attacks_lib.UPDATE_ATTACKS[attack_name](
                    k_atk, x, mask, **kw
                ),
                s,
            )

        # ---- Center: norm-based thresholding (Algorithm 1 step 6) ----
        norms = _per_worker_norms(s, m)
        ranks = jnp.argsort(jnp.argsort(norms))
        keep = (ranks < n_keep).astype(jnp.float32)

        def masked_mean(x):
            w = keep.reshape((m,) + (1,) * (x.ndim - 1)).astype(x.dtype)
            return (w * x).sum(0) / jnp.asarray(n_keep, x.dtype)

        update = cu(jax.tree_util.tree_map(masked_mean, s))
        new_params = jax.tree_util.tree_map(
            lambda p, u: (
                p.astype(jnp.float32) + cfg.eta * u.astype(jnp.float32)
            ).astype(p.dtype),
            params,
            update,
        )
        # wire accounting: uplink bits each worker pays this step (static;
        # leaf sizes are known at trace time).  two_round's first phase
        # ships the local gradient at full precision.  float32 metric for
        # convenience — exact integers via module-level wire_bits_per_step.
        d_worker = tree_size(params)
        bits = (
            tc.wire_bits_tree(s, m) if tc is not None else 32 * d_worker
        )
        if cfg.two_round:
            bits += 32 * d_worker
        metrics = {
            "loss": loss_val,
            "update_norms": norms,
            "kept": keep,
            "update_norm": jnp.sqrt(tree_sqnorm(update)),
            "wire_bits_per_worker": jnp.float32(bits),
        }
        return new_params, metrics

    return train_step


def make_robust_sgd_step(
    loss_fn: Callable,
    lr: float,
    m_workers: int,
    beta: float = 0.125,
    constrain_worker: Optional[Callable] = None,
):
    """First-order robust baseline: per-worker gradients + norm-trim + SGD.

    Used by the communication benchmark to contrast against first-order
    methods the paper outperforms on rounds-to-accuracy.
    """
    m = m_workers
    n_keep = max(1, int(round((1.0 - beta) * m)))
    grad_fn = jax.grad(loss_fn)
    cw = constrain_worker or (lambda t: t)

    def step(params, batch, key):
        del key
        loss_val = loss_fn(params, _merge_workers(batch))
        g = cw(jax.vmap(lambda b: grad_fn(params, b))(batch))
        norms = _per_worker_norms(g, m)
        ranks = jnp.argsort(jnp.argsort(norms))
        keep = (ranks < n_keep).astype(jnp.float32)

        def masked_mean(x):
            w = keep.reshape((m,) + (1,) * (x.ndim - 1)).astype(x.dtype)
            return (w * x).sum(0) / jnp.asarray(n_keep, x.dtype)

        update = jax.tree_util.tree_map(masked_mean, g)
        new_params = tree_axpy(-lr, update, params)
        return new_params, {"loss": loss_val, "update_norms": norms}

    return step
