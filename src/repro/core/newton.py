"""Algorithm 1 — Byzantine-Robust Distributed Cubic-Regularized Newton.

This module is the *paper-faithful* runtime: m workers simulated on one
process, explicit per-worker gradients / Hessians (the paper's LIBSVM regime,
d ≤ a few hundred), the paper's Algorithm 2 inner solver, the Byzantine
attacks resolved from the :mod:`repro.api.attacks` registry, and a
:mod:`repro.api.aggregators` registry rule at the center (the paper's
norm-based thresholding by default; krum / trimmed-mean /
coordinate-median / mean as declared).

Every transmission goes through :mod:`repro.comm` — the unified
communication-channel layer (§1's third pillar / COMRADE): an **uplink**
:class:`~repro.comm.VectorChannel` carries the δ-compressed worker
updates s_i with per-worker EF/EF21 state and the Byzantine-injection
hook; an optional **downlink** channel compresses the center→worker
broadcast of the aggregated step; in two-round (Remark 5) mode the
gradient round is a second uplink channel with its own EF21 state, so
ε_g = 0 no longer costs full precision on the wire.  Exact integer wire
accounting comes from the channels' static ``bits_per_round`` feeding a
host-side :class:`~repro.comm.WireLedger` (never a lossy traced float).

The at-scale (mesh-sharded, matrix-free) variant for the assigned
architectures lives in :mod:`repro.core.distributed`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .cubic import solve_cubic_gd
from ..comm import VectorChannel, WireLedger
from ..compression import AdaptiveTopK
from ..telemetry import (
    RoundRecord,
    SuspicionTracker,
    compile_scope,
    get_telemetry,
    planted_byzantine_ids,
    record_retrace,
    rejected_from_keep,
)


@dataclasses.dataclass(frozen=True)
class NewtonConfig:
    """Hyper-parameters of Algorithm 1 (paper's notation)."""

    M: float = 10.0          # cubic regularization weight
    gamma: float = 1.0       # sub-problem second/third-order emphasis (Remark 1)
    eta: float = 1.0         # step size η_k (paper uses 1 in experiments)
    beta: float = 0.0        # trim fraction (β > α required for resilience)
    solver_tol: float = 1e-6
    solver_iters: int = 500  # cap for Algorithm 2's while-loop
    exact_gradient: bool = False  # Remark 5: extra round ⇒ ε_g = 0
    momentum: float = 0.0    # beyond-paper: CR-with-momentum [WZLL20]
    # δ-approximate compression (repro.compression spec strings, e.g.
    # "topk:0.1", "signnorm", "adaptive_topk:0.05:0.5"; None ⇒ full
    # precision) for the three wire segments, each its own channel:
    compressor: Optional[str] = None           # uplink: worker updates s_i
    downlink_compressor: Optional[str] = None  # center→worker broadcast
    grad_compressor: Optional[str] = None      # Remark-5 gradient round
    error_feedback: str = "ef21"  # "none" | "ef" | "ef21" (tracking)
    ef_damping: float = 0.75      # θ; mid-plateau on w8a (see error_feedback.py)
    # center aggregation rule as a repro.api.aggregators spec string
    # ("norm_trim:0.25", "krum:2", "trimmed_mean:0.1", "coordinate_median",
    # "mean", or a fused-kernel variant like "krum_kernel:2"); None keeps
    # the legacy β-field behaviour (norm_trim(β) when β > 0, plain mean
    # otherwise)
    aggregator: Optional[str] = None
    # sparse-domain center: aggregate top-k wire payloads directly
    # (O(m·k) center memory, never densifying the m worker vectors).
    # None ⇒ auto — on whenever the uplink channel supports the sparse
    # receive (sparse compressor, no error feedback, no update attack)
    # AND the aggregator has a sparse path (mean / norm_trim).  True
    # demands it (build error when unsupported); False forces the dense
    # center.
    sparse_center: Optional[bool] = None


@dataclasses.dataclass(frozen=True)
class AttackConfig:
    name: str = "none"            # a repro.api.attacks rule name
    alpha: float = 0.0            # Byzantine fraction
    sigma: float = 10.0           # gaussian attack scale
    c: float = 0.9                # negative-update attack scale
    scale: float = 5.0            # saddle attack scale
    num_classes: int = 2


class DistributedCubicNewton:
    """Simulated cluster running Algorithm 1.

    ``loss_fn(w, X, y) -> scalar`` is the per-worker empirical loss; workers'
    data is stacked on a leading axis: ``X: (m, n, d)``, ``y: (m, n)``.
    One ``step`` = one communication round (two if ``exact_gradient``).

    ``runtime_label`` names the runtime in emitted round records;
    subclasses (the async runtime) override it.

    Channels (and their compressors / error-feedback wrappers) are
    resolved ONCE, lazily at the first step for the observed ``(d, m)``
    — never inside a trace.  ``self.ledger`` accumulates exact integer
    uplink/downlink bits host-side.
    """

    runtime_label = "paper"

    def __init__(
        self,
        loss_fn: Callable,
        config: NewtonConfig = NewtonConfig(),
        attack: AttackConfig = AttackConfig(),
    ):
        # registries resolve ONCE here, never inside a trace (the api
        # import is lazy purely to keep the package import graph acyclic)
        from ..api.aggregators import default_aggregator_spec, make_aggregator
        from ..api.attacks import resolve_attack

        self.loss_fn = loss_fn
        self.config = config
        self.attack = attack
        self.aggregator = make_aggregator(
            config.aggregator
            if config.aggregator is not None
            else default_aggregator_spec(config.beta)
        )
        self._attack_rule = resolve_attack(attack)
        self._grad_fn = jax.grad(loss_fn)
        self._hess_fn = jax.hessian(loss_fn)
        self.rounds_per_step = 2 if config.exact_gradient else 1
        self.ledger = WireLedger()
        # channels need (d, m); built once at the first step
        self._dims: Optional[tuple] = None
        self._use_sparse_center = False
        self.uplink: Optional[VectorChannel] = None
        self.downlink: Optional[VectorChannel] = None
        self.grad_uplink: Optional[VectorChannel] = None
        self._rebuild_jit()

    # -- channel construction (once per (d, m), never per trace) -------
    def _rebuild_jit(self):
        """(Re)create the jitted step — required whenever a channel's
        static shape (an adaptive compressor's k) changes.  Each rebuild
        is an explicit telemetry re-trace event carrying the shape key
        (the live per-channel ks) that triggered it."""
        if self._dims is not None:   # a re-build, not the initial build
            record_retrace(
                "newton.step.rebuild",
                **{f"k_{name}": ch.compressor.k
                   for name, ch in self.channels.items()
                   if isinstance(ch.compressor, AdaptiveTopK)},
            )
        self._step = jax.jit(self._step_impl)

    def _ensure_channels(self, d: int, m: int):
        if self._dims == (d, m):
            return
        cfg = self.config
        self.uplink = VectorChannel(
            "uplink", cfg.compressor, d, m,
            error_feedback=cfg.error_feedback, damping=cfg.ef_damping,
            attack_hook=self._attack_rule.update_hook(m),
        )
        self.downlink = VectorChannel(
            "downlink", cfg.downlink_compressor, d, 1,
            error_feedback=cfg.error_feedback, damping=cfg.ef_damping,
        )
        # Remark-5 gradient round: its own channel + EF21 state, so the
        # extra round no longer forces full precision on the wire.
        self.grad_uplink = VectorChannel(
            "uplink", cfg.grad_compressor, d, m,
            error_feedback=cfg.error_feedback, damping=cfg.ef_damping,
        ) if cfg.exact_gradient else None
        # sparse-domain center: resolved once the channels exist
        can_sparse = (self.uplink.supports_sparse_receive
                      and self.aggregator.supports_sparse)
        if cfg.sparse_center and not can_sparse:
            raise ValueError(
                "sparse_center=True needs a sparse uplink compressor "
                "(top-k family) with error_feedback='none', no update "
                "attack, and a mean/norm_trim aggregator — got "
                f"compressor={cfg.compressor!r}, "
                f"error_feedback={cfg.error_feedback!r}, "
                f"attack={self.attack.name!r}, "
                f"aggregator={self.aggregator.spec!r}"
            )
        self._use_sparse_center = (can_sparse if cfg.sparse_center is None
                                   else bool(cfg.sparse_center))
        if self._dims is not None:
            self._rebuild_jit()   # stale trace would bake the old channels in
        self._dims = (d, m)

    @property
    def channels(self):
        """The live channels (built at first step), keyed by segment."""
        chans = {"uplink": self.uplink, "downlink": self.downlink}
        if self.grad_uplink is not None:
            chans["grad_uplink"] = self.grad_uplink
        return chans

    def init_comm_state(self):
        """Fresh channel-state pytree (per-worker EF memories)."""
        return {
            "uplink": self.uplink.init_state(),
            "downlink": self.downlink.init_state(),
            "grad": (self.grad_uplink.init_state()
                     if self.grad_uplink is not None else jnp.zeros((0,))),
        }

    # ------------------------------------------------------------------
    def _worker_solve(self, w, X, y, global_g):
        """One worker: local g, H; solve the cubic sub-problem (Eq. 2)."""
        cfg = self.config
        g = self._grad_fn(w, X, y) if global_g is None else global_g
        H = self._hess_fn(w, X, y)
        return solve_cubic_gd(
            g,
            H,
            M=cfg.M,
            gamma=cfg.gamma,
            tol=cfg.solver_tol,
            max_iters=cfg.solver_iters,
        )

    def _step_impl(self, w, v, state, X, y, key):
        cfg = self.config
        m = X.shape[0]
        k_label, k_update, k_comp, k_grad, k_down = jax.random.split(key, 5)
        new_state = dict(state)

        # Data-level attacks corrupt Byzantine workers' labels *before* the
        # local computation (they "train on wrong labels", §6).
        y_used = self._attack_rule.corrupt_labels(k_label, y)

        global_g = None
        if cfg.exact_gradient:
            # Remark 5: round 1 ships local gradients through the gradient
            # channel (δ-compressed + EF21 when configured); the center
            # aggregates with the SAME registry rule as the update round
            # (Byzantine workers corrupt their gradient share too).
            per_g = jax.vmap(self._grad_fn, in_axes=(None, 0, 0))(w, X, y_used)
            per_g, new_state["grad"] = self.grad_uplink.transmit(
                per_g, state["grad"], key=k_grad
            )
            global_g, _ = self.aggregator(per_g)

        s = jax.vmap(
            lambda Xi, yi: self._worker_solve(w, Xi, yi, global_g)
        )(X, y_used)

        # Uplink: honest workers δ-compress s_i (EF/EF21 memory carries the
        # residual across rounds); the channel's Byzantine hook corrupts the
        # *reconstructed* vectors — Byzantine workers send arbitrary
        # payloads, so compression grants them no protection.  ``measure``
        # surfaces the achieved contraction δ̂ (one norm ratio, taken
        # BEFORE Byzantine injection) for the adaptive-k schedule.
        # per-worker δ̂ is forensic-only: staged into the trace ONLY when
        # telemetry is enabled at trace time, so the disabled program is
        # the exact pre-forensics HLO (the zero-cost contract's pin)
        forensics = get_telemetry().enabled
        worker_delta = None
        if self._use_sparse_center:
            # sparse-domain center: the wire payloads (m, k) go straight
            # to the aggregator's sparse path — the m dense (d,) vectors
            # are never materialized at the center (O(m·k) not O(m·d)).
            # Valid exactly when the channel has no EF state and no
            # update attack (supports_sparse_receive, checked at build).
            if forensics:
                (pv, pidx), new_state["uplink"], uplink_delta, \
                    worker_delta = self.uplink.transmit_sparse(
                        s, state["uplink"], key=k_comp, measure=True,
                        per_sender=True,
                    )
            else:
                (pv, pidx), new_state["uplink"], uplink_delta = \
                    self.uplink.transmit_sparse(
                        s, state["uplink"], key=k_comp, measure=True
                    )
            agg, keep = self.aggregator.sparse(pv, pidx, w.shape[0])
            # payload norms == reconstruction norms (distinct indices)
            update_norms = jnp.linalg.norm(pv, axis=-1)
        else:
            if forensics:
                s, new_state["uplink"], uplink_delta, worker_delta = \
                    self.uplink.transmit(
                        s, state["uplink"], key=k_comp, attack_key=k_update,
                        measure=True, per_sender=True,
                    )
            else:
                s, new_state["uplink"], uplink_delta = self.uplink.transmit(
                    s, state["uplink"], key=k_comp, attack_key=k_update,
                    measure=True
                )

            # Center: the resolved aggregation rule (Algorithm 1, step 6
            # is norm_trim; krum / trimmed_mean / coordinate_median /
            # mean come from the same registry).
            agg, keep = self.aggregator(s)
            update_norms = jnp.linalg.norm(s, axis=-1)
        # optional momentum on the aggregated direction (CRm, [WZLL20] —
        # cited in §2; the paper itself uses v ≡ agg, i.e. momentum = 0)
        v_new = cfg.momentum * v + agg

        # Downlink: the center broadcasts the aggregated step η·v through
        # its own channel (EF state lives at the center); every worker —
        # and the center's own iterate — applies the same reconstruction,
        # so the cluster stays in sync.
        delta, new_state["downlink"] = self.downlink.transmit(
            cfg.eta * v_new, state["downlink"], key=k_down
        )
        w_new = w + delta
        info = {
            "update_norms": update_norms, "keep": keep,
            "uplink_delta": uplink_delta,
        }
        if worker_delta is not None:
            info["worker_delta"] = worker_delta
        return w_new, v_new, new_state, info

    # ------------------------------------------------------------------
    def step(self, w, X, y, key, v=None, state=None):
        """One round.  Returns (w, v, state, info) where ``state`` is the
        channel-state pytree (per-worker EF memories; see
        :meth:`init_comm_state`)."""
        self._ensure_channels(w.shape[0], X.shape[0])
        v = jnp.zeros_like(w) if v is None else v
        state = self.init_comm_state() if state is None else state
        # every (re)compile of the step is attributed to this scope by
        # the telemetry compile-counter (host-side contextvar, never
        # traced) — the compile-count regression pins read it
        with compile_scope("newton.step"):
            return self._step(w, v, state, X, y, key)

    # -- wire accounting ------------------------------------------------
    def bits_per_step(self) -> dict:
        """Exact bits ONE step costs per direction (static Python ints;
        channels must exist — i.e. after the first step or
        :meth:`_ensure_channels`).  Two-round mode adds the gradient
        channel uplink and the full-precision gradient broadcast."""
        up = self.uplink.bits_per_round()
        down = self.downlink.bits_per_round()
        if self.grad_uplink is not None:
            up += self.grad_uplink.bits_per_round()
            down += 32 * self.uplink.d  # center broadcasts the averaged g
        return {"uplink": up, "downlink": down}

    def center_bytes_per_round(self) -> int:
        """Bytes the center's aggregation path touches per round (static
        Python int, like :meth:`bits_per_step`): what the receiver
        materializes between the wire and the (d,) aggregate.  Sparse
        center: the m (value, index) payloads (4 B each entry) plus the
        aggregate — O(m·k + d).  Dense center: m reconstructed f32
        vectors plus the aggregate — O(m·d).  Re-read per round: an
        adaptive uplink moves k between rounds."""
        m, d = self.uplink.n_senders, self.uplink.d
        if self._use_sparse_center:
            k = min(self.uplink.compressor.k, d)
            return m * k * 8 + 4 * d
        return m * d * 4 + 4 * d

    def _agg_kernel_label(self) -> str:
        """Which center path this configuration runs — the round record's
        ``agg_kernel`` field: ``"sparse"`` (payload-domain aggregation),
        ``"fused"`` (a kernel-backed dense rule), or ``"dense"``."""
        if self._use_sparse_center:
            return "sparse"
        if getattr(self.aggregator, "use_kernel", False):
            return "fused"
        return "dense"

    def _maybe_adapt(self, grad_norm: float,
                     measured_delta: Optional[float] = None) -> bool:
        """Feed adaptive compressors the host-side signals (gradient-norm
        plateau + the uplink channel's measured per-round δ); rebuild the
        jitted step when any k changed (static shapes moved).  Returns
        whether a rebuild happened (the round record's ``k_changed``)."""
        changed = False
        for name, ch in self.channels.items():
            comp = ch.compressor
            if isinstance(comp, AdaptiveTopK):
                changed |= comp.schedule_update(
                    grad_norm=grad_norm,
                    measured_delta=(measured_delta
                                    if name == "uplink" else None),
                )
        if changed:
            self._rebuild_jit()
        return changed

    def _uplink_k(self) -> Optional[int]:
        """The uplink's live adaptive k (None on non-adaptive wires)."""
        comp = self.uplink.compressor if self.uplink is not None else None
        return comp.k if isinstance(comp, AdaptiveTopK) else None

    def _worker_round_fields(self, info: dict, m: int, bps: dict,
                             tracker: SuspicionTracker) -> dict:
        """The schema-v4 per-worker round fields (host-side; called only
        when telemetry is enabled).  Uplink bits split evenly: every
        worker ships the same static payload per round."""
        keep = [float(k) for k in info["keep"]]
        norms = [float(n) for n in info["update_norms"]]
        fields = {
            "worker_bits": [bps["uplink"] // m] * m,
            "worker_keep": keep,
            "worker_norms": norms,
            "suspicion": tracker.update(keep=keep, norms=norms),
        }
        if info.get("worker_delta") is not None:
            fields["worker_delta"] = [float(x) for x in info["worker_delta"]]
        if self._attack_rule.kind != "none":
            fields["byzantine_true"] = planted_byzantine_ids(
                m, self._attack_rule.alpha
            )
        return fields

    def run(
        self,
        w0,
        X,
        y,
        n_steps: int,
        key=None,
        eval_fn: Optional[Callable] = None,
        grad_tol: Optional[float] = None,
        full_data=None,
        deadline: Optional[float] = None,
        saddle_value: Optional[float] = None,
    ):
        """Run Algorithm 1 for ``n_steps`` (or until ‖∇f‖ ≤ grad_tol on the
        pooled data).  Returns (w, history dict); the history carries the
        exact integer uplink/downlink wire totals from the ledger plus the
        per-step cumulative total (the bits-to-ε curve's x axis), the
        per-round measured δ̂, and the adaptive-k trajectory (``None``
        entries on non-adaptive wires) — so sweep stores can pivot on
        them.

        ``deadline`` (a ``time.monotonic()`` timestamp) cooperatively
        truncates the loop at the first round boundary past it — always
        after at least one round — with ``hist["truncated"] = True``;
        the sweep runner's per-cell wall-time budget.

        ``saddle_value`` (the problem's known f at its strict saddle, if
        any) defines the saddle-escape flag: the round whose loss first
        drops below it is the escape round (telemetry round records +
        ``hist["saddle_escape_step"]``)."""
        import time as _time

        key = key if key is not None else jax.random.PRNGKey(0)
        if full_data is None:
            full_data = (X.reshape(-1, X.shape[-1]), y.reshape(-1))
        Xf, yf = full_data
        gradf = jax.jit(jax.grad(self.loss_fn))
        lossf = jax.jit(self.loss_fn)

        self._ensure_channels(w0.shape[0], X.shape[0])
        ledger = self.ledger
        ledger.reset()
        hist = {"loss": [], "grad_norm": [], "eval": [], "rounds": 0,
                "bits_cumulative": [], "uplink_delta": [],
                "k_trajectory": [], "saddle_escape_step": None,
                "truncated": False}
        tel = get_telemetry()
        # f(w0) anchors the first round's model decrease; only computed
        # when someone is listening (one extra loss eval)
        prev_loss = float(lossf(w0, Xf, yf)) if tel.enabled else None
        tracker = SuspicionTracker(X.shape[0]) if tel.enabled else None
        w = w0
        v = jnp.zeros_like(w0)
        state = self.init_comm_state()
        for t in range(n_steps):
            if deadline is not None and hist["loss"] \
                    and _time.monotonic() >= deadline:
                hist["truncated"] = True
                if tel.enabled:
                    tel.event("newton.truncated", step=t)
                break
            key, sub = jax.random.split(key)
            k_live = self._uplink_k()      # the k this round transmits at
            w, v, state, info = self.step(w, X, y, sub, v, state)
            # re-read every step: adaptive compressors move k between steps
            bps = self.bits_per_step()
            ledger.record(uplink=bps["uplink"], downlink=bps["downlink"],
                          rounds=self.rounds_per_step, label="round")
            hist["bits_cumulative"].append(ledger.total_bits)
            delta_hat = float(info["uplink_delta"])
            hist["uplink_delta"].append(delta_hat)
            hist["k_trajectory"].append(k_live)
            gn = float(jnp.linalg.norm(gradf(w, Xf, yf)))
            loss = float(lossf(w, Xf, yf))
            hist["loss"].append(loss)
            hist["grad_norm"].append(gn)
            if eval_fn is not None:
                hist["eval"].append(float(eval_fn(w)))
            hit_tol = grad_tol is not None and gn <= grad_tol
            k_changed = False
            if not hit_tol:
                k_changed = self._maybe_adapt(gn, measured_delta=delta_hat)
            escaped = (saddle_value is not None
                       and hist["saddle_escape_step"] is None
                       and loss < saddle_value)
            if escaped:
                hist["saddle_escape_step"] = t
            if tel.enabled:
                center_bytes = self.center_bytes_per_round()
                tel.round(RoundRecord(
                    step=t, runtime=self.runtime_label, loss=loss,
                    grad_norm=gn,
                    model_decrease=(None if prev_loss is None
                                    else prev_loss - loss),
                    uplink_delta=delta_hat, k=k_live, k_changed=k_changed,
                    saddle_escape=escaped,
                    rejected=rejected_from_keep(info["keep"]),
                    attack=self.attack.name, alpha=self.attack.alpha,
                    wire_uplink_bits=bps["uplink"],
                    wire_downlink_bits=bps["downlink"],
                    center_bytes=center_bytes,
                    agg_kernel=self._agg_kernel_label(),
                    **self._worker_round_fields(info, X.shape[0], bps,
                                                tracker),
                ), name="newton.round")
                # the O(m·k)-vs-O(m·d) claim, measured per round
                tel.gauge("newton.center_bytes", center_bytes, step=t,
                          agg_kernel=self._agg_kernel_label(),
                          aggregator=self.aggregator.name)
                prev_loss = loss
            if hit_tol:
                break
        hist.update(ledger.snapshot())
        return w, hist
