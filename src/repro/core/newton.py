"""Algorithm 1 — Byzantine-Robust Distributed Cubic-Regularized Newton.

This module is the *paper-faithful* runtime: m workers simulated on one
process, explicit per-worker gradients / Hessians (the paper's LIBSVM regime,
d ≤ a few hundred), the paper's Algorithm 2 inner solver, the four Byzantine
attacks, norm-based thresholding at the center, and (§1's third pillar)
δ-approximate compression of the worker→center updates with error
feedback and exact wire-bit accounting (:mod:`repro.compression`).  It reproduces Figures
1–3 and Table 1.

The at-scale (mesh-sharded, matrix-free) variant for the assigned
architectures lives in :mod:`repro.core.distributed`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import attacks as attacks_lib
from .aggregation import AGGREGATORS, norm_trim
from .cubic import solve_cubic_gd
from ..compression import make_compressor, make_error_feedback


@dataclasses.dataclass(frozen=True)
class NewtonConfig:
    """Hyper-parameters of Algorithm 1 (paper's notation)."""

    M: float = 10.0          # cubic regularization weight
    gamma: float = 1.0       # sub-problem second/third-order emphasis (Remark 1)
    eta: float = 1.0         # step size η_k (paper uses 1 in experiments)
    beta: float = 0.0        # trim fraction (β > α required for resilience)
    solver_tol: float = 1e-6
    solver_iters: int = 500  # cap for Algorithm 2's while-loop
    exact_gradient: bool = False  # Remark 5: extra round ⇒ ε_g = 0
    momentum: float = 0.0    # beyond-paper: CR-with-momentum [WZLL20]
    # δ-approximate compression of the worker→center update s_i (§1's
    # third pillar / COMRADE): a repro.compression spec string, e.g.
    # "topk:0.1", "signnorm", "int8" — None ⇒ full precision.
    compressor: Optional[str] = None
    error_feedback: str = "ef21"  # "none" | "ef" | "ef21" (tracking)
    ef_damping: float = 0.75      # θ; mid-plateau on w8a (see error_feedback.py)


@dataclasses.dataclass(frozen=True)
class AttackConfig:
    name: str = "none"            # one of attacks_lib UPDATE/LABEL attacks
    alpha: float = 0.0            # Byzantine fraction
    sigma: float = 10.0           # gaussian attack scale
    c: float = 0.9                # negative-update attack scale
    num_classes: int = 2


class DistributedCubicNewton:
    """Simulated cluster running Algorithm 1.

    ``loss_fn(w, X, y) -> scalar`` is the per-worker empirical loss; workers'
    data is stacked on a leading axis: ``X: (m, n, d)``, ``y: (m, n)``.
    One ``step`` = one communication round (two if ``exact_gradient``).
    """

    def __init__(
        self,
        loss_fn: Callable,
        config: NewtonConfig = NewtonConfig(),
        attack: AttackConfig = AttackConfig(),
    ):
        self.loss_fn = loss_fn
        self.config = config
        self.attack = attack
        self._grad_fn = jax.grad(loss_fn)
        self._hess_fn = jax.hessian(loss_fn)
        self._step = jax.jit(self._step_impl)
        self.rounds_per_step = 2 if config.exact_gradient else 1

    # ------------------------------------------------------------------
    def _worker_solve(self, w, X, y, global_g):
        """One worker: local g, H; solve the cubic sub-problem (Eq. 2)."""
        cfg = self.config
        g = self._grad_fn(w, X, y) if global_g is None else global_g
        H = self._hess_fn(w, X, y)
        return solve_cubic_gd(
            g,
            H,
            M=cfg.M,
            gamma=cfg.gamma,
            tol=cfg.solver_tol,
            max_iters=cfg.solver_iters,
        )

    def _step_impl(self, w, v, e, X, y, key):
        cfg, atk = self.config, self.attack
        m = X.shape[0]
        mask = attacks_lib.byzantine_mask(m, atk.alpha)
        k_label, k_update, k_comp = jax.random.split(key, 3)

        # Data-level attacks corrupt Byzantine workers' labels *before* the
        # local computation (they "train on wrong labels", §6).
        y_used = y
        if atk.name in attacks_lib.LABEL_ATTACKS and atk.name != "none":
            y_used = attacks_lib.LABEL_ATTACKS[atk.name](
                k_label, y, mask, num_classes=atk.num_classes
            )

        global_g = None
        if cfg.exact_gradient:
            # Remark 5: round 1 ships local gradients; center averages and
            # broadcasts ∇f(x_k).  Byzantine workers corrupt their share too,
            # so we guard the average with the same norm-trim rule.
            per_g = jax.vmap(self._grad_fn, in_axes=(None, 0, 0))(w, X, y_used)
            global_g, _ = norm_trim(per_g, max(cfg.beta, 1e-9))

        s = jax.vmap(
            lambda Xi, yi: self._worker_solve(w, Xi, yi, global_g)
        )(X, y_used)

        # Honest workers δ-compress s_i before transmitting, with EF/EF21
        # memory carrying the compression residual across rounds.
        # Byzantine workers send arbitrary payloads anyway, so the update
        # attacks below corrupt the *reconstructed* vectors.
        comp = make_compressor(cfg.compressor, w.shape[0])
        if comp is not None:
            ef = make_error_feedback(cfg.error_feedback, comp, cfg.ef_damping)
            keys = jax.random.split(k_comp, m)
            if ef is not None:
                s, e = jax.vmap(lambda xi, ei, ki: ef.apply(xi, ei, key=ki))(
                    s, e, keys
                )
            else:
                s = jax.vmap(lambda xi, ki: comp.roundtrip(xi, key=ki))(
                    s, keys
                )

        # Update-level attacks corrupt what Byzantine workers *send*.
        if atk.name in attacks_lib.UPDATE_ATTACKS and atk.name != "none":
            s = attacks_lib.UPDATE_ATTACKS[atk.name](
                k_update, s, mask, **self._attack_kwargs()
            )

        # Center: norm-based thresholding (Algorithm 1, step 6).
        if cfg.beta > 0:
            agg, keep = norm_trim(s, cfg.beta)
        else:
            agg, keep = s.mean(0), jnp.ones((m,))
        # optional momentum on the aggregated direction (CRm, [WZLL20] —
        # cited in §2; the paper itself uses v ≡ agg, i.e. momentum = 0)
        v_new = cfg.momentum * v + agg
        w_new = w + cfg.eta * v_new
        return w_new, v_new, e, {
            "update_norms": jnp.linalg.norm(s, axis=-1), "keep": keep,
        }

    def _attack_kwargs(self):
        if self.attack.name == "gaussian":
            return {"sigma": self.attack.sigma}
        if self.attack.name == "negative":
            return {"c": self.attack.c}
        return {}

    # ------------------------------------------------------------------
    def step(self, w, X, y, key, v=None, e=None):
        """One round.  Returns (w, v, e, info) where ``e`` is the workers'
        (m, d) error-feedback memory (zeros when compression is off)."""
        v = jnp.zeros_like(w) if v is None else v
        e = self._init_error(w, X.shape[0]) if e is None else e
        return self._step(w, v, e, X, y, key)

    def _init_error(self, w, m):
        return jnp.zeros((m, w.shape[0]), jnp.float32)

    def wire_bits_per_step(self, d: int, m: int) -> int:
        """Exact uplink bits one *step* costs: m compressed s_i payloads,
        plus (in two-round mode) m full-precision local gradients."""
        comp = make_compressor(self.config.compressor, d)
        bits = m * (comp.wire_bits(d) if comp is not None else 32 * d)
        if self.config.exact_gradient:
            bits += m * 32 * d   # Remark-5 gradient round is uncompressed
        return bits

    def run(
        self,
        w0,
        X,
        y,
        n_steps: int,
        key=None,
        eval_fn: Optional[Callable] = None,
        grad_tol: Optional[float] = None,
        full_data=None,
    ):
        """Run Algorithm 1 for ``n_steps`` (or until ‖∇f‖ ≤ grad_tol on the
        pooled data).  Returns (w, history dict)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        if full_data is None:
            full_data = (X.reshape(-1, X.shape[-1]), y.reshape(-1))
        Xf, yf = full_data
        gradf = jax.jit(jax.grad(self.loss_fn))
        lossf = jax.jit(self.loss_fn)

        hist = {"loss": [], "grad_norm": [], "eval": [], "rounds": 0,
                "wire_bits": 0}
        bits_per_step = self.wire_bits_per_step(w0.shape[0], X.shape[0])
        w = w0
        v = jnp.zeros_like(w0)
        e = self._init_error(w0, X.shape[0])
        for t in range(n_steps):
            key, sub = jax.random.split(key)
            w, v, e, _ = self.step(w, X, y, sub, v, e)
            hist["rounds"] += self.rounds_per_step
            hist["wire_bits"] += bits_per_step
            gn = float(jnp.linalg.norm(gradf(w, Xf, yf)))
            hist["loss"].append(float(lossf(w, Xf, yf)))
            hist["grad_norm"].append(gn)
            if eval_fn is not None:
                hist["eval"].append(float(eval_fn(w)))
            if grad_tol is not None and gn <= grad_tol:
                break
        return w, hist
