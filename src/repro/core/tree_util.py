"""Pytree vector-space helpers used throughout the cubic-Newton core.

The paper operates on parameter vectors ``x ∈ R^d``.  For the large assigned
architectures the parameter is a pytree; these helpers give the handful of
vector-space operations (axpy, dot, norm, zeros-like) the algorithms need,
with semantics identical to flattening the tree into one ``d``-vector.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(a, c):
    return jax.tree_util.tree_map(lambda x: x * c, a)


def tree_axpy(c, x, y):
    """y + c * x (the BLAS axpy), elementwise over the tree."""
    return jax.tree_util.tree_map(lambda xi, yi: yi + c * xi, x, y)


def tree_dot(a, b):
    """<a, b> as if both trees were flattened to d-vectors (fp32 accumulate)."""
    leaves = jax.tree_util.tree_map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return jax.tree_util.tree_reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_sqnorm(a):
    return tree_dot(a, a)


def tree_norm(a):
    return jnp.sqrt(tree_sqnorm(a))


def tree_zeros_like(a):
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def tree_randn_like(key, a, scale=1.0):
    """Gaussian tree with the same structure/shapes/dtypes as ``a``."""
    leaves, treedef = jax.tree_util.tree_flatten(a)
    keys = jax.random.split(key, len(leaves))
    out = [
        (scale * jax.random.normal(k, x.shape)).astype(x.dtype)
        for k, x in zip(keys, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_size(a):
    """Total number of scalar parameters d."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(a))


def tree_cast(a, dtype):
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), a)
