from .pipeline import WorkerBatcher
from .synthetic import (
    TokenStream,
    make_classification,
    make_regression,
    paper_dataset,
    shard_to_workers,
)

__all__ = [
    "TokenStream",
    "WorkerBatcher",
    "make_classification",
    "make_regression",
    "paper_dataset",
    "shard_to_workers",
]
