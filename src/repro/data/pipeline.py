"""Sharded batching pipeline: worker-major batches for the Newton step.

``WorkerBatcher`` produces batches whose leaves carry the ``(m_workers,
per_worker_batch, …)`` layout that :func:`repro.core.distributed.make_train_step`
expects, plus the modality stubs (prefix/frame embeddings) the VLM/audio
architectures need.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .synthetic import TokenStream


class WorkerBatcher:
    def __init__(self, cfg, m_workers: int, global_batch: int, seq_len: int, seed=0):
        assert global_batch % m_workers == 0, (global_batch, m_workers)
        self.cfg = cfg
        self.m = m_workers
        self.per_worker = global_batch // m_workers
        self.seq_len = seq_len
        self.stream = TokenStream(cfg.vocab_size, seed)
        self.seed = seed

    def text_len(self):
        if self.cfg.family == "vlm":
            return self.seq_len - self.cfg.num_prefix_tokens
        return self.seq_len

    def __call__(self, step: int):
        B = self.m * self.per_worker
        toks, targets = self.stream.batch(step, B, self.text_len())
        batch = {
            "tokens": toks.reshape(self.m, self.per_worker, -1),
            "targets": targets.reshape(self.m, self.per_worker, -1),
        }
        if self.cfg.family == "vlm":
            key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 7), step)
            batch["prefix_emb"] = jax.random.normal(
                key,
                (self.m, self.per_worker, self.cfg.num_prefix_tokens, self.cfg.d_model),
                jnp.float32,
            )
        if self.cfg.family == "audio":
            key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 13), step)
            batch["enc_emb"] = jax.random.normal(
                key,
                (self.m, self.per_worker, self.cfg.encoder_len, self.cfg.d_model),
                jnp.float32,
            )
        return batch
