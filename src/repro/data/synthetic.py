"""Synthetic data generators.

* LIBSVM twins (paper experiments): binary classification matched to the
  published a9a / w8a shapes, from a ground-truth separator + label noise —
  the offline stand-in justified in DESIGN.md §6/§8.
* Robust-regression data with heavy-tailed outliers (the non-convex loss of
  the paper's Eq. (9) is exactly built for this).
* Token streams for the LM architectures (Zipf-distributed with Markov
  structure so the loss has signal to descend).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def make_classification(key, n, d, *, label_noise=0.05, margin=1.0):
    """Linear-separator binary data: X (n,d), y∈{0,1} (n,)."""
    kx, kw, kn = jax.random.split(key, 3)
    X = jax.random.normal(kx, (n, d))
    w_star = margin * jax.random.normal(kw, (d,)) / jnp.sqrt(d)
    p = jax.nn.sigmoid(X @ w_star / 0.5)
    y = (jax.random.uniform(kn, (n,)) < p).astype(jnp.float32)
    flip = jax.random.uniform(jax.random.fold_in(kn, 1), (n,)) < label_noise
    y = jnp.where(flip, 1.0 - y, y)
    return X, y, w_star


def make_regression(key, n, d, *, noise=0.1, outlier_frac=0.1, outlier_scale=10.0):
    """Linear data with heavy-tailed outliers (robust-regression target)."""
    kx, kw, kn, ko, km = jax.random.split(key, 5)
    X = jax.random.normal(kx, (n, d))
    w_star = jax.random.normal(kw, (d,)) / jnp.sqrt(d)
    y = X @ w_star + noise * jax.random.normal(kn, (n,))
    out_mask = jax.random.uniform(km, (n,)) < outlier_frac
    y = jnp.where(out_mask, y + outlier_scale * jax.random.normal(ko, (n,)), y)
    return X, y, w_star


def shard_to_workers(X, y, m):
    """Split pooled (n, …) data into m worker shards: (m, n/m, …)."""
    n = (X.shape[0] // m) * m
    return (
        X[:n].reshape(m, n // m, *X.shape[1:]),
        y[:n].reshape(m, n // m, *y.shape[1:]),
    )


def paper_dataset(workload, seed=0):
    """Build the train/test twin of a paper workload (see configs)."""
    key = jax.random.PRNGKey(seed)
    ktr, kte = jax.random.split(key)
    if workload.problem == "logistic":
        Xtr, ytr, w_star = make_classification(ktr, workload.n_train, workload.dim)
        Xte, yte, _ = make_classification(kte, workload.n_test, workload.dim)
        # re-label test with the same separator for a consistent task
        p = jax.nn.sigmoid(Xte @ w_star / 0.5)
        yte = (p > 0.5).astype(jnp.float32)
    else:
        Xtr, ytr, w_star = make_regression(ktr, workload.n_train, workload.dim)
        Xte, yte, _ = make_regression(kte, workload.n_test, workload.dim, outlier_frac=0.0)
    Xm, ym = shard_to_workers(Xtr, ytr, workload.m_workers)
    return {
        "X_workers": Xm,
        "y_workers": ym,
        "X_train": Xtr,
        "y_train": ytr,
        "X_test": Xte,
        "y_test": yte,
        "w_star": w_star,
    }


# ----------------------------- LM token streams ---------------------------


class TokenStream:
    """Zipf+Markov synthetic token source.  Deterministic per (seed, step)."""

    def __init__(self, vocab_size: int, seed: int = 0, zipf_a: float = 1.2):
        self.vocab = vocab_size
        self.seed = seed
        # modest working vocab so bigram structure is learnable
        self.active = min(vocab_size, 4096)
        rng = np.random.default_rng(seed)
        self._shift = rng.integers(1, self.active - 1)
        ranks = np.arange(1, self.active + 1, dtype=np.float64)
        p = ranks ** (-zipf_a)
        self._probs = jnp.asarray(p / p.sum(), jnp.float32)

    def batch(self, step: int, batch_size: int, seq_len: int):
        """tokens, targets: (batch, seq)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        base = jax.random.choice(
            key, self.active, (batch_size, seq_len + 1), p=self._probs
        )
        # inject a deterministic bigram: even positions predict a shifted copy
        idx = jnp.arange(seq_len + 1)
        shifted = (jnp.roll(base, 1, axis=1) + self._shift) % self.active
        toks = jnp.where((idx % 2 == 1)[None, :], shifted, base).astype(jnp.int32)
        return toks[:, :-1], toks[:, 1:]
