"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §2):

* :mod:`flash_attention` — online-softmax attention, causal + sliding window
  (the prefill/train hot loop of every attention arch).
* :mod:`cubic_step` — fused Algorithm-2 inner iteration for the paper's
  explicit-Hessian regime (the solver hot loop of the reproduction).
* :mod:`topk_compress` — fused top-k compression payload (threshold
  bisection + MXU pack), the wire hot-spot of repro.compression.
* :mod:`rmsnorm` — row-tiled RMSNorm.

Each has a pure-jnp oracle in :mod:`ref` and a jit wrapper in :mod:`ops`;
kernels run interpret=True off-TPU.
"""
from .ops import (
    attention_bshd,
    cubic_solve_fused,
    cubic_step,
    flash_attention,
    rmsnorm,
    rmsnorm_nd,
    topk_compress,
    topk_decompress,
)

__all__ = [
    "attention_bshd",
    "cubic_solve_fused",
    "cubic_step",
    "flash_attention",
    "rmsnorm",
    "rmsnorm_nd",
    "topk_compress",
    "topk_decompress",
]
