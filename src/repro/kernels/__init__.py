"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §2):

* :mod:`flash_attention` — online-softmax attention, causal + sliding window
  (the prefill/train hot loop of every attention arch).
* :mod:`cubic_step` — fused Algorithm-2 inner iteration for the paper's
  explicit-Hessian regime (the solver hot loop of the reproduction).
* :mod:`topk_compress` — fused top-k compression payload, the wire
  hot-spot of repro.compression: a single-tile launch (threshold
  bisection + MXU pack) for d ≤ 1408 and a sharded grid-over-blocks
  launch with a two-pass radix-select global threshold for model-scale
  vectors; ``topk_compress`` auto-selects by d (``kernel_plan``).
* :mod:`rmsnorm` — row-tiled RMSNorm.

Each has a pure-jnp oracle in :mod:`ref` and a jit wrapper in :mod:`ops`;
kernels run interpret=True off-TPU.
"""
from .ops import (
    DEFAULT_BLOCK,
    SINGLE_TILE_MAX_D,
    attention_bshd,
    cubic_solve_fused,
    cubic_step,
    flash_attention,
    kernel_plan,
    rmsnorm,
    rmsnorm_nd,
    topk_compress,
    topk_compress_sharded,
    topk_compress_tiled,
    topk_decompress,
)

__all__ = [
    "DEFAULT_BLOCK",
    "SINGLE_TILE_MAX_D",
    "attention_bshd",
    "cubic_solve_fused",
    "cubic_step",
    "flash_attention",
    "kernel_plan",
    "rmsnorm",
    "rmsnorm_nd",
    "topk_compress",
    "topk_compress_sharded",
    "topk_compress_tiled",
    "topk_decompress",
]
