"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §2):

* :mod:`flash_attention` — online-softmax attention, causal + sliding window
  (the prefill/train hot loop of every attention arch).
* :mod:`cubic_step` — fused Algorithm-2 inner iteration for the paper's
  explicit-Hessian regime (the solver hot loop of the reproduction).
* :mod:`topk_compress` — fused top-k compression payload, the wire
  hot-spot of repro.compression: a single-tile launch (threshold
  bisection + MXU pack) for d ≤ 1408 and a sharded grid-over-blocks
  launch with a two-pass radix-select global threshold for model-scale
  vectors; ``topk_compress`` auto-selects by d (``kernel_plan``).
* :mod:`robust_agg` — fused robust aggregation for the center's hot
  path: sparse-domain segmented scatter-add over top-k wire payloads
  (O(m·k) center memory, never densifying), blocked O(m²) krum pairwise
  distances with on-chip score reduction, and a tiled per-coordinate
  bitonic row sort behind trimmed-mean / coordinate-median;
  ``agg_kernel_plan`` auto-selects the launch.
* :mod:`rmsnorm` — row-tiled RMSNorm.

Each has a pure-jnp oracle in :mod:`ref` and a jit wrapper in :mod:`ops`;
kernels run interpret=True off-TPU.
"""
from .ops import (
    AGG_BLOCK,
    DEFAULT_BLOCK,
    DENSE_FUSED_MAX_M,
    SINGLE_TILE_MAX_D,
    SPARSE_SCATTER_MAX_D,
    agg_kernel_plan,
    aggregate_sparse,
    aggregate_sparse_gridded,
    aggregate_sparse_scatter,
    attention_bshd,
    coordinate_median_fused,
    cubic_solve_fused,
    cubic_step,
    flash_attention,
    kernel_plan,
    krum_scores_fused,
    krum_select_fused,
    rmsnorm,
    rmsnorm_nd,
    sort_workers_fused,
    topk_compress,
    topk_compress_sharded,
    topk_compress_tiled,
    topk_decompress,
    trimmed_mean_fused,
)

__all__ = [
    "AGG_BLOCK",
    "DEFAULT_BLOCK",
    "DENSE_FUSED_MAX_M",
    "SINGLE_TILE_MAX_D",
    "SPARSE_SCATTER_MAX_D",
    "agg_kernel_plan",
    "aggregate_sparse",
    "aggregate_sparse_gridded",
    "aggregate_sparse_scatter",
    "attention_bshd",
    "coordinate_median_fused",
    "cubic_solve_fused",
    "cubic_step",
    "flash_attention",
    "kernel_plan",
    "krum_scores_fused",
    "krum_select_fused",
    "rmsnorm",
    "rmsnorm_nd",
    "sort_workers_fused",
    "topk_compress",
    "topk_compress_sharded",
    "topk_compress_tiled",
    "topk_decompress",
    "trimmed_mean_fused",
]
