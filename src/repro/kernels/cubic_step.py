"""Pallas TPU kernel: fused Algorithm-2 inner iteration (explicit Hessian).

The paper's cubic solver inner loop (Algorithm 2) on the LIBSVM-scale
problems (d ≤ ~1k) is a chain of small ops — matvec, norm, three axpys —
each of which would round-trip HBM as a separate XLA kernel.  This kernel
fuses one full iteration

    G = g + γ·H s + (Mγ²/2)·‖s‖·s ;   s ← s − ξ·G

into a single VMEM-resident pass: H is tiled (block_d rows at a time, each
row tile (block_d, d) in VMEM), the matvec accumulates in fp32, and the norm
is computed once from s which stays resident.  For d=300 (w8a) the whole
state is (300² + 3·300)·4B ≈ 360 KB — comfortably inside the ~16 MB VMEM,
so the default is a single-tile launch.

Validated in interpret mode against :func:`repro.kernels.ref.cubic_step_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cubic_kernel(s_ref, g_ref, h_ref, o_ref, *, M, gamma, lr):
    s = s_ref[...].astype(jnp.float32)      # (d,)
    g = g_ref[...].astype(jnp.float32)      # (d,)
    H = h_ref[...].astype(jnp.float32)      # (d, d)
    sn = jnp.sqrt(jnp.sum(s * s))
    Hs = jax.lax.dot_general(
        H, s, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    G = g + gamma * Hs + 0.5 * M * gamma**2 * sn * s
    o_ref[...] = (s - lr * G).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("M", "gamma", "lr", "interpret")
)
def cubic_step(s, g, H, *, M=10.0, gamma=1.0, lr=1e-2, interpret=None):
    """One fused Algorithm-2 iteration.  s,g: (d,), H: (d,d)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    d = s.shape[0]
    kernel = functools.partial(_cubic_kernel, M=M, gamma=gamma, lr=lr)
    return pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[
            pl.BlockSpec((d,), lambda: (0,)),
            pl.BlockSpec((d,), lambda: (0,)),
            pl.BlockSpec((d, d), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((d,), lambda: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), s.dtype),
        interpret=interpret,
    )(s, g, H)


def cubic_solve_fused(g, H, *, M=10.0, gamma=1.0, lr=None, n_iters=200,
                      interpret=None):
    """Full Algorithm-2 run with the fused kernel as the loop body."""
    if lr is None:
        lr = float(1.0 / (gamma * (jnp.linalg.norm(H) + M * gamma) + 1e-8))
    step = functools.partial(
        cubic_step, M=M, gamma=gamma, lr=lr, interpret=interpret
    )
    def body(_, s):
        return step(s, g, H)
    return jax.lax.fori_loop(0, n_iters, body, jnp.zeros_like(g))
