"""Pallas TPU flash attention (causal / sliding-window, fp32 accumulation).

TPU-native tiling (DESIGN.md §3): grid = (B·H, S/Bq, S/Bk) with the KV axis
innermost so the (max, sum-exp, accumulator) scratch carries across KV steps
for one query tile; block shapes are MXU-aligned (Bq, Bk multiples of 128,
head_dim lanes).  Per-tile VMEM footprint:

    q (Bq,Dh) + k,v (Bk,Dh) + acc (Bq,Dh) + logits (Bq,Bk)   ≈ 4·128·128·4B
                                                             « 16 MB VMEM.

Out-of-band tiles (fully above the causal diagonal / outside the window) are
skipped with ``pl.when`` — the kernel issues no MXU work for them, which is
the structural win over the masked dense form.

Validated in interpret mode on CPU against :func:`repro.kernels.ref.flash_attention_ref`
(this container has no TPU; interpret=True executes the same kernel body).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
               *, causal, window, block_q, block_k, n_k, scale):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    q_start = qi * block_q
    k_start = ki * block_k

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # visibility of this (q, k) tile pair
    relevant = True
    if causal:
        relevant = k_start <= q_start + block_q - 1  # some key ≤ some query
    if window > 0:
        relevant = jnp.logical_and(
            relevant, k_start + block_k - 1 > q_start - window
        )

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (Bq, Dh)
        k = k_ref[0].astype(jnp.float32)          # (Bk, Dh)
        v = v_ref[0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                  # (Bq, Bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new[:, None])
        l_scr[...] = l_prev * corr + p.sum(axis=-1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(ki == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(q, k, v, *, causal=True, window=0,
                    block_q=128, block_k=128, interpret=None):
    """q,k,v: (B, H, S, Dh) → (B, H, S, Dh).  GQA is handled by the caller
    (repeat kv heads) — the kernel sees head-major already-matched tensors."""
    B, H, S, Dh = q.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    n_q, n_k = S // block_q, S // block_k
    scale = 1.0 / (Dh ** 0.5)

    qf = q.reshape(B * H, S, Dh)
    kf = k.reshape(B * H, S, Dh)
    vf = v.reshape(B * H, S, Dh)

    kernel = functools.partial(
        _fa_kernel, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k, scale=scale,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, Dh), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, Dh), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, Dh), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dh), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),   # running max
            pltpu.VMEM((block_q,), jnp.float32),   # running sum-exp
            pltpu.VMEM((block_q, Dh), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, Dh)
