"""jit'd public wrappers around the Pallas kernels.

These present model-layer-friendly signatures (GQA head matching, layout
transposes) so call sites can swap between the pure-JAX reference path and
the TPU kernels with one flag.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .cubic_step import cubic_solve_fused, cubic_step
from .flash_attention import flash_attention
from .rmsnorm import rmsnorm
from .topk_compress import (
    DEFAULT_BLOCK,
    SINGLE_TILE_MAX_D,
    kernel_plan,
    topk_compress,
    topk_compress_sharded,
    topk_compress_tiled,
    topk_decompress,
)


def attention_bshd(q, k, v, *, causal=True, window=0, **kw):
    """(B, S, H, Dh) layout (the model zoo's) → flash kernel layout and back.
    GQA: kv heads repeated up to q heads before the kernel."""
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    if Hkv != H:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    out = flash_attention(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=causal,
        window=window,
        **kw,
    )
    return out.transpose(0, 2, 1, 3)


def rmsnorm_nd(x, w, **kw):
    """RMSNorm over the last axis of an arbitrarily-batched tensor."""
    shape = x.shape
    out = rmsnorm(x.reshape(-1, shape[-1]), w, **kw)
    return out.reshape(shape)


__all__ = [
    "DEFAULT_BLOCK",
    "SINGLE_TILE_MAX_D",
    "attention_bshd",
    "cubic_solve_fused",
    "cubic_step",
    "flash_attention",
    "kernel_plan",
    "rmsnorm",
    "rmsnorm_nd",
    "topk_compress",
    "topk_compress_sharded",
    "topk_compress_tiled",
    "topk_decompress",
]
