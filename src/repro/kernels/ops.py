"""jit'd public wrappers around the Pallas kernels.

These present model-layer-friendly signatures (GQA head matching, layout
transposes) so call sites can swap between the pure-JAX reference path and
the TPU kernels with one flag.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .cubic_step import cubic_solve_fused, cubic_step
from .flash_attention import flash_attention
from .rmsnorm import rmsnorm
from .robust_agg import (
    AGG_BLOCK,
    DENSE_FUSED_MAX_M,
    SPARSE_SCATTER_MAX_D,
    agg_kernel_plan,
    aggregate_sparse,
    aggregate_sparse_gridded,
    aggregate_sparse_scatter,
    coordinate_median_fused,
    krum_scores_fused,
    krum_select_fused,
    sort_workers_fused,
    trimmed_mean_fused,
)
from .topk_compress import (
    DEFAULT_BLOCK,
    SINGLE_TILE_MAX_D,
    kernel_plan,
    topk_compress,
    topk_compress_sharded,
    topk_compress_tiled,
    topk_decompress,
)


def attention_bshd(q, k, v, *, causal=True, window=0, **kw):
    """(B, S, H, Dh) layout (the model zoo's) → flash kernel layout and back.
    GQA: kv heads repeated up to q heads before the kernel."""
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    if Hkv != H:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    out = flash_attention(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=causal,
        window=window,
        **kw,
    )
    return out.transpose(0, 2, 1, 3)


def rmsnorm_nd(x, w, **kw):
    """RMSNorm over the last axis of an arbitrarily-batched tensor."""
    shape = x.shape
    out = rmsnorm(x.reshape(-1, shape[-1]), w, **kw)
    return out.reshape(shape)


__all__ = [
    "AGG_BLOCK",
    "DEFAULT_BLOCK",
    "DENSE_FUSED_MAX_M",
    "SINGLE_TILE_MAX_D",
    "SPARSE_SCATTER_MAX_D",
    "agg_kernel_plan",
    "aggregate_sparse",
    "aggregate_sparse_gridded",
    "aggregate_sparse_scatter",
    "attention_bshd",
    "coordinate_median_fused",
    "cubic_solve_fused",
    "cubic_step",
    "flash_attention",
    "kernel_plan",
    "krum_scores_fused",
    "krum_select_fused",
    "rmsnorm",
    "rmsnorm_nd",
    "sort_workers_fused",
    "topk_compress",
    "topk_compress_sharded",
    "topk_compress_tiled",
    "topk_decompress",
    "trimmed_mean_fused",
]
