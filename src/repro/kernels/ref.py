"""Pure-jnp oracles for every Pallas kernel (the contract each kernel must
match; tests sweep shapes/dtypes and assert_allclose against these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q,k,v: (B, H, S, Dh) (head-major layout the kernel uses).
    window=0 ⇒ no sliding window."""
    B, H, S, Dh = q.shape
    scale = 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    qpos = jnp.arange(S)
    kpos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def cubic_step_ref(s, g, H, *, M, gamma, lr):
    """One Algorithm-2 inner iteration (explicit Hessian, the paper's d≤300
    regime):  G = g + γHs + (Mγ²/2)‖s‖s;  s ← s − ξG."""
    s32, g32, H32 = s.astype(jnp.float32), g.astype(jnp.float32), H.astype(jnp.float32)
    sn = jnp.sqrt(jnp.sum(s32 * s32))
    G = g32 + gamma * (H32 @ s32) + 0.5 * M * gamma**2 * sn * s32
    return (s32 - lr * G).astype(s.dtype)


def topk_compress_ref(x, k):
    """Packed top-|x| payload in index-ascending order (the wire format of
    repro.compression.TopK): values (k,), indices (k,) int32."""
    _, idx = jax.lax.top_k(jnp.abs(x), k)
    idx = jnp.sort(idx)
    return x[idx], idx.astype(jnp.int32)


def rmsnorm_ref(x, w, eps=1e-6):
    """x: (N, d), w: (d,).  Gemma-style (1+w) scaling, fp32 accumulation."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))).astype(
        x.dtype
    )
