"""Pure-jnp oracles for every Pallas kernel (the contract each kernel must
match; tests sweep shapes/dtypes and assert_allclose against these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q,k,v: (B, H, S, Dh) (head-major layout the kernel uses).
    window=0 ⇒ no sliding window."""
    B, H, S, Dh = q.shape
    scale = 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    qpos = jnp.arange(S)
    kpos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def cubic_step_ref(s, g, H, *, M, gamma, lr):
    """One Algorithm-2 inner iteration (explicit Hessian, the paper's d≤300
    regime):  G = g + γHs + (Mγ²/2)‖s‖s;  s ← s − ξG."""
    s32, g32, H32 = s.astype(jnp.float32), g.astype(jnp.float32), H.astype(jnp.float32)
    sn = jnp.sqrt(jnp.sum(s32 * s32))
    G = g32 + gamma * (H32 @ s32) + 0.5 * M * gamma**2 * sn * s32
    return (s32 - lr * G).astype(s.dtype)


def topk_compress_ref(x, k):
    """Packed top-|x| payload in index-ascending order (the wire format of
    repro.compression.TopK): values (k,), indices (k,) int32."""
    _, idx = jax.lax.top_k(jnp.abs(x), k)
    idx = jnp.sort(idx)
    return x[idx], idx.astype(jnp.int32)


def topk_compress_sharded_ref(x, k, block=512):
    """Sharded oracle: the two-pass blocked contract of
    :func:`repro.kernels.topk_compress_sharded`, spelled out in numpy-style
    jnp (global threshold → sure/tie split → per-block tie budgets →
    blocked pack → compaction) with NO kernels.  Must equal
    :func:`topk_compress_ref` exactly — proving the blocked layout is a
    pure re-arrangement that changes neither the selected support nor the
    wire payload."""
    import numpy as np

    x = np.asarray(x, np.float32)
    d = x.shape[-1]
    mag = np.abs(x)
    # exact global threshold: the k-th largest magnitude (fp32 total order)
    t = np.sort(mag)[d - k]
    sure = mag > t                          # strictly inside the top-k band
    tie = mag == t                          # fill lowest-index-first
    n_sure = int(sure.sum())
    vals, idx = [], []
    budget_left = k - n_sure                # global tie budget
    for b0 in range(0, d, block):           # block order IS index order
        blk = slice(b0, min(b0 + block, d))
        tie_pos = np.nonzero(tie[blk])[0]
        tie_budget = min(budget_left, len(tie_pos))  # this block's budget
        keep = np.nonzero(sure[blk])[0].tolist()
        keep += tie_pos[:tie_budget].tolist()
        keep = sorted(keep)                 # per-block slice, index-ascending
        budget_left -= tie_budget
        idx += [b0 + j for j in keep]       # rebase to global coordinates
        vals += [x[b0 + j] for j in keep]
    assert len(idx) == k, "blocked budgets must pack exactly k survivors"
    return jnp.asarray(vals, jnp.float32), jnp.asarray(idx, jnp.int32)


def sparse_aggregate_ref(vals, idx, d, weights=None):
    """Segmented-merge contract of :func:`repro.kernels.aggregate_sparse`,
    spelled out sequentially: the m (k,) payloads ravel into one stream,
    the stream is stably sorted by coordinate (lowest-index-first;
    duplicate coordinates keep worker order), and every entry adds into
    the (d,) f32 accumulator **in that order** — one unbuffered
    ``np.add.at`` sweep.  Per-worker weights fold into the values before
    the merge.  No (m, d) array exists at any point."""
    import numpy as np

    v = np.asarray(vals, np.float32)
    if weights is not None:
        v = v * np.asarray(weights, np.float32)[:, None]
    vs = v.reshape(-1)
    ix = np.asarray(idx).reshape(-1)
    order = np.argsort(ix, kind="stable")
    out = np.zeros((d,), np.float32)
    np.add.at(out, ix[order], vs[order])
    return jnp.asarray(out)


def krum_scores_ref(flat, n_byz):
    """Naive O(m²) double-loop krum scores — the [BMGS17] definition the
    fused kernel and the registry ``krum_select`` must both minimize:
    score(i) = Σ of the k = max(m − n_byz − 2, 1) smallest ‖xᵢ − xⱼ‖²
    over j ≠ i, each distance summed coordinate-by-coordinate."""
    import numpy as np

    f = np.asarray(flat, np.float32)
    m = f.shape[0]
    k = max(m - int(n_byz) - 2, 1)
    scores = []
    for i in range(m):
        d2 = sorted(
            float(np.sum((f[i] - f[j]) ** 2)) for j in range(m) if j != i
        )
        scores.append(sum(d2[:k]))
    return jnp.asarray(scores, jnp.float32)


def rmsnorm_ref(x, w, eps=1e-6):
    """x: (N, d), w: (d,).  Gemma-style (1+w) scaling, fp32 accumulation."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))).astype(
        x.dtype
    )
