"""Pallas TPU kernel: RMSNorm (row-tiled, fp32 accumulation).

Grid tiles the row axis; each step normalizes a (block_rows, d) tile fully
in VMEM — one HBM read + one write per element instead of the separate
square/mean/rsqrt/scale kernels XLA would otherwise emit on the norm-heavy
decode path.  d up to ~16k fp32 at block_rows=128 stays ≈ 8 MB < VMEM.

Validated in interpret mode against :func:`repro.kernels.ref.rmsnorm_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)          # (rows, d)
    w = w_ref[...].astype(jnp.float32)          # (d,)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * (1.0 + w)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, *, eps=1e-6, block_rows=128, interpret=None):
    """x: (N, d); w: (d,)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    N, d = x.shape
    block_rows = min(block_rows, N)
    assert N % block_rows == 0, (N, block_rows)
    kernel = functools.partial(_rms_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(N // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d), x.dtype),
        interpret=interpret,
    )(x, w)
