"""Pallas TPU kernels: fused robust aggregation — keep the center compressed.

The center's aggregation rules (``repro.core.aggregation``) consume m
dense (d,) worker vectors.  With top-k uplinks the wire carries only
O(m·k) entries, yet the XLA center path scatters every payload to dense
before aggregating — O(m·d) memory traffic exactly where m·d is largest.
The kernels here close that gap from both ends, mirroring the PR-4
two-pass top-k kernel's sharded structure:

* **sparse-domain aggregation** (:func:`aggregate_sparse`) — a segmented
  scatter-add/merge over the raw (indices, values) wire payloads.  The m
  dense vectors are never materialized: center memory is the O(m·k)
  payload stream plus the single (d,) aggregate.
* **fused distance kernels** — krum's O(m²) pairwise squared distances
  with the score reduction on-chip (:func:`krum_scores_fused`), and the
  per-coordinate sort behind trimmed-mean / coordinate-median as a tiled
  (m, block) bitonic network (:func:`sort_workers_fused`).  Both are
  pinned against the registry implementations the way ``topk_compress``
  is pinned against ``lax.top_k``.

:func:`agg_kernel_plan` is the ``kernel_plan``-style dispatcher the
``repro.api.aggregators`` kernel variants select through.

Sparse segmented merge
----------------------
The contract (oracle: :func:`repro.kernels.ref.sparse_aggregate_ref`):
entries merge in **index-sorted, worker-stable order** — for one output
coordinate, contributions combine lowest-index-first with duplicates in
worker order.  The launch:

1. *stream prep (host-visible jnp, O(m·k log m·k))*: per-worker weights
   fold into the values; the raveled (N = m·k) stream is stably sorted
   by coordinate; duplicate coordinates — adjacent after the sort — are
   combined into their first occurrence (cumsum differences) and the
   leftovers re-keyed to the sentinel coordinate d_pad and sorted to the
   tail.  After this pass every output coordinate owns **at most one**
   stream entry, so a ``block``-wide output block owns at most ``block``
   entries — the static occupancy bound the kernel's window relies on.
2. *gridded merge*: a 1-D grid over output blocks.  Block j's entries
   are the contiguous run [S[j], S[j+1]) of the sorted stream
   (S = searchsorted of the block edges).  Data-dependent offsets meet
   static BlockSpecs via the **two-view window trick**: the stream is
   passed twice with (1, W) blocks at scalar-prefetched chunk indices
   q = S[j]//W and q+1, so the concatenated (1, 2W) window always covers
   [S[j], S[j]+W] ⊇ the run (W ≥ block ≥ occupancy).  In-window entries
   outside the run are masked by position; the masked one-hot
   (chunk, block) matmul scatters values to their in-block columns.
   Because of step 1's dedup, the matmul is an exact permutation — no
   float summation happens inside the kernel.

:data:`SPARSE_SCATTER_MAX_D` gates the launch: below it a single jnp
``.at[].add`` over the payload stream is already payload-shaped (it too
never builds an (m, d) array), so the kernel only takes over where the
grid pays for itself.

Fused distance kernels
----------------------
* **krum** — grid over coordinate blocks; each step accumulates the
  (P, P) pairwise-squared-distance tile (P = m padded to a power of
  two) from (P, chunk) slabs of its (P, block) tile, revisiting the
  output block (``@pl.when(j == 0)`` init).  The last grid step finishes
  on-chip: the diagonal takes the registry's +1e30, padding rows/columns
  are masked to +1e30, every column is sorted ascending by a bitonic
  network over sublanes, and the k-nearest partial sums land in a (1, P)
  score row.  Only the m scores leave the kernel; ``argmin`` on the host
  matches :func:`repro.core.aggregation.krum_select`.
* **row sort** — trimmed-mean and coordinate-median reduce to one
  per-coordinate ascending sort over workers; the kernel runs the same
  bitonic network on (P, block) tiles (+inf row padding sinks below
  every real value).  Sorting only permutes values, so the kernel output
  equals ``jnp.sort(updates, axis=0)`` bit-for-bit and the registry's
  own slice/mean epilogue runs unchanged on top.

The bitonic network sorts the sublane axis in p(p+1)/2 vectorized
compare-exchange steps (p = log₂P): partners via ``jnp.roll(±s)``, the
keep-min side chosen by ``has_bit ^ ascending`` per merge stage.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# sparse merge: output-block width (multiple of 128 lanes); the largest
# tile is the (chunk ≤ 512, block) one-hot scatter matmul operand
AGG_BLOCK = 1024
# below this d the jnp scatter-add fallback (also payload-shaped — it
# never builds (m, d)) beats the grid-launch overhead
SPARSE_SCATTER_MAX_D = 4096
# dense fused rules hold a (P, P) distance/score tile on-chip, P = m
# rounded up to a power of two — past this m the registry path serves
DENSE_FUSED_MAX_M = 256
# dense fused rules: coordinate-block width per grid step
DENSE_BLOCK = 512
# diagonal / padding mask, matching krum_select's jnp.eye(m) * 1e30
_BIG = 1e30


def _round_up(n, mult):
    return -(-n // mult) * mult


def _pow2_at_least(n, floor=8):
    p = floor
    while p < n:
        p *= 2
    return p


def agg_kernel_plan(m: int, d: int, *, k=None, block=None):
    """Launch plan for aggregating m workers at dimension d.

    With ``k`` (a sparse payload width): ``("scatter", block)`` — the
    payload-shaped jnp fallback — or ``("sparse_gridded", block)``.
    Without ``k`` (dense fused rules): ``("fused", P)`` with the padded
    worker-tile height, or ``("dense", None)`` when m exceeds the
    on-chip (P, P) budget and the registry path serves.  Raises
    ``ValueError`` for a block the TPU tiling cannot serve — the
    build-time sanity check of the ``*_kernel`` aggregator specs."""
    if k is not None:
        blk = AGG_BLOCK if block is None else block
        if blk % 128 != 0 or blk <= 0:
            raise ValueError(
                f"sparse aggregation block size must be a positive multiple "
                f"of 128 lanes, got {blk}"
            )
        # VMEM peak: the (512, block) one-hot scatter tile (f32)
        if 4 * 512 * blk > 14 * 2**20:
            raise ValueError(
                f"sparse aggregation block={blk} needs "
                f"~{(4 * 512 * blk) >> 20} MB VMEM tiles (> the ~14 MB "
                f"budget) — use block ≤ 4096"
            )
        if d <= SPARSE_SCATTER_MAX_D:
            return ("scatter", blk)
        return ("sparse_gridded", blk)
    blk = DENSE_BLOCK if block is None else block
    if blk % 128 != 0 or blk <= 0:
        raise ValueError(
            f"fused aggregation block size must be a positive multiple of "
            f"128 lanes, got {blk}"
        )
    if m > DENSE_FUSED_MAX_M:
        return ("dense", None)
    return ("fused", _pow2_at_least(m))


# ---------------------------------------------------------------------------
# sparse-domain aggregation: segmented scatter-add over wire payloads
# ---------------------------------------------------------------------------


def _sorted_stream(vals, idx, d_pad, weights):
    """Payloads → the deduplicated index-sorted stream (module docstring,
    step 1).  Returns (values (N,), coordinates (N,) int32) with at most
    one entry per coordinate; evicted duplicates carry the sentinel
    coordinate ``d_pad`` and value 0 at the stream tail."""
    v = vals.astype(jnp.float32)
    if weights is not None:
        v = v * weights.astype(jnp.float32)[:, None]
    vs = v.reshape(-1)
    ix = idx.reshape(-1).astype(jnp.int32)
    order = jnp.argsort(ix, stable=True)          # worker-stable within ties
    vs, ix = vs[order], ix[order]
    n = vs.shape[0]
    first = jnp.searchsorted(ix, ix, side="left")
    last = jnp.searchsorted(ix, ix, side="right") - 1
    csum = jnp.cumsum(vs)
    run_sum = csum[last] - csum[first] + vs[first]
    is_first = jnp.arange(n) == first
    vs = jnp.where(is_first, run_sum, 0.0)
    ix = jnp.where(is_first, ix, d_pad)
    order = jnp.argsort(ix, stable=True)          # sentinels sink to the tail
    return vs[order], ix[order]


def _sparse_agg_kernel(qw_ref, s_ref, e_ref, v0_ref, v1_ref, i0_ref, i1_ref,
                       out_ref, *, window, block, chunk):
    j = pl.program_id(0)
    vs = jnp.concatenate([v0_ref[...], v1_ref[...]], axis=1)   # (1, 2W)
    ix = jnp.concatenate([i0_ref[...], i1_ref[...]], axis=1)
    pos = qw_ref[j] * window + jax.lax.broadcasted_iota(
        jnp.int32, (1, 2 * window), 1)
    live = ((pos >= s_ref[j]) & (pos < e_ref[j])).astype(jnp.float32)
    base = j * block
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, block), 1)

    def body(c, acc):
        vc = jax.lax.dynamic_slice(vs, (0, c * chunk), (1, chunk))
        ic = jax.lax.dynamic_slice(ix, (0, c * chunk), (1, chunk))
        lc = jax.lax.dynamic_slice(live, (0, c * chunk), (1, chunk))
        # the dedup pass guarantees ≤ 1 live entry per column: the matmul
        # is an exact permutation-scatter, never a float reduction
        onehot = ((ic.reshape(chunk, 1) - base) == cols).astype(
            jnp.float32) * lc.reshape(chunk, 1)
        return acc + jax.lax.dot_general(
            vc, onehot, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
            precision=jax.lax.Precision.HIGHEST,
        )

    out_ref[...] = jax.lax.fori_loop(
        0, (2 * window) // chunk, body,
        jnp.zeros((1, block), jnp.float32))


@functools.partial(jax.jit, static_argnames=("d", "block", "interpret"))
def aggregate_sparse_gridded(vals, idx, d, weights=None, *, block=AGG_BLOCK,
                             interpret=None):
    """Gridded segmented-merge launch: (m, k) payloads → the (d,) f32
    weighted scatter-add aggregate, O(m·k + d) memory, any d."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, k = vals.shape
    assert idx.shape == (m, k)
    nb = _round_up(d, block) // block
    d_pad = nb * block
    vs, ix = _sorted_stream(vals, idx, d_pad, weights)
    n = vs.shape[0]
    # window ≥ the per-block occupancy bound min(N, block); 256-multiples
    # keep 2W divisible by the 512-wide scatter chunks
    window = _round_up(min(max(n, 1), block), 256)
    npad = (_round_up(n, window) // window + 2) * window
    vp = jnp.pad(vs, (0, npad - n)).reshape(1, npad)
    ip = jnp.pad(ix, (0, npad - n), constant_values=d_pad).reshape(1, npad)
    # S[j] = first stream position with coordinate ≥ j·block; sentinels
    # (evicted duplicates, padding) sort past S[nb] and never merge
    S = jnp.searchsorted(
        ix, jnp.arange(nb + 1, dtype=jnp.int32) * block).astype(jnp.int32)
    chunk = min(512, 2 * window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, window), lambda j, q, s, e: (0, q[j])),
            pl.BlockSpec((1, window), lambda j, q, s, e: (0, q[j] + 1)),
            pl.BlockSpec((1, window), lambda j, q, s, e: (0, q[j])),
            pl.BlockSpec((1, window), lambda j, q, s, e: (0, q[j] + 1)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda j, q, s, e: (0, j)),
    )
    out = pl.pallas_call(
        functools.partial(_sparse_agg_kernel, window=window, block=block,
                          chunk=chunk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, d_pad), jnp.float32),
        interpret=interpret,
    )(S[:nb] // window, S[:nb], S[1:], vp, vp, ip, ip)
    return out[0, :d]


def aggregate_sparse_scatter(vals, idx, d, weights=None):
    """Payload-shaped jnp fallback: one scatter-add over the raveled
    stream.  Also never materializes an (m, d) array."""
    v = vals.astype(jnp.float32)
    if weights is not None:
        v = v * weights.astype(jnp.float32)[:, None]
    return jnp.zeros((d,), jnp.float32).at[idx.reshape(-1)].add(v.reshape(-1))


def aggregate_sparse(vals, idx, d, weights=None, *, block=None,
                     interpret=None):
    """Weighted sum of m sparse payloads, Σᵢ wᵢ · scatter(valsᵢ, idxᵢ),
    without densifying any per-worker vector: values (m, k), indices
    (m, k) int32, optional weights (m,) → the (d,) f32 aggregate.

    Auto-selects the launch by d (:func:`agg_kernel_plan`): the jnp
    scatter-add up to :data:`SPARSE_SCATTER_MAX_D`, the gridded
    segmented-merge kernel beyond it.  Both agree with
    :func:`repro.kernels.ref.sparse_aggregate_ref`."""
    plan, blk = agg_kernel_plan(vals.shape[0], d, k=vals.shape[1],
                                block=block)
    if plan == "scatter":
        return aggregate_sparse_scatter(vals, idx, d, weights)
    return aggregate_sparse_gridded(vals, idx, d, weights, block=blk,
                                    interpret=interpret)


# ---------------------------------------------------------------------------
# fused distance kernels: krum pairwise distances, per-coordinate row sort
# ---------------------------------------------------------------------------


def _bitonic_sort_cols(x):
    """Sort every column of a (P, B) tile ascending along the sublane
    axis (P a power of two) — the vectorized bitonic network."""
    P = x.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    stages = P.bit_length() - 1
    for stage in range(stages):
        for sub in range(stage, -1, -1):
            s = 1 << sub
            has_bit = (row & s) != 0
            partner = jnp.where(has_bit, jnp.roll(x, s, axis=0),
                                jnp.roll(x, -s, axis=0))
            asc = (row & (1 << (stage + 1))) == 0
            keep_min = has_bit ^ asc
            x = jnp.where(keep_min, jnp.minimum(x, partner),
                          jnp.maximum(x, partner))
    return x


def _krum_kernel(x_ref, d2_ref, score_ref, *, m, k_near, n_blocks, chunk):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        d2_ref[...] = jnp.zeros_like(d2_ref)
        score_ref[...] = jnp.zeros_like(score_ref)

    x = x_ref[...].astype(jnp.float32)            # (P, block)
    P = x.shape[0]

    def body(c, acc):
        xc = jax.lax.dynamic_slice(x, (0, c * chunk), (P, chunk))
        diff = xc[:, None, :] - xc[None, :, :]    # (P, P, chunk)
        return acc + jnp.sum(diff * diff, axis=-1)

    d2_ref[...] += jax.lax.fori_loop(
        0, x.shape[1] // chunk, body, jnp.zeros((P, P), jnp.float32))

    @pl.when(j == n_blocks - 1)
    def _score():
        # on-chip score stage: registry diagonal, padding masked to the
        # same +1e30, columns sorted ascending, k-nearest partial sums.
        # By symmetry d2[i, j] == d2[j, i] exactly, so column sums equal
        # krum_select's row-wise nearest.sum(1).
        d2 = d2_ref[...]
        rows = jax.lax.broadcasted_iota(jnp.int32, (P, P), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (P, P), 1)
        d2 = d2 + jnp.where(rows == cols, _BIG, 0.0)
        d2 = jnp.where((rows >= m) | (cols >= m), _BIG, d2)
        srt = _bitonic_sort_cols(d2)
        score_ref[...] = jnp.sum(srt[:k_near, :], axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("n_byz", "block", "interpret"))
def krum_scores_fused(flat, n_byz, *, block=DENSE_BLOCK, interpret=None):
    """Krum scores for an (m, d) stack: blocked O(m²) pairwise squared
    distances with the score reduction on-chip — only the (m,) scores
    leave the kernel.  k-nearest count matches ``krum_select``:
    k = max(m − n_byz − 2, 1)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, d = flat.shape
    plan, P = agg_kernel_plan(m, d, block=block)
    if plan != "fused":
        raise ValueError(
            f"fused krum serves m ≤ {DENSE_FUSED_MAX_M} (a (P, P) VMEM "
            f"score tile), got m={m} — use the registry path"
        )
    nd = _round_up(d, block) // block
    xp = jnp.pad(flat.astype(jnp.float32), ((0, P - m), (0, nd * block - d)))
    chunk = 8                                     # (P, P, 8) diff slabs
    _, scores = pl.pallas_call(
        functools.partial(_krum_kernel, m=m,
                          k_near=max(m - int(n_byz) - 2, 1),
                          n_blocks=nd, chunk=chunk),
        grid=(nd,),
        in_specs=[pl.BlockSpec((P, block), lambda j: (0, j))],
        out_specs=[
            pl.BlockSpec((P, P), lambda j: (0, 0)),
            pl.BlockSpec((1, P), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, P), jnp.float32),
            jax.ShapeDtypeStruct((1, P), jnp.float32),
        ],
        interpret=interpret,
    )(xp)
    return scores[0, :m]


def krum_select_fused(flat, n_byz, *, block=DENSE_BLOCK, interpret=None):
    """Fused-kernel drop-in for :func:`repro.core.aggregation.krum_select`:
    the index of the worker with the smallest k-nearest distance sum."""
    return jnp.argmin(krum_scores_fused(flat, n_byz, block=block,
                                        interpret=interpret))


def _rowsort_kernel(x_ref, out_ref):
    out_ref[...] = _bitonic_sort_cols(x_ref[...].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def sort_workers_fused(updates, *, block=DENSE_BLOCK, interpret=None):
    """Per-coordinate ascending sort over the worker axis of an (m, d)
    stack, tiled (P, block) per grid step (+inf row padding sinks below
    every real value).  Sorting only permutes, so this equals
    ``jnp.sort(updates, axis=0)`` bit-for-bit — the registry's
    trimmed-mean/median epilogues run unchanged on the output."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, d = updates.shape
    plan, P = agg_kernel_plan(m, d, block=block)
    if plan != "fused":
        raise ValueError(
            f"fused row sort serves m ≤ {DENSE_FUSED_MAX_M}, got m={m} — "
            f"use the registry path"
        )
    nd = _round_up(d, block) // block
    xp = jnp.pad(updates.astype(jnp.float32),
                 ((0, P - m), (0, nd * block - d)),
                 constant_values=jnp.inf)
    srt = pl.pallas_call(
        _rowsort_kernel,
        grid=(nd,),
        in_specs=[pl.BlockSpec((P, block), lambda j: (0, j))],
        out_specs=pl.BlockSpec((P, block), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((P, nd * block), jnp.float32),
        interpret=interpret,
    )(xp)
    return srt[:m, :d]


def trimmed_mean_fused(updates, trim_frac: float, *, block=DENSE_BLOCK,
                       interpret=None):
    """Fused-kernel drop-in for :func:`repro.core.aggregation.trimmed_mean`
    (identical k clamp and slice/mean epilogue on the kernel-sorted
    stack)."""
    m = updates.shape[0]
    srt = sort_workers_fused(updates, block=block, interpret=interpret)
    kt = min(int(round(trim_frac * m)), (m - 1) // 2)
    kept = srt if kt == 0 else srt[kt:m - kt]
    return kept.mean(0)


def coordinate_median_fused(updates, *, block=DENSE_BLOCK, interpret=None):
    """Fused-kernel drop-in for
    :func:`repro.core.aggregation.coordinate_median` — the middle row(s)
    of the kernel-sorted stack, combined with ``jnp.median``'s midpoint
    mean (low + high) · 0.5 on even m."""
    m = updates.shape[0]
    srt = sort_workers_fused(updates, block=block, interpret=interpret)
    if m % 2:
        return srt[m // 2]
    return (srt[m // 2 - 1] + srt[m // 2]) * 0.5
