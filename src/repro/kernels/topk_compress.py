"""Pallas TPU kernel: fused top-k compression (threshold-select + pack).

The XLA path for top-k compression is three kernels with HBM round-trips
between them: ``top_k`` (a full sort on TPU), a gather, and a scatter at
the receiver.  This kernel produces the packed wire payload — k values
and k int32 indices in index-ascending order — in ONE VMEM-resident
pass:

1. *threshold-select*: bisection on the magnitude range finds the
   largest t with |{i : |x_i| ≥ t}| ≥ k (a fori_loop of d-wide
   reductions; after ~64 halvings the interval is below fp32 spacing, so
   the count is exact for distinct magnitudes);
2. *pack*: selected coordinates are compacted MXU-style — the rank of
   each selected coordinate is a strict-lower-triangular matvec (no
   cumsum primitive needed), and a (d, k) one-hot of those ranks gathers
   values and indices with two matmuls.  Coordinates strictly above the
   threshold band are always kept; ties at the threshold fill the
   remaining slots lowest-index-first (``lax.top_k``'s rule).

Like :mod:`repro.kernels.cubic_step` this is a single-tile launch sized
for the paper's d ≤ a few-k regime: VMEM holds two (d_pad, d_pad)
iota-comparison tiles, so d_pad² · 4 B must fit in ~16 MB (d ≲ 1.4k).

Validated in interpret mode against :func:`repro.kernels.ref.topk_compress_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_up(n, mult):
    return -(-n // mult) * mult


def _topk_kernel(x_ref, vals_ref, idx_ref, *, k, d, n_iter):
    x = x_ref[...].astype(jnp.float32)                      # (1, dp)
    dp = x.shape[1]
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, dp), 1)
    valid = pos < d
    ax = jnp.where(valid, jnp.abs(x), -1.0)                 # padding never selects

    # -- threshold-select: largest t ≥ 0 with count(|x| ≥ t) ≥ k --------
    def bisect(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((ax >= mid).astype(jnp.float32))
        take = cnt >= k
        return jnp.where(take, mid, lo), jnp.where(take, hi, mid)

    lo, hi = jax.lax.fori_loop(
        0, n_iter, bisect, (jnp.float32(0.0), jnp.max(ax))
    )
    # `sure` (|x| ≥ hi) are strictly inside the top-k band; `tie` sits at
    # the threshold and only fills the remaining slots, lowest index first
    # (lax.top_k's rule).  Keeping first-k of the raw ≥lo mask instead
    # would drop large-magnitude coordinates at high indices on ties.
    sure = ((ax >= hi) & valid).astype(jnp.float32)         # (1, dp)
    tie = ((ax >= lo) & valid).astype(jnp.float32) - sure

    # -- pack: ranks via strict-lower-triangular matvecs, gather via one-hot
    ii = jax.lax.broadcasted_iota(jnp.int32, (dp, dp), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (dp, dp), 1)
    lt = (ii < jj).astype(jnp.float32)

    def rank_of(sel):                                       # # selected before j
        return jax.lax.dot_general(
            sel, lt, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    n_sure = jnp.sum(sure)
    keep = sure * (rank_of(sure) < k).astype(jnp.float32) + tie * (
        rank_of(tie) < k - n_sure
    ).astype(jnp.float32)
    rank = rank_of(keep)

    kp = vals_ref.shape[1]
    slot = jax.lax.broadcasted_iota(jnp.float32, (dp, kp), 1)
    sel = (rank.reshape(dp, 1) == slot).astype(jnp.float32) * keep.reshape(dp, 1)

    def gather(row):                                        # (1, dp) @ (dp, kp)
        return jax.lax.dot_general(
            row, sel, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    vals_ref[...] = gather(x).astype(vals_ref.dtype)
    idx_ref[...] = jnp.round(gather(pos.astype(jnp.float32))).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "n_iter", "interpret"))
def topk_compress(x, k, *, n_iter=64, interpret=None):
    """Packed top-|x| payload of a 1-D vector: (values (k,), indices (k,)),
    index-ascending — the wire format of :class:`repro.compression.TopK`."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    d = x.shape[-1]
    assert x.ndim == 1 and 1 <= k <= d
    dp, kp = _round_up(d, 128), _round_up(k, 128)
    xp = jnp.pad(x, (0, dp - d)).reshape(1, dp)
    kernel = functools.partial(_topk_kernel, k=k, d=d, n_iter=n_iter)
    vals, idx = pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[pl.BlockSpec((1, dp), lambda: (0, 0))],
        out_specs=[
            pl.BlockSpec((1, kp), lambda: (0, 0)),
            pl.BlockSpec((1, kp), lambda: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, kp), jnp.float32),
            jax.ShapeDtypeStruct((1, kp), jnp.int32),
        ],
        interpret=interpret,
    )(xp)
    return vals[0, :k].astype(x.dtype), idx[0, :k]


def topk_decompress(vals, idx, d):
    """Center-side reconstruction: scatter the packed payload to dense."""
    return jnp.zeros((d,), vals.dtype).at[idx].set(vals)
