"""Pallas TPU kernels: fused top-k compression (threshold-select + pack).

The XLA path for top-k compression is three kernels with HBM round-trips
between them: ``top_k`` (a full sort on TPU), a gather, and a scatter at
the receiver.  The kernels here produce the packed wire payload — k
values and k int32 indices in index-ascending order — without ever
sorting.  Two launches cover every scale:

* **single-tile** (d ≤ :data:`SINGLE_TILE_MAX_D`): the whole vector is
  VMEM-resident and one launch does threshold-select (bisection on the
  magnitude range) + pack (see below) — the paper's d ≤ a-few-k regime.
* **sharded** (any d): a grid over coordinate blocks with a two-pass
  global threshold — the model-scale path (see "Sharded launch" below).

:func:`topk_compress` picks the launch by d; both are validated in
interpret mode against :func:`repro.kernels.ref.topk_compress_ref` and
agree with ``jax.lax.top_k`` bit-for-bit, including its tie rule
(ties at the threshold magnitude keep the lowest indices).

Single-tile launch
------------------
1. *threshold-select*: bisection on the magnitude range finds the
   largest t with |{i : |x_i| ≥ t}| ≥ k (a fori_loop of d-wide
   reductions; after ~64 halvings the interval is below fp32 spacing, so
   the count is exact for distinct magnitudes);
2. *pack*: selected coordinates are compacted MXU-style — the rank of
   each selected coordinate is a strict-lower-triangular matvec (no
   cumsum primitive needed), and a (d, k) one-hot of those ranks gathers
   values and indices with two matmuls.  Coordinates strictly above the
   threshold band are always kept; ties at the threshold fill the
   remaining slots lowest-index-first (``lax.top_k``'s rule).

VMEM holds two (d_pad, d_pad) iota-comparison tiles, so d_pad² · 4 B
must fit in ~16 MB ⇒ d ≲ 1.4k.

Sharded launch
--------------
The two-pass global-threshold contract for model-scale vectors:

* **pass 1 — per-block radix histograms.** The vector is split into
  ``block``-wide coordinate blocks (a 1-D grid).  The fp32 bit pattern
  of |x_i| is order-isomorphic to the magnitude (non-negative floats
  compare like their int32 patterns; padding lanes are forced to the
  sentinel −1 so they never count), so a radix-select over the 31
  magnitude bits finds the EXACT bit pattern p of the k-th largest |x|:
  each round a gridded kernel histograms the next ``nbits`` of every
  in-prefix coordinate's pattern, and a host-visible reduction (plain
  jnp on the (n_blocks, n_buckets) counts) walks the global histogram
  from the top to pick the bucket holding the k-th magnitude.  Three
  rounds (10 + 10 + 11 bits, :data:`_RADIX_ROUNDS`) resolve all 31
  bits, so the threshold t = bitcast(p) is exact — no approximation,
  ties are whole-magnitude classes, and parity with ``lax.top_k`` is
  bit-exact.
* **threshold → per-block budgets (host-visible reduction).**  With p
  fixed, coordinates split into *sure* (pattern > p, all kept — fewer
  than k by construction) and *ties* (pattern == p, filling the
  remaining k − n_sure slots lowest-index-first, ``lax.top_k``'s rule).
  Per-block tie budgets and pack offsets are exclusive prefix sums of
  the per-block sure/tie counts — block order IS global index order, so
  lowest-index-first across blocks falls out of the cumsum.
* **pass 2 — per-block pack.**  Each grid step packs its block's
  survivors (sure + first-``budget`` ties) into its slice of the
  blocked wire payload using the same strict-lower-triangular-matvec
  rank trick as the single-tile kernel, now on (block, block) tiles;
  indices are rebased to global int32 coordinates.  A final fixed-shape
  scatter compacts the blocked slices at their pack offsets into the
  (k,) wire arrays — identical payload, identical wire bits: the
  blocked layout transmits exactly k values + k indices, so
  ``TopK.wire_bits`` (and the :class:`repro.comm.WireLedger` totals)
  are unchanged relative to the single-tile/XLA paths.

Per-launch VMEM is O(block²) regardless of d, so the default
``block=512`` keeps every tile comfortably inside 16 MB at any model
scale.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# single-tile launch: two (d_pad, d_pad) f32 comparison tiles must sit in
# ~16 MB VMEM next to the pack buffers ⇒ d ≲ 1.4k; beyond it
# topk_compress routes to the sharded grid automatically
SINGLE_TILE_MAX_D = 1408
# sharded launch: coordinate-block width (multiple of 128 lanes); the
# largest pass-1/2 tiles are (block, 2048) and (block, block) f32
DEFAULT_BLOCK = 512
# radix-select rounds over the 31 bits of the |x| fp32 pattern:
# (shift, nbits) — 10 + 10 + 11 bits resolve the threshold exactly
_RADIX_ROUNDS = ((21, 10), (11, 10), (0, 11))


def _round_up(n, mult):
    return -(-n // mult) * mult


def kernel_plan(d: int, block: int = DEFAULT_BLOCK):
    """Launch plan for a d-vector: ``("single_tile", d_pad)`` or
    ``("gridded", block)``.  Raises ``ValueError`` for a block size the
    TPU tiling cannot serve — the facade's build-time sanity check."""
    if block % 128 != 0 or block <= 0:
        raise ValueError(
            f"top-k kernel block size must be a positive multiple of 128 "
            f"lanes, got {block}"
        )
    # sharded-launch VMEM peaks: the (block, 2048) pass-1 histogram
    # one-hot vs the three (block, block) pass-2 rank/select tiles (f32)
    tile_bytes = 4 * max(block * 2048, 3 * block * block)
    if tile_bytes > 14 * 2**20:
        raise ValueError(
            f"top-k kernel block={block} needs ~{tile_bytes >> 20} MB of "
            f"VMEM tiles (> the ~14 MB budget) — use block ≤ 1024"
        )
    if d <= SINGLE_TILE_MAX_D:
        return ("single_tile", _round_up(max(d, 1), 128))
    return ("gridded", block)


def _topk_kernel(x_ref, vals_ref, idx_ref, *, k, d, n_iter):
    x = x_ref[...].astype(jnp.float32)                      # (1, dp)
    dp = x.shape[1]
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, dp), 1)
    valid = pos < d
    ax = jnp.where(valid, jnp.abs(x), -1.0)                 # padding never selects

    # -- threshold-select: largest t ≥ 0 with count(|x| ≥ t) ≥ k --------
    def bisect(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((ax >= mid).astype(jnp.float32))
        take = cnt >= k
        return jnp.where(take, mid, lo), jnp.where(take, hi, mid)

    lo, hi = jax.lax.fori_loop(
        0, n_iter, bisect, (jnp.float32(0.0), jnp.max(ax))
    )
    # `sure` (|x| ≥ hi) are strictly inside the top-k band; `tie` sits at
    # the threshold and only fills the remaining slots, lowest index first
    # (lax.top_k's rule).  Keeping first-k of the raw ≥lo mask instead
    # would drop large-magnitude coordinates at high indices on ties.
    sure = ((ax >= hi) & valid).astype(jnp.float32)         # (1, dp)
    tie = ((ax >= lo) & valid).astype(jnp.float32) - sure

    # -- pack: ranks via strict-lower-triangular matvecs, gather via one-hot
    ii = jax.lax.broadcasted_iota(jnp.int32, (dp, dp), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (dp, dp), 1)
    lt = (ii < jj).astype(jnp.float32)

    def rank_of(sel):                                       # # selected before j
        return jax.lax.dot_general(
            sel, lt, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
            precision=jax.lax.Precision.HIGHEST,
        )

    n_sure = jnp.sum(sure)
    keep = sure * (rank_of(sure) < k).astype(jnp.float32) + tie * (
        rank_of(tie) < k - n_sure
    ).astype(jnp.float32)
    rank = rank_of(keep)

    kp = vals_ref.shape[1]
    slot = jax.lax.broadcasted_iota(jnp.float32, (dp, kp), 1)
    sel = (rank.reshape(dp, 1) == slot).astype(jnp.float32) * keep.reshape(dp, 1)

    def gather(row):                                        # (1, dp) @ (dp, kp)
        return jax.lax.dot_general(
            row, sel, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
            precision=jax.lax.Precision.HIGHEST,
        )

    vals_ref[...] = gather(x).astype(vals_ref.dtype)
    idx_ref[...] = jnp.round(gather(pos.astype(jnp.float32))).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "n_iter", "interpret"))
def topk_compress_tiled(x, k, *, n_iter=64, interpret=None):
    """Single-tile launch (d ≤ :data:`SINGLE_TILE_MAX_D`): one VMEM-resident
    threshold-select + pack pass over the whole vector."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    d = x.shape[-1]
    assert x.ndim == 1 and 1 <= k <= d
    dp, kp = _round_up(d, 128), _round_up(k, 128)
    xp = jnp.pad(x, (0, dp - d)).reshape(1, dp)
    kernel = functools.partial(_topk_kernel, k=k, d=d, n_iter=n_iter)
    vals, idx = pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[pl.BlockSpec((1, dp), lambda: (0, 0))],
        out_specs=[
            pl.BlockSpec((1, kp), lambda: (0, 0)),
            pl.BlockSpec((1, kp), lambda: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, kp), jnp.float32),
            jax.ShapeDtypeStruct((1, kp), jnp.int32),
        ],
        interpret=interpret,
    )(xp)
    return vals[0, :k].astype(x.dtype), idx[0, :k]


# ---------------------------------------------------------------------------
# sharded launch: grid over coordinate blocks, two-pass global threshold
# ---------------------------------------------------------------------------


def _hist_kernel(patt_ref, prefix_ref, hist_ref, *, shift, nbits):
    """Pass 1, one radix round: per-block bucket counts of the next
    ``nbits`` of each in-prefix |x| bit pattern (padding = −1 never
    matches any prefix: −1 >> s == −1 ≠ prefix ≥ 0)."""
    patt = patt_ref[...]                                    # (1, B) int32
    nbuckets = 1 << nbits
    match = (patt >> (shift + nbits)) == prefix_ref[0, 0]
    bucket = (patt >> shift) & (nbuckets - 1)
    B = patt.shape[1]
    cols = jax.lax.broadcasted_iota(jnp.int32, (B, nbuckets), 1)
    onehot = (bucket.reshape(B, 1) == cols) & match.reshape(B, 1)
    hist_ref[...] = jnp.sum(onehot.astype(jnp.int32), axis=0, keepdims=True)


def _pack_kernel(x_ref, patt_ref, thresh_ref, budget_ref, vals_ref, idx_ref,
                 *, block):
    """Pass 2: pack this block's survivors — all sure coordinates
    (pattern > p) plus the first ``budget`` ties (pattern == p),
    lowest-index-first — into its slice of the blocked wire payload,
    via the strict-lower-triangular-matvec rank trick per tile."""
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)                      # (1, B)
    patt = patt_ref[...]
    p = thresh_ref[0, 0]
    budget = budget_ref[0, 0].astype(jnp.float32)
    B = block
    sure = (patt > p).astype(jnp.float32)                   # (1, B)
    tie = (patt == p).astype(jnp.float32)

    ii = jax.lax.broadcasted_iota(jnp.int32, (B, B), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (B, B), 1)
    lt = (ii < jj).astype(jnp.float32)

    def rank_of(sel):                                       # # selected before j
        return jax.lax.dot_general(
            sel, lt, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
            precision=jax.lax.Precision.HIGHEST,
        )

    keep = sure + tie * (rank_of(tie) < budget).astype(jnp.float32)
    rank = rank_of(keep)
    W = vals_ref.shape[1]
    slot = jax.lax.broadcasted_iota(jnp.float32, (B, W), 1)
    sel = (rank.reshape(B, 1) == slot).astype(jnp.float32) * keep.reshape(B, 1)

    def gather(row):                                        # (1, B) @ (B, W)
        return jax.lax.dot_general(
            row, sel, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
            precision=jax.lax.Precision.HIGHEST,
        )

    # local positions stay < B ≤ 2^24 (exact in f32); rebasing to global
    # int32 AFTER the matmul keeps the kernel exact at any d.  The
    # Precision.HIGHEST on the dots keeps the MXU from truncating the f32
    # operands to bf16 on real TPUs (positions > 256 and arbitrary values
    # must survive the matmul bit-exactly)
    lpos = jax.lax.broadcasted_iota(jnp.float32, (1, B), 1)
    vals_ref[...] = gather(x)
    idx_ref[...] = jnp.round(gather(lpos)).astype(jnp.int32) + i * B


@functools.partial(jax.jit, static_argnames=("k", "block", "interpret"))
def topk_compress_sharded(x, k, *, block=DEFAULT_BLOCK, interpret=None):
    """Sharded launch: grid over ``block``-wide coordinate blocks with the
    two-pass global threshold (module docstring, "Sharded launch") —
    model-scale vectors, O(block²) VMEM per grid step, any d."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    d = x.shape[-1]
    assert x.ndim == 1 and 1 <= k <= d
    kernel_plan(d, block)                                   # block sanity
    nb = _round_up(d, block) // block
    xp = jnp.pad(x.astype(jnp.float32), (0, nb * block - d)).reshape(nb, block)
    # |x| fp32 bit patterns compare like magnitudes (non-negative floats);
    # padding lanes get the sentinel −1 so no kernel needs a valid mask
    patt = jax.lax.bitcast_convert_type(jnp.abs(xp), jnp.int32)
    gpos = jnp.arange(nb * block, dtype=jnp.int32).reshape(nb, block)
    patt = jnp.where(gpos < d, patt, -1)

    # -- pass 1: radix-select the exact bit pattern p of the k-th |x| ----
    prefix = jnp.zeros((1, 1), jnp.int32)
    n_above = jnp.int32(0)                  # count strictly above the prefix
    for shift, nbits in _RADIX_ROUNDS:
        nbuckets = 1 << nbits
        hist = pl.pallas_call(
            functools.partial(_hist_kernel, shift=shift, nbits=nbits),
            grid=(nb,),
            in_specs=[
                pl.BlockSpec((1, block), lambda i: (i, 0)),
                pl.BlockSpec((1, 1), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((1, nbuckets), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((nb, nbuckets), jnp.int32),
            interpret=interpret,
        )(patt, prefix)
        # host-visible reduction: walk the global histogram from the top
        g = jnp.sum(hist, axis=0)
        S = jnp.cumsum(g[::-1])[::-1]                # S[q] = count(≥ bucket q)
        in_band = (n_above + S) >= k
        q = jnp.max(jnp.where(in_band,
                              jnp.arange(nbuckets, dtype=jnp.int32), -1))
        n_above = n_above + S[q] - g[q]
        prefix = (prefix << nbits) | q
    p = prefix                                       # (1, 1): exact pattern

    # -- threshold → per-block tie budgets and pack offsets --------------
    sure_b = jnp.sum(patt > p[0, 0], axis=1)
    tie_b = jnp.sum(patt == p[0, 0], axis=1)
    n_sure = jnp.sum(sure_b)
    tie_before = jnp.cumsum(tie_b) - tie_b           # block order = index order
    budget_b = jnp.clip(k - n_sure - tie_before, 0, tie_b).astype(jnp.int32)
    count_b = (sure_b + budget_b).astype(jnp.int32)
    base_b = (jnp.cumsum(count_b) - count_b).astype(jnp.int32)

    # -- pass 2: pack each block's survivors into the blocked payload ----
    W = min(block, _round_up(k, 128))                # per-block slice width
    vals, idx = pl.pallas_call(
        functools.partial(_pack_kernel, block=block),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, W), lambda i: (i, 0)),
            pl.BlockSpec((1, W), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, W), jnp.float32),
            jax.ShapeDtypeStruct((nb, W), jnp.int32),
        ],
        interpret=interpret,
    )(xp, patt, p, budget_b.reshape(nb, 1))

    # compact the blocked slices at their offsets into the (k,) wire
    # arrays (Σ count_b == k exactly, so every slot is written once)
    wpos = jnp.arange(W, dtype=jnp.int32)[None, :]
    tgt = jnp.where(wpos < count_b[:, None], base_b[:, None] + wpos, k)
    vals_out = jnp.zeros((k,), jnp.float32).at[tgt.ravel()].set(
        vals.ravel(), mode="drop")
    idx_out = jnp.zeros((k,), jnp.int32).at[tgt.ravel()].set(
        idx.ravel(), mode="drop")
    return vals_out.astype(x.dtype), idx_out


def topk_compress(x, k, *, n_iter=64, interpret=None, block=DEFAULT_BLOCK):
    """Packed top-|x| payload of a 1-D vector: (values (k,), indices (k,)),
    index-ascending — the wire format of :class:`repro.compression.TopK`.

    Auto-selects the launch by d (:func:`kernel_plan`): the single-tile
    kernel up to :data:`SINGLE_TILE_MAX_D`, the sharded grid beyond it.
    Both agree with ``jax.lax.top_k`` bit-for-bit."""
    plan, _ = kernel_plan(x.shape[-1], block)
    if plan == "single_tile":
        return topk_compress_tiled(x, k, n_iter=n_iter, interpret=interpret)
    return topk_compress_sharded(x, k, block=block, interpret=interpret)


def topk_decompress(vals, idx, d):
    """Center-side reconstruction: scatter the packed payload to dense."""
    return jnp.zeros((d,), vals.dtype).at[idx].set(vals)
