from .mesh import make_host_mesh, make_production_mesh, num_workers, worker_axes

__all__ = [
    "make_host_mesh",
    "make_production_mesh",
    "num_workers",
    "worker_axes",
]
