import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination on the production mesh, print memory/cost analysis, and emit the
roofline terms (EXPERIMENTS.md §Dry-run / §Roofline read from this output).

The two lines above MUST stay the first executable statements: jax locks the
device count at first backend init (see the brief).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
        --shape train_4k [--multi-pod] [--solver-iters 2] [--json out.json]
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
"""
import argparse
import json
import sys
import time

import jax

from repro.api import ExperimentSpec
from repro.configs import ARCHS, INPUT_SHAPES, VARIANTS, get_config
from repro.launch.hlo import Roofline, analyze_hlo, model_flops
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import make_problem


def active_param_ratio(cfg):
    """fraction of params active per token (MoE top-k routing)."""
    if cfg.num_experts and cfg.top_k:
        # expert params scale with E; active with top_k (+ shared)
        total_e = cfg.num_experts
        active_e = cfg.top_k
        # rough: expert FFN dominates the ratio; attention shared
        return None  # handled via n_active computation in run_one
    return None


def count_params(problem):
    params_shape = problem.args[0]
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params_shape))


def count_active_params(cfg, n_total):
    """N_active for MoE: swap routed-expert count for top_k."""
    if not cfg.num_experts:
        return n_total
    f = cfg.expert_d_ff or cfg.d_ff
    per_expert = 3 * cfg.d_model * f  # gate/up/down
    routed_total = cfg.num_layers * cfg.num_experts * per_expert
    routed_active = cfg.num_layers * cfg.top_k * per_expert
    return n_total - routed_total + routed_active


def run_one(arch: str, shape_name: str, multi_pod: bool,
            solver_iters: int = 2, two_round: bool = False,
            worker_groups: int = 1, compressor: str | None = None,
            error_feedback: str = "none", aggregator: str | None = None,
            verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    # config built (and validated) through the experiment facade
    newton = ExperimentSpec(
        problem="external", runtime="mesh",
        solver_iters=solver_iters, exact_gradient=two_round,
        compressor=compressor, error_feedback=error_feedback,
        aggregator=aggregator if aggregator is not None
        else "norm_trim:0.125",
    ).to_distributed_config()

    problem = make_problem(cfg, shape, mesh, newton, worker_groups=worker_groups)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": int(chips),
        "worker_groups": worker_groups,
    }
    if problem.skipped:
        rec["status"] = "skipped"
        rec["reason"] = problem.skipped
        if verbose:
            print(f"[dryrun] SKIP {problem.label} ({rec['mesh']}): {problem.skipped}")
        return rec

    t0 = time.time()
    with mesh:
        lowered = jax.jit(
            problem.step_fn, in_shardings=problem.in_shardings
        ).lower(*problem.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        # older jax returns [per-partition dict], newer returns one dict
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()

    # loop-aware HLO analysis (cost_analysis counts while bodies once —
    # useless for scanned layer stacks; see repro.launch.hlo)
    hc = analyze_hlo(hlo)
    flops_dev = float(hc["flops"])
    bytes_dev = float(hc["bytes"])
    coll_dev = float(hc["collective_total"])

    roof = Roofline(flops_dev, bytes_dev, coll_dev, int(chips))
    n_total = count_params(problem)
    n_active = count_active_params(cfg, n_total)
    bp_eq = (1.0 + 2.0 * solver_iters) if shape.kind == "train" else 1.0
    mflops = model_flops(cfg, shape, n_params_active=n_active,
                         backprop_equivalents=bp_eq)
    useful = mflops / (flops_dev * chips) if flops_dev else 0.0

    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "params": n_total,
        "params_active": n_active,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collectives": hc["collectives"],
        "collective_counts": hc["collective_counts"],
        "unknown_loops": hc["unknown_loops"],
        "cost_analysis_raw": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "roofline": roof.as_dict(),
        "model_flops": mflops,
        "useful_flops_ratio": useful,
        "memory": {
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
    })
    if verbose:
        print(f"[dryrun] OK {problem.label} mesh={rec['mesh']} "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s")
        print(f"  memory_analysis: args={rec['memory']['argument_bytes']} "
              f"temp={rec['memory']['temp_bytes']} out={rec['memory']['output_bytes']}")
        print(f"  cost: flops/dev={flops_dev:.3e} bytes/dev={bytes_dev:.3e} "
              f"coll/dev={coll_dev:.3e}")
        print(f"  roofline: compute={roof.compute_s*1e3:.2f}ms "
              f"memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms "
              f"dominant={roof.dominant} useful_ratio={useful:.3f}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id, variant id, or 'all'")
    ap.add_argument("--shape", default="all", choices=list(INPUT_SHAPES) + ["all"])
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2×16×16 multi-pod mesh (default single-pod 16×16)")
    ap.add_argument("--solver-iters", type=int, default=2)
    ap.add_argument("--two-round", action="store_true",
                    help="Remark-5 exact-gradient variant")
    ap.add_argument("--worker-groups", type=int, default=1,
                    help="coalesce N data rows per worker (memory knob)")
    ap.add_argument("--compressor", default=None,
                    help="uplink channel spec (e.g. topk:0.1)")
    ap.add_argument("--error-feedback", default="none",
                    choices=["none", "ef", "ef21"],
                    help="thread mesh-scale EF channel state (stateful step; "
                         "requires --compressor)")
    ap.add_argument("--aggregator", default=None,
                    help="center aggregation spec (norm_trim:<beta>/krum:<n>/"
                         "trimmed_mean:<f>/coordinate_median/mean)")
    ap.add_argument("--json", default=None, help="append JSONL records here")
    args = ap.parse_args(argv)

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]

    records, failures = [], []
    for a in archs:
        for s in shapes:
            try:
                rec = run_one(a, s, args.multi_pod,
                              solver_iters=args.solver_iters,
                              two_round=args.two_round,
                              worker_groups=args.worker_groups,
                              compressor=args.compressor,
                              error_feedback=args.error_feedback,
                              aggregator=args.aggregator)
            except Exception as e:  # noqa: BLE001 — report, keep sweeping
                rec = {"arch": a, "shape": s, "status": "error",
                       "error": f"{type(e).__name__}: {e}"}
                failures.append(rec)
                print(f"[dryrun] FAIL {a}×{s}: {rec['error']}")
            records.append(rec)
            if args.json:
                with open(args.json, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
