"""Loop-aware HLO cost analysis + roofline terms.

``compiled.cost_analysis()`` visits each ``while`` body ONCE — a 126-layer
scanned transformer is undercounted ~126×, which would make every roofline
term garbage.  Post-optimization HLO, however, annotates every loop with
``backend_config={"known_trip_count":{"n":...}}``, so we parse the compiled
module text ourselves and multiply through the call graph:

* FLOPs: ``dot`` = 2·|result|·K (K from lhs_contracting_dims),
  ``convolution`` = 2·|result|·(kernel/out_features), elementwise ≈ |result|;
  fusions recurse into their called computation.
* bytes accessed: per *top-level* instruction (a fusion is one kernel):
  Σ operand bytes + result bytes.
* collective bytes: operand bytes of every all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute — trip-multiplied like
  everything else.

The module text is the *partitioned per-device* program, so all quantities
are per-device; multiply by chip count for globals.

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s+->")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_ARRAY_TYPE_RE = re.compile(r"^(\w+\[[\d,]*\](?:\{[^}]*\})?)\s+")
_OP_RE = re.compile(r"^\s*([\w\-]+)\(")


def _parse_instr_line(ln: str):
    """→ (name, type_str, op) or None.  Handles tuple types containing
    ``/*index=N*/`` comments (which defeat any single regex with [^=])."""
    m = _NAME_RE.match(ln)
    if not m:
        return None
    name = m.group(1)
    rest = ln[m.end():]
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str = rest[: i + 1]
                    rest = rest[i + 1:]
                    break
        else:
            return None
    else:
        ma = _ARRAY_TYPE_RE.match(rest)
        if not ma:
            return None
        type_str = ma.group(1)
        rest = rest[ma.end():]
    mo = _OP_RE.match(rest)
    if not mo:
        return None
    return name, type_str, mo.group(1)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_RE = re.compile(r"(?:true_computation|false_computation)=%?([\w\.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "opt-barrier"}
_ELEMENTWISE_FREE = {"broadcast", "reshape", "transpose", "copy", "slice",
                     "concatenate", "pad", "reverse", "dynamic-slice",
                     "dynamic-update-slice", "gather", "scatter", "select",
                     "convert", "reduce", "sort", "rng-bit-generator", "map",
                     "clamp", "compare"}


def _array_dims(type_str):
    """[(dtype, [dims…]), …] for every array in a (possibly tuple) type."""
    out = []
    for m in _ARRAY_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _type_bytes(type_str):
    return sum(
        _DTYPE_BYTES[dt] * math.prod(dims) for dt, dims in _array_dims(type_str)
    )


def _type_numel(type_str):
    return sum(math.prod(dims) for _dt, dims in _array_dims(type_str))


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str

    def operands(self):
        i = self.line.index(self.op + "(") + len(self.op) + 1
        depth, buf, names = 1, "", []
        for ch in self.line[i:]:
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
                if depth == 0:
                    break
            if ch == "," and depth == 1:
                names.append(buf.strip())
                buf = ""
            else:
                buf += ch
        if buf.strip():
            names.append(buf.strip())
        out = []
        for n in names:
            if not n.strip():
                continue
            # operands print either as "%name" or (newer XLA) as
            # "f32[32,64]{1,0} %name" — the name is the %-token
            toks = n.strip().split()
            tok = next((t for t in toks if t.startswith("%")), toks[-1])
            out.append(tok.lstrip("%").rstrip(","))
        return out


def parse_hlo(text: str):
    """→ (computations: {name: [Instr]}, entry_name)."""
    comps, entry = {}, None
    cur = None
    for ln in text.splitlines():
        if not ln.strip():
            continue
        if not ln.startswith(" ") and ("{" in ln) and ("->" in ln):
            m = _COMP_HDR.match(ln.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if ln.strip().startswith("ENTRY"):
                    entry = cur
                continue
        if ln.strip() == "}":
            continue
        parsed = _parse_instr_line(ln)
        if parsed and cur is not None:
            name, type_str, op = parsed
            comps[cur].append(Instr(name, type_str, op, ln))
    return comps, entry


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_counts: dict = dataclasses.field(default_factory=lambda: defaultdict(int))
    unknown_loops: int = 0

    def add(self, other, k=1.0):
        self.flops += k * other.flops
        self.bytes += k * other.bytes
        for key, v in other.coll.items():
            self.coll[key] += k * v
        for key, v in other.coll_counts.items():
            self.coll_counts[key] += int(k * v)
        self.unknown_loops += other.unknown_loops


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps, self.entry = parse_hlo(text)
        self._memo: dict[str, Cost] = {}

    # ------------------------------------------------------------------
    def _instr_flops(self, ins: Instr, symtab, inside_fusion):
        op = ins.op
        if op == "dot":
            numel = _type_numel(ins.type_str)
            k = 1
            m = _LHS_CONTRACT_RE.search(ins.line)
            ops = ins.operands()
            if m and ops:
                lhs = symtab.get(ops[0])
                if lhs:
                    dims = _array_dims(lhs.type_str)
                    if dims:
                        shape = dims[0][1]
                        for ci in (int(c) for c in m.group(1).split(",") if c):
                            if ci < len(shape):
                                k *= shape[ci]
            return 2.0 * numel * k
        if op == "convolution":
            numel = _type_numel(ins.type_str)
            ops = ins.operands()
            kern = symtab.get(ops[1]) if len(ops) > 1 else None
            if kern:
                kd = _array_dims(kern.type_str)
                if kd:
                    kshape = kd[0][1]
                    out_dims = _array_dims(ins.type_str)
                    # per-output-element MACs ≈ prod(kernel)/out_features
                    of = max(kshape[-1], 1)
                    return 2.0 * numel * math.prod(kshape) / of
            return 2.0 * numel
        if op in _SKIP_OPS or op in _ELEMENTWISE_FREE:
            # reduce/sort/gather move data; count ~1 flop/elem for reduce
            if op == "reduce":
                return _type_numel(ins.type_str)
            return 0.0
        if op in ("fusion", "call", "while", "conditional", "custom-call"):
            return 0.0  # handled via call graph
        # generic elementwise / transcendental
        return float(_type_numel(ins.type_str))

    def _operand_bytes(self, ins: Instr, symtab):
        total = 0
        for nm in ins.operands():
            o = symtab.get(nm)
            if o is not None:
                total += _type_bytes(o.type_str)
        return total

    # ------------------------------------------------------------------
    def comp_cost(self, name: str, inside_fusion=False) -> Cost:
        if name in self._memo:
            return self._memo[name]
        cost = Cost()
        instrs = self.comps.get(name, [])
        symtab = {i.name: i for i in instrs}
        for ins in instrs:
            op = ins.op
            if op in _SKIP_OPS:
                continue
            coll_kind = next((c for c in COLLECTIVES if op.startswith(c)), None)
            if coll_kind:
                if op.endswith("-done"):
                    continue
                b = self._operand_bytes(ins, symtab) or _type_bytes(ins.type_str)
                cost.coll[coll_kind] += b
                cost.coll_counts[coll_kind] += 1
                cost.bytes += b + _type_bytes(ins.type_str)
                continue
            if op == "while":
                body = _BODY_RE.search(ins.line)
                condc = _COND_RE.search(ins.line)
                trip_m = _TRIP_RE.search(ins.line)
                trip = int(trip_m.group(1)) if trip_m else None
                if trip is None:
                    trip = 1
                    cost.unknown_loops += 1
                if body:
                    cost.add(self.comp_cost(body.group(1)), trip)
                if condc:
                    cost.add(self.comp_cost(condc.group(1)), trip + 1)
                continue
            if op == "call":
                m = _TO_APPLY_RE.search(ins.line)
                if m:
                    cost.add(self.comp_cost(m.group(1)))
                continue
            if op == "conditional":
                branches = _BRANCH_RE.search(ins.line)
                names = []
                if branches:
                    names = [b.strip().lstrip("%") for b in branches.group(1).split(",")]
                else:
                    names = [m.group(1) for m in _TF_RE.finditer(ins.line)]
                if names:
                    sub = [self.comp_cost(n) for n in names]
                    # conservative: the most expensive branch
                    best = max(sub, key=lambda c: c.flops + c.bytes)
                    cost.add(best)
                continue
            if op == "fusion":
                m = _CALLS_RE.search(ins.line)
                inner_root_dus = False
                if m:
                    inner = self.comp_cost(m.group(1), inside_fusion=True)
                    cost.flops += inner.flops
                    cost.add(
                        Cost(coll=inner.coll, coll_counts=inner.coll_counts,
                             unknown_loops=inner.unknown_loops)
                    )
                    inner_instrs = self.comps.get(m.group(1), [])
                    inner_root_dus = any(
                        i.op == "dynamic-update-slice" and "ROOT" in i.line
                        for i in inner_instrs
                    )
                if inner_root_dus:
                    # in-place slice-update fusion: the big buffer is aliased;
                    # traffic ≈ the non-aliased operands twice (read + write)
                    ops_b = [
                        _type_bytes(symtab[o].type_str)
                        for o in ins.operands()
                        if o in symtab
                    ]
                    cost.bytes += 2.0 * (sum(ops_b) - (max(ops_b) if ops_b else 0))
                else:
                    cost.bytes += self._operand_bytes(ins, symtab) + _type_bytes(
                        ins.type_str
                    )
                continue
            if op == "custom-call":
                cost.bytes += self._operand_bytes(ins, symtab) + _type_bytes(ins.type_str)
                continue
            cost.flops += self._instr_flops(ins, symtab, inside_fusion)
            if not inside_fusion:
                cost.bytes += self._instr_bytes(ins, symtab)
        self._memo[name] = cost
        return cost

    def _instr_bytes(self, ins: Instr, symtab):
        """Bytes moved by one top-level instruction.  Slice-update ops are
        in-place in XLA — count the touched slice, not the whole buffer
        (a loop-carried flash-attention accumulator would otherwise count
        its full size once per scan step: 1000× inflation)."""
        op = ins.op
        if op == "dynamic-update-slice":
            ops = ins.operands()
            upd = symtab.get(ops[1]) if len(ops) > 1 else None
            b = _type_bytes(upd.type_str) if upd else _type_bytes(ins.type_str)
            return 2.0 * b
        if op in ("dynamic-slice", "gather", "slice"):
            return 2.0 * _type_bytes(ins.type_str)
        return self._operand_bytes(ins, symtab) + _type_bytes(ins.type_str)

    def entry_cost(self) -> Cost:
        return self.comp_cost(self.entry)


def analyze_hlo(text: str) -> dict:
    c = HloAnalyzer(text).entry_cost()
    coll = dict(c.coll)
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collectives": coll,
        "collective_counts": dict(c.coll_counts),
        "collective_total": sum(coll.values()),
        "unknown_loops": c.unknown_loops,
    }


# legacy helper kept for tests / quick use -----------------------------------


def collective_bytes(hlo_text: str) -> dict:
    a = analyze_hlo(hlo_text)
    return {**a["collectives"], "total": a["collective_total"],
            "counts": a["collective_counts"]}


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    chips: int
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def compute_s(self):
        return self.flops_per_device / self.peak_flops

    @property
    def memory_s(self):
        return self.bytes_per_device / self.hbm_bw

    @property
    def collective_s(self):
        return self.collective_bytes_per_device / self.ici_bw

    @property
    def dominant(self):
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    def as_dict(self):
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def model_flops(cfg, shape, n_params_active: int | None = None,
                n_params: int | None = None, backprop_equivalents: float = 1.0):
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE), per the brief.

    ``backprop_equivalents`` scales for the cubic-Newton step (1 grad +
    2·solver_iters HVP backprop-equivalents on top of the loss forward).
    """
    N = n_params_active if n_params_active is not None else n_params
    if shape.kind == "train":
        D = shape.global_batch * shape.seq_len
        return 6.0 * N * D * backprop_equivalents
    if shape.kind == "prefill":
        D = shape.global_batch * shape.seq_len
        return 2.0 * N * D
    # decode: one token per sequence
    return 2.0 * N * shape.global_batch
