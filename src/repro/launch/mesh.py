"""Production mesh factory.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required for the smoke tests, which must see one
CPU device, while the dry-run process sees 512 forced host devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over however many devices this host actually has —
    used by the runnable examples/tests on CPU."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def worker_axes(mesh) -> tuple:
    """Mesh axes that enumerate the paper's 'worker machines'."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def num_workers(mesh) -> int:
    import math

    return math.prod(mesh.shape[a] for a in worker_axes(mesh))
