"""Serving driver: batched prefill + greedy decode with the KV-cache path.

Checkpoint/param distribution is a center→replica broadcast, so it rides
the same downlink :class:`~repro.comm.TreeChannel` the training runtimes
use: ``--downlink int8`` quantizes the whole parameter tree on the wire
(8 bits/coordinate + one fp32 scale per block) and the serving banner
reports the exact ledger bits of the broadcast next to the
full-precision cost it replaced.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-27b \
        --preset smoke --batch 4 --prompt-len 32 --gen 32 --downlink int8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..comm import DOWNLINK, TreeChannel, WireLedger
from ..configs import get_config
from ..data.synthetic import TokenStream
from ..models import build_model
from ..telemetry import get_telemetry


def broadcast_params(params, downlink, *, seed=0, ledger=None):
    """Distribute a parameter tree through a downlink channel.

    Returns ``(params_as_received, info)`` where ``info`` carries the
    exact ledger bits of the one broadcast round and the full-precision
    bits it replaced.  ``downlink=None`` is the identity wire (still
    accounted: 32 bits/coordinate).
    """
    ledger = ledger if ledger is not None else WireLedger()
    channel = TreeChannel(DOWNLINK, downlink)
    params, _ = channel.transmit(params, (),
                                 key=jax.random.PRNGKey(seed))
    channel.record(ledger, params)
    # baseline from the same accounting path, not a hand-rolled 32·d
    full_bits = TreeChannel(DOWNLINK, None).bits_per_round(params)
    return params, {
        "downlink_bits": ledger.downlink_bits,
        "full_precision_bits": full_bits,
        "saving": full_bits / max(ledger.downlink_bits, 1),
    }


def run_serving(arch="gemma3-27b", preset="smoke", batch=4, prompt_len=32,
                gen=32, seed=0, downlink=None, telemetry_dir=None):
    # memory-only telemetry when no dir was given: the latency histograms
    # below aggregate (and print p50/p99) without any file I/O
    tel = get_telemetry()
    if not tel.enabled or telemetry_dir is not None:
        tel.enable(telemetry_dir)
    cfg = get_config(arch)
    if preset == "smoke":
        cfg = cfg.reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    with tel.span("serve.broadcast", arch=arch, downlink=downlink or "id"):
        params, wire = broadcast_params(params, downlink, seed=seed)
    tel.gauge("serve.broadcast_bits", wire["downlink_bits"],
              full_precision_bits=wire["full_precision_bits"])
    print(f"[serve] downlink={downlink or 'identity'} "
          f"broadcast_bits={wire['downlink_bits']} "
          f"(full-precision {wire['full_precision_bits']}, "
          f"{wire['saving']:.2f}x saving)")

    stream = TokenStream(cfg.vocab_size, seed)
    prompts, _ = stream.batch(0, batch, prompt_len)

    max_len = prompt_len + gen
    cache = model.init_cache(batch, max_len)
    step = jax.jit(model.decode_step)

    # prefill token-by-token through the decode path (exactness over speed —
    # the prefill_32k dry-run shape covers the batched-prefill compute path)
    t0 = time.time()
    logits = None
    with tel.span("serve.prefill", tokens=prompt_len, batch=batch):
        for t in range(prompt_len):
            tt0 = time.time()
            logits, cache = step(params, cache, prompts[:, t], jnp.int32(t))
            jax.block_until_ready(logits)
            tel.observe("serve.prefill_step_s", time.time() - tt0)
    t_prefill = time.time() - t0

    out_tokens = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.time()
    with tel.span("serve.decode", tokens=gen, batch=batch):
        for t in range(prompt_len, max_len):
            out_tokens.append(tok)
            tt0 = time.time()
            logits, cache = step(params, cache, tok, jnp.int32(t))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            jax.block_until_ready(tok)
            tel.observe("serve.decode_step_s", time.time() - tt0)
    t_dec = time.time() - t0
    toks = jnp.stack(out_tokens, 1)
    print(f"[serve] arch={cfg.name} batch={batch} prefill={prompt_len}tok "
          f"({t_prefill:.2f}s) decode={gen}tok ({t_dec:.2f}s, "
          f"{batch*gen/max(t_dec,1e-9):.1f} tok/s)")
    lat = tel.histogram("serve.decode_step_s")
    if lat:
        print(f"[serve] decode latency p50={lat['p50']*1e3:.1f}ms "
              f"p99={lat['p99']*1e3:.1f}ms over {lat['count']} steps")
    if telemetry_dir is not None:
        tel.flush()
        print(f"[serve] telemetry → {telemetry_dir}")
    return toks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--downlink", default=None,
                    help="compress the param broadcast through a downlink "
                         "TreeChannel (repro.compression spec, e.g. 'int8')")
    ap.add_argument("--telemetry-dir", default=None,
                    help="write telemetry (events.jsonl + trace.json) here; "
                         "latency histograms aggregate in memory either way")
    args = ap.parse_args(argv)
    run_serving(args.arch, args.preset, args.batch, args.prompt_len, args.gen,
                downlink=args.downlink, telemetry_dir=args.telemetry_dir)


if __name__ == "__main__":
    main()
