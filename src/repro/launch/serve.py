"""Serving driver: batched prefill + greedy decode with the KV-cache path.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-27b \
        --preset smoke --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..data.synthetic import TokenStream
from ..models import build_model


def run_serving(arch="gemma3-27b", preset="smoke", batch=4, prompt_len=32,
                gen=32, seed=0):
    cfg = get_config(arch)
    if preset == "smoke":
        cfg = cfg.reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = model.init(key)

    stream = TokenStream(cfg.vocab_size, seed)
    prompts, _ = stream.batch(0, batch, prompt_len)

    max_len = prompt_len + gen
    cache = model.init_cache(batch, max_len)
    step = jax.jit(model.decode_step)

    # prefill token-by-token through the decode path (exactness over speed —
    # the prefill_32k dry-run shape covers the batched-prefill compute path)
    t0 = time.time()
    logits = None
    for t in range(prompt_len):
        logits, cache = step(params, cache, prompts[:, t], jnp.int32(t))
    t_prefill = time.time() - t0

    out_tokens = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.time()
    for t in range(prompt_len, max_len):
        out_tokens.append(tok)
        logits, cache = step(params, cache, tok, jnp.int32(t))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t_dec = time.time() - t0
    toks = jnp.stack(out_tokens, 1)
    print(f"[serve] arch={cfg.name} batch={batch} prefill={prompt_len}tok "
          f"({t_prefill:.2f}s) decode={gen}tok ({t_dec:.2f}s, "
          f"{batch*gen/max(t_dec,1e-9):.1f} tok/s)")
    return toks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)
    run_serving(args.arch, args.preset, args.batch, args.prompt_len, args.gen)


if __name__ == "__main__":
    main()
