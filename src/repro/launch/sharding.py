"""Sharding rules: param / batch / cache PartitionSpecs (DESIGN.md §5).

Parameters: FSDP over the ``data`` (and ``pod``) axes × tensor-parallel over
``model`` — classified by leaf name ("column" weights shard their output dim
over ``model``, "row" weights their input dim), with a leading ``None`` for
the scan-stacked repeat axis.

Decode caches: ``batch → data(+pod)``, ``cache sequence → model`` — the
flash-decode layout that sidesteps indivisible kv-head counts and spreads a
500k-token cache across the pod (DESIGN.md §5).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import worker_axes

# leaf-name classification -------------------------------------------------
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj_z", "in_proj_x",
        "w_gelu", "w_rnn_in", "w_r", "w_i", "prefix_proj"}  # out dim → model
_ROW = {"wo", "w_down", "out_proj", "w_out", "lm_head"}  # in → model
_REPL = {"A_log", "dt_bias", "lam", "b_r", "b_i", "norm", "norm1", "norm2",
         "final_norm", "norm_cross", "norm_mlp", "conv_b", "router",
         # small SSD side projections: replicated ⇒ the B/C/dt einsums and
         # the state-space contractions need no collectives (§Perf iter 6)
         "in_proj_B", "in_proj_C", "in_proj_dt",
         "conv_B_w", "conv_B_b", "conv_C_w", "conv_C_b"}


def _param_rule(path_names, leaf, fsdp):
    name = path_names[-1]
    nd = leaf.ndim
    stacked = "unit" in path_names  # leading repeat axis from stack_layers
    lead = (None,) if stacked else ()

    def spec(*dims):
        return P(*(lead + dims))

    # §Perf iteration 2: keep the vocab axis of the embedding/lm_head on
    # ``model`` and the d axis UNsharded.  Sharding d over the workers (the
    # FSDP-natural choice) makes every logits einsum a d-contraction of
    # partial products ⇒ an all-reduce of the full (B,S,V/16) logits per
    # pass (~1e13 B/dev on gemma3-27b train).  With d replicated the logits
    # are produced vocab-sharded with no collective; the softmax then only
    # reduces (B,S) scalars.  Cost: embed+lm_head lose FSDP (~350 MB/dev on
    # the 262k-vocab configs) — measured 9.6× collective-term win.
    if name == "embed":
        return P(None, "model")
    if name == "lm_head":
        return P(None, "model")
    if name in _REPL or nd - len(lead) <= 1:
        return P(*(lead + (None,) * (nd - len(lead))))
    if name == "conv_w":
        return spec(None, "model")
    if name in _COL:
        if nd - len(lead) == 3:  # MoE experts (E, d, f): experts → model
            return spec("model", fsdp, None)
        return spec(fsdp, "model")
    if name in _ROW:
        if nd - len(lead) == 3:  # (E, f, d)
            return spec("model", None, fsdp)
        return spec("model", fsdp)
    # default: replicate (safe, and loud in the roofline if it matters)
    return P(*(lead + (None,) * (nd - len(lead))))


def param_specs(params_shape, mesh):
    """PartitionSpec pytree matching a params (shape-)pytree."""
    fsdp = worker_axes(mesh)

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        return _param_rule(path, tree, fsdp)

    return walk(params_shape, ())


def param_shardings(params_shape, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        param_specs(params_shape, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------


def tp_only_constraint(mesh):
    """Constraint fn for one scanned superblock's param slice: TP ('model')
    sharding kept, FSDP axes stripped — the per-layer ZeRO-3 gather target
    (installed via repro.models.runtime during lowering)."""

    def strip(spec):
        return P(*(None if (d == "data" or isinstance(d, tuple)) else d
                   for d in spec))

    def constrain(tree):
        def walk(t, path):
            if isinstance(t, dict):
                return {k: walk(v, path + (k,)) for k, v in t.items()}
            # path here never contains "unit" (the slice already lost the
            # reps axis), so _param_rule emits unstacked specs.
            spec = strip(_param_rule(path, t, ("data",)))
            return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))

        return walk(tree, ())

    return constrain


def channels_last_constraint(mesh):
    """Activation hook: last axis → 'model', everything else unsharded."""

    def constrain(x):
        spec = P(*((None,) * (x.ndim - 1) + ("model",)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


def worker_tree_specs(params_shape, mesh, grouped: bool = False):
    """Specs for worker-stacked update trees (leading m axis).

    ``grouped=False`` (m == #data rows): m → data(+pod) worker axes, TP dims
    keep ``model``, FSDP dims go unsharded — each worker's update lives on
    its own data-row, TP-sharded.

    ``grouped=True`` (m < #data rows, worker = a group of rows): the m axis
    is replicated and the update keeps the FULL param sharding (FSDP × TP) —
    per-chip footprint m·P/chips, which is what lets llama3-405b's
    cubic-Newton state fit (DESIGN.md §5 / EXPERIMENTS §Perf)."""
    w = worker_axes(mesh)
    base = param_specs(params_shape, mesh)

    if grouped:
        return jax.tree_util.tree_map(
            lambda s: P(None, *s), base, is_leaf=lambda x: isinstance(x, P)
        )

    def strip_fsdp(spec):
        dims = tuple(None if d == w or d == "data" or (isinstance(d, tuple))
                     else d for d in spec)
        return P(w, *dims)

    return jax.tree_util.tree_map(
        strip_fsdp, base, is_leaf=lambda x: isinstance(x, P)
    )


def worker_tree_shardings(params_shape, mesh, grouped: bool = False):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        worker_tree_specs(params_shape, mesh, grouped),
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_specs(batch_shape, mesh, worker_axis=True):
    """Training batches: leading worker axis → data(+pod) mesh axes."""
    w = worker_axes(mesh)

    def rule(leaf):
        dims = (w,) + (None,) * (leaf.ndim - 1)
        return P(*dims)

    return jax.tree_util.tree_map(rule, batch_shape)


def _cache_rule(path_names, leaf, b_ax):
    name = path_names[-1]
    stacked = "unit" in path_names
    lead = (None,) if stacked else ()

    def spec(*dims):
        return P(*(lead + dims))

    if name in ("k", "v"):       # (B, S_cache, Hkv, Dh): seq → model
        return spec(b_ax, "model", None, None)
    if name in ("ck", "cv"):     # (B, S_enc, Hkv, Dh): heads → model
        return spec(b_ax, None, "model", None)
    if name == "ssm":            # (B, H, N, P): heads → model
        return spec(b_ax, "model", None, None)
    if name == "conv":           # (B, W-1, Ch): channels → model
        return spec(b_ax, None, "model")
    if name in ("conv_B", "conv_C"):  # (B, W-1, N): small, replicate chans
        return spec(b_ax, None, None)
    if name == "h":              # (B, d): features → model
        return spec(b_ax, "model")
    return P(*(lead + (None,) * (leaf.ndim - len(lead))))


def cache_specs(cache_shape, mesh, batch_size):
    """Decode caches.  batch → data(+pod) when it divides evenly, else
    replicated (the long_500k single-sequence case)."""
    w = worker_axes(mesh)
    import math

    n_w = math.prod(mesh.shape[a] for a in w)
    b_ax = w if batch_size % n_w == 0 and batch_size >= n_w else None

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        return _cache_rule(path, tree, b_ax)

    return walk(cache_shape, ())


def decode_token_spec(mesh, batch_size):
    import math

    w = worker_axes(mesh)
    n_w = math.prod(mesh.shape[a] for a in w)
    return P(w) if batch_size % n_w == 0 and batch_size >= n_w else P(None)
