"""input_specs(): ShapeDtypeStruct stand-ins + shardings for every
(architecture × input-shape × mesh) combination — the dry-run's contract.

Nothing here allocates device memory: params/caches come from
``jax.eval_shape``; batches are synthesized ShapeDtypeStructs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..api import ExperimentSpec
from ..configs.base import INPUT_SHAPES, InputShape, ModelConfig
from ..core.distributed import (
    DistributedNewtonConfig,
    make_stateful_train_step,
    make_train_step,
)
from ..models import build_model
from .mesh import num_workers, worker_axes
from ..models import runtime
from .sharding import (
    batch_specs,
    cache_specs,
    channels_last_constraint,
    decode_token_spec,
    param_shardings,
    param_specs,
    tp_only_constraint,
    worker_tree_shardings,
)


class DryrunProblem(NamedTuple):
    step_fn: Callable
    args: tuple              # ShapeDtypeStruct pytrees
    in_shardings: tuple
    label: str
    skipped: str | None      # reason, if this (arch, shape) is skipped


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: ModelConfig, m: int, per_worker: int, seq_len: int):
    """Training-batch ShapeDtypeStructs with a leading worker axis."""
    text = seq_len - (cfg.num_prefix_tokens if cfg.family == "vlm" else 0)
    b = {
        "tokens": _sds((m, per_worker, text), jnp.int32),
        "targets": _sds((m, per_worker, text), jnp.int32),
    }
    if cfg.family == "vlm":
        b["prefix_emb"] = _sds(
            (m, per_worker, cfg.num_prefix_tokens, cfg.d_model), jnp.float32
        )
    if cfg.family == "audio":
        b["enc_emb"] = _sds(
            (m, per_worker, cfg.encoder_len, cfg.d_model), jnp.float32
        )
    return b


def flat_batch_struct(cfg: ModelConfig, batch: int, seq_len: int):
    """Prefill batch (no worker axis)."""
    text = seq_len - (cfg.num_prefix_tokens if cfg.family == "vlm" else 0)
    b = {
        "tokens": _sds((batch, text), jnp.int32),
        "targets": _sds((batch, text), jnp.int32),
    }
    if cfg.family == "vlm":
        b["prefix_emb"] = _sds((batch, cfg.num_prefix_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        b["enc_emb"] = _sds((batch, cfg.encoder_len, cfg.d_model), jnp.float32)
    return b


def skip_reason(cfg: ModelConfig, shape: InputShape) -> str | None:
    """DESIGN.md §4 long_500k policy."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return (
            "pure full-attention arch: 500k decode cache is quadratic-history "
            "/ exceeds HBM; run the swa variant instead (DESIGN.md §4)"
        )
    if shape.name == "long_500k" and cfg.family == "audio":
        return "enc-dec audio model: 500k decode out of family scope"
    return None


def make_problem(
    cfg: ModelConfig,
    shape: InputShape,
    mesh,
    newton: DistributedNewtonConfig | None = None,
    worker_groups: int = 1,
) -> DryrunProblem:
    """``worker_groups`` > 1 coalesces data rows into m = rows/groups bigger
    workers — the per-worker update regains FSDP sharding (memory knob for
    the biggest archs; see sharding.worker_tree_specs)."""
    label = f"{cfg.name}×{shape.name}"
    reason = skip_reason(cfg, shape)
    if reason:
        return DryrunProblem(None, None, None, label, reason)

    model = build_model(cfg)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_shard = param_shardings(params_shape, mesh)
    layer_gather = tp_only_constraint(mesh)
    chan_last = channels_last_constraint(mesh)

    def _hooked(fn):
        """Trace ``fn`` with the per-layer ZeRO-3 gather + channels-last
        activation constraints live."""

        def wrapped(*a):
            with runtime.layer_param_constraint(layer_gather, chan_last):
                return fn(*a)

        return wrapped

    if shape.kind == "train":
        grouped = worker_groups > 1
        m = num_workers(mesh) // worker_groups
        assert m >= 2, "need ≥2 workers for trimming to mean anything"
        # default config builds through the validated facade
        newton = newton or ExperimentSpec(
            problem="external", runtime="mesh", aggregator="norm_trim:0.125"
        ).to_distributed_config()
        w_shard = worker_tree_shardings(params_shape, mesh, grouped=grouped)

        def constrain_worker(tree):
            return jax.lax.with_sharding_constraint(tree, w_shard)

        def constrain_update(tree):
            return jax.lax.with_sharding_constraint(
                tree,
                jax.tree_util.tree_map(
                    lambda s: NamedSharding(mesh, P(*s.spec[1:])), w_shard
                ),
            )

        stateful = (newton.error_feedback != "none"
                    and (newton.compressor is not None
                         or newton.downlink_compressor is not None))
        if stateful:
            # channel-state plumbing: the (m, …) EF tree rides along as an
            # extra donated arg, sharded like the worker update trees
            # (uplink) / the aggregated update (downlink).
            raw_step, init_comm_state = make_stateful_train_step(
                model.loss_fn, newton, m,
                constrain_worker=constrain_worker,
                constrain_update=constrain_update,
            )
            comm_struct = jax.eval_shape(init_comm_state, params_shape)

            def _comm_shard(sub, stacked):
                if not jax.tree_util.tree_leaves(sub):
                    return sub  # stateless segment: empty carry
                base = w_shard if stacked else p_shard
                return jax.tree_util.tree_map(lambda _, sh: sh, sub, base)

            cs_shard = {
                "uplink": _comm_shard(comm_struct["uplink"], True),
                "downlink": _comm_shard(comm_struct["downlink"], False),
            }

            def step_fn(params, batch, comm_state):
                return raw_step(params, batch, jax.random.PRNGKey(0), comm_state)

            step_fn = _hooked(step_fn)
            batch = batch_struct(cfg, m, shape.global_batch // m, shape.seq_len)
        else:
            raw_step = make_train_step(
                model.loss_fn, newton, m,
                constrain_worker=constrain_worker,
                constrain_update=constrain_update,
            )

            def step_fn(params, batch):
                return raw_step(params, batch, jax.random.PRNGKey(0))

            step_fn = _hooked(step_fn)
            batch = batch_struct(cfg, m, shape.global_batch // m, shape.seq_len)
        if grouped:
            # m replicated; the (bigger) per-worker batch shards over the
            # data(+pod) rows instead.
            w = worker_axes(mesh)
            b_shard = jax.tree_util.tree_map(
                lambda leaf: NamedSharding(
                    mesh, P(None, w, *((None,) * (len(leaf.shape) - 2)))
                ),
                batch,
            )
        else:
            b_shard = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), batch_specs(batch, mesh)
            )
        if stateful:
            return DryrunProblem(
                step_fn, (params_shape, batch, comm_struct),
                (p_shard, b_shard, cs_shard), label, None,
            )
        return DryrunProblem(step_fn, (params_shape, batch), (p_shard, b_shard), label, None)

    if shape.kind == "prefill":

        @_hooked
        def step_fn(params, batch):
            logits, _ = model.forward(
                params,
                batch["tokens"],
                prefix_emb=batch.get("prefix_emb"),
                enc_emb=batch.get("enc_emb"),
            )
            return logits

        batch = flat_batch_struct(cfg, shape.global_batch, shape.seq_len)
        w = worker_axes(mesh)
        b_shard = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, P(w, *((None,) * (len(s.shape) - 1)))),
            batch,
        )
        return DryrunProblem(step_fn, (params_shape, batch), (p_shard, b_shard), label, None)

    # decode ---------------------------------------------------------------
    B = shape.global_batch
    cache_shape = jax.eval_shape(lambda: model.init_cache(B, shape.seq_len))
    c_shard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        cache_specs(cache_shape, mesh, B),
        is_leaf=lambda x: isinstance(x, P),
    )
    tok = _sds((B,), jnp.int32)
    pos = _sds((), jnp.int32)
    t_shard = NamedSharding(mesh, decode_token_spec(mesh, B))
    s_shard = NamedSharding(mesh, P())

    @_hooked
    def step_fn(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return DryrunProblem(
        step_fn,
        (params_shape, cache_shape, tok, pos),
        (p_shard, c_shard, t_shard, s_shard),
        label,
        None,
    )
