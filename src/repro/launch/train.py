"""End-to-end training driver: Byzantine-robust cubic-Newton on an LM.

Runs on whatever devices exist (CPU here, a pod in production — the mesh and
shardings come from the same code paths the dry-run proves out).

Example (the examples/train_lm.py quickstart uses this):

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-780m \
        --preset smoke --steps 50 --m-workers 4 --attack negative \
        --alpha 0.25 --beta 0.5

All solver/channel/resilience configuration builds through one validated
:class:`repro.api.ExperimentSpec` (β > α, spec-string grammar, EF/
compressor compatibility are checked before anything traces);
``--aggregator`` takes any registry spec (``norm_trim:0.5``, ``krum:1``,
``trimmed_mean:0.25``, ``coordinate_median``, ``mean``).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from ..api import ExperimentSpec, default_aggregator_spec
from ..checkpoint import save_checkpoint
from ..comm import WireLedger
from ..configs import get_config
from ..core.distributed import (
    make_robust_sgd_step,
    make_stateful_train_step,
    make_train_step,
)
from ..data import WorkerBatcher
from ..models import build_model
from ..telemetry import (RoundRecord, compile_scope, get_telemetry,
                         rejected_from_keep)


def scale_config(cfg, preset: str):
    if preset == "full":
        return cfg
    if preset == "smoke":
        return cfg.reduced()
    if preset == "100m":
        # ~100M-param member of the same family (train_lm example target)
        return dataclasses.replace(
            cfg.reduced(),
            name=cfg.name + "-100m",
            num_layers=max(len(cfg.hybrid_pattern) or 0, 8),
            d_model=768,
            num_heads=12,
            num_kv_heads=max(1, 12 // max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))),
            head_dim=64,
            d_ff=3072 if cfg.d_ff else 0,
            vocab_size=min(cfg.vocab_size, 32768),
            dtype="float32",
        )
    raise ValueError(preset)


def run_training(
    arch: str = "mamba2-780m",
    preset: str = "smoke",
    steps: int = 50,
    m_workers: int = 4,
    per_worker_batch: int = 2,
    seq_len: int = 128,
    eta: float = 1.0,
    M: float = 10.0,
    beta: float = 0.25,
    solver_iters: int = 4,
    attack: str = "none",
    alpha: float = 0.0,
    aggregator: str | None = None,
    optimizer: str = "cubic_newton",
    lr: float = 0.3,
    two_round: bool = False,
    compressor: str | None = None,
    downlink_compressor: str | None = None,
    error_feedback: str = "none",
    seed: int = 0,
    ckpt_dir: str | None = None,
    log_every: int = 10,
    telemetry_dir: str | None = None,
):
    tel = get_telemetry()
    if telemetry_dir is not None:
        tel.enable(telemetry_dir)
    cfg = scale_config(get_config(arch), preset)
    model = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    n_params = model.param_count(params)
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"m={m_workers} attack={attack}@{alpha} optimizer={optimizer}")

    ledger = WireLedger()
    comm_state = None
    wire_bits = None
    if optimizer == "cubic_newton":
        # one declarative spec — validated (β > α, spec grammars, EF/
        # compressor compatibility) before anything traces — is the only
        # config constructor on this path.
        spec = ExperimentSpec(
            problem="external", runtime="mesh", m_workers=m_workers,
            M=M, eta=eta, solver_iters=solver_iters,
            exact_gradient=two_round, compressor=compressor,
            downlink_compressor=downlink_compressor,
            error_feedback=error_feedback,
            aggregator=aggregator if aggregator is not None
            else default_aggregator_spec(beta),
            attack=attack, alpha=alpha, seed=seed,
        )
        ncfg = spec.to_distributed_config()
        if error_feedback != "none":
            # stateful channels: the (m, d)-tree EF memory is threaded (and
            # donated) through the step so long runs keep error feedback.
            raw_step, init_comm_state = make_stateful_train_step(
                model.loss_fn, ncfg, m_workers,
                attack_name=attack, attack_alpha=alpha,
            )
            comm_state = init_comm_state(params)
            step = jax.jit(raw_step, donate_argnums=(3,))
        else:
            raw_step = make_train_step(
                model.loss_fn, ncfg, m_workers,
                attack_name=attack, attack_alpha=alpha,
            )
            step = jax.jit(raw_step)
        wire_bits = raw_step.wire_bits(params)  # exact static ints
    else:
        step = jax.jit(make_robust_sgd_step(model.loss_fn, lr, m_workers, beta=beta))

    batcher = WorkerBatcher(cfg, m_workers, m_workers * per_worker_batch, seq_len, seed)
    history = []
    prev_loss = None
    t0 = time.time()
    for it in range(steps):
        key, sub = jax.random.split(key)
        # the compile-counter attributes every (re)trace of the mesh step
        # to this scope (host-side contextvar, never traced)
        with compile_scope("mesh.step"):
            if comm_state is not None:
                params, metrics, comm_state = step(params, batcher(it), sub, comm_state)
            else:
                params, metrics = step(params, batcher(it), sub)
        if wire_bits is not None:
            ledger.record(uplink=wire_bits["uplink"],
                          downlink=wire_bits["downlink"],
                          rounds=2 if two_round else 1, label="round")
        loss = float(metrics["loss"])
        history.append(loss)
        if tel.enabled:
            tel.round(RoundRecord(
                step=it, runtime="mesh", loss=loss,
                model_decrease=(None if prev_loss is None
                                else prev_loss - loss),
                uplink_delta=(float(metrics["uplink_delta"])
                              if "uplink_delta" in metrics else None),
                rejected=(rejected_from_keep(metrics["kept"])
                          if "kept" in metrics else ()),
                attack=attack, alpha=alpha,
                wire_uplink_bits=(wire_bits or {}).get("uplink"),
                wire_downlink_bits=(wire_bits or {}).get("downlink"),
            ), name="train.round")
            prev_loss = loss
        if it % log_every == 0 or it == steps - 1:
            dt = time.time() - t0
            wire = (f" wire_up={ledger.uplink_bits} wire_down={ledger.downlink_bits}"
                    if wire_bits is not None else "")
            print(f"[train] step={it:5d} loss={loss:.4f} "
                  f"update_norm={float(metrics.get('update_norm', 0.0)):.3e} "
                  f"({dt/(it+1):.2f}s/step){wire}")
        if ckpt_dir and (it + 1) % 100 == 0:
            save_checkpoint(ckpt_dir, params, it + 1, {"loss": loss})
    if ckpt_dir:
        save_checkpoint(ckpt_dir, params, steps, {"loss": history[-1]})
    if wire_bits is not None:
        print(f"[train] wire ledger (exact ints): {ledger.snapshot()}")
    if telemetry_dir is not None:
        tel.flush()
        print(f"[train] telemetry → {telemetry_dir}")
    return params, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "100m", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--m-workers", type=int, default=4)
    ap.add_argument("--per-worker-batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--eta", type=float, default=1.0)
    ap.add_argument("--M", type=float, default=10.0)
    ap.add_argument("--beta", type=float, default=0.25)
    ap.add_argument("--solver-iters", type=int, default=4)
    ap.add_argument("--attack", default="none",
                    help="attack spec (none/gaussian[:sigma]/negative[:c]/"
                         "saddle[:scale])")
    ap.add_argument("--alpha", type=float, default=0.0)
    ap.add_argument("--aggregator", default=None,
                    help="aggregator spec (norm_trim:<beta>/krum:<n>/"
                         "trimmed_mean:<f>/coordinate_median/mean); "
                         "default norm_trim:<--beta>")
    ap.add_argument("--optimizer", default="cubic_newton",
                    choices=["cubic_newton", "robust_sgd"])
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--two-round", action="store_true")
    ap.add_argument("--compressor", default=None,
                    help="uplink spec, e.g. topk:0.1 / signnorm / int8")
    ap.add_argument("--downlink-compressor", default=None,
                    help="center→worker broadcast spec")
    ap.add_argument("--error-feedback", default="none",
                    choices=["none", "ef", "ef21"],
                    help="mesh-scale EF (threads channel state through the step)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--telemetry-dir", default=None,
                    help="write telemetry (per-round records, wire events, "
                         "compile spans, trace.json) into this directory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    _, hist = run_training(**{k.replace("-", "_"): v for k, v in vars(args).items()})
    print(json.dumps({"final_loss": hist[-1], "first_loss": hist[0]}))


if __name__ == "__main__":
    main()
