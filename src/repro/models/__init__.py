from .api import Model, build_model
from .decoder import layer_plan

__all__ = ["Model", "build_model", "layer_plan"]
