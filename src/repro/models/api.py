"""Public model API: ``build_model(cfg)`` → a ``Model`` facade.

Every architecture family goes through the generic pattern decoder
(:mod:`repro.models.decoder`); the facade binds the config and exposes the
five functions the rest of the framework consumes (train step, serving,
dry-run, smoke tests).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax

from . import decoder
from ..configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable            # (key) -> params
    forward: Callable         # (params, tokens, **mods) -> (logits, aux)
    loss_fn: Callable         # (params, batch) -> scalar
    init_cache: Callable      # (batch, max_len) -> cache
    decode_step: Callable     # (params, cache, tokens, pos) -> (logits, cache)

    def param_count(self, params) -> int:
        return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def build_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=partial(decoder.init, cfg=cfg),
        forward=lambda params, tokens, **kw: decoder.forward(params, cfg, tokens, **kw),
        loss_fn=lambda params, batch: decoder.loss_fn(params, cfg, batch),
        init_cache=lambda batch, max_len: decoder.init_cache(cfg, batch, max_len),
        decode_step=lambda params, cache, tokens, pos: decoder.decode_step(
            params, cfg, cache, tokens, pos
        ),
    )
