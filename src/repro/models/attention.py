"""Attention: chunked (online-softmax) training path + KV-cache decode path.

The training/prefill path is a pure-JAX "flash" attention: an outer
``lax.scan`` over query chunks with, per query chunk,

* **global causal**: an inner scan over KV chunks carrying running
  (max, sum-exp, accumulator) statistics — live memory is O(chunk²), never
  O(S²).  Chunks strictly above the diagonal still issue (masked) FLOPs —
  the classic static-shape tax, quantified in EXPERIMENTS.md §Roofline.
* **sliding window**: a ``dynamic_slice`` of exactly ``window + chunk`` keys
  per query chunk — honestly sub-quadratic FLOPs, which is what lets
  gemma3/recurrentgemma run the ``long_500k`` shape.

The decode path scores one query token against a (possibly model-axis
sharded) cache — O(S) per emitted token.

The Pallas TPU kernel in :mod:`repro.kernels.flash_attention` implements the
same contract for the hot path; :func:`attention` is also its reference
oracle (see kernels/ref.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _repeat_kv(k, num_heads):
    """GQA: repeat kv heads to match query heads. k: (B,S,Hkv,Dh)."""
    hkv = k.shape[2]
    if hkv == num_heads:
        return k
    rep = num_heads // hkv
    return jnp.repeat(k, rep, axis=2)


def reference_attention(q, k, v, *, causal=True, window=None, q_offset=0):
    """O(S²)-memory oracle. q: (B,Sq,H,Dh), k/v: (B,Sk,Hkv,Dh)."""
    B, Sq, H, Dh = q.shape
    Sk = k.shape[1]
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    scale = 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None and window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


@partial(jax.jit, static_argnames=("causal", "window", "q_chunk", "kv_chunk"))
def chunked_attention(q, k, v, *, causal=True, window=None, q_chunk=512, kv_chunk=512):
    """Memory-bounded attention (online softmax).  Same contract as
    :func:`reference_attention` with q_offset=0 and Sq == Sk."""
    B, S_orig, H, Dh = q.shape
    Hkv = k.shape[2]
    q_chunk = min(q_chunk, S_orig)
    kv_chunk = min(kv_chunk, S_orig)
    # pad to a chunk multiple; padded keys are masked out, padded query rows
    # are sliced off at the end.
    import math

    pad = (-S_orig) % math.lcm(q_chunk, kv_chunk)
    if pad:
        padspec = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, padspec)
        k = jnp.pad(k, padspec)
        v = jnp.pad(v, padspec)
    S = S_orig + pad
    n_q = S // q_chunk
    scale = 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    rep = H // Hkv

    qs = q.reshape(B, n_q, q_chunk, H, Dh)

    if window is not None and window > 0:
        # Banded path: slice exactly window+q_chunk keys per query chunk.
        span = window + q_chunk
        span = min(span, S)
        kpad = jnp.pad(k, ((0, 0), (span, 0), (0, 0), (0, 0)))
        vpad = jnp.pad(v, ((0, 0), (span, 0), (0, 0), (0, 0)))

        def q_body(carry, qi):
            qc = qs[:, qi]  # (B,C,H,Dh)
            qstart = qi * q_chunk
            # keys [qstart+q_chunk-span, qstart+q_chunk) in padded coords
            start = qstart + q_chunk
            kc = jax.lax.dynamic_slice_in_dim(kpad, start, span, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(vpad, start, span, axis=1)
            kc = _repeat_kv(kc, H)
            vc = _repeat_kv(vc, H)
            logits = (
                jnp.einsum("bqhd,bkhd->bhqk", qc.astype(jnp.float32), kc.astype(jnp.float32))
                * scale
            )
            qpos = qstart + jnp.arange(q_chunk)
            kpos = (qstart + q_chunk - span) + jnp.arange(span)
            mask = (kpos[None, :] <= qpos[:, None]) if causal else jnp.ones((q_chunk, span), bool)
            mask &= kpos[None, :] > qpos[:, None] - window
            mask &= kpos[None, :] >= 0
            logits = jnp.where(mask[None, None], logits, NEG_INF)
            probs = jax.nn.softmax(logits, axis=-1)
            out = jnp.einsum("bhqk,bkhd->bqhd", probs, vc.astype(jnp.float32))
            return carry, out.astype(q.dtype)

        _, outs = jax.lax.scan(q_body, 0, jnp.arange(n_q))
        return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, Dh)[:, :S_orig]

    n_kv = S // kv_chunk
    ks = k.reshape(B, n_kv, kv_chunk, Hkv, Dh)
    vs = v.reshape(B, n_kv, kv_chunk, Hkv, Dh)

    if causal:
        # Triangle scan (§Perf iteration 4): enumerate only the visible
        # (q-chunk, kv-chunk) pairs statically — n(n+1)/2 tiles instead of
        # n², halving both issued FLOPs and chunk-logits HBM traffic vs the
        # masked dense grid.  Only diagonal tiles need a mask.
        pairs = [
            (qi, ki)
            for qi in range(n_q)
            for ki in range(n_kv)
            if ki * kv_chunk <= qi * q_chunk + q_chunk - 1
        ]
        pair_arr = jnp.asarray(pairs, jnp.int32)  # (P, 2)

        def pair_body(state, pair):
            m_run, l_run, acc = state  # (n_q,B,H,C), …, (n_q,B,H,C,Dh)
            qi, ki = pair[0], pair[1]
            qc = jax.lax.dynamic_index_in_dim(qs, qi, 1, keepdims=False)
            qc = qc.astype(jnp.float32)                      # (B,C,H,Dh)
            kc = _repeat_kv(
                jax.lax.dynamic_index_in_dim(ks, ki, 1, keepdims=False), H
            ).astype(jnp.float32)
            vc = _repeat_kv(
                jax.lax.dynamic_index_in_dim(vs, ki, 1, keepdims=False), H
            ).astype(jnp.float32)
            logits = jnp.einsum("bqhd,bkhd->bhqk", qc, kc) * scale
            qpos = qi * q_chunk + jnp.arange(q_chunk)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            mask = kpos[None, :] <= qpos[:, None]
            if pad:
                mask &= (kpos < S_orig)[None, :]
            logits = jnp.where(mask[None, None], logits, NEG_INF)

            m_prev = jax.lax.dynamic_index_in_dim(m_run, qi, 0, keepdims=False)
            l_prev = jax.lax.dynamic_index_in_dim(l_run, qi, 0, keepdims=False)
            a_prev = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
            m_new = jnp.maximum(m_prev, logits.max(-1))
            corr = jnp.exp(m_prev - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l_prev * corr + p.sum(-1)
            a_new = a_prev * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vc)
            m_run = jax.lax.dynamic_update_index_in_dim(m_run, m_new, qi, 0)
            l_run = jax.lax.dynamic_update_index_in_dim(l_run, l_new, qi, 0)
            acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 0)
            return (m_run, l_run, acc), None

        m0 = jnp.full((n_q, B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((n_q, B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((n_q, B, H, q_chunk, Dh), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(pair_body, (m0, l0, a0), pair_arr)
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]      # (n_q,B,H,C,Dh)
        out = out.transpose(1, 0, 3, 2, 4).reshape(B, S, H, Dh)
        return out.astype(q.dtype)[:, :S_orig]

    # Non-causal path: inner scan over all KV chunks with running
    # max / sum-exp — flash-attention in pure JAX.
    def q_body(carry, qi):
        qc = qs[:, qi].astype(jnp.float32)  # (B,C,H,Dh)
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_body(state, ki):
            m_run, l_run, acc = state
            kc = _repeat_kv(ks[:, ki], H).astype(jnp.float32)
            vc = _repeat_kv(vs[:, ki], H).astype(jnp.float32)
            logits = jnp.einsum("bqhd,bkhd->bhqk", qc, kc) * scale
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if pad:
                mask &= (kpos < S_orig)[None, :]
            if causal or pad:
                logits = jnp.where(mask[None, None], logits, NEG_INF)
            m_new = jnp.maximum(m_run, logits.max(-1))
            correction = jnp.exp(m_run - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l_run * correction + p.sum(-1)
            acc = acc * correction[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vc)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, Dh), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), jnp.arange(n_kv))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return carry, out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,C,H,Dh)

    _, outs = jax.lax.scan(q_body, 0, jnp.arange(n_q))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, Dh)[:, :S_orig]


def decode_attention(q, k_cache, v_cache, cache_len, *, window=None):
    """One-token decode against a cache.

    q: (B, H, Dh); caches: (B, S_max, Hkv, Dh); cache_len: scalar int —
    number of valid positions (the new token's KV must already be written at
    ``cache_len - 1``).  Returns (B, H, Dh).
    """
    B, S_max, Hkv, Dh = k_cache.shape
    H = q.shape[1]
    k = _repeat_kv(k_cache, H)
    v = _repeat_kv(v_cache, H)
    scale = 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    logits = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    kpos = jnp.arange(S_max)
    mask = kpos < cache_len
    if window is not None and window > 0:
        mask &= kpos >= cache_len - window
    logits = jnp.where(mask[None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
