"""Block registry: every architecture family is a string of block types.

    'G' global causal attention + SwiGLU MLP          (llama/qwen/internlm…)
    'L' sliding-window causal attention + SwiGLU MLP  (gemma3 local, griffin)
    'M' global attention + MoE FFN                    (deepseek-moe, phi3.5)
    'S' Mamba-2 SSD mixer (no MLP)                    (mamba2)
    'R' RG-LRU recurrent mixer + SwiGLU MLP           (recurrentgemma)
    'C' causal self-attn + cross-attn + MLP           (whisper decoder)
    'E' bidirectional attention + MLP                 (whisper encoder)

Each block type provides ``init(key,cfg,dtype)``, ``apply(p,x,ctx)`` →
``(x, aux)``, ``cache_init(cfg,batch,max_len,dtype)`` and
``decode(p, x_t, cache, ctx)`` → ``(x_t, cache)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import chunked_attention, decode_attention
from .layers import (
    apply_rope,
    init_attention,
    init_mlp,
    rms_norm,
    swiglu,
)
from . import mamba2 as m2
from . import moe as moe_lib
from . import rglru as rg

ZERO_AUX = lambda: jnp.float32(0.0)


# --------------------------------------------------------------------------
# attention blocks ('G', 'L', 'E', and the attention part of 'M'/'C')
# --------------------------------------------------------------------------


def _init_attn_mlp(key, cfg, dtype, with_mlp=True):
    ka, km = jax.random.split(key)
    p = {
        "attn": init_attention(
            ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim, dtype
        ),
        "norm1": jnp.zeros((cfg.d_model,), dtype),
        "norm2": jnp.zeros((cfg.d_model,), dtype),
    }
    if with_mlp:
        p["mlp"] = init_mlp(km, cfg.d_model, cfg.d_ff, dtype)
    return p


def _self_attention(p_attn, h, cfg, positions, *, causal, window):
    B, S, _ = h.shape
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", h, p_attn["wq"]).reshape(B, S, H, Dh)
    k = jnp.einsum("bsd,de->bse", h, p_attn["wk"]).reshape(B, S, Hkv, Dh)
    v = jnp.einsum("bsd,de->bse", h, p_attn["wv"]).reshape(B, S, Hkv, Dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = chunked_attention(
        q, k, v, causal=causal, window=window,
        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
    )
    return jnp.einsum("bse,ed->bsd", o.reshape(B, S, H * Dh), p_attn["wo"])


def _attn_apply(p, x, ctx, *, window, causal=True, with_mlp=True):
    cfg = ctx["cfg"]
    h = rms_norm(x, p["norm1"])
    x = x + _self_attention(p["attn"], h, cfg, ctx["positions"], causal=causal, window=window)
    if with_mlp:
        h2 = rms_norm(x, p["norm2"])
        mp = p["mlp"]
        x = x + swiglu(h2, mp["w_gate"], mp["w_up"], mp["w_down"])
    return x, ZERO_AUX()


def _attn_cache_init(cfg, batch, max_len, dtype, *, window=0):
    S = min(window, max_len) if window else max_len
    Hkv, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, S, Hkv, Dh), dtype),
        "v": jnp.zeros((batch, S, Hkv, Dh), dtype),
    }


def _attn_decode(p, x, cache, ctx, *, window=0, with_mlp=True):
    cfg = ctx["cfg"]
    pos = ctx["pos"]  # scalar: index of the new token
    B = x.shape[0]
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    h = rms_norm(x, p["norm1"])
    q = (h @ p["attn"]["wq"]).reshape(B, 1, H, Dh)
    k = (h @ p["attn"]["wk"]).reshape(B, 1, Hkv, Dh)
    v = (h @ p["attn"]["wv"]).reshape(B, Hkv, Dh)
    posv = jnp.full((B, 1), pos)
    q = apply_rope(q, posv, cfg.rope_theta)[:, 0]
    k = apply_rope(k, posv, cfg.rope_theta)[:, 0]
    S_cache = cache["k"].shape[1]
    if window:
        # rolling window cache: slot cycles; every resident entry is in-window
        slot = pos % S_cache
        cache_len = jnp.minimum(pos + 1, S_cache)
    else:
        slot = pos
        cache_len = pos + 1
    kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k[:, None], slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v[:, None], slot, axis=1)
    o = decode_attention(q, kc, vc, cache_len)
    x = x + (o.reshape(B, H * Dh) @ p["attn"]["wo"])
    if with_mlp:
        h2 = rms_norm(x, p["norm2"])
        mp = p["mlp"]
        x = x + swiglu(h2, mp["w_gate"], mp["w_up"], mp["w_down"])
    return x, {"k": kc, "v": vc}


# --------------------------------------------------------------------------
# MoE block
# --------------------------------------------------------------------------


def _moe_init(key, cfg, dtype):
    ka, km = jax.random.split(key)
    p = _init_attn_mlp(ka, cfg, dtype, with_mlp=False)
    p["moe"] = moe_lib.init_moe(km, cfg, dtype)
    return p


def _moe_apply(p, x, ctx):
    cfg = ctx["cfg"]
    x, _ = _attn_apply(p, x, ctx, window=None, with_mlp=False)
    h2 = rms_norm(x, p["norm2"])
    y, aux = moe_lib.apply_moe(p["moe"], h2, cfg)
    return x + y, aux["lb_loss"] + aux["z_loss"]


def _moe_decode(p, x, cache, ctx):
    cfg = ctx["cfg"]
    x, cache = _attn_decode(p, x, cache, ctx, with_mlp=False)
    h2 = rms_norm(x, p["norm2"])
    y, _ = moe_lib.apply_moe(p["moe"], h2[:, None, :], cfg)
    return x + y[:, 0], cache


# --------------------------------------------------------------------------
# Mamba-2 block
# --------------------------------------------------------------------------


def _ssm_init(key, cfg, dtype):
    return {
        "norm1": jnp.zeros((cfg.d_model,), dtype),
        "mixer": m2.init_mamba2(key, cfg, dtype),
    }


def _ssm_apply(p, x, ctx):
    cfg = ctx["cfg"]
    h = rms_norm(x, p["norm1"])
    return x + m2.apply_mamba2(p["mixer"], h, cfg), ZERO_AUX()


def _ssm_cache_init(cfg, batch, max_len, dtype):
    del max_len
    return m2.init_mamba2_cache(cfg, batch, dtype)


def _ssm_decode(p, x, cache, ctx):
    cfg = ctx["cfg"]
    h = rms_norm(x, p["norm1"])
    y, cache = m2.decode_mamba2(p["mixer"], h, cache, cfg)
    return x + y, cache


# --------------------------------------------------------------------------
# RG-LRU block ('R')
# --------------------------------------------------------------------------


def _rg_init(key, cfg, dtype):
    kr, km = jax.random.split(key)
    return {
        "norm1": jnp.zeros((cfg.d_model,), dtype),
        "norm2": jnp.zeros((cfg.d_model,), dtype),
        "mixer": rg.init_rglru_block(kr, cfg, dtype),
        "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, dtype),
    }


def _rg_apply(p, x, ctx):
    cfg = ctx["cfg"]
    h = rms_norm(x, p["norm1"])
    x = x + rg.apply_rglru_block(p["mixer"], h, cfg)
    h2 = rms_norm(x, p["norm2"])
    mp = p["mlp"]
    return x + swiglu(h2, mp["w_gate"], mp["w_up"], mp["w_down"]), ZERO_AUX()


def _rg_cache_init(cfg, batch, max_len, dtype):
    del max_len
    return rg.init_rglru_cache(cfg, batch, dtype)


def _rg_decode(p, x, cache, ctx):
    cfg = ctx["cfg"]
    h = rms_norm(x, p["norm1"])
    y, cache = rg.decode_rglru_block(p["mixer"], h, cache, cfg)
    x = x + y
    h2 = rms_norm(x, p["norm2"])
    mp = p["mlp"]
    return x + swiglu(h2, mp["w_gate"], mp["w_up"], mp["w_down"]), cache


# --------------------------------------------------------------------------
# whisper decoder block ('C'): self + cross + MLP
# --------------------------------------------------------------------------


def _cross_init(key, cfg, dtype):
    ks, kc, km = jax.random.split(key, 3)
    return {
        "self": _init_attn_mlp(ks, cfg, dtype, with_mlp=False),
        "cross_attn": init_attention(
            kc, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim, dtype
        ),
        "norm_cross": jnp.zeros((cfg.d_model,), dtype),
        "norm_mlp": jnp.zeros((cfg.d_model,), dtype),
        "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, dtype),
    }


def _cross_attention(pa, h, enc_out, cfg):
    B, S, _ = h.shape
    Se = enc_out.shape[1]
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", h, pa["wq"]).reshape(B, S, H, Dh)
    k = jnp.einsum("bsd,de->bse", enc_out, pa["wk"]).reshape(B, Se, Hkv, Dh)
    v = jnp.einsum("bsd,de->bse", enc_out, pa["wv"]).reshape(B, Se, Hkv, Dh)
    from .attention import reference_attention

    o = reference_attention(q, k, v, causal=False)
    return jnp.einsum("bse,ed->bsd", o.reshape(B, S, H * Dh), pa["wo"])


def _cross_apply(p, x, ctx):
    cfg = ctx["cfg"]
    x, _ = _attn_apply(p["self"], x, ctx, window=None, with_mlp=False)
    h = rms_norm(x, p["norm_cross"])
    x = x + _cross_attention(p["cross_attn"], h, ctx["enc_out"], cfg)
    h2 = rms_norm(x, p["norm_mlp"])
    mp = p["mlp"]
    return x + swiglu(h2, mp["w_gate"], mp["w_up"], mp["w_down"]), ZERO_AUX()


def _cross_cache_init(cfg, batch, max_len, dtype):
    Hkv, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "self": _attn_cache_init(cfg, batch, max_len, dtype),
        # cross K/V computed once from encoder output at prefill
        "ck": jnp.zeros((batch, cfg.encoder_len, Hkv, Dh), dtype),
        "cv": jnp.zeros((batch, cfg.encoder_len, Hkv, Dh), dtype),
    }


def _cross_decode(p, x, cache, ctx):
    cfg = ctx["cfg"]
    B = x.shape[0]
    H, Dh = cfg.num_heads, cfg.resolved_head_dim
    x, self_cache = _attn_decode(p["self"], x, cache["self"], ctx, with_mlp=False)
    h = rms_norm(x, p["norm_cross"])
    q = (h @ p["cross_attn"]["wq"]).reshape(B, H, Dh)
    o = decode_attention(q, cache["ck"], cache["cv"], cache["ck"].shape[1])
    x = x + (o.reshape(B, H * Dh) @ p["cross_attn"]["wo"])
    h2 = rms_norm(x, p["norm_mlp"])
    mp = p["mlp"]
    x = x + swiglu(h2, mp["w_gate"], mp["w_up"], mp["w_down"])
    return x, {"self": self_cache, "ck": cache["ck"], "cv": cache["cv"]}


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------


class BlockType:
    def __init__(self, init, apply, cache_init, decode):
        self.init = init
        self.apply = apply
        self.cache_init = cache_init
        self.decode = decode


def _window_of(cfg):
    return cfg.window if cfg.window > 0 else None


BLOCKS = {
    "G": BlockType(
        init=lambda key, cfg, dt: _init_attn_mlp(key, cfg, dt),
        apply=lambda p, x, ctx: _attn_apply(p, x, ctx, window=None),
        cache_init=lambda cfg, b, s, dt: _attn_cache_init(cfg, b, s, dt),
        decode=lambda p, x, c, ctx: _attn_decode(p, x, c, ctx),
    ),
    "L": BlockType(
        init=lambda key, cfg, dt: _init_attn_mlp(key, cfg, dt),
        apply=lambda p, x, ctx: _attn_apply(p, x, ctx, window=_window_of(ctx["cfg"])),
        cache_init=lambda cfg, b, s, dt: _attn_cache_init(cfg, b, s, dt, window=cfg.window),
        decode=lambda p, x, c, ctx: _attn_decode(p, x, c, ctx, window=ctx["cfg"].window),
    ),
    "E": BlockType(
        init=lambda key, cfg, dt: _init_attn_mlp(key, cfg, dt),
        apply=lambda p, x, ctx: _attn_apply(p, x, ctx, window=None, causal=False),
        cache_init=None,
        decode=None,
    ),
    "M": BlockType(_moe_init, _moe_apply,
                   lambda cfg, b, s, dt: _attn_cache_init(cfg, b, s, dt),
                   _moe_decode),
    "S": BlockType(_ssm_init, _ssm_apply, _ssm_cache_init, _ssm_decode),
    "R": BlockType(_rg_init, _rg_apply, _rg_cache_init, _rg_decode),
    "C": BlockType(_cross_init, _cross_apply, _cross_cache_init, _cross_decode),
}
