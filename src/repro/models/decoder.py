"""Generic layer-pattern decoder: one model implementation for all families.

A config resolves to a *layer plan* ``(unit, reps, tail)`` — e.g. gemma3-27b
is ``("LLLLLG", 10, "LL")`` — and the stack runs as a ``lax.scan`` over the
``reps`` repeats of the unit (block params stacked on a leading repeat axis)
followed by the unrolled tail.  One scan body = one superblock; with
``cfg.remat`` the body is wrapped in ``jax.checkpoint`` so activation memory
is O(one superblock), compile time O(1) in depth.

The same plan drives the decode path: per-block caches are stacked on the
repeat axis and scanned through.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import runtime
from .blocks import BLOCKS
from .layers import cross_entropy_loss, dense_init, rms_norm, stack_layers


def layer_plan(cfg) -> Tuple[str, int, str]:
    if cfg.hybrid_pattern:
        unit = cfg.hybrid_pattern
    elif cfg.family == "moe":
        unit = "M"
    elif cfg.family == "ssm":
        unit = "S"
    elif cfg.family == "audio":
        unit = "C"
    elif cfg.local_global_pattern[0] > 0:
        nl, ng = cfg.local_global_pattern
        unit = "L" * nl + "G" * ng
    elif cfg.window > 0:
        unit = "L"
    else:
        unit = "G"
    reps = cfg.num_layers // len(unit)
    tail = unit[: cfg.num_layers % len(unit)]
    return unit, reps, tail


def param_dtype(cfg):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def init(key, cfg):
    dt = param_dtype(cfg)
    unit, reps, tail = layer_plan(cfg)
    ke, ku, kt, kh, kenc, kpp = jax.random.split(key, 6)

    params = {
        "embed": dense_init(ke, (cfg.padded_vocab, cfg.d_model), dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
        "lm_head": dense_init(kh, (cfg.d_model, cfg.padded_vocab), dt),
        "unit": {},
        "tail": {},
    }
    ukeys = jax.random.split(ku, len(unit))
    for j, t in enumerate(unit):
        params["unit"][f"b{j}"] = stack_layers(
            lambda k, t=t: BLOCKS[t].init(k, cfg, dt), ukeys[j], reps
        )
    tkeys = jax.random.split(kt, max(len(tail), 1))
    for j, t in enumerate(tail):
        params["tail"][f"b{j}"] = BLOCKS[t].init(tkeys[j], cfg, dt)

    if cfg.family == "audio":
        # whisper encoder: stub conv frontend ⇒ frame embeddings arrive at
        # d_model; encoder = stack of 'E' blocks.
        kl, kn = jax.random.split(kenc)
        params["encoder"] = {
            "unit": stack_layers(
                lambda k: BLOCKS["E"].init(k, cfg, dt), kl, cfg.encoder_layers
            ),
            "norm": jnp.zeros((cfg.d_model,), dt),
        }
    if cfg.family == "vlm":
        # projector between (stub) vision embeddings and the LM.
        params["prefix_proj"] = dense_init(kpp, (cfg.d_model, cfg.d_model), dt)
    return params


# --------------------------------------------------------------------------
# encoder (whisper)
# --------------------------------------------------------------------------


def encode(params, cfg, enc_emb):
    """enc_emb: (B, Se, d) stub frame embeddings → encoder output."""
    x = enc_emb.astype(param_dtype(cfg))
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1])[None], (x.shape[0], x.shape[1])
    )
    ctx = {"cfg": cfg, "positions": positions}
    apply_e = BLOCKS["E"].apply

    def body(x, p):
        p = runtime.constrain_layer_params(p)
        x, _ = apply_e(p, x, ctx)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"]["unit"])
    return rms_norm(x, params["encoder"]["norm"])


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------


def forward(params, cfg, tokens, *, prefix_emb=None, enc_emb=None):
    """Returns (logits, aux_loss).  tokens: (B, S_text)."""
    dt = param_dtype(cfg)
    x = params["embed"][tokens]  # (B,S,d) gather
    if prefix_emb is not None:
        pe = jnp.einsum("bpd,de->bpe", prefix_emb.astype(dt), params["prefix_proj"])
        x = jnp.concatenate([pe, x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    ctx = {"cfg": cfg, "positions": positions}
    if enc_emb is not None:
        ctx["enc_out"] = encode(params, cfg, enc_emb)

    unit, reps, tail = layer_plan(cfg)

    def body(carry, ps):
        x, aux = carry
        ps = runtime.constrain_layer_params(ps)  # ZeRO-3 per-layer gather
        for j, t in enumerate(unit):
            x, a = BLOCKS[t].apply(ps[f"b{j}"], x, ctx)
            aux = aux + a
        return (x, aux), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["unit"])
    for j, t in enumerate(tail):
        x, a = BLOCKS[t].apply(params["tail"][f"b{j}"], x, ctx)
        aux = aux + a

    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits, aux


def loss_fn(params, cfg, batch):
    """batch: tokens (B,S), targets (B,S) [, loss_mask, prefix_emb, enc_emb]."""
    logits, aux = forward(
        params,
        cfg,
        batch["tokens"],
        prefix_emb=batch.get("prefix_emb"),
        enc_emb=batch.get("enc_emb"),
    )
    targets = batch["targets"]
    if "prefix_emb" in batch and batch["prefix_emb"] is not None:
        logits = logits[:, batch["prefix_emb"].shape[1] :]  # text positions only
    ce = cross_entropy_loss(logits, targets, batch.get("loss_mask"))
    return ce + aux


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------


def init_cache(cfg, batch, max_len, rng=None):
    """Cache pytree for one-token decode against a ``max_len`` context."""
    dt = param_dtype(cfg)
    unit, reps, tail = layer_plan(cfg)
    cache = {"unit": {}, "tail": {}}
    for j, t in enumerate(unit):
        one = BLOCKS[t].cache_init(cfg, batch, max_len, dt)
        cache["unit"][f"b{j}"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (reps,) + x.shape), one
        )
    for j, t in enumerate(tail):
        cache["tail"][f"b{j}"] = BLOCKS[t].cache_init(cfg, batch, max_len, dt)
    return cache


def decode_step(params, cfg, cache, tokens, pos, *, enc_out=None):
    """One new token.  tokens: (B,) int32; pos: scalar int32 (its position,
    == current cache fill).  Returns (logits (B,V), new cache)."""
    x = params["embed"][tokens]  # (B,d)
    ctx = {"cfg": cfg, "pos": pos}
    unit, reps, tail = layer_plan(cfg)

    def body(x, scanned):
        ps, cs = scanned
        new_cs = {}
        for j, t in enumerate(unit):
            x, new_cs[f"b{j}"] = BLOCKS[t].decode(ps[f"b{j}"], x, cs[f"b{j}"], ctx)
        return x, new_cs

    x, new_unit_cache = jax.lax.scan(body, x, (params["unit"], cache["unit"]))
    new_cache = {"unit": new_unit_cache, "tail": {}}
    for j, t in enumerate(tail):
        x, new_cache["tail"][f"b{j}"] = BLOCKS[t].decode(
            params["tail"][f"b{j}"], x, cache["tail"][f"b{j}"], ctx
        )
    x = rms_norm(x, params["final_norm"])
    logits = x @ params["lm_head"]
    return logits, new_cache
