"""Shared neural-net layers (pure functions over param dicts).

All parameters are plain nested dicts of jnp arrays; block parameters are
stacked on a leading layer axis and consumed by ``lax.scan`` (constant
compile time at 126 layers, see DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INIT_SCALE = 0.02


def dense_init(key, shape, dtype, scale=INIT_SCALE):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def rms_norm(x, weight, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + weight.astype(jnp.float32))
    return out.astype(x.dtype)


def rope_freqs(head_dim, theta=1e4):
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    return inv  # (head_dim/2,)


def apply_rope(x, positions, theta=1e4):
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, Dh/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., None, :]  # broadcast over heads
    cos = cos[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP: down( silu(x·gate) ⊙ (x·up) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def init_mlp(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def init_attention(key, d_model, num_heads, num_kv_heads, head_dim, dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d_model, num_heads * head_dim), dtype),
        "wk": dense_init(kk, (d_model, num_kv_heads * head_dim), dtype),
        "wv": dense_init(kv, (d_model, num_kv_heads * head_dim), dtype),
        "wo": dense_init(ko, (num_heads * head_dim, d_model), dtype),
    }


def init_block(key, cfg, dtype):
    """One dense transformer block (attention + MLP + two norms)."""
    ka, km, kn1, kn2 = jax.random.split(key, 4)
    del kn1, kn2
    return {
        "attn": init_attention(
            ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, dtype
        ),
        "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, dtype),
        "norm1": jnp.zeros((cfg.d_model,), dtype),
        "norm2": jnp.zeros((cfg.d_model,), dtype),
    }


def stack_layers(init_fn, key, n_layers):
    """vmap an init over layer keys → params stacked on a leading L axis."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_fn)(keys)


def cross_entropy_loss(logits, targets, mask=None):
    """Mean next-token NLL in fp32.  logits (..., S, V), targets (..., S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
