"""Mamba-2 / SSD (state-space duality) mixer [arXiv:2405.21060].

Chunked SSD algorithm: the sequence is split into chunks of length Q; within
a chunk the recurrence

    h_t = a_t · h_{t-1} + Δ_t · B_t xᵀ_t          (per head, state (N,P))
    y_t = C_t · h_t

is computed in its *dual* quadratic ("attention-like") form, while states
propagate *across* chunks through a short sequential ``lax.scan``.  This is
the TPU-native blocking of the paper's insight: intra-chunk work is dense
MXU matmuls, inter-chunk work is an O(S/Q) scan of (H,N,P) states.

``ssd_reference`` is the step-by-step recurrence used as the test oracle,
and also the single-token decode update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, rms_norm


def d_inner(cfg):
    return cfg.ssm_expand * cfg.d_model


def n_ssm_heads(cfg):
    return d_inner(cfg) // cfg.ssm_head_dim


def init_mamba2(key, cfg, dtype):
    d = cfg.d_model
    din = d_inner(cfg)
    N = cfg.ssm_state
    H = n_ssm_heads(cfg)
    kz, kx, kb, kc, kdt, kout, kcx, kcb, kcc = jax.random.split(key, 9)
    # z / x / B / C / dt are SEPARATE projections (not one fused in_proj):
    # slicing a fused output at non-shard-aligned channel offsets costs a
    # collective-permute halo per split per layer per pass (§Perf iteration
    # 6 — 31k permutes on train_4k).  The depthwise conv likewise splits
    # into per-component convs — mathematically identical to Mamba-2's
    # fused conv over [x|B|C].  B/C/dt weights are small and kept replicated
    # so the SSD einsums need no contraction collectives.
    return {
        "in_proj_z": dense_init(kz, (d, din), dtype),
        "in_proj_x": dense_init(kx, (d, din), dtype),
        "in_proj_B": dense_init(kb, (d, N), dtype),
        "in_proj_C": dense_init(kc, (d, N), dtype),
        "in_proj_dt": dense_init(kdt, (d, H), dtype),
        "conv_w": dense_init(kcx, (cfg.conv_width, din), dtype, scale=0.1),
        "conv_b": jnp.zeros((din,), dtype),
        "conv_B_w": dense_init(kcb, (cfg.conv_width, N), jnp.float32, scale=0.1),
        "conv_B_b": jnp.zeros((N,), jnp.float32),
        "conv_C_w": dense_init(kcc, (cfg.conv_width, N), jnp.float32, scale=0.1),
        "conv_C_b": jnp.zeros((N,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),  # A = -exp(A_log) ⇒ stable
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),  # softplus ≈ 0.12
        "norm": jnp.zeros((din,), dtype),
        "out_proj": dense_init(kout, (din, d), dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv as W shifted multiply-adds.  x: (B,S,Ch),
    w: (W,Ch).  Written without conv_general_dilated: XLA's grouped-conv
    *gradient* under vmap∘jvp degrades to a dense cross-channel convolution
    (Ch² kernel!), which blows up both FLOPs and memory — see DESIGN.md §8."""
    W = w.shape[0]
    x32 = x.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    out = x32 * w32[W - 1]
    for i in range(1, W):
        shifted = jnp.pad(x32, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w32[W - 1 - i]
    return (out + b.astype(jnp.float32)).astype(x.dtype)




def ssd_chunked(x, B, C, log_a, dt, chunk):
    """Chunked SSD scan.

    x: (b,S,H,P)   input values (per head)
    B: (b,S,N)     input gates (1 state group, shared across heads)
    C: (b,S,N)     output gates
    log_a: (b,S,H) per-step log decay (= Δ_t · A, A<0)
    dt: (b,S,H)    discretization step
    Returns y: (b,S,H,P).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    f32 = jnp.float32

    # chunk-major layout for the scan: (nc, b, Q, …).  All per-chunk work
    # (including the Q×Q dual form) happens INSIDE the scan body so live
    # memory is one chunk, not the whole sequence (a (b,nc,H,Q,Q) decay
    # tensor at 4k×48H is ~100 GB — see DESIGN.md §8).
    # (A bf16 dual-form variant was tried and REFUTED by the dry-run byte
    # count: the added convert ops outweigh the halved DG buffer at the
    # CPU-HLO fusion granularity the analyzer sees — §Perf iter 7.)
    xc = x.reshape(b, nc, Q, H, P).swapaxes(0, 1).astype(f32)
    Bc = B.reshape(b, nc, Q, N).swapaxes(0, 1).astype(f32)
    Cc = C.reshape(b, nc, Q, N).swapaxes(0, 1).astype(f32)
    la = log_a.reshape(b, nc, Q, H).swapaxes(0, 1).astype(f32)
    dtc = dt.reshape(b, nc, Q, H).swapaxes(0, 1).astype(f32)

    idx = jnp.arange(Q)
    causal = idx[:, None] >= idx[None, :]  # j<=i

    def scan_body(h_prev, inp):
        xcc, bcc, ccc, lac, dtc_c = inp     # (b,Q,H,P), (b,Q,N), …, (b,Q,H)
        La = jnp.cumsum(lac, axis=1)        # inclusive decay-from-chunk-start
        La_tot = La[:, -1, :]               # (b,H)

        # intra-chunk dual quadratic form
        G = jnp.einsum("bin,bjn->bij", ccc, bcc)              # (b,Q,Q)
        D = jnp.exp(jnp.clip(
            La.transpose(0, 2, 1)[:, :, :, None]              # (b,H,Q,1)
            - La.transpose(0, 2, 1)[:, :, None, :], -60, 0))
        D = jnp.where(causal[None, None], D, 0.0)
        DG = D * G[:, None]                                   # (b,H,Q,Q)
        xdt = xcc * dtc_c[..., None]
        y_intra = jnp.einsum("bhij,bjhp->bihp", DG, xdt)

        # contribution of the carried state
        y_inter = jnp.einsum(
            "bin,bhnp,bih->bihp", ccc, h_prev, jnp.exp(jnp.clip(La, -60, 0))
        )

        # chunk summary + state propagation
        w = jnp.exp(jnp.clip(La_tot[:, None, :] - La, -60, 0)) * dtc_c  # (b,Q,H)
        S_c = jnp.einsum("bjh,bjn,bjhp->bhnp", w, bcc, xcc)
        h_next = (
            jnp.exp(jnp.clip(La_tot, -60, 0))[..., None, None] * h_prev + S_c
        )
        return h_next, y_intra + y_inter

    h0 = jnp.zeros((b, H, N, P), f32)
    _, ys = jax.lax.scan(scan_body, h0, (xc, Bc, Cc, la, dtc))
    y = ys.swapaxes(0, 1).reshape(b, S, H, P)
    return y.astype(x.dtype)


def ssd_reference(x, B, C, log_a, dt):
    """Step-by-step oracle (same signature as ssd_chunked, no chunk arg)."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    f32 = jnp.float32

    def step(h, inp):
        xt, Bt, Ct, lat, dtt = inp
        h = jnp.exp(lat)[..., None, None] * h + jnp.einsum(
            "bh,bn,bhp->bhnp", dtt, Bt, xt
        )
        y = jnp.einsum("bn,bhnp->bhp", Ct, h)
        return h, y

    h0 = jnp.zeros((b, H, N, P), f32)
    xs = (
        x.transpose(1, 0, 2, 3).astype(f32),
        B.transpose(1, 0, 2).astype(f32),
        C.transpose(1, 0, 2).astype(f32),
        log_a.transpose(1, 0, 2).astype(f32),
        dt.transpose(1, 0, 2).astype(f32),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)


def _mix_inputs(p, x, cfg):
    from . import runtime

    z = jnp.einsum("bsd,de->bse", x, p["in_proj_z"])
    xs = jnp.einsum("bsd,de->bse", x, p["in_proj_x"])
    Bg = jnp.einsum("bsd,dn->bsn", x, p["in_proj_B"])
    Cg = jnp.einsum("bsd,dn->bsn", x, p["in_proj_C"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["in_proj_dt"])
    xs = runtime.constrain_channels_last(xs)  # keep seq unsharded (§Perf)
    xs = jax.nn.silu(_causal_conv(xs, p["conv_w"], p["conv_b"]))
    Bg = jax.nn.silu(_causal_conv(Bg, p["conv_B_w"], p["conv_B_b"]))
    Cg = jax.nn.silu(_causal_conv(Cg, p["conv_C_w"], p["conv_C_b"]))
    H = n_ssm_heads(cfg)
    P = cfg.ssm_head_dim
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    log_a = dt * A  # (b,s,H)
    xh = xs.reshape(x.shape[0], x.shape[1], H, P)
    return z, xh, Bg, Cg, log_a, dt


def apply_mamba2(p, x, cfg):
    """Full mixer: in_proj → conv → SSD → gated norm → out_proj."""
    z, xh, Bg, Cg, log_a, dt = _mix_inputs(p, x, cfg)
    y = ssd_chunked(xh, Bg, Cg, log_a, dt, cfg.ssm_chunk)
    y = y.reshape(x.shape[0], x.shape[1], -1)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"])


# ------------------------- decode path -------------------------------------


def init_mamba2_cache(cfg, batch, dtype):
    din = d_inner(cfg)
    N = cfg.ssm_state
    H = n_ssm_heads(cfg)
    P = cfg.ssm_head_dim
    W = cfg.conv_width - 1
    return {
        "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
        "conv": jnp.zeros((batch, W, din), dtype),
        "conv_B": jnp.zeros((batch, W, N), jnp.float32),
        "conv_C": jnp.zeros((batch, W, N), jnp.float32),
    }


def _conv_step(win_cache, x_t, w, b):
    """One causal-conv step.  win_cache: (B,W-1,C); x_t: (B,C)."""
    win = jnp.concatenate([win_cache, x_t[:, None, :]], axis=1)
    out = (
        jnp.einsum("bwc,wc->bc", win.astype(jnp.float32), w.astype(jnp.float32))
        + b.astype(jnp.float32)
    )
    return out.astype(x_t.dtype), win[:, 1:, :]


def decode_mamba2(p, x, cache, cfg):
    """x: (B, d) one token.  Returns (y (B,d), new cache)."""
    B = x.shape[0]
    z = x @ p["in_proj_z"]
    xs_t = x @ p["in_proj_x"]
    Bg_t = x @ p["in_proj_B"]
    Cg_t = x @ p["in_proj_C"]
    dt_raw = x @ p["in_proj_dt"]
    xs_t, conv_x = _conv_step(cache["conv"], xs_t, p["conv_w"], p["conv_b"])
    Bg_t, conv_B = _conv_step(cache["conv_B"], Bg_t, p["conv_B_w"], p["conv_B_b"])
    Cg_t, conv_C = _conv_step(cache["conv_C"], Cg_t, p["conv_C_w"], p["conv_C_b"])
    xs_t, Bg_t, Cg_t = map(jax.nn.silu, (xs_t, Bg_t, Cg_t))
    H, P = n_ssm_heads(cfg), cfg.ssm_head_dim
    xs = xs_t.reshape(B, H, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(dt * (-jnp.exp(p["A_log"])))
    h = a[..., None, None] * cache["ssm"] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, Bg_t.astype(jnp.float32), xs.astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhnp->bhp", Cg_t.astype(jnp.float32), h).reshape(B, -1)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), p["norm"])
    out = y @ p["out_proj"]
    new_cache = {"ssm": h, "conv": conv_x, "conv_B": conv_B, "conv_C": conv_C}
    return out, new_cache
