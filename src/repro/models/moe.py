"""Mixture-of-Experts FFN with sort-free capacity dispatch.

Expert-parallel design (DESIGN.md §5): the expert buffer ``(E, C, d)`` is the
unit of sharding — ``E`` maps to the ``model`` mesh axis, so the
scatter/gather around it is the all-to-all the roofline's collective term
sees.  Dispatch is static-shaped:

1. router logits → top-k (gates renormalized over the chosen k);
2. position-in-expert by a cumsum over one-hot assignments;
3. tokens beyond the per-expert capacity ``C = ceil(T·k/E · cf)`` are
   dropped (``.at[...].add(mode="drop")``) — the standard capacity-dropping
   scheme (Switch/GShard), which keeps every shape static for pjit;
4. grouped expert matmul ``(E,C,d)×(E,d,f)`` — MXU-aligned batched GEMMs;
5. gather back + gate-weighted combine (+ shared experts, DeepSeekMoE-style).

Returns auxiliary losses (load-balance + router z-loss) so the HVP through
the router stays well-conditioned (DESIGN.md §4 MoE note).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, swiglu


def init_moe(key, cfg, dtype):
    E, d, f = cfg.num_experts, cfg.d_model, cfg.expert_d_ff or cfg.d_ff
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(kr, (d, E), jnp.float32),
        "w_gate": dense_init(k1, (E, d, f), dtype),
        "w_up": dense_init(k2, (E, d, f), dtype),
        "w_down": dense_init(k3, (E, f, d), dtype),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        ka, kb, kc = jax.random.split(ks, 3)
        p["shared"] = {
            "w_gate": dense_init(ka, (d, fs), dtype),
            "w_up": dense_init(kb, (d, fs), dtype),
            "w_down": dense_init(kc, (fs, d), dtype),
        }
    return p


def apply_moe(p, x, cfg):
    """x: (B, S, d) → (y, aux) with aux = {"lb_loss", "z_loss"}."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    T = B * S
    C = max(1, int(T * k / E * cfg.capacity_factor))

    xf = x.reshape(T, d)
    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)  # (T,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- static-shape position-in-expert via sort-based ranking ----
    # (the classic one-hot cumsum is O(T·k·E) compute AND memory — ~1.6 GB
    # per layer per pass at 1M tokens × 64 experts; a stable argsort +
    # running segment-start is O(T·k·log) — §Perf iteration 8)
    e_flat = idx.reshape(-1)  # (T*k,)
    n_flat = e_flat.shape[0]
    order = jnp.argsort(e_flat, stable=True)
    sorted_e = e_flat[order]
    ar = jnp.arange(n_flat, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]]
    )
    seg_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, ar, 0)
    )
    pos_sorted = ar - seg_start
    pos_in_e = jnp.zeros((n_flat,), jnp.int32).at[order].set(pos_sorted)
    keep = pos_in_e < C
    dst = jnp.where(keep, e_flat * C + pos_in_e, E * C)  # E*C = drop slot

    # ---- dispatch: scatter tokens into the (E, C, d) expert buffer ----
    xrep = jnp.broadcast_to(xf[:, None, :], (T, k, d)).reshape(T * k, d)
    buf = jnp.zeros((E * C, d), x.dtype).at[dst].add(
        jnp.where(keep[:, None], xrep, 0), mode="drop"
    )
    buf = buf.reshape(E, C, d)

    # ---- grouped expert GEMMs (expert axis = model mesh axis) ----
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])

    # ---- combine: gather back, gate-weight, sum over k ----
    outf = out.reshape(E * C, d)
    gathered = jnp.where(
        keep[:, None], outf.at[dst].get(mode="fill", fill_value=0), 0
    )
    y = (
        gathered.reshape(T, k, d).astype(jnp.float32)
        * gates[..., None]
    ).sum(1)
    y = y.astype(x.dtype).reshape(B, S, d)

    if cfg.num_shared_experts:
        sh = p["shared"]
        y = y + swiglu(x, sh["w_gate"], sh["w_up"], sh["w_down"])

    # ---- aux losses: Switch load-balance + router z ----
    me = probs.mean(0)  # (E,) mean router prob
    ce = jnp.zeros((E,)).at[e_flat].add(1.0) / (T * k)  # fraction routed
    lb_loss = E * jnp.sum(me * ce)
    z_loss = cfg.router_z_weight * jnp.mean(
        jax.scipy.special.logsumexp(logits, axis=-1) ** 2
    )
    return y, {"lb_loss": lb_loss, "z_loss": z_loss}
