"""RG-LRU recurrent block (RecurrentGemma / Griffin) [arXiv:2402.19427].

Real-Gated Linear Recurrent Unit:

    r_t = σ(W_r ξ_t + b_r)                     (recurrence gate)
    i_t = σ(W_i ξ_t + b_i)                     (input gate)
    a_t = exp(−c · softplus(Λ) ⊙ r_t)          (c = 8)
    h_t = a_t ⊙ h_{t−1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ ξ_t)

Training parallelizes the linear recurrence with ``lax.associative_scan``
over time (TPU-friendly log-depth scan); decode is the single-step update.
The full block is Griffin's gated structure: GeLU branch ⊙ (conv → RG-LRU)
branch → output projection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init

_C = 8.0


def init_rglru_block(key, cfg, dtype):
    d = cfg.d_model
    kx, ky, kr, ki, kl, ko, kc = jax.random.split(key, 7)
    return {
        "w_gelu": dense_init(kx, (d, d), dtype),
        "w_rnn_in": dense_init(ky, (d, d), dtype),
        "conv_w": dense_init(kc, (cfg.conv_width, d), dtype, scale=0.1),
        "conv_b": jnp.zeros((d,), dtype),
        "w_r": dense_init(kr, (d, d), dtype),
        "b_r": jnp.zeros((d,), jnp.float32),
        "w_i": dense_init(ki, (d, d), dtype),
        "b_i": jnp.zeros((d,), jnp.float32),
        # Λ init so that a ≈ U[0.9, 0.999] at r=1 (paper's init range)
        "lam": jnp.linspace(2.0, 5.0, d).astype(jnp.float32),
        "w_out": dense_init(ko, (d, d), dtype),
    }


def _gates(p, xi):
    r = jax.nn.sigmoid(xi.astype(jnp.float32) @ p["w_r"].astype(jnp.float32) + p["b_r"])
    i = jax.nn.sigmoid(xi.astype(jnp.float32) @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r  # (B,[S,]d), < 0
    return i, log_a


def rglru_scan(p, xi):
    """xi: (B,S,d) → h: (B,S,d) via associative scan."""
    i, log_a = _gates(p, xi)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 0.0, 1.0)) * i * xi.astype(jnp.float32)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, b_l * a_r + b_r

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    del a_s
    return h.astype(xi.dtype)


def rglru_step(p, xi_t, h_prev):
    """Single decode step.  xi_t: (B,d); h_prev: (B,d) fp32."""
    i, log_a = _gates(p, xi_t)
    a = jnp.exp(log_a)
    h = a * h_prev + jnp.sqrt(jnp.clip(1.0 - a * a, 0.0, 1.0)) * i * xi_t.astype(
        jnp.float32
    )
    return h


def _causal_conv(x, w, b):
    """Depthwise causal conv as shifted multiply-adds (see mamba2._causal_conv
    for why conv_general_dilated is avoided)."""
    W = w.shape[0]
    x32 = x.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    out = x32 * w32[W - 1]
    for i in range(1, W):
        shifted = jnp.pad(x32, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w32[W - 1 - i]
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def apply_rglru_block(p, x, cfg):
    """Griffin recurrent mixer.  x: (B,S,d) → (B,S,d)."""
    from . import runtime

    gelu_branch = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_gelu"]))
    xi = jnp.einsum("bsd,de->bse", x, p["w_rnn_in"])
    xi = runtime.constrain_channels_last(xi)  # keep seq unsharded (§Perf)
    xi = _causal_conv(xi, p["conv_w"], p["conv_b"])
    h = rglru_scan(p, xi)
    return jnp.einsum("bse,ed->bsd", gelu_branch * h, p["w_out"])


def init_rglru_cache(cfg, batch, dtype):
    d = cfg.d_model
    return {
        "h": jnp.zeros((batch, d), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, d), dtype),
    }


def decode_rglru_block(p, x, cache, cfg):
    """x: (B,d) → (y, new_cache)."""
    gelu_branch = jax.nn.gelu(x @ p["w_gelu"])
    xi = x @ p["w_rnn_in"]
    win = jnp.concatenate([cache["conv"], xi[:, None, :]], axis=1)
    xi_t = (
        jnp.einsum("bwc,wc->bc", win.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
        + p["conv_b"].astype(jnp.float32)
    ).astype(x.dtype)
    h = rglru_step(p, xi_t, cache["h"])
    y = (gelu_branch * h.astype(x.dtype)) @ p["w_out"]
    return y, {"h": h, "conv": win[:, 1:, :]}
