"""Trace-time runtime hooks the launch layer can install into the model.

``layer_param_constraint``: applied to each scanned superblock's parameter
slice inside the layer scan.  The launch layer installs a
``with_sharding_constraint`` that pins layer weights to their TP-only
(compute) sharding — i.e. ZeRO-3 per-layer all-gather.  Without it GSPMD
resolves the FSDP(d)×TP(f) weight vs activation mismatch by ALL-REDUCING the
full (B,S,f) partial products (~1e13 B/device on gemma3-27b train, §Perf
iteration 3) instead of all-gathering the (d, f/16) weight shard.
"""
from __future__ import annotations

import contextlib
from typing import Callable, Optional

_LAYER_PARAM_CONSTRAINT: Optional[Callable] = None
_CHANNEL_CONSTRAINT: Optional[Callable] = None


def constrain_layer_params(tree):
    if _LAYER_PARAM_CONSTRAINT is None:
        return tree
    return _LAYER_PARAM_CONSTRAINT(tree)


def constrain_channels_last(x):
    """Pin an activation's LAST axis to the TP ('model') axis and leave the
    sequence axis unsharded.  Used around the causal-conv shifts: if GSPMD
    ever shards the sequence axis there, every 1-step shift becomes a halo
    ``collective-permute`` (31k of them on mamba2 train — §Perf iter 6)."""
    if _CHANNEL_CONSTRAINT is None:
        return x
    return _CHANNEL_CONSTRAINT(x)


@contextlib.contextmanager
def layer_param_constraint(fn: Callable, channel_fn: Optional[Callable] = None):
    """Install hooks for the duration of a trace/lower call."""
    global _LAYER_PARAM_CONSTRAINT, _CHANNEL_CONSTRAINT
    prev, prev_c = _LAYER_PARAM_CONSTRAINT, _CHANNEL_CONSTRAINT
    _LAYER_PARAM_CONSTRAINT = fn
    _CHANNEL_CONSTRAINT = channel_fn
    try:
        yield
    finally:
        _LAYER_PARAM_CONSTRAINT = prev
        _CHANNEL_CONSTRAINT = prev_c
