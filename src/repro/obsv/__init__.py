"""repro.obsv — run-health doctor and benchmark regression ledger.

The observability layer ON TOP of :mod:`repro.telemetry`: where
telemetry records what happened, ``obsv`` judges it.

* ``python -m repro.obsv doctor <telemetry-dir|events.jsonl>`` joins a
  schema-v4 telemetry stream (optionally with a sweep
  :class:`~repro.sweep.store.ResultStore`) into a run-health report:
  per-run attack-detection precision/recall (the suspicion-flagged
  worker set vs the planted Byzantine ids), saddle-escape /
  EF-divergence / wire-ledger-mismatch anomaly flags, and per-worker
  suspicion tracks appended to the existing Perfetto trace;
* ``python -m repro.obsv bench-compare`` diffs the fingerprinted
  ``BENCH_<name>.json`` ledgers ``benchmarks/run.py`` appends against
  committed baselines and fails on threshold regressions.

See ``src/repro/telemetry/README.md`` for the schema-v4 field table the
doctor consumes.
"""
from .bench import (
    append_ledger,
    compare_ledgers,
    extract_scalars,
    fingerprint,
)
from .doctor import (
    analyze_events,
    augment_trace,
    detection_metrics,
    flagged_workers,
    group_runs,
    load_events,
    run_anomalies,
)

__all__ = [
    "analyze_events",
    "augment_trace",
    "detection_metrics",
    "flagged_workers",
    "group_runs",
    "load_events",
    "run_anomalies",
    "append_ledger",
    "compare_ledgers",
    "extract_scalars",
    "fingerprint",
]
