"""CLI: ``python -m repro.obsv {doctor,bench-compare}``.

    # judge a traced run: who got flagged, did it match the plant?
    python -m repro.obsv doctor results/telemetry \\
        --store results/sweep/store.jsonl --trace results/telemetry/trace.json \\
        --expect-precision 1.0 --expect-recall 1.0

    # gate a benchmark run against the committed baselines
    python -m repro.obsv bench-compare results/bench \\
        --baseline benchmarks/baselines
"""
from __future__ import annotations

import argparse
import json
import sys

from .bench import compare_ledgers
from .doctor import analyze_events, augment_trace, load_events, \
    summarize_store


def _doctor(args) -> int:
    events, problems = load_events(args.path)
    report = analyze_events(events, threshold=args.threshold)
    report["schema_problems"] = problems
    if args.store:
        report["store"] = summarize_store(args.store)
    if args.trace:
        report["trace"] = augment_trace(args.trace, events,
                                        out_path=args.trace_out)

    failures = list(problems)
    failures += report["wire_ledger_mismatch"]
    runs_with_truth = [r for r in report["runs"]
                       if r.get("detection") is not None]
    for r in report["runs"]:
        for a in r["anomalies"]:
            line = (f"{r['runtime']}/{r['attack']}/alpha={r['alpha']}: "
                    f"{a['flag']} — {a['detail']}")
            if args.fail_on_anomaly:
                failures.append(line)
    if args.expect_precision is not None or args.expect_recall is not None:
        if not runs_with_truth:
            failures.append("--expect-precision/--expect-recall given but "
                            "no run carries byzantine_true ground truth")
        for r in runs_with_truth:
            det = r["detection"]
            where = (f"{r['runtime']}/{r['attack']}/alpha={r['alpha']}")
            if (args.expect_precision is not None
                    and det["precision"] < args.expect_precision):
                failures.append(
                    f"{where}: precision {det['precision']:.3f} < "
                    f"expected {args.expect_precision} "
                    f"(flagged={r['flagged']}, truth={r['byzantine_true']})")
            if (args.expect_recall is not None
                    and det["recall"] < args.expect_recall):
                failures.append(
                    f"{where}: recall {det['recall']:.3f} < "
                    f"expected {args.expect_recall} "
                    f"(flagged={r['flagged']}, truth={r['byzantine_true']})")

    if args.json:
        print(json.dumps(report, indent=1))
    else:
        print(f"[doctor] {report['n_events']} events, "
              f"{report['n_runs']} run(s)")
        for r in report["runs"]:
            det = r.get("detection")
            det_str = (f" precision={det['precision']:.2f} "
                       f"recall={det['recall']:.2f}" if det else "")
            anom = ("" if not r["anomalies"] else
                    " anomalies=" + ",".join(a["flag"]
                                             for a in r["anomalies"]))
            print(f"[doctor]   {r['runtime']}/{r['attack']}"
                  f"/alpha={r['alpha']}: {r['n_rounds']} rounds, "
                  f"flagged={r['flagged']} ({r['method']})"
                  f"{det_str}{anom}")
        if report["wire_ledger_mismatch"]:
            for p in report["wire_ledger_mismatch"]:
                print(f"[doctor]   wire_ledger_mismatch: {p}")
        else:
            print("[doctor]   wire ledger: exact")
        if args.store:
            s = report["store"]
            print(f"[doctor]   store {s['path']}: {s['n_ok']}/"
                  f"{s['n_cells']} cells ok")
        if args.trace:
            print(f"[doctor]   per-worker tracks -> {report['trace']}")
    for f_line in failures:
        print(f"[doctor] FAIL: {f_line}", file=sys.stderr)
    return 1 if failures else 0


def _bench_compare(args) -> int:
    problems, warnings, n = compare_ledgers(
        args.current, args.baseline,
        bits_ratio=args.bits_ratio, rounds_ratio=args.rounds_ratio,
        check_times=args.check_times, strict=args.strict,
    )
    print(f"[bench-compare] {n} scalars compared against {args.baseline}")
    for w in warnings:
        print(f"[bench-compare] warning: {w}")
    for p in problems:
        print(f"[bench-compare] FAIL: {p}", file=sys.stderr)
    if not problems:
        print("[bench-compare] no regressions")
    return 1 if problems else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obsv")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_doc = sub.add_parser(
        "doctor", help="run-health report over a telemetry stream")
    p_doc.add_argument("path",
                       help="telemetry dir (containing events.jsonl) or "
                            "an events.jsonl path")
    p_doc.add_argument("--store", default=None,
                       help="join this sweep ResultStore (store.jsonl) "
                            "into the report")
    p_doc.add_argument("--trace", default=None,
                       help="append per-worker suspicion tracks to this "
                            "Perfetto trace.json")
    p_doc.add_argument("--trace-out", default=None,
                       help="write the augmented trace here instead of "
                            "in place")
    p_doc.add_argument("--threshold", type=float, default=0.5,
                       help="suspicion level that flags a worker "
                            "(default 0.5)")
    p_doc.add_argument("--expect-precision", type=float, default=None,
                       help="fail unless every ground-truthed run's "
                            "flagged-set precision is >= this")
    p_doc.add_argument("--expect-recall", type=float, default=None,
                       help="fail unless every ground-truthed run's "
                            "flagged-set recall is >= this")
    p_doc.add_argument("--fail-on-anomaly", action="store_true",
                       help="exit nonzero when any run carries an "
                            "anomaly flag")
    p_doc.add_argument("--json", action="store_true",
                       help="print the full report as JSON")
    p_doc.set_defaults(fn=_doctor)

    p_cmp = sub.add_parser(
        "bench-compare",
        help="diff benchmark ledgers against committed baselines")
    p_cmp.add_argument("current", help="dir of BENCH_<name>.json ledgers "
                                       "from the run under test")
    p_cmp.add_argument("--baseline", default="benchmarks/baselines",
                       help="dir of committed baseline ledgers")
    p_cmp.add_argument("--bits-ratio", type=float, default=1.5)
    p_cmp.add_argument("--rounds-ratio", type=float, default=2.0)
    p_cmp.add_argument("--check-times", action="store_true",
                       help="also gate wall-clock (off by default: CI "
                            "hosts are not comparable)")
    p_cmp.add_argument("--strict", action="store_true",
                       help="promote missing-entry warnings to failures")
    p_cmp.set_defaults(fn=_bench_compare)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
