"""Benchmark regression ledger: fingerprinted records, threshold diffs.

Every ``benchmarks/run.py`` invocation appends one record per benchmark
entry to ``BENCH_<name>.json`` (a JSON array — the ledger), carrying a
host **fingerprint** (git sha, jax/jaxlib versions, platform, UTC
timestamp) plus the entry's **scalars**: the deterministic quantities a
regression in is a bug (exact wire bits, rounds-to-ε, center bytes) and
the informational ones (kernel vs XLA wall-clock) that only gate when
asked.

``compare_ledgers`` diffs the newest record of each current ledger
against the newest committed baseline record, classifying every scalar
key by name:

* ``bits`` / ``bytes``  — exact static ints; regression when
  ``current > baseline × bits_ratio`` (default 1.5×, so an accidental
  2× wire blow-up always trips);
* ``rounds``            — convergence counts; lenient
  ``rounds_ratio`` (default 2×) plus a small absolute slack, since a
  platform's float drift can move an ε-crossing by a round;
* ``us`` / ``time``     — wall-clock; **skipped by default** (CI
  machines are not comparable), opt in with ``check_times``;
* anything else         — informational, never gates.

Missing keys or missing current ledgers are warnings (errors under
``strict``) — a benchmark that silently stops reporting a number is a
different failure mode from one that regresses it.
"""
from __future__ import annotations

import json
import os
import subprocess
from datetime import datetime, timezone
from typing import Optional


def fingerprint() -> dict:
    """Who/where/when of one benchmark run (everything best-effort —
    a missing git binary must not fail the benchmark)."""
    import platform as _platform

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        sha = "unknown"
    try:
        import jax
        import jaxlib
        jax_v, jaxlib_v = jax.__version__, jaxlib.__version__
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        jax_v = jaxlib_v = "unknown"
    return {
        "git_sha": sha,
        "jax": jax_v,
        "jaxlib": jaxlib_v,
        "platform": _platform.platform(),
        "python": _platform.python_version(),
        "timestamp_utc": datetime.now(timezone.utc).isoformat(),
    }


def _num(v) -> Optional[float]:
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return None
    return v


def extract_scalars(name: str, entry) -> dict:
    """Flatten one ``all_results`` entry to the ledger's scalar dict
    (dotted keys).  Unknown entries return {} — no ledger file."""
    out = {}

    def put(key, v):
        v = _num(v)
        if v is not None:
            out[key] = v

    if name in ("fig3", "fig12") and isinstance(entry, dict):
        for cell, hist in entry.items():
            if isinstance(hist, dict) and hist.get("loss"):
                put(f"{cell}.final_loss", hist["loss"][-1])
                put(f"{cell}.n_rounds", len(hist["loss"]))
    elif name == "table1" and isinstance(entry, list):
        for row in entry:
            key = f"{row.get('attack')}.alpha={row.get('alpha')}"
            put(f"{key}.newton_rounds", row.get("newton_rounds"))
            put(f"{key}.pgd_rounds", row.get("pgd_rounds"))
            put(f"{key}.newton_uplink_bits", row.get("newton_uplink_bits"))
            put(f"{key}.newton_downlink_bits",
                row.get("newton_downlink_bits"))
    elif name == "table1_compression" and isinstance(entry, list):
        for row in entry:
            key = str(row.get("compressor"))
            put(f"{key}.rounds", row.get("rounds"))
            put(f"{key}.uplink_bits_per_round",
                row.get("uplink_bits_per_round"))
            put(f"{key}.downlink_bits_per_round",
                row.get("downlink_bits_per_round"))
            put(f"{key}.uplink_bits", row.get("uplink_bits"))
            put(f"{key}.downlink_bits", row.get("downlink_bits"))
    elif name == "bits_to_eps" and isinstance(entry, list):
        for row in entry:
            key = str(row.get("compressor"))
            for eps, bits in (row.get("bits_to_eps") or {}).items():
                put(f"{key}.bits@eps={eps}", bits)
    elif name == "headtohead" and isinstance(entry, list):
        for row in entry:
            key = (f"{row.get('attack')}.{row.get('aggregator')}"
                   f".alpha={row.get('alpha')}")
            for col, v in row.items():
                if "_rounds@" in col or "_bits@" in col:
                    put(f"{key}.{col}", v)
    elif name == "topk_kernel_timing" and isinstance(entry, list):
        for row in entry:
            key = f"d={row.get('d')}"
            put(f"{key}.kernel_us", row.get("kernel_us"))
            put(f"{key}.xla_topk_us", row.get("xla_topk_us"))
    elif name == "agg_roofline" and isinstance(entry, list):
        for row in entry:
            key = f"{row.get('rule')}.m={row.get('m')}.d={row.get('d')}"
            put(f"{key}.kernel_us", row.get("kernel_us"))
            put(f"{key}.xla_dense_us", row.get("xla_dense_us"))
            put(f"{key}.center_bytes_sparse", row.get("center_bytes_sparse"))
            put(f"{key}.center_bytes_dense", row.get("center_bytes_dense"))
    elif name == "saddle_escape" and isinstance(entry, dict):
        for variant, hist in entry.items():
            if isinstance(hist, dict) and hist.get("loss"):
                put(f"{variant}.final_loss", hist["loss"][-1])
    elif name == "async_staleness" and isinstance(entry, dict):
        for cell in entry.get("cells", ()):
            key = (f"stale={cell.get('staleness')}"
                   f".p={cell.get('participation')}"
                   f".alpha={cell.get('alpha')}")
            put(f"{key}.uplink_bits", cell.get("uplink_bits"))
            put(f"{key}.saddle_escape_step", cell.get("saddle_escape_step"))
    return out


def append_ledger(ledger_dir: str, name: str, scalars: dict,
                  meta: dict) -> str:
    """Append one fingerprinted record to ``BENCH_<name>.json`` (created
    on first use).  Returns the ledger path."""
    os.makedirs(ledger_dir, exist_ok=True)
    path = os.path.join(ledger_dir, f"BENCH_{name}.json")
    records = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                records = json.load(f)
        except (OSError, json.JSONDecodeError):
            records = []
        if not isinstance(records, list):
            records = []
    records.append({"meta": meta, "scalars": scalars})
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(records, f, indent=1)
    os.replace(tmp, path)
    return path


def _classify(key: str) -> str:
    low = key.lower()
    if low.endswith("_us") or "time" in low:
        return "time"
    if "bits" in low or "bytes" in low:
        return "bits"
    if "rounds" in low:
        return "rounds"
    return "info"


def _latest(path: str) -> Optional[dict]:
    try:
        with open(path) as f:
            records = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    if not isinstance(records, list) or not records:
        return None
    return records[-1]


def compare_ledgers(current_dir: str, baseline_dir: str, *,
                    bits_ratio: float = 1.5, rounds_ratio: float = 2.0,
                    rounds_slack: int = 2, times_ratio: float = 5.0,
                    check_times: bool = False, strict: bool = False):
    """Diff current ledgers against committed baselines.

    Returns ``(problems, warnings, n_compared)`` — nonempty problems
    mean CI should fail; warnings are missing entries/keys (promoted to
    problems under ``strict``)."""
    problems, warnings = [], []
    n_compared = 0
    names = sorted(
        fn[len("BENCH_"):-len(".json")]
        for fn in os.listdir(baseline_dir)
        if fn.startswith("BENCH_") and fn.endswith(".json")
    )
    if not names:
        problems.append(f"{baseline_dir}: no BENCH_*.json baselines")
    for name in names:
        base = _latest(os.path.join(baseline_dir, f"BENCH_{name}.json"))
        cur_path = os.path.join(current_dir, f"BENCH_{name}.json")
        cur = _latest(cur_path)
        if base is None:
            warnings.append(f"{name}: unreadable baseline ledger")
            continue
        if cur is None:
            warnings.append(f"{name}: no current ledger at {cur_path}")
            continue
        base_s, cur_s = base.get("scalars", {}), cur.get("scalars", {})
        for key, bval in sorted(base_s.items()):
            cls = _classify(key)
            if cls == "info":
                continue
            if cls == "time" and not check_times:
                continue
            cval = cur_s.get(key)
            if cval is None:
                warnings.append(f"{name}/{key}: present in baseline, "
                                f"missing from current run")
                continue
            n_compared += 1
            if cls == "bits":
                limit = bval * bits_ratio
            elif cls == "rounds":
                limit = bval * rounds_ratio + rounds_slack
            else:
                limit = bval * times_ratio
            if cval > limit:
                problems.append(
                    f"{name}/{key}: {cval:g} vs baseline {bval:g} "
                    f"(limit {limit:g}, class {cls}) — REGRESSION"
                )
    if strict:
        problems += warnings
        warnings = []
    return problems, warnings, n_compared
