"""The run-health doctor: judge a telemetry stream, don't just store it.

A telemetry directory accumulates round records from any number of runs
(a quickstart, a sweep's cells, an example script).  The doctor segments
the stream back into runs, and for each run answers the forensic
question the schema-v4 fields exist for: **which workers does the
evidence accuse, and does that match the attack that was actually
planted?**

* a **run** is a maximal stretch of ``kind == "round"`` events whose
  ``step`` increases and whose identity ``(pid, runtime, attack,
  alpha)`` is constant — step resets and identity changes both start a
  new run (robust to many runs appended to one events.jsonl);
* the **flagged set** is read from the last round's ``suspicion`` vector
  (EWMA, see :class:`repro.telemetry.SuspicionTracker`) at a threshold;
  v1–v3 streams (no per-worker fields) fall back to rejection frequency
  over the run's ``rejected`` lists — degraded but never useless;
* **precision/recall** compare the flagged set against the planted
  ground truth (``byzantine_true``, emitted whenever the attack rule is
  live).  Runs without ground truth report flagged-only;
* **anomaly flags**: ``no_saddle_escape`` (a saddle-pushing attack run
  that never crossed below the problem's saddle value),
  ``loss_divergence`` (non-finite loss/grad anywhere),
  ``ef_divergence`` (a negative measured δ̂ — the error-feedback
  contract broke), and the stream-global ``wire_ledger_mismatch``
  (re-using the validator's exact-int wire check);
* the existing Perfetto trace gains one named **per-worker track** per
  run (thread-name metadata + a ``ph: "C"`` suspicion counter series),
  so the forensic timeline sits next to the spans the runtimes already
  emit.
"""
from __future__ import annotations

import json
import math
import os
from typing import Optional

from ..telemetry.__main__ import check_wire_exactness
from ..telemetry.schema import validate_stream

#: per-worker Perfetto tracks use tids far above any real thread id hash
_WORKER_TID_BASE = 0x10000


def load_events(path: str):
    """Load ``events.jsonl`` (or a telemetry dir containing one).

    Returns ``(events, problems)`` — schema violations are reported, not
    raised, so the doctor can still judge a partially bad stream."""
    if os.path.isdir(path):
        path = os.path.join(path, "events.jsonl")
    if not os.path.exists(path):
        return [], [f"{path}: no such file"]
    with open(path) as f:
        lines = [ln for ln in f.read().splitlines() if ln.strip()]
    problems = [f"line {ln}: {msg}" for ln, msg in validate_stream(lines)]
    events = []
    for ln in lines:
        try:
            events.append(json.loads(ln))
        except json.JSONDecodeError:
            pass  # already reported by validate_stream
    return events, problems


def group_runs(events: list) -> list:
    """Segment the stream's round records into runs (see module doc).

    Returns a list of ``{"runtime", "attack", "alpha", "pid",
    "rounds": [...]}`` in stream order."""
    runs = []
    cur = None
    for ev in events:
        if ev.get("kind") != "round":
            continue
        ident = (ev.get("pid"), ev.get("runtime"), ev.get("attack"),
                 ev.get("alpha"))
        step = ev.get("step", 0)
        if (cur is None or ident != cur["_ident"]
                or step <= cur["rounds"][-1].get("step", -1)):
            cur = {"_ident": ident, "pid": ident[0], "runtime": ident[1],
                   "attack": ident[2], "alpha": ident[3], "rounds": []}
            runs.append(cur)
        cur["rounds"].append(ev)
    for r in runs:
        r.pop("_ident")
    return runs


def flagged_workers(run: dict, threshold: float = 0.5):
    """The worker ids this run's evidence accuses.

    Schema-v4 runs: ids whose FINAL suspicion ≥ ``threshold``.  Older
    streams: ids rejected in ≥ half the rounds that recorded a
    ``rejected`` list.  Returns ``(flagged_ids, method)``."""
    rounds = run["rounds"]
    for ev in reversed(rounds):
        susp = ev.get("suspicion")
        if susp is not None:
            return ([i for i, s in enumerate(susp) if s >= threshold],
                    "suspicion")
    counts: dict[int, int] = {}
    n = 0
    for ev in rounds:
        rej = ev.get("rejected")
        if rej is None:
            continue
        n += 1
        for i in rej:
            counts[i] = counts.get(i, 0) + 1
    if n == 0:
        return [], "none"
    return (sorted(i for i, c in counts.items() if c / n >= 0.5),
            "rejection_frequency")


def detection_metrics(flagged, truth) -> dict:
    """Precision/recall of a flagged-worker set against the planted
    Byzantine ids (both empty ⇒ perfect: nothing to find, nothing
    accused)."""
    flagged, truth = set(flagged), set(truth)
    tp = len(flagged & truth)
    precision = tp / len(flagged) if flagged else (1.0 if not truth else 0.0)
    recall = tp / len(truth) if truth else 1.0
    return {"precision": precision, "recall": recall,
            "true_positives": tp, "false_positives": len(flagged - truth),
            "false_negatives": len(truth - flagged)}


def run_anomalies(run: dict) -> list:
    """Per-run anomaly flags (see module doc)."""
    rounds = run["rounds"]
    flags = []
    attack = run.get("attack") or "none"
    if "saddle" in attack and rounds \
            and not any(ev.get("saddle_escape") for ev in rounds):
        flags.append({
            "flag": "no_saddle_escape",
            "detail": f"attack {attack!r} ran {len(rounds)} rounds "
                      f"without ever crossing below the saddle value",
        })
    bad_loss = [ev.get("step") for ev in rounds
                if any(v is not None and not math.isfinite(v)
                       for v in (ev.get("loss"), ev.get("grad_norm")))]
    if bad_loss:
        flags.append({
            "flag": "loss_divergence",
            "detail": f"non-finite loss/grad_norm at steps {bad_loss[:5]}",
        })
    neg_delta = [ev.get("step") for ev in rounds
                 if ev.get("uplink_delta") is not None
                 and ev["uplink_delta"] < 0.0]
    if neg_delta:
        flags.append({
            "flag": "ef_divergence",
            "detail": f"negative measured δ̂ at steps {neg_delta[:5]} — "
                      f"the compressed update moved AWAY from what was "
                      f"sent (error feedback diverging)",
        })
    return flags


def analyze_events(events: list, *, threshold: float = 0.5) -> dict:
    """The full report over one loaded stream."""
    runs = group_runs(events)
    report_runs = []
    for run in runs:
        flagged, method = flagged_workers(run, threshold)
        truth = None
        for ev in reversed(run["rounds"]):
            if ev.get("byzantine_true") is not None:
                truth = ev["byzantine_true"]
                break
        entry = {
            "runtime": run["runtime"], "attack": run["attack"],
            "alpha": run["alpha"], "n_rounds": len(run["rounds"]),
            "flagged": flagged, "method": method,
            "byzantine_true": truth,
            "anomalies": run_anomalies(run),
        }
        if truth is not None:
            entry["detection"] = detection_metrics(flagged, truth)
        report_runs.append(entry)
    wire_problems = check_wire_exactness(events) \
        if any(e.get("kind") == "ledger" for e in events) else []
    return {
        "n_events": len(events),
        "n_runs": len(report_runs),
        "runs": report_runs,
        "wire_ledger_mismatch": wire_problems,
    }


def summarize_store(store_path: str) -> dict:
    """Join a sweep ResultStore into the report: cell counts plus the
    failed cells' specs (the doctor's 'what broke' section)."""
    from ..sweep.store import ResultStore

    store = ResultStore(store_path)
    records = store.records()
    failed = [r for r in records if r.get("status") != "ok"]
    return {
        "path": store_path,
        "n_cells": len(records),
        "n_ok": len(records) - len(failed),
        "failed": [{"hash": r.get("hash"), "spec": r.get("spec"),
                    "status": r.get("status")} for r in failed[:20]],
    }


def augment_trace(trace_path: str, events: list,
                  out_path: Optional[str] = None) -> str:
    """Append per-worker forensic tracks to an existing Perfetto trace.

    For every run with suspicion vectors: one thread-name metadata event
    per worker (``worker <i> [<runtime>/<attack>]``) plus a ``ph: "C"``
    counter series of that worker's suspicion over the run — rendered by
    Perfetto as per-worker counter tracks beside the runtime's spans.
    Writes ``out_path`` (default: overwrite in place) and returns it."""
    with open(trace_path) as f:
        doc = json.load(f)
    trace_events = doc.setdefault("traceEvents", [])
    named = set()
    for run in group_runs(events):
        label = f"{run['runtime']}/{run.get('attack') or 'none'}"
        for ev in run["rounds"]:
            susp = ev.get("suspicion")
            if susp is None:
                continue
            pid = ev.get("pid", 0)
            ts = round(float(ev.get("ts", 0.0)) * 1e6, 3)
            for i, s in enumerate(susp):
                tid = _WORKER_TID_BASE + i
                if (pid, tid, label) not in named:
                    named.add((pid, tid, label))
                    trace_events.append({
                        "name": "thread_name", "ph": "M", "ts": 0,
                        "pid": pid, "tid": tid,
                        "args": {"name": f"worker {i} [{label}]"},
                    })
                trace_events.append({
                    "name": f"suspicion.w{i}", "ph": "C", "ts": ts,
                    "pid": pid, "tid": tid,
                    "args": {"suspicion": s},
                })
    out_path = out_path or trace_path
    tmp = f"{out_path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(doc, f)
    os.replace(tmp, out_path)
    return out_path
