from .optimizers import Optimizer, adam, apply_updates, cosine_schedule, sgd

__all__ = ["Optimizer", "adam", "apply_updates", "cosine_schedule", "sgd"]
