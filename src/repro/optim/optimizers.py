"""Optimizer layer: a minimal optax-like API.

``cubic_newton`` is the paper's method as a first-class optimizer (wrapping
:func:`repro.core.distributed.make_train_step`); ``sgd`` / ``adam`` are the
reference first-order optimizers used by baselines and ablations.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable  # params -> state
    update: Callable  # (grads, state, params) -> (updates, state)


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(grads, state, params=None):
        del params
        if momentum == 0.0:
            return jax.tree_util.tree_map(lambda g: -lr * g, grads), ()
        new_m = jax.tree_util.tree_map(lambda m, g: momentum * m + g, state, grads)
        return jax.tree_util.tree_map(lambda m: -lr * m, new_m), new_m

    return Optimizer(init, update)


def adam(lr: float, b1=0.9, b2=0.999, eps=1e-8) -> Optimizer:
    def init(params):
        z = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": z, "v": jax.tree_util.tree_map(jnp.copy, z), "t": jnp.int32(0)}

    def update(grads, state, params=None):
        del params
        t = state["t"] + 1
        m = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["m"], grads
        )
        v = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"],
            grads,
        )
        mh = jax.tree_util.tree_map(lambda x: x / (1 - b1**t), m)
        vh = jax.tree_util.tree_map(lambda x: x / (1 - b2**t), v)
        upd = jax.tree_util.tree_map(
            lambda mh, vh: -lr * mh / (jnp.sqrt(vh) + eps), mh, vh
        )
        return upd, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params,
        updates,
    )


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        w = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        return base_lr * w * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))

    return lr
