"""`repro.solvers` — first-order baselines behind the `solver:` spec axis.

The paper's headline claim is ~25% better iteration complexity than
first-order methods; this package supplies the first-order side of that
comparison as alternate solvers every :class:`~repro.api.ExperimentSpec`
can select next to ``aggregator:`` / ``attack:``:

    "cubic_newton"                 Algorithm 1 (the default; lives in
                                   repro.core.newton — not here)
    "byzantine_pgd[:<R>:<Q>]"      perturbed robust gradient descent
                                   [Yin et al., ICML 2019] with the
                                   Escape sub-routine (R probe
                                   perturbations × Q robust-GD rounds)
    "compressed_sgd[:<radius>:<gtol>]"
                                   compressed Byzantine-resilient SGD
                                   (Chen/Li/Chi 2023, arXiv 2310.19059):
                                   δ-compressed gradient rounds with
                                   EF21, optional isotropic perturbation
                                   of radius ``radius`` whenever
                                   ‖aggregate‖ ≤ ``gtol`` (saddle
                                   escape; off at the default radius 0)

Both solvers transmit **exclusively** through the same
:class:`repro.comm.VectorChannel` stack as the Newton runtimes — m
uplink gradient payloads (δ-compressed, EF/EF21 state, the Byzantine
injection hook) plus one downlink broadcast per communication round,
every exchange billed at send time on a :class:`repro.comm.WireLedger`
(escape-probe rounds included) — resolve their aggregator and attack
from the :mod:`repro.api` registries, and emit the same history schema
and per-round :class:`~repro.telemetry.RoundRecord`s, so the sweep /
report pivots work unchanged across the solver axis.

Degenerate-parity contracts (pinned in ``tests/test_solvers.py``):

* ``compressed_sgd`` with ``compressor=None``, ``aggregator="mean"``,
  α = 0 is **bit-exact** with the plain robust-SGD reference loop
  ``w ← w − η·mean_i ∇f_i(w)``;
* ``byzantine_pgd`` through the facade reproduces the legacy
  ``repro.core.ByzantinePGD`` loop's round count (the legacy class is
  now a thin shim over :class:`ChannelByzantinePGD`).
"""
from __future__ import annotations

from ..api.errors import SpecError

SOLVER_SPECS = ("cubic_newton", "byzantine_pgd[:<R>:<Q>]",
                "compressed_sgd[:<radius>:<gtol>]")

#: solver heads that ship first-order gradient rounds (paper runtime only)
FIRST_ORDER_SOLVERS = ("byzantine_pgd", "compressed_sgd")


def parse_solver_spec(spec) -> tuple:
    """Validate a ``solver`` spec string → ``(head, params dict)``.

    Pure grammar — no registry objects are built here, so
    :meth:`ExperimentSpec.validate` can call it without touching JAX.
    Raises :class:`~repro.api.errors.SpecError` on unknown heads, wrong
    arity, or non-numeric / out-of-range parameters.
    """
    if spec is None:
        spec = "cubic_newton"
    if not isinstance(spec, str):
        raise SpecError(f"solver spec must be a string, got {spec!r}")
    head, _, rest = spec.partition(":")
    args = rest.split(":") if rest else []
    if head == "cubic_newton":
        if args:
            raise SpecError(
                f"solver 'cubic_newton' takes no parameters, got {spec!r}"
            )
        return head, {}
    if head == "byzantine_pgd":
        if len(args) not in (0, 2):
            raise SpecError(
                f"solver spec {spec!r}: expected 'byzantine_pgd' or "
                f"'byzantine_pgd:<R>:<Q>' (escape attempts × GD rounds "
                f"per attempt)"
            )
        try:
            R = int(args[0]) if args else 10
            Q = int(args[1]) if args else 10
        except ValueError:
            raise SpecError(
                f"solver spec {spec!r}: R and Q must be integers"
            ) from None
        if R < 0 or Q < 1:
            raise SpecError(
                f"solver spec {spec!r}: need R ≥ 0 escape attempts and "
                f"Q ≥ 1 GD rounds per attempt"
            )
        return head, {"R": R, "Q": Q}
    if head == "compressed_sgd":
        if len(args) not in (0, 2):
            raise SpecError(
                f"solver spec {spec!r}: expected 'compressed_sgd' or "
                f"'compressed_sgd:<radius>:<gtol>' (perturbation radius "
                f"and its ‖aggregate‖ trigger)"
            )
        try:
            radius = float(args[0]) if args else 0.0
            gtol = float(args[1]) if args else 0.0
        except ValueError:
            raise SpecError(
                f"solver spec {spec!r}: radius and gtol must be numbers"
            ) from None
        if radius < 0 or gtol < 0:
            raise SpecError(
                f"solver spec {spec!r}: radius and gtol must be ≥ 0"
            )
        return head, {"perturb_radius": radius, "perturb_gtol": gtol}
    raise SpecError(
        f"unknown solver spec {spec!r}; expected one of {SOLVER_SPECS}"
    )


def make_solver(spec, loss_fn):
    """Validated :class:`~repro.api.ExperimentSpec` + loss → a built
    first-order solver (the ``Experiment.algo`` for non-Newton specs).

    Channel wiring mirrors :meth:`ExperimentSpec.to_newton_config`: the
    uplink takes ``spec.compressor`` with the resolved error feedback
    and the attack registry's injection hook, the downlink broadcast
    takes ``spec.downlink_compressor``, and ``eta`` is the step size.
    """
    from ..api.attacks import make_attack
    from .pgd import ChannelByzantinePGD, PGDParams
    from .sgd import CompressedSGD, SGDParams

    head, params = parse_solver_spec(spec.solver)
    attack = make_attack(spec.attack, spec.alpha,
                         num_classes=spec.num_classes)
    common = dict(
        lr=spec.eta,
        compressor=spec.compressor,
        downlink_compressor=spec.downlink_compressor,
        error_feedback=spec.resolved_error_feedback(),
        ef_damping=spec.ef_damping,
    )
    if head == "byzantine_pgd":
        return ChannelByzantinePGD(
            loss_fn, PGDParams(**common, **params),
            aggregator=spec.aggregator, attack=attack, seed=spec.seed,
        )
    if head == "compressed_sgd":
        return CompressedSGD(
            loss_fn, SGDParams(**common, momentum=spec.momentum, **params),
            aggregator=spec.aggregator, attack=attack, seed=spec.seed,
        )
    raise SpecError(
        f"solver {spec.solver!r} is not a repro.solvers solver "
        f"(cubic_newton builds through repro.core.newton)"
    )


def __getattr__(name):
    # heavy solver classes resolve lazily so `parse_solver_spec` stays
    # importable without pulling JAX into spec validation
    if name in ("ChannelByzantinePGD", "PGDParams"):
        from . import pgd

        return getattr(pgd, name)
    if name in ("CompressedSGD", "SGDParams"):
        from . import sgd

        return getattr(sgd, name)
    raise AttributeError(name)


__all__ = [
    "FIRST_ORDER_SOLVERS",
    "SOLVER_SPECS",
    "ChannelByzantinePGD",
    "CompressedSGD",
    "PGDParams",
    "SGDParams",
    "make_solver",
    "parse_solver_spec",
]
