"""Shared channel/ledger plumbing for the first-order solvers.

:class:`FirstOrderSolver` owns exactly what
:class:`~repro.core.newton.DistributedCubicNewton` owns — and nothing it
doesn't: the uplink/downlink :class:`~repro.comm.VectorChannel` pair
(resolved ONCE per observed ``(d, m)``, never inside a trace), the
registry-resolved aggregator and :class:`~repro.api.ResolvedAttack`, the
host-side exact-int :class:`~repro.comm.WireLedger`, the adaptive-k
schedule hook, and the common history bookkeeping.  Subclasses implement
one jitted communication round plus their host loop.

One **communication round** is always m uplink gradient payloads + one
downlink broadcast of the model step — `bits_per_step()` is the same
static-int introspection the Newton runtimes expose, and every executed
round (main loop *and* escape probes) is billed on the ledger at send
time, so the history's ledger snapshot is exact by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax

from ..comm import VectorChannel, WireLedger
from ..compression import AdaptiveTopK
from ..telemetry import (
    RoundRecord,
    SuspicionTracker,
    compile_scope,
    get_telemetry,
    planted_byzantine_ids,
    record_retrace,
    rejected_from_keep,
)


@dataclasses.dataclass(frozen=True)
class FirstOrderParams:
    """Channel + step-size parameters shared by both first-order solvers
    (the fields :func:`repro.solvers.make_solver` maps off an
    :class:`~repro.api.ExperimentSpec`)."""

    lr: float = 1.0                            # spec.eta
    compressor: Optional[str] = None           # uplink gradient payloads
    downlink_compressor: Optional[str] = None  # center→worker broadcast
    error_feedback: str = "none"               # "none" | "ef" | "ef21"
    ef_damping: float = 0.75


class FirstOrderSolver:
    """Channel-routed robust first-order loop (template for PGD / SGD).

    ``loss_fn(w, X, y) -> scalar`` with worker-stacked data ``X: (m, n,
    d)``, ``y: (m, n)`` — the paper runtime's layout.  ``aggregator`` is
    a :mod:`repro.api.aggregators` spec string (or resolved Aggregator);
    ``attack`` a :class:`~repro.api.ResolvedAttack` (or a legacy
    :class:`~repro.core.newton.AttackConfig`).
    """

    runtime_label = "first_order"
    rounds_per_step = 1

    def __init__(self, loss_fn: Callable, params: FirstOrderParams,
                 aggregator="mean", attack=None, seed: int = 0):
        from ..api.aggregators import make_aggregator
        from ..api.attacks import make_attack, resolve_attack

        self.loss_fn = loss_fn
        self.params = params
        self.seed = int(seed)
        self.aggregator = make_aggregator(aggregator)
        if attack is None or isinstance(attack, str):
            self._attack_rule = make_attack(attack or "none", 0.0)
        elif hasattr(attack, "update_hook"):
            self._attack_rule = attack          # already resolved
        else:
            self._attack_rule = resolve_attack(attack)  # legacy config
        self._grad_fn = jax.grad(loss_fn)
        self._per_worker_grads = jax.vmap(self._grad_fn,
                                          in_axes=(None, 0, 0))
        self.ledger = WireLedger()
        self._dims: Optional[tuple] = None
        self.uplink: Optional[VectorChannel] = None
        self.downlink: Optional[VectorChannel] = None
        self._rebuild_jit()

    # -- channels (once per (d, m), never per trace) --------------------
    def _rebuild_jit(self):
        """(Re)trace the jitted round — needed at channel (re)build and
        whenever an adaptive compressor's static k moves."""
        if self._dims is not None:
            record_retrace(
                f"{self.runtime_label}.round.rebuild",
                **{f"k_{name}": ch.compressor.k
                   for name, ch in self.channels.items()
                   if isinstance(ch.compressor, AdaptiveTopK)},
            )
        self._round = jax.jit(self._round_impl)

    def _ensure_channels(self, d: int, m: int):
        if self._dims == (d, m):
            return
        p = self.params
        self.uplink = VectorChannel(
            "uplink", p.compressor, d, m,
            error_feedback=p.error_feedback, damping=p.ef_damping,
            attack_hook=self._attack_rule.update_hook(m),
        )
        self.downlink = VectorChannel(
            "downlink", p.downlink_compressor, d, 1,
            error_feedback=p.error_feedback, damping=p.ef_damping,
        )
        if self._dims is not None:
            self._rebuild_jit()   # stale trace would bake old channels in
        self._dims = (d, m)

    @property
    def channels(self):
        return {"uplink": self.uplink, "downlink": self.downlink}

    def init_comm_state(self):
        """Fresh channel-state pytree (per-worker EF memories)."""
        return {"uplink": self.uplink.init_state(),
                "downlink": self.downlink.init_state()}

    # -- wire accounting ------------------------------------------------
    def bits_per_step(self) -> dict:
        """Exact bits ONE communication round costs per direction
        (static Python ints; channels must exist)."""
        return {"uplink": self.uplink.bits_per_round(),
                "downlink": self.downlink.bits_per_round()}

    def _bill_round(self, label: str = "round") -> dict:
        """Bill one executed round on the ledger at send time (re-read
        per round: an adaptive uplink moves k between rounds)."""
        bps = self.bits_per_step()
        self.ledger.record(uplink=bps["uplink"], downlink=bps["downlink"],
                           rounds=1, label=label)
        return bps

    # -- adaptive-k (same schedule hook as the Newton runtimes) ---------
    def _maybe_adapt(self, grad_norm: float,
                     measured_delta: Optional[float] = None) -> bool:
        changed = False
        for name, ch in self.channels.items():
            comp = ch.compressor
            if isinstance(comp, AdaptiveTopK):
                changed |= comp.schedule_update(
                    grad_norm=grad_norm,
                    measured_delta=(measured_delta
                                    if name == "uplink" else None),
                )
        if changed:
            self._rebuild_jit()
        return changed

    def _uplink_k(self) -> Optional[int]:
        comp = self.uplink.compressor if self.uplink is not None else None
        return comp.k if isinstance(comp, AdaptiveTopK) else None

    # -- the one jitted communication round (subclass) ------------------
    def _round_impl(self, *args):
        raise NotImplementedError

    # -- history bookkeeping (one schema across all solvers) ------------
    @staticmethod
    def _fresh_hist() -> dict:
        return {"loss": [], "grad_norm": [], "eval": [], "rounds": 0,
                "bits_cumulative": [], "uplink_delta": [],
                "k_trajectory": [], "saddle_escape_step": None,
                "truncated": False}

    def _emit_round(self, tel, *, step, loss, gn, prev_loss, delta_hat,
                    k_live, k_changed, escaped, info, bps, tracker=None):
        if not tel.enabled:
            return
        keep = info["keep"]
        fields = {}
        if tracker is not None:
            # schema-v4 per-worker forensics (host-side; the traced round
            # only stages the extra outputs when telemetry was enabled at
            # trace time, see the subclasses' _round_impl gates)
            m = tracker.m
            keep_l = [float(k) for k in keep]
            norms = info.get("update_norms")
            norms_l = ([float(n) for n in norms]
                       if norms is not None else None)
            fields = {
                "worker_bits": [bps["uplink"] // m] * m,
                "worker_keep": keep_l,
                "suspicion": tracker.update(keep=keep_l, norms=norms_l),
            }
            if norms_l is not None:
                fields["worker_norms"] = norms_l
            if info.get("worker_delta") is not None:
                fields["worker_delta"] = [float(x)
                                          for x in info["worker_delta"]]
            if self._attack_rule.kind != "none":
                fields["byzantine_true"] = planted_byzantine_ids(
                    m, self._attack_rule.alpha
                )
        tel.round(RoundRecord(
            step=step, runtime=self.runtime_label, loss=loss, grad_norm=gn,
            model_decrease=(None if prev_loss is None else prev_loss - loss),
            uplink_delta=delta_hat, k=k_live, k_changed=k_changed,
            saddle_escape=escaped,
            rejected=rejected_from_keep(keep),
            attack=self._attack_rule.spec,
            alpha=self._attack_rule.alpha,
            wire_uplink_bits=bps["uplink"],
            wire_downlink_bits=bps["downlink"],
            **fields,
        ), name=f"{self.runtime_label}.round")

    def _jit_round(self, *args):
        """Run the jitted round under the compile-attribution scope."""
        with compile_scope(f"{self.runtime_label}.round"):
            return self._round(*args)

    # convenience the run loops share
    def _pooled_fns(self, X, y, full_data):
        if full_data is None:
            full_data = (X.reshape(-1, X.shape[-1]), y.reshape(-1))
        Xf, yf = full_data
        return Xf, yf, jax.jit(self._grad_fn), jax.jit(self.loss_fn)

    @staticmethod
    def _telemetry():
        return get_telemetry()
