"""ByzantinePGD [Yin et al., ICML 2019] routed through the channel stack.

Perturbed robust gradient descent — the first-order baseline the paper's
Table 1 beats.  Every round each worker ships its local gradient through
the **uplink** :class:`~repro.comm.VectorChannel` (δ-compressed, EF/EF21
state, the registry attack's injection hook); the center aggregates with
a :mod:`repro.api.aggregators` rule and broadcasts the GD step through
the **downlink** channel.  Whenever the pooled gradient is small the
``Escape`` sub-routine probes: up to ``R`` random perturbations in an
r-ball, each followed by up to ``Q`` robust-GD rounds — every probe
round is a full communication round, transmitted through the same
channels and billed on the :class:`~repro.comm.WireLedger` at send time
(the exact-int wire cost Table 1 now reads instead of the old
``rounds · m · 32 · d`` estimate).

Differences from the legacy ``repro.core.byzantine_pgd`` loop (which is
now a shim over this class):

* attacks/aggregators come from the api registries, so a spec-named
  attack (``"gaussian:10.0"``, ``"saddle:5.0"``) means the same thing
  here as in both Newton runtimes;
* the Escape budget is capped at the remaining round budget, so
  ``hist["rounds"] ≤ n_steps`` always (the legacy loop could overshoot
  its ``max_rounds`` by up to R·Q probe rounds);
* escape state (channel EF memories) reverts with the iterate when an
  attempt is rejected — the bits stay billed (they crossed the wire),
  but the center's belief doesn't advance on a rejected probe.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..telemetry import SuspicionTracker
from .base import FirstOrderParams, FirstOrderSolver


@dataclasses.dataclass(frozen=True)
class PGDParams(FirstOrderParams):
    """Yin et al.'s experiment defaults: R=10, r=5, Q=10."""

    R: int = 10            # escape attempts
    r: float = 5.0         # perturbation radius
    Q: int = 10            # robust-GD rounds per escape attempt
    f_th: float = 1e-3     # function-decrease threshold to accept an escape
    grad_th: float = 1e-4  # "gradient is small" escape trigger (fallback
    #                        when the caller passes no grad_tol)


class ChannelByzantinePGD(FirstOrderSolver):
    """Channel-routed perturbed robust gradient descent."""

    runtime_label = "pgd"

    # -- one jitted communication round ---------------------------------
    def _round_impl(self, w, state, X, y, key):
        p = self.params
        k_label, k_update, k_comp, k_down = jax.random.split(key, 4)
        new_state = dict(state)

        # data-level attacks corrupt Byzantine workers' labels before the
        # local gradient; update-level attacks corrupt the reconstructed
        # uplink payloads inside the channel (same order as the Newton
        # step, so one spec means one attack across the solver axis)
        y_used = self._attack_rule.corrupt_labels(k_label, y)
        g = self._per_worker_grads(w, X, y_used)
        # forensics (schema v4): stage per-sender δ̂ and update norms only
        # when telemetry was enabled at trace time — the disabled round
        # compiles to the exact pre-forensics HLO
        forensics = self._telemetry().enabled
        if forensics:
            g, new_state["uplink"], delta, worker_delta = \
                self.uplink.transmit(
                    g, state["uplink"], key=k_comp, attack_key=k_update,
                    measure=True, per_sender=True,
                )
        else:
            g, new_state["uplink"], delta = self.uplink.transmit(
                g, state["uplink"], key=k_comp, attack_key=k_update,
                measure=True,
            )
        agg, keep = self.aggregator(g)
        step, new_state["downlink"] = self.downlink.transmit(
            -p.lr * agg, state["downlink"], key=k_down
        )
        info = {
            "keep": keep, "uplink_delta": delta,
            "agg_norm": jnp.linalg.norm(agg),
        }
        if forensics:
            info["worker_delta"] = worker_delta
            info["update_norms"] = jnp.linalg.norm(
                g.reshape(g.shape[0], -1), axis=-1
            )
        return w + step, new_state, info

    # -- the Escape sub-routine -----------------------------------------
    def _escape(self, w, state, X, y, key, budget, lossf, Xf, yf, f0):
        """Probe up to R perturbations × Q robust-GD rounds within
        ``budget`` remaining communication rounds.  Returns
        ``(escaped?, w, state, rounds_used)`` — iterate AND channel
        state revert on a rejected attempt (billed bits stay billed)."""
        p = self.params
        used = 0
        for _ in range(p.R):
            if used >= budget:
                break
            key, kp, kg = jax.random.split(key, 3)
            u = jax.random.normal(kp, w.shape)
            u = (u / (jnp.linalg.norm(u) + 1e-12)
                 * p.r * jax.random.uniform(kp))
            w_try, st_try = w + u, state
            for _q in range(p.Q):
                if used >= budget:
                    break
                kg, sub = jax.random.split(kg)
                w_try, st_try, _ = self._jit_round(w_try, st_try, X, y, sub)
                self._bill_round(label="escape")
                used += 1
            f_try = float(lossf(w_try, Xf, yf))
            if f0 - f_try > p.f_th:
                return True, w_try, st_try, used  # decreased ⇒ was a saddle
        return False, w, state, used

    # -- host loop -------------------------------------------------------
    def run(self, w0, X, y, n_steps, key=None, eval_fn=None,
            grad_tol=None, full_data=None, deadline=None,
            saddle_value=None):
        """Run robust PGD for at most ``n_steps`` communication rounds
        (main-loop AND escape-probe rounds both count — the Table-1
        metric), stopping early only when Escape certifies a
        second-order stationary point.  Same signature and history
        schema as :meth:`DistributedCubicNewton.run`; the small-gradient
        escape trigger is ``grad_tol`` when given, else
        ``params.grad_th``."""
        import time as _time

        key = key if key is not None else jax.random.PRNGKey(self.seed)
        Xf, yf, gradf, lossf = self._pooled_fns(X, y, full_data)
        self._ensure_channels(w0.shape[0], X.shape[0])
        ledger = self.ledger
        ledger.reset()
        hist = self._fresh_hist()
        hist["escape_rounds"] = 0
        tel = self._telemetry()
        prev_loss = float(lossf(w0, Xf, yf)) if tel.enabled else None
        tracker = SuspicionTracker(X.shape[0]) if tel.enabled else None
        trigger = grad_tol if grad_tol is not None else self.params.grad_th

        w = w0
        state = self.init_comm_state()
        t = 0
        while ledger.rounds < n_steps:
            if deadline is not None and hist["loss"] \
                    and _time.monotonic() >= deadline:
                hist["truncated"] = True
                if tel.enabled:
                    tel.event("pgd.truncated", step=t)
                break
            key, sub = jax.random.split(key)
            k_live = self._uplink_k()
            w, state, info = self._jit_round(w, state, X, y, sub)
            bps = self._bill_round()
            hist["bits_cumulative"].append(ledger.total_bits)
            delta_hat = float(info["uplink_delta"])
            hist["uplink_delta"].append(delta_hat)
            hist["k_trajectory"].append(k_live)
            gn = float(jnp.linalg.norm(gradf(w, Xf, yf)))
            loss = float(lossf(w, Xf, yf))
            hist["loss"].append(loss)
            hist["grad_norm"].append(gn)
            if eval_fn is not None:
                hist["eval"].append(float(eval_fn(w)))
            escaped_saddle = (saddle_value is not None
                              and hist["saddle_escape_step"] is None
                              and loss < saddle_value)
            if escaped_saddle:
                hist["saddle_escape_step"] = t
            k_changed = self._maybe_adapt(gn, measured_delta=delta_hat)
            self._emit_round(tel, step=t, loss=loss, gn=gn,
                             prev_loss=prev_loss, delta_hat=delta_hat,
                             k_live=k_live, k_changed=k_changed,
                             escaped=escaped_saddle, info=info,
                             bps=bps, tracker=tracker)
            prev_loss = loss
            t += 1
            if gn <= trigger:
                # candidate stationary point: certify it is not a saddle
                key, esc = jax.random.split(key)
                escaped, w, state, used = self._escape(
                    w, state, X, y, esc, n_steps - ledger.rounds,
                    lossf, Xf, yf, loss,
                )
                hist["escape_rounds"] += used
                if tel.enabled:
                    tel.event("pgd.escape", step=t, escaped=escaped,
                              probe_rounds=used)
                if not escaped:
                    break  # certified: no descent in R perturbations
        hist.update(ledger.snapshot())
        return w, hist
