"""Compressed Byzantine-resilient SGD (Chen/Li/Chi 2023, arXiv 2310.19059).

The second first-order baseline: plain robust (momentum-)SGD whose
gradient rounds ride the δ-compressed uplink with EF21 error feedback —
the regime of "Byzantine-robust decentralized learning with compression"
— plus the optional saddle-escape device of the perturbed variant: when
the aggregated gradient's norm falls to ``perturb_gtol`` the center adds
an isotropic perturbation of radius ``perturb_radius`` to the broadcast
step.  Unlike ByzantinePGD's Escape there are no probe rounds: the
perturbation piggybacks on the normal downlink broadcast, so every
communication round costs exactly ``bits_per_step()`` and rounds-to-ε
vs bits-to-ε tell the whole story.

Degenerate-parity contract (pinned in ``tests/test_solvers.py``): with
``compressor=None``, aggregator ``"mean"``, α = 0, momentum = 0, and the
default ``perturb_radius = 0``, one round is **bit-exact** with the
plain-SGD reference ``w ← w − η·mean_i ∇f_i(w)`` — the perturbation and
momentum terms are gated by *static* Python branches, so the degenerate
round compiles to the identical HLO, not to an ``x + 0`` approximation
of it.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..telemetry import SuspicionTracker
from .base import FirstOrderParams, FirstOrderSolver


@dataclasses.dataclass(frozen=True)
class SGDParams(FirstOrderParams):
    momentum: float = 0.0
    perturb_radius: float = 0.0  # 0 ⇒ no saddle-escape perturbation
    perturb_gtol: float = 0.0    # ‖aggregate‖ level that arms it


class CompressedSGD(FirstOrderSolver):
    """Channel-routed robust SGD with optional isotropic perturbation."""

    runtime_label = "sgd"

    # -- one jitted communication round ---------------------------------
    def _round_impl(self, w, v, state, X, y, key):
        p = self.params
        k_label, k_update, k_comp, k_down, k_perturb = \
            jax.random.split(key, 5)
        new_state = dict(state)

        y_used = self._attack_rule.corrupt_labels(k_label, y)
        g = self._per_worker_grads(w, X, y_used)
        # forensics (schema v4): per-sender δ̂ + update norms staged only
        # when telemetry was enabled at trace time — the degenerate-parity
        # contract (disabled round ≡ reference HLO) is untouched
        forensics = self._telemetry().enabled
        if forensics:
            g, new_state["uplink"], delta, worker_delta = \
                self.uplink.transmit(
                    g, state["uplink"], key=k_comp, attack_key=k_update,
                    measure=True, per_sender=True,
                )
        else:
            g, new_state["uplink"], delta = self.uplink.transmit(
                g, state["uplink"], key=k_comp, attack_key=k_update,
                measure=True,
            )
        agg, keep = self.aggregator(g)
        # static gates: the degenerate round must be the reference HLO,
        # not a `+ 0.0 * noise` perturbation of it
        v_new = agg if p.momentum == 0.0 else p.momentum * v + agg
        step = -p.lr * v_new
        if p.perturb_radius > 0.0:
            u = jax.random.normal(k_perturb, w.shape)
            u = (u / (jnp.linalg.norm(u) + 1e-12) * p.perturb_radius
                 * jax.random.uniform(k_perturb))
            armed = (jnp.linalg.norm(agg) <= p.perturb_gtol)
            step = step + jnp.where(armed, 1.0, 0.0) * u
        step, new_state["downlink"] = self.downlink.transmit(
            step, state["downlink"], key=k_down
        )
        info = {"keep": keep, "uplink_delta": delta}
        if forensics:
            info["worker_delta"] = worker_delta
            info["update_norms"] = jnp.linalg.norm(
                g.reshape(g.shape[0], -1), axis=-1
            )
        return w + step, v_new, new_state, info

    # -- host loop -------------------------------------------------------
    def run(self, w0, X, y, n_steps, key=None, eval_fn=None,
            grad_tol=None, full_data=None, deadline=None,
            saddle_value=None):
        """Run compressed robust SGD for ``n_steps`` rounds (or until the
        pooled ‖∇f‖ ≤ grad_tol).  Same signature and history schema as
        :meth:`DistributedCubicNewton.run`."""
        import time as _time

        key = key if key is not None else jax.random.PRNGKey(self.seed)
        Xf, yf, gradf, lossf = self._pooled_fns(X, y, full_data)
        self._ensure_channels(w0.shape[0], X.shape[0])
        ledger = self.ledger
        ledger.reset()
        hist = self._fresh_hist()
        tel = self._telemetry()
        prev_loss = float(lossf(w0, Xf, yf)) if tel.enabled else None
        tracker = SuspicionTracker(X.shape[0]) if tel.enabled else None

        w = w0
        v = jnp.zeros_like(w0)
        state = self.init_comm_state()
        for t in range(n_steps):
            if deadline is not None and hist["loss"] \
                    and _time.monotonic() >= deadline:
                hist["truncated"] = True
                if tel.enabled:
                    tel.event("sgd.truncated", step=t)
                break
            key, sub = jax.random.split(key)
            k_live = self._uplink_k()
            w, v, state, info = self._jit_round(w, v, state, X, y, sub)
            bps = self._bill_round()
            hist["bits_cumulative"].append(ledger.total_bits)
            delta_hat = float(info["uplink_delta"])
            hist["uplink_delta"].append(delta_hat)
            hist["k_trajectory"].append(k_live)
            gn = float(jnp.linalg.norm(gradf(w, Xf, yf)))
            loss = float(lossf(w, Xf, yf))
            hist["loss"].append(loss)
            hist["grad_norm"].append(gn)
            if eval_fn is not None:
                hist["eval"].append(float(eval_fn(w)))
            hit_tol = grad_tol is not None and gn <= grad_tol
            k_changed = False
            if not hit_tol:
                k_changed = self._maybe_adapt(gn, measured_delta=delta_hat)
            escaped = (saddle_value is not None
                       and hist["saddle_escape_step"] is None
                       and loss < saddle_value)
            if escaped:
                hist["saddle_escape_step"] = t
            self._emit_round(tel, step=t, loss=loss, gn=gn,
                             prev_loss=prev_loss, delta_hat=delta_hat,
                             k_live=k_live, k_changed=k_changed,
                             escaped=escaped, info=info, bps=bps,
                             tracker=tracker)
            prev_loss = loss
            if hit_tol:
                break
        hist.update(ledger.snapshot())
        return w, hist
