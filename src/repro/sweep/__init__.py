"""`repro.sweep` — sharded, resumable spec-grid sweeps.

The experiment engine over :class:`repro.api.ExperimentSpec`: a grid of
axis dicts expands into validated specs (:mod:`~repro.sweep.grid`),
hash-sharded across hosts and executed with failure isolation and
per-cell wall-time budgets (:mod:`~repro.sweep.runner`), into an
append-only JSONL store keyed by a canonical spec hash that makes
re-runs free and multi-host merges deterministic
(:mod:`~repro.sweep.store`), from which every paper artifact is a pivot
(:mod:`~repro.sweep.report`).  ``python -m repro.sweep`` drives it all
(``plan`` / ``run`` / ``merge`` / ``report``); the figure benchmarks are
thin views over the same engine.
"""
from .grid import (
    DEFAULT_STEPS,
    PlanEntry,
    SweepPlan,
    expand_axes,
    paper_strengths,
    plan_grid,
)
from .grids import (
    PRESETS,
    fig3_grid,
    fig12_grid,
    fig12_full_grid,
    headtohead_grid,
    smoke_grid,
)
from .report import (
    bits_to_eps,
    eps_table,
    headtohead_table,
    render_table,
    report,
    resilience_table,
    rounds_to_eps,
)
from .runner import run_plan, shard_entries
from .store import ResultStore, canonical_json, merge, spec_hash

__all__ = [
    "DEFAULT_STEPS",
    "PRESETS",
    "PlanEntry",
    "ResultStore",
    "SweepPlan",
    "bits_to_eps",
    "canonical_json",
    "eps_table",
    "expand_axes",
    "fig3_grid",
    "fig12_full_grid",
    "fig12_grid",
    "headtohead_grid",
    "headtohead_table",
    "merge",
    "paper_strengths",
    "plan_grid",
    "render_table",
    "report",
    "resilience_table",
    "rounds_to_eps",
    "run_plan",
    "shard_entries",
    "smoke_grid",
    "spec_hash",
]
