"""CLI: ``python -m repro.sweep {plan,run,merge,report}``.

    # one host
    python -m repro.sweep run --preset fig12 --store results/sweep/fig12.jsonl

    # two hosts, disjoint shards, then a deterministic union
    python -m repro.sweep run --preset fig12 --shard 0/2 --store s0.jsonl
    python -m repro.sweep run --preset fig12 --shard 1/2 --store s1.jsonl
    python -m repro.sweep merge s0.jsonl s1.jsonl --out fig12.jsonl
    python -m repro.sweep report fig12.jsonl

Re-invoking ``run`` over a finished store performs zero experiment
builds (every cell hash hits the store).  ``--grid file.json`` takes a
``{"axes": {...}, "base": {...}}`` dict instead of a preset.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from . import grids, runner
from . import store as store_mod
from .grid import plan_grid
from .report import report as report_store
from .report import telemetry_report


def _build_plan(args):
    if args.grid:
        ignored = [flag for flag, v in (
            ("--steps", args.steps), ("--datasets", args.datasets),
            ("--alphas", args.alphas), ("--seed", args.seed),
        ) if v is not None]
        if ignored:
            raise SystemExit(
                f"{', '.join(ignored)} only override a --preset; with "
                f"--grid, edit the grid file's axes/base instead"
            )
        with open(args.grid) as f:
            g = json.load(f)
        axes, base = g.get("axes", {}), g.get("base", {})
    else:
        kw = {}
        if args.steps is not None:
            kw["n_steps"] = args.steps
        if getattr(args, "datasets", None):
            kw["datasets"] = tuple(args.datasets.split(","))
        if getattr(args, "alphas", None):
            kw["alphas"] = tuple(float(a) for a in args.alphas.split(","))
        if getattr(args, "seed", None) is not None:
            kw["seed"] = args.seed
        try:
            axes, base = grids.PRESETS[args.preset](**kw)
        except TypeError as e:
            raise SystemExit(
                f"preset {args.preset!r} does not take one of the "
                f"supplied overrides: {e}"
            ) from None
    return plan_grid(axes, base)


def _add_grid_args(p, with_run=False):
    src = p.add_mutually_exclusive_group()
    src.add_argument("--preset", choices=sorted(grids.PRESETS),
                     default="smoke")
    src.add_argument("--grid", help="JSON file with {'axes': …, 'base': …}")
    p.add_argument("--steps", type=int, default=None,
                   help="override the preset's per-cell round budget")
    p.add_argument("--datasets", help="comma list, e.g. a9a,w8a")
    p.add_argument("--alphas", help="comma list, e.g. 0.1,0.2")
    p.add_argument("--seed", type=int, default=None)
    if with_run:
        p.add_argument("--shard", default="0/1", metavar="I/N",
                       help="run shard I of N (hash-partitioned, disjoint)")
        p.add_argument("--store", default=None,
                       help="JSONL store path (default "
                            "results/sweep/<preset>.jsonl)")
        p.add_argument("--budget-s", type=float, default=None,
                       help="per-cell wall-time budget (cooperative)")
        p.add_argument("--limit", type=int, default=None,
                       help="build at most this many cells this invocation")
        p.add_argument("--jobs", type=int, default=1, metavar="N",
                       help="run cells on an N-worker process pool "
                            "(spawn-context; merged stores stay byte-"
                            "identical to --jobs 1)")
        p.add_argument("--retry-failed", action="store_true")
        p.add_argument("--retry-truncated", action="store_true",
                       help="re-run cells a previous --budget-s cut short")
        p.add_argument("--telemetry-dir", default=None,
                       help="enable repro.telemetry: per-cell/shard spans "
                            "+ wire/compile events into DIR (events.jsonl "
                            "+ trace.json; use one DIR per shard)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.sweep")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_plan = sub.add_parser("plan", help="expand + validate a grid")
    _add_grid_args(p_plan)
    p_plan.add_argument("--out", help="write the plan (hashes + specs) here")

    p_run = sub.add_parser("run", help="run (a shard of) a grid into a store")
    _add_grid_args(p_run, with_run=True)

    p_merge = sub.add_parser("merge", help="union shard stores (canonical)")
    p_merge.add_argument("stores", nargs="+")
    p_merge.add_argument("--out", required=True)

    p_rep = sub.add_parser("report", help="pivot a store into the tables")
    p_rep.add_argument("store", nargs="?", default=None)
    p_rep.add_argument("--eps", default="0.3,0.1,0.05",
                       help="comma list of ε thresholds")
    p_rep.add_argument("--telemetry", metavar="EVENTS_JSONL", default=None,
                       help="summarize a telemetry events.jsonl stream "
                            "(span timings, cell outcomes, wire/compile "
                            "totals) — the live sweep progress view")
    p_rep.add_argument("--plots", metavar="DIR", default=None,
                       help="also render the Fig. 1-3 panels as PNGs into "
                            "DIR (requires matplotlib; skipped with a "
                            "hint when it is missing)")

    args = ap.parse_args(argv)

    if args.cmd == "plan":
        plan = _build_plan(args)
        print(plan.summary())
        for e in plan.entries:
            print(f"  {e.hash}  n_steps={e.n_steps}  "
                  f"{store_mod.canonical_json(e.spec.to_dict())}")
        for s in plan.skipped:
            print(f"  SKIP {s['reason']}")
        if args.out:
            with open(args.out, "w") as f:
                json.dump([{"hash": e.hash, "n_steps": e.n_steps,
                            "spec": e.spec.to_dict()}
                           for e in plan.entries], f, indent=1)
            print(f"plan written to {args.out}")
        return 0

    if args.cmd == "run":
        plan = _build_plan(args)
        try:
            idx, num = (int(x) for x in args.shard.split("/"))
        except ValueError:
            raise SystemExit(f"--shard must look like 0/2, got {args.shard!r}")
        if args.store:
            path = args.store
        else:
            stem = (args.preset if not args.grid else
                    os.path.splitext(os.path.basename(args.grid))[0])
            path = f"results/sweep/{stem}.jsonl"
        if args.telemetry_dir:
            from ..telemetry import get_telemetry

            get_telemetry().enable(args.telemetry_dir)
        st = store_mod.ResultStore(path)
        print(plan.summary() + f"; shard {idx}/{num} → {path}")
        summary = runner.run_plan(
            plan, st, shard_index=idx, num_shards=num,
            time_budget_s=args.budget_s, limit=args.limit,
            retry_failed=args.retry_failed,
            retry_truncated=args.retry_truncated, jobs=args.jobs,
            log=print,
        )
        print(f"[sweep] done: built={summary['built']} "
              f"cached={summary['cached']} failed={summary['failed']} "
              f"(shard total {summary['total']})")
        if args.telemetry_dir:
            from ..telemetry import get_telemetry

            get_telemetry().flush()
            print(f"[sweep] telemetry → {args.telemetry_dir}")
        return 1 if summary["failed"] else 0

    if args.cmd == "merge":
        n = store_mod.merge(args.stores, args.out)
        print(f"merged {len(args.stores)} stores → {args.out} ({n} cells)")
        return 0

    if args.cmd == "report":
        if args.store is None and args.telemetry is None:
            raise SystemExit("report needs a store path and/or --telemetry")
        if args.telemetry is not None:
            telemetry_report(args.telemetry)
        if args.store is not None:
            if args.telemetry is not None:
                print()
            eps = tuple(float(e) for e in args.eps.split(","))
            st = store_mod.ResultStore(args.store)
            report_store(st, eps_grid=eps)
            if args.plots is not None:
                from .report import plots as plot_store

                plot_store(st, args.plots)
        elif args.plots is not None:
            raise SystemExit("--plots needs a store path")
        return 0

    return 2


if __name__ == "__main__":
    sys.exit(main())
