"""Sweep planner: axis dicts → validated :class:`~repro.api.ExperimentSpec`s.

A **grid** is a base dict of :class:`ExperimentSpec` fields plus an
``axes`` dict mapping field names to value lists:

    axes = {"aggregator": ["mean", "norm_trim", "krum"],
            "attack": ["gaussian", "flipped_label"],
            "compressor": [None, "topk:0.1"]}

:func:`plan_grid` expands the cartesian product (axes in insertion
order, values in given order — fully deterministic), applies **resolve
hooks** (e.g. :func:`paper_strengths`, which turns a bare registry head
like ``"norm_trim"`` into the paper's per-α strength), then **prune
hooks** and the facade's own :meth:`ExperimentSpec.validate` — so
invalid cross-axis combos (EF-without-compressor, mesh label attacks,
krum at an uncoverable α, …) are *skipped at plan time with a recorded
reason*, never crashed at build time.  ``"n_steps"`` is the one non-spec
key: it names the per-cell round budget and becomes part of the cell's
canonical hash.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Iterable, Optional

from ..api import ExperimentSpec, SpecError
from .store import spec_hash

DEFAULT_STEPS = 15   # the paper figures' round budget

#: registry heads whose strength the paper derives from (α, m)
_STRENGTH_RULES = ("norm_trim", "krum", "trimmed_mean")


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    """One sweep cell: a validated spec plus its round budget."""

    spec: ExperimentSpec
    n_steps: int

    @property
    def hash(self) -> str:
        return spec_hash(self.spec, self.n_steps)


@dataclasses.dataclass
class SweepPlan:
    """Expansion result: runnable cells + plan-time skips with reasons."""

    entries: list
    skipped: list     # [{"point": {...}, "reason": str}, ...]

    def __len__(self) -> int:
        return len(self.entries)

    def hashes(self) -> list:
        return [e.hash for e in self.entries]

    def summary(self) -> str:
        return (f"{len(self.entries)} cells planned, "
                f"{len(self.skipped)} skipped at plan time")


# ---------------------------------------------------------------- hooks
def paper_strengths(point: dict) -> dict:
    """Resolve bare aggregator heads to the paper's per-α strengths.

    ``"norm_trim"`` → β = α + 2/m (the paper's rule), ``"krum"`` →
    n_byz = ⌊α·m⌋, ``"trimmed_mean"`` → per-side fraction α + 1/m.
    Specs that already carry a strength (``"norm_trim:0.3"``) pass
    through untouched, as do strength-free rules.
    """
    agg = point.get("aggregator")
    if agg in _STRENGTH_RULES:
        alpha = float(point.get("alpha", 0.0))
        m = int(point.get("m_workers", 20))
        if agg == "norm_trim":
            agg = f"norm_trim:{alpha + 2.0 / m}"
        elif agg == "krum":
            agg = f"krum:{int(alpha * m)}"
        else:
            agg = f"trimmed_mean:{alpha + 1.0 / m}"
        point = dict(point, aggregator=agg)
    return point


# ------------------------------------------------------------- expansion
def expand_axes(axes: dict, base: Optional[dict] = None):
    """Deterministic cartesian product of ``axes`` over ``base``."""
    base = dict(base or {})
    if not axes:
        yield base
        return
    names = list(axes)
    for values in itertools.product(*(axes[n] for n in names)):
        point = dict(base)
        point.update(zip(names, values))
        yield point


def plan_grid(
    axes: dict,
    base: Optional[dict] = None,
    *,
    resolve: Iterable[Callable] = (paper_strengths,),
    prune: Iterable[Callable] = (),
) -> SweepPlan:
    """Expand + validate a grid into a :class:`SweepPlan`.

    ``resolve`` hooks map a point dict to a point dict (strength
    resolution, derived fields); ``prune`` hooks return a skip-reason
    string (or None to keep).  After the hooks, every point must pass
    :meth:`ExperimentSpec.validate` — a :class:`SpecError` becomes a
    recorded skip, and duplicate cells (two points resolving to the same
    hash) keep the first occurrence.  A single callable is accepted for
    either hook argument.
    """
    if callable(resolve):
        resolve = (resolve,)
    if callable(prune):
        prune = (prune,)
    entries: list[PlanEntry] = []
    skipped: list[dict] = []
    seen: set[str] = set()
    for point in expand_axes(axes, base):
        for hook in resolve:
            point = hook(point)
        n_steps = int(point.pop("n_steps", DEFAULT_STEPS))
        reason = None
        for hook in prune:
            reason = hook(point)
            if reason is not None:
                break
        if reason is not None:
            skipped.append({"point": point, "reason": str(reason)})
            continue
        try:
            entry = PlanEntry(
                ExperimentSpec.from_dict(point).validate(), n_steps
            )
        except SpecError as e:
            skipped.append({"point": point, "reason": str(e)})
            continue
        if entry.hash in seen:
            skipped.append({"point": point,
                            "reason": f"duplicate of cell {entry.hash}"})
            continue
        seen.add(entry.hash)
        entries.append(entry)
    return SweepPlan(entries, skipped)
