"""The paper's grids as named presets (shared by CLI + benchmarks).

Each preset returns ``(axes, base)`` for :func:`repro.sweep.plan_grid`;
the figure benchmarks build the *same* axes here, so a store produced by
``python -m repro.sweep run --preset fig12`` serves the fig12 benchmark
byte-for-byte (identical cell hashes).
"""
from __future__ import annotations

REGISTRY_AGGREGATORS = ("mean", "norm_trim", "krum", "trimmed_mean",
                        "coordinate_median")
REGISTRY_ATTACKS = ("gaussian", "negative", "saddle", "random_label",
                    "flipped_label")
FIG12_ATTACKS = ("flipped_label", "negative", "gaussian", "random_label")


def _problems(datasets, kinds=("logistic", "robust")):
    return [f"{ds}-{kind}" for ds in datasets for kind in kinds]


def smoke_grid(n_steps: int = 2, seed: int = 0):
    """The CI 2×2×2 grid: tiny synthetic problem, seconds-scale."""
    axes = {
        "aggregator": ["mean", "norm_trim"],
        "attack": ["gaussian", "flipped_label"],
        "compressor": [None, "topk:0.25"],
    }
    base = {"problem": "synthetic-logistic:400:16", "m_workers": 10,
            "alpha": 0.2, "M": 10.0, "seed": seed, "n_steps": n_steps}
    return axes, base


def fig3_grid(n_steps: int = 15, datasets=("a9a", "w8a"),
              Ms=(10.0, 15.0, 20.0), seed: int = 0):
    """Fig. 3 — non-Byzantine convergence: problem × M, plain mean."""
    axes = {"problem": _problems(datasets), "M": list(Ms)}
    base = {"aggregator": "mean", "eta": 1.0, "seed": seed,
            "n_steps": n_steps}
    return axes, base


def fig12_grid(n_steps: int = 15, datasets=("a9a", "w8a"),
               attacks=FIG12_ATTACKS, alphas=(0.10, 0.15, 0.20),
               aggregators=("norm_trim", "krum", "trimmed_mean"),
               compressors=(None,), seed: int = 0):
    """Figs. 1 & 2 — the Byzantine grid the benchmark sweeps.

    The full acceptance grid (every registry aggregator × every attack ×
    {identity, topk:0.1}) is this with ``aggregators=
    REGISTRY_AGGREGATORS, attacks=REGISTRY_ATTACKS, compressors=(None,
    "topk:0.1")`` — what the CLI preset ``fig12-full`` expands to.
    Bare aggregator heads get the paper's per-α strengths from the
    :func:`~repro.sweep.grid.paper_strengths` resolve hook.
    """
    axes = {
        "problem": _problems(datasets),
        "attack": list(attacks),
        "alpha": list(alphas),
        "aggregator": list(aggregators),
        "compressor": list(compressors),
    }
    base = {"M": 10.0, "eta": 1.0, "seed": seed, "n_steps": n_steps}
    return axes, base


def fig12_full_grid(n_steps: int = 15, datasets=("a9a", "w8a"),
                    alphas=(0.10, 0.15, 0.20), seed: int = 0):
    """The acceptance grid: every registry aggregator × every registry
    attack × {identity, topk:0.1}."""
    return fig12_grid(n_steps, datasets, REGISTRY_ATTACKS, alphas,
                      REGISTRY_AGGREGATORS, (None, "topk:0.1"), seed)


def staleness_grid(n_steps: int = 8, participations=(1.0, 0.5),
                   stalenesses=(0, 1, 4), alphas=(0.0, 0.2),
                   seed: int = 0):
    """Resilience-vs-staleness: the async runtime under the saddle
    attack, sweeping cohort fraction × max packet lag × Byzantine
    fraction on the matrix-factorization saddle problem.

    The ``alpha=0, staleness=0, participation=1.0`` cell is the
    degenerate async config — bit-exact with ``runtime="paper"`` (the
    acceptance criterion's anchor cell); every other cell measures how
    escape degrades as the cohort shrinks and updates arrive late.
    """
    axes = {
        "staleness": list(stalenesses),
        "participation": list(participations),
        "alpha": list(alphas),
    }
    base = {"runtime": "async", "problem": "matrix-factor:8:2",
            "m_workers": 10, "attack": "saddle", "aggregator": "norm_trim",
            "M": 10.0, "seed": seed, "n_steps": n_steps}
    return axes, base


def headtohead_grid(n_steps: int = 60, datasets=("w8a",),
                    alphas=(0.2,), seed: int = 0):
    """The paper's headline comparison as ONE grid: second-order
    (``cubic_newton``) vs first-order (``byzantine_pgd``,
    ``compressed_sgd``) per attack × aggregator, everything else held
    fixed.

    All three solvers transmit through the same channel stack, so the
    report's rounds-to-ε and bits-to-ε pivots compare exact
    :class:`~repro.comm.WireLedger` ints across the solver axis — the
    "~25% better iteration complexity than first-order methods" claim,
    regenerated from one store.  Bare aggregator heads get the paper's
    per-α strengths from the :func:`~repro.sweep.grid.paper_strengths`
    resolve hook; the first-order cells keep the Newton cells' η = 1
    (Yin et al.'s GD step size on these workloads).
    """
    axes = {
        "solver": ["cubic_newton", "byzantine_pgd", "compressed_sgd"],
        "attack": ["none", "gaussian", "saddle"],
        "aggregator": ["norm_trim", "trimmed_mean"],
    }
    base = {"problem": f"{datasets[0]}-robust", "m_workers": 20,
            "alpha": alphas[0], "M": 10.0, "eta": 1.0, "seed": seed,
            "n_steps": n_steps}
    if len(datasets) > 1:
        axes["problem"] = [f"{ds}-robust" for ds in datasets]
        del base["problem"]
    if len(alphas) > 1:
        axes["alpha"] = list(alphas)
        del base["alpha"]
    return axes, base


PRESETS = {
    "smoke": smoke_grid,
    "fig3": fig3_grid,
    "fig12": fig12_grid,
    "fig12-full": fig12_full_grid,
    "staleness": staleness_grid,
    "headtohead": headtohead_grid,
}
