"""Summarizer: pivot a result store into the paper's tables.

Five pivots, each a pure function of the store's ``"ok"`` records:

* :func:`resilience_table` — the attack × aggregator frontier (Figs. 1-2
  / the byzantine_attacks example table): final loss (or final test
  accuracy for logistic problems) per cell, one table per
  (problem, α, compressor) group;
* :func:`rounds_to_eps` — communication rounds until ‖∇f‖ ≤ ε (Table 1's
  round counts);
* :func:`bits_to_eps` — exact cumulative wire bits until ‖∇f‖ ≤ ε (the
  communication-efficiency axis), straight off the ledger ints stored
  with every record;
* :func:`headtohead_table` — second-order vs first-order per (problem,
  attack, aggregator, α) on solver-axis stores (the ``headtohead``
  preset): per-solver rounds-to-ε / exact-ledger bits-to-ε columns plus
  the first-order/Newton round ratios (the paper's headline claim);
* :func:`wire_table` — per-cell wire adaptivity off the persisted
  per-round ``uplink_delta`` / ``k_trajectory`` series: mean / final
  measured δ̂, the k the schedule started and ended at, and how many
  times it moved.

``render_table`` turns rows into the aligned ASCII the CLI prints.
:func:`telemetry_report` is the live progress view over a telemetry
``events.jsonl`` stream (``python -m repro.sweep report --telemetry``).
"""
from __future__ import annotations

import json
from collections import OrderedDict
from typing import Iterable, Optional

# ---------------------------------------------------------------- helpers
def final_loss(rec: dict) -> Optional[float]:
    loss = rec.get("metrics", {}).get("loss") or []
    return loss[-1] if loss else None


def final_accuracy(rec: dict) -> Optional[float]:
    ev = rec.get("metrics", {}).get("eval") or []
    return ev[-1] if ev else None


def _first_hit(series, threshold, values=None):
    """Index of the first ``series`` element ≤ threshold → values[i]
    (or i+1 when values is None: a 1-based round count)."""
    for i, s in enumerate(series):
        if s <= threshold:
            return values[i] if values is not None else i + 1
    return None


def rounds_to_eps(rec: dict, eps: float) -> Optional[int]:
    """Rounds until ‖∇f‖ ≤ ε (None: never reached / no grad history)."""
    m = rec.get("metrics", {})
    gn = m.get("grad_norm") or []
    rounds_per_step = max(m.get("rounds", len(gn)) // max(len(gn), 1), 1)
    hit = _first_hit(gn, eps)
    return hit * rounds_per_step if hit is not None else None


def bits_to_eps(rec: dict, eps: float) -> Optional[int]:
    """Exact total wire bits until ‖∇f‖ ≤ ε (ledger ints)."""
    m = rec.get("metrics", {})
    gn = m.get("grad_norm") or []
    return _first_hit(gn, eps, values=m.get("bits_cumulative") or [])


def _spec(rec: dict) -> dict:
    return rec.get("spec", {})


def _agg_head(rec: dict) -> str:
    return str(_spec(rec).get("aggregator", "?")).partition(":")[0]


def _comp_label(rec: dict) -> str:
    return str(_spec(rec).get("compressor") or "identity")


#: solver spec heads → the short column labels the pivots print
_SOLVER_LABELS = {"cubic_newton": "newton", "byzantine_pgd": "pgd",
                  "compressed_sgd": "sgd"}


def _solver_head(rec: dict) -> str:
    return str(_spec(rec).get("solver")
               or "cubic_newton").partition(":")[0]


# ----------------------------------------------------------------- pivots
def resilience_table(records: Iterable[dict]) -> list[dict]:
    """Attack × aggregator frontier, grouped by (problem, α, compressor).

    One row per (group, attack); aggregator heads become columns holding
    final accuracy (logistic) or final loss (everything else).
    """
    groups: "OrderedDict[tuple, OrderedDict]" = OrderedDict()
    for rec in records:
        s = _spec(rec)
        gkey = (s.get("problem"), s.get("alpha"), _comp_label(rec))
        row_key = str(s.get("attack", "none")).partition(":")[0]
        acc = final_accuracy(rec)
        value = acc if acc is not None else final_loss(rec)
        groups.setdefault(gkey, OrderedDict()) \
              .setdefault(row_key, OrderedDict())[_agg_head(rec)] = value
    rows = []
    for (problem, alpha, comp), attacks in groups.items():
        for attack, cells in attacks.items():
            row = {"problem": problem, "alpha": alpha, "compressor": comp,
                   "attack": attack}
            row.update(cells)
            rows.append(row)
    return rows


def eps_table(records: Iterable[dict], eps_grid=(0.3, 0.1, 0.05)) -> list[dict]:
    """Rounds-to-ε and bits-to-ε per record (Table-1 style rows)."""
    rows = []
    for rec in records:
        s = _spec(rec)
        row = {"problem": s.get("problem"),
               "aggregator": _agg_head(rec),
               "attack": str(s.get("attack", "none")).partition(":")[0],
               "alpha": s.get("alpha"),
               "compressor": _comp_label(rec),
               "total_bits": rec.get("metrics", {}).get("total_bits")}
        if "solver" in s:   # only solver-axis stores grow the column
            row["solver"] = _SOLVER_LABELS.get(_solver_head(rec),
                                               _solver_head(rec))
        for eps in eps_grid:
            row[f"rounds@{eps:g}"] = rounds_to_eps(rec, eps)
            row[f"bits@{eps:g}"] = bits_to_eps(rec, eps)
        rows.append(row)
    return rows


def headtohead_table(records: Iterable[dict],
                     eps: float = 0.05) -> list[dict]:
    """Second-order vs first-order per (problem, attack, aggregator, α).

    One row per scenario; per-solver columns hold rounds-to-ε and exact
    ledger bits-to-ε (``—`` where the budget never reached ε), and the
    ``*_round_ratio`` columns give first-order rounds / Newton rounds —
    the paper's headline iteration-complexity comparison, straight off
    one store.  Only meaningful on stores that sweep the ``solver`` axis
    (e.g. the ``headtohead`` preset); returns ``[]`` otherwise.
    """
    groups: "OrderedDict[tuple, OrderedDict]" = OrderedDict()
    for rec in records:
        s = _spec(rec)
        gkey = (s.get("problem"),
                str(s.get("attack", "none")).partition(":")[0],
                _agg_head(rec), s.get("alpha"))
        # to_dict omits the default solver, so a missing key IS the
        # Newton cell — the scenario's comparison anchor
        label = _SOLVER_LABELS.get(_solver_head(rec), _solver_head(rec))
        groups.setdefault(gkey, OrderedDict())[label] = rec
    rows = []
    for (problem, attack, agg, alpha), cells in groups.items():
        if set(cells) == {"newton"}:
            continue    # no first-order cell to compare against
        row = {"problem": problem, "attack": attack, "aggregator": agg,
               "alpha": alpha}
        for label, rec in cells.items():
            row[f"{label}_rounds@{eps:g}"] = rounds_to_eps(rec, eps)
            row[f"{label}_bits@{eps:g}"] = bits_to_eps(rec, eps)
        newton = row.get(f"newton_rounds@{eps:g}")
        for label in cells:
            if label == "newton":
                continue
            fo = row.get(f"{label}_rounds@{eps:g}")
            row[f"{label}_round_ratio"] = (
                fo / newton if fo is not None and newton else None
            )
        rows.append(row)
    return rows


def wire_table(records: Iterable[dict]) -> list[dict]:
    """Wire-adaptivity pivot: per-cell measured δ̂ and the adaptive-k
    trajectory the runtimes persist (``hist["uplink_delta"]`` /
    ``hist["k_trajectory"]``).  Cells on a non-adaptive wire report
    their δ̂ series with k columns empty."""
    rows = []
    for rec in records:
        s = _spec(rec)
        m = rec.get("metrics", {})
        deltas = [d for d in (m.get("uplink_delta") or []) if d is not None]
        ks = [k for k in (m.get("k_trajectory") or []) if k is not None]
        rows.append({
            "problem": s.get("problem"),
            "compressor": _comp_label(rec),
            "attack": str(s.get("attack", "none")).partition(":")[0],
            "delta_mean": (sum(deltas) / len(deltas)) if deltas else None,
            "delta_final": deltas[-1] if deltas else None,
            "k_start": ks[0] if ks else None,
            "k_final": ks[-1] if ks else None,
            "k_moves": (sum(1 for a, b in zip(ks, ks[1:]) if a != b)
                        if ks else None),
        })
    return rows


# ---------------------------------------------------------------- render
def _fmt(v) -> str:
    if v is None:
        return "—"
    if isinstance(v, float):
        return f"{v:.4f}"
    return str(v)


def render_table(rows: list[dict]) -> str:
    """Aligned ASCII table over the union of row keys (insertion order)."""
    if not rows:
        return "(empty)"
    cols: list[str] = []
    for row in rows:
        for k in row:
            if k not in cols:
                cols.append(k)
    cells = [[_fmt(row.get(c)) for c in cols] for row in rows]
    widths = [max(len(c), *(len(line[i]) for line in cells))
              for i, c in enumerate(cols)]
    out = [" | ".join(c.rjust(w) for c, w in zip(cols, widths))]
    out.append("-+-".join("-" * w for w in widths))
    out.extend(" | ".join(v.rjust(w) for v, w in zip(line, widths))
               for line in cells)
    return "\n".join(out)


def report(store, eps_grid=(0.3, 0.1, 0.05), printer=print) -> dict:
    """Print every pivot of a store; returns them as data for callers."""
    recs = store.ok_records()
    n_failed = sum(1 for r in store.records() if r.get("status") == "failed")
    printer(f"# sweep report — {len(recs)} ok cells, {n_failed} failed, "
            f"{len(store)} stored")
    frontier = resilience_table(recs)
    printer("\n## attack × aggregator resilience frontier")
    printer(render_table(frontier))
    eps_rows = eps_table(recs, eps_grid)
    printer("\n## rounds-to-ε / bits-to-ε")
    printer(render_table(eps_rows))
    h2h_rows = headtohead_table(recs, eps=min(eps_grid))
    if h2h_rows:
        printer("\n## solver head-to-head (second-order vs first-order)")
        printer(render_table(h2h_rows))
    wire_rows = wire_table(recs)
    if any(r["delta_mean"] is not None or r["k_start"] is not None
           for r in wire_rows):
        printer("\n## wire adaptivity (measured δ̂ / adaptive-k trajectory)")
        printer(render_table(wire_rows))
    else:
        wire_rows = []
    return {"resilience": frontier, "eps": eps_rows,
            "headtohead": h2h_rows, "wire": wire_rows}


# ----------------------------------------------------------------- plots
def plots(store, out_dir: str, printer=print) -> Optional[list]:
    """Render the paper's figure panels from a result store (Figs. 1-3).

    Writes up to three PNGs under ``out_dir`` and returns their paths:

    * ``fig12_resilience.png`` — loss/accuracy trajectories under attack,
      one panel per attack head, one line per aggregator (Figs. 1-2);
    * ``fig3_convergence.png`` — the non-Byzantine convergence curves,
      one panel per problem, one line per (compressor, aggregator);
    * ``fig_bits_to_eps.png`` — ‖∇f‖ against exact cumulative wire bits
      per compressor (the Table-1 communication-efficiency axis).

    Gated on matplotlib: returns ``None`` (and prints a hint) when the
    dependency is missing, so the text report never regresses on a
    matplotlib-free host.  Panels whose series are absent from the store
    (e.g. no ``grad_norm`` history) are skipped, not fatal.
    """
    try:
        import matplotlib
    except ImportError:
        printer("[sweep] --plots skipped: matplotlib is not installed")
        return None
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    import os

    os.makedirs(out_dir, exist_ok=True)
    recs = store.ok_records()
    written = []

    def _series(rec):
        m = rec.get("metrics", {})
        ev, loss = m.get("eval") or [], m.get("loss") or []
        return (ev, "accuracy") if ev else (loss, "loss")

    def _save(fig, fname):
        path = os.path.join(out_dir, fname)
        fig.tight_layout()
        fig.savefig(path, dpi=120)
        plt.close(fig)
        written.append(path)

    # Figs. 1-2: one panel per attack, lines per aggregator head.
    attacked = [r for r in recs
                if str(_spec(r).get("attack", "none")) not in ("none", "None")
                and _series(r)[0]]
    if attacked:
        heads = []
        for r in attacked:
            h = str(_spec(r).get("attack")).partition(":")[0]
            if h not in heads:
                heads.append(h)
        fig, axes = plt.subplots(1, len(heads),
                                 figsize=(4.2 * len(heads), 3.4),
                                 squeeze=False)
        for ax, attack in zip(axes[0], heads):
            ylabel = "loss"
            for r in attacked:
                if str(_spec(r).get("attack")).partition(":")[0] != attack:
                    continue
                ys, ylabel = _series(r)
                label = (f"{_agg_head(r)} (α={_spec(r).get('alpha')})"
                         if _spec(r).get("alpha") is not None
                         else _agg_head(r))
                ax.plot(range(1, len(ys) + 1), ys, label=label)
            ax.set_title(f"attack: {attack}")
            ax.set_xlabel("round")
            ax.set_ylabel(ylabel)
            ax.legend(fontsize=7)
        fig.suptitle("Byzantine resilience (Figs. 1-2)")
        _save(fig, "fig12_resilience.png")

    # Fig. 3: non-Byzantine convergence, one panel per problem.
    clean = [r for r in recs
             if str(_spec(r).get("attack", "none")) in ("none", "None")
             and _series(r)[0]]
    if clean:
        problems = []
        for r in clean:
            p = str(_spec(r).get("problem", "?"))
            if p not in problems:
                problems.append(p)
        fig, axes = plt.subplots(1, len(problems),
                                 figsize=(4.2 * len(problems), 3.4),
                                 squeeze=False)
        for ax, problem in zip(axes[0], problems):
            ylabel = "loss"
            for r in clean:
                if str(_spec(r).get("problem", "?")) != problem:
                    continue
                ys, ylabel = _series(r)
                ax.plot(range(1, len(ys) + 1), ys,
                        label=f"{_comp_label(r)}/{_agg_head(r)}")
            ax.set_title(problem)
            ax.set_xlabel("round")
            ax.set_ylabel(ylabel)
            ax.legend(fontsize=7)
        fig.suptitle("Convergence without attack (Fig. 3)")
        _save(fig, "fig3_convergence.png")

    # Bits-to-ε: ‖∇f‖ vs exact cumulative wire bits, per compressor.
    wired = [r for r in recs
             if (r.get("metrics", {}).get("grad_norm") or [])
             and (r.get("metrics", {}).get("bits_cumulative") or [])]
    if wired:
        fig, ax = plt.subplots(figsize=(5.2, 3.8))
        for r in wired:
            m = r.get("metrics", {})
            gn, bits = m["grad_norm"], m["bits_cumulative"]
            n = min(len(gn), len(bits))
            ax.plot(bits[:n], gn[:n],
                    label=f"{_comp_label(r)}/{_agg_head(r)}")
        ax.set_xscale("log")
        ax.set_yscale("log")
        ax.set_xlabel("cumulative wire bits (exact, ledger)")
        ax.set_ylabel("‖∇f‖")
        ax.set_title("bits-to-ε (Table 1 axis)")
        ax.legend(fontsize=7)
        _save(fig, "fig_bits_to_eps.png")

    printer(f"[sweep] wrote {len(written)} plot(s) → {out_dir}")
    return written


# ------------------------------------------------------- telemetry view
def telemetry_report(path: str, printer=print) -> dict:
    """Progress view over a telemetry ``events.jsonl`` stream: span
    timings by name (the sweep's build/run/store phases), cell outcomes,
    compile activity, and exact wire totals.  Tolerant of a live,
    partially-written stream (bad lines are counted, not fatal)."""
    spans: "OrderedDict[str, dict]" = OrderedDict()
    cells = {"ok": 0, "failed": 0, "truncated": 0}
    compile_n = 0
    compile_s = 0.0
    wire = {"uplink": 0, "downlink": 0, "rounds": 0}
    rounds = 0
    bad = 0
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except ValueError:
                bad += 1
                continue
            kind, name = ev.get("kind"), ev.get("name")
            if kind == "span":
                agg = spans.setdefault(name, {"count": 0, "total_s": 0.0,
                                              "errors": 0})
                agg["count"] += 1
                agg["total_s"] += float(ev.get("dur_s") or 0.0)
                if (ev.get("args") or {}).get("status") == "error":
                    agg["errors"] += 1
                if name == "sweep.cell":
                    cells["ok"] += 1
            elif kind == "event" and name == "sweep.cell.failed":
                cells["failed"] += 1
                cells["ok"] -= 1    # its sweep.cell span counted above
            elif kind == "event" and name == "sweep.cell.truncated":
                cells["truncated"] += 1
            elif kind == "compile":
                compile_n += 1
                compile_s += float(ev.get("dur_s") or 0.0)
            elif kind == "wire":
                wire["uplink"] += int(ev.get("uplink") or 0)
                wire["downlink"] += int(ev.get("downlink") or 0)
                wire["rounds"] += int(ev.get("rounds") or 0)
            elif kind == "round":
                rounds += 1
    span_rows = [{"span": n, "count": a["count"],
                  "total_s": round(a["total_s"], 3),
                  "mean_s": round(a["total_s"] / a["count"], 4),
                  "errors": a["errors"]}
                 for n, a in spans.items()]
    printer(f"# telemetry report — {path}"
            + (f" ({bad} unparseable lines)" if bad else ""))
    printer(f"cells: {cells['ok']} ok, {cells['failed']} failed, "
            f"{cells['truncated']} truncated; {rounds} round records")
    printer(f"compile: {compile_n} events, {compile_s:.2f}s total")
    printer(f"wire: {wire['uplink']} uplink bits, {wire['downlink']} "
            f"downlink bits over {wire['rounds']} rounds")
    if span_rows:
        printer("\n## spans")
        printer(render_table(span_rows))
    return {"spans": span_rows, "cells": cells, "wire": wire,
            "compiles": compile_n, "rounds": rounds, "bad_lines": bad}
