"""Deterministic sharder + executor for a :class:`~repro.sweep.SweepPlan`.

Sharding is **by canonical cell hash**, not plan position:
``shard_entries(entries, i, N)`` keeps the cells whose
``int(hash, 16) % N == i``.  For any N the shards are provably disjoint
(each hash has exactly one residue) and covering (every hash has one),
and — because the hash is host-independent — two hosts planning the same
grid independently agree on who owns which cell without coordination.

Execution is failure-isolated and resumable: a cell whose hash is
already in the store is skipped (zero builds on a re-run), a cell that
raises is recorded as ``status="failed"`` with the error and the sweep
moves on, and a per-cell wall-time budget cooperatively truncates a
diverging run at the next round boundary (recorded in the metrics as
``truncated``).
"""
from __future__ import annotations

import time
import traceback
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .grid import PlanEntry, SweepPlan
from .store import ResultStore
from ..telemetry import get_telemetry


# ---------------------------------------------------------------- shard
def shard_entries(entries, shard_index: int, num_shards: int):
    """The sub-list of cells shard ``shard_index`` of ``num_shards`` owns."""
    if not 0 <= shard_index < num_shards:
        raise ValueError(
            f"shard_index must be in [0, {num_shards}), got {shard_index}"
        )
    return [e for e in entries
            if int(e.hash, 16) % num_shards == shard_index]


# -------------------------------------------------------------- execute
def _build_and_run(entry: PlanEntry, deadline: Optional[float]) -> dict:
    """Build one cell and run it; returns the JSON-ready metrics dict.

    Split out so tests can inject failures, and so a future async/remote
    executor can replace just this function.
    """
    tel = get_telemetry()
    with tel.span("sweep.cell.build", hash=entry.hash):
        exp = entry.spec.build()
    with tel.span("sweep.cell.run", hash=entry.hash):
        w, hist = exp.run(entry.n_steps, deadline=deadline)
    metrics = {k: v for k, v in hist.items()}
    w_star = getattr(exp.problem, "w_star", None)
    if w_star is not None and isinstance(w, jax.Array) and w.ndim == 1 \
            and w.shape == w_star.shape:
        metrics["w_err"] = float(
            jnp.linalg.norm(w - w_star) / jnp.linalg.norm(w_star)
        )
    if exp.problem.saddle_value is not None:
        metrics["saddle_value"] = exp.problem.saddle_value
    return metrics


def run_plan(
    plan: SweepPlan,
    store: ResultStore,
    *,
    shard_index: int = 0,
    num_shards: int = 1,
    time_budget_s: Optional[float] = None,
    limit: Optional[int] = None,
    retry_failed: bool = False,
    retry_truncated: bool = False,
    log: Optional[Callable[[str], None]] = None,
) -> dict:
    """Run this shard of the plan into ``store``; returns the summary
    ``{"built": …, "cached": …, "failed": …, "shard": …, "total": …}``.

    ``limit`` caps the number of cells *built* this invocation (the CI
    smoke lever, and how tests simulate a killed run); ``retry_failed``
    re-runs cells whose stored status is ``"failed"``, and
    ``retry_truncated`` re-runs cells a previous wall-time budget cut
    short, instead of treating either as done.
    """
    log = log or (lambda s: None)
    tel = get_telemetry()
    entries = shard_entries(plan.entries, shard_index, num_shards)
    built = cached = failed = 0
    with tel.span("sweep.shard", shard=shard_index, num_shards=num_shards,
                  cells=len(entries)):
        for entry in entries:
            h = entry.hash
            prior = store.get(h)
            done = prior is not None
            if done and retry_failed and prior.get("status") == "failed":
                done = False
            if done and retry_truncated \
                    and prior.get("metrics", {}).get("truncated"):
                done = False
            if done:
                cached += 1
                continue
            if limit is not None and built >= limit:
                break
            deadline = (time.monotonic() + time_budget_s
                        if time_budget_s is not None else None)
            t0 = time.monotonic()
            record = {"hash": h, "spec": entry.spec.to_dict(),
                      "n_steps": entry.n_steps}
            with tel.span("sweep.cell", hash=h,
                          problem=entry.spec.problem,
                          aggregator=entry.spec.aggregator,
                          attack=entry.spec.attack):
                try:
                    record["status"] = "ok"
                    record["metrics"] = _build_and_run(entry, deadline)
                except Exception as e:   # noqa: BLE001 — failure isolation is the point
                    record["status"] = "failed"
                    record["error"] = f"{type(e).__name__}: {e}"
                    log(f"[sweep] FAILED {h} {entry.spec.aggregator}/"
                        f"{entry.spec.attack}: {record['error']}")
                    log(traceback.format_exc(limit=3))
                    failed += 1
                    if tel.enabled:
                        tel.event("sweep.cell.failed", hash=h,
                                  error=record["error"])
                else:
                    built += 1
                    if tel.enabled \
                            and record["metrics"].get("truncated"):
                        tel.event("sweep.cell.truncated", hash=h)
            record["wall_time_s"] = round(time.monotonic() - t0, 3)
            with tel.span("sweep.cell.store", hash=h):
                store.append(record)
            log(f"[sweep] {record['status']} {h} "
                f"problem={entry.spec.problem} agg={entry.spec.aggregator} "
                f"attack={entry.spec.attack} comp={entry.spec.compressor} "
                f"({record['wall_time_s']:.1f}s)")
    return {"built": built, "cached": cached, "failed": failed,
            "shard": (shard_index, num_shards), "total": len(entries)}
