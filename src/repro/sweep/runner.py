"""Deterministic sharder + executor for a :class:`~repro.sweep.SweepPlan`.

Sharding is **by canonical cell hash**, not plan position:
``shard_entries(entries, i, N)`` keeps the cells whose
``int(hash, 16) % N == i``.  For any N the shards are provably disjoint
(each hash has exactly one residue) and covering (every hash has one),
and — because the hash is host-independent — two hosts planning the same
grid independently agree on who owns which cell without coordination.

Execution is failure-isolated and resumable: a cell whose hash is
already in the store is skipped (zero builds on a re-run), a cell that
raises is recorded as ``status="failed"`` with the error and the sweep
moves on, and a per-cell wall-time budget cooperatively truncates a
diverging run at the next round boundary (recorded in the metrics as
``truncated``).

**Executor pool** (``jobs > 1``): cells fan out over a spawn-context
:class:`~concurrent.futures.ProcessPoolExecutor` — spawn, not fork,
because a forked JAX runtime inherits locked XLA state.  Each worker
rebuilds its cell from the JSON spec dict (the same serde the store
uses) and runs the identical :func:`execute_cell`, so per-cell
deadline/failure-isolation semantics are unchanged; per-cell wall time
and the executor worker id are recorded as *volatile* store fields
(stripped on merge), so a pool run's **merged store is byte-identical
to the serial run's** — the CI ``async-smoke`` job asserts it with
``cmp``.  A worker process that dies outright (OOM, segfault) fails
only its own cell: the parent records a ``status="failed"`` line and
the pool keeps draining.
"""
from __future__ import annotations

import os
import time
import traceback
from typing import Callable, FrozenSet, Optional

import jax
import jax.numpy as jnp

from .grid import PlanEntry, SweepPlan
from .store import ResultStore
from ..telemetry import get_telemetry


# ---------------------------------------------------------------- shard
def shard_entries(entries, shard_index: int, num_shards: int):
    """The sub-list of cells shard ``shard_index`` of ``num_shards`` owns."""
    if not 0 <= shard_index < num_shards:
        raise ValueError(
            f"shard_index must be in [0, {num_shards}), got {shard_index}"
        )
    return [e for e in entries
            if int(e.hash, 16) % num_shards == shard_index]


# -------------------------------------------------------------- execute
def _build_and_run(entry: PlanEntry, deadline: Optional[float]) -> dict:
    """Build one cell and run it; returns the JSON-ready metrics dict.

    Split out so tests can inject failures, and so alternative executors
    can replace just this function.
    """
    tel = get_telemetry()
    with tel.span("sweep.cell.build", hash=entry.hash):
        exp = entry.spec.build()
    with tel.span("sweep.cell.run", hash=entry.hash):
        w, hist = exp.run(entry.n_steps, deadline=deadline)
    metrics = {k: v for k, v in hist.items()}
    w_star = getattr(exp.problem, "w_star", None)
    if w_star is not None and isinstance(w, jax.Array) and w.ndim == 1 \
            and w.shape == w_star.shape:
        metrics["w_err"] = float(
            jnp.linalg.norm(w - w_star) / jnp.linalg.norm(w_star)
        )
    if exp.problem.saddle_value is not None:
        metrics["saddle_value"] = exp.problem.saddle_value
    return metrics


def execute_cell(entry: PlanEntry, *,
                 time_budget_s: Optional[float] = None,
                 inject_fail: FrozenSet[str] = frozenset(),
                 log: Optional[Callable[[str], None]] = None) -> dict:
    """Run ONE cell to a complete store record — the unit both the
    serial loop and the pool workers execute, so their semantics cannot
    drift.  Never raises: failures are isolated into
    ``status="failed"`` records.  ``inject_fail`` (a set of cell hashes
    to fail deliberately) is the cross-process test seam — monkeypatches
    don't survive a spawn, a plain argument does.

    The record's ``wall_time_s`` and ``worker_id`` are *volatile* store
    fields: per-run diagnostics stripped on merge, keeping pool and
    serial merged stores byte-identical.
    """
    log = log or (lambda s: None)
    tel = get_telemetry()
    h = entry.hash
    deadline = (time.monotonic() + time_budget_s
                if time_budget_s is not None else None)
    t0 = time.monotonic()
    record = {"hash": h, "spec": entry.spec.to_dict(),
              "n_steps": entry.n_steps}
    with tel.span("sweep.cell", hash=h,
                  problem=entry.spec.problem,
                  aggregator=entry.spec.aggregator,
                  attack=entry.spec.attack):
        try:
            if h in inject_fail:
                raise RuntimeError(f"injected failure for cell {h}")
            record["status"] = "ok"
            record["metrics"] = _build_and_run(entry, deadline)
        except Exception as e:   # noqa: BLE001 — failure isolation is the point
            record["status"] = "failed"
            record.pop("metrics", None)
            record["error"] = f"{type(e).__name__}: {e}"
            log(f"[sweep] FAILED {h} {entry.spec.aggregator}/"
                f"{entry.spec.attack}: {record['error']}")
            log(traceback.format_exc(limit=3))
            if tel.enabled:
                tel.event("sweep.cell.failed", hash=h,
                          error=record["error"])
        else:
            if tel.enabled and record["metrics"].get("truncated"):
                tel.event("sweep.cell.truncated", hash=h)
    record["wall_time_s"] = round(time.monotonic() - t0, 3)
    record["worker_id"] = os.getpid()
    return record


def _pool_cell(spec_dict: dict, n_steps: int,
               time_budget_s: Optional[float],
               inject_fail: FrozenSet[str]) -> dict:
    """Pool-worker entry point: rebuild the cell from its JSON spec dict
    (the store's own serde — nothing unpicklable crosses the process
    boundary) and execute it.  Runs in a spawn-context child."""
    from ..api import ExperimentSpec

    entry = PlanEntry(ExperimentSpec.from_dict(spec_dict), int(n_steps))
    return execute_cell(entry, time_budget_s=time_budget_s,
                        inject_fail=inject_fail)


def _cell_log_line(record: dict, entry: PlanEntry) -> str:
    return (f"[sweep] {record['status']} {record['hash']} "
            f"problem={entry.spec.problem} agg={entry.spec.aggregator} "
            f"attack={entry.spec.attack} comp={entry.spec.compressor} "
            f"({record['wall_time_s']:.1f}s)")


def run_plan(
    plan: SweepPlan,
    store: ResultStore,
    *,
    shard_index: int = 0,
    num_shards: int = 1,
    time_budget_s: Optional[float] = None,
    limit: Optional[int] = None,
    retry_failed: bool = False,
    retry_truncated: bool = False,
    jobs: int = 1,
    log: Optional[Callable[[str], None]] = None,
    _inject_fail: FrozenSet[str] = frozenset(),
) -> dict:
    """Run this shard of the plan into ``store``; returns the summary
    ``{"built": …, "cached": …, "failed": …, "shard": …, "total": …}``.

    ``limit`` caps the number of cells *built* this invocation (the CI
    smoke lever, and how tests simulate a killed run); ``retry_failed``
    re-runs cells whose stored status is ``"failed"``, and
    ``retry_truncated`` re-runs cells a previous wall-time budget cut
    short, instead of treating either as done.

    ``jobs > 1`` runs the shard's cells on a spawn-context process pool
    (see module doc).  Per-cell semantics are identical to serial; the
    one behavioural difference is ``limit``, which caps *submissions*
    in pool mode (cells in flight when the cap is reached still finish)
    rather than successful builds — resumability makes the distinction
    harmless (the next invocation skips whatever completed).
    """
    log = log or (lambda s: None)
    tel = get_telemetry()
    entries = shard_entries(plan.entries, shard_index, num_shards)
    built = cached = failed = 0
    with tel.span("sweep.shard", shard=shard_index, num_shards=num_shards,
                  cells=len(entries), jobs=jobs):
        todo = []
        for entry in entries:
            prior = store.get(entry.hash)
            done = prior is not None
            if done and retry_failed and prior.get("status") == "failed":
                done = False
            if done and retry_truncated \
                    and prior.get("metrics", {}).get("truncated"):
                done = False
            if done:
                cached += 1
            else:
                todo.append(entry)

        if jobs <= 1:
            for entry in todo:
                if limit is not None and built >= limit:
                    break
                record = execute_cell(
                    entry, time_budget_s=time_budget_s,
                    inject_fail=_inject_fail, log=log,
                )
                if record["status"] == "ok":
                    built += 1
                else:
                    failed += 1
                with tel.span("sweep.cell.store", hash=record["hash"]):
                    store.append(record)
                log(_cell_log_line(record, entry))
        else:
            built, failed = _run_pool(
                todo if limit is None else todo[:limit],
                store, jobs=jobs, time_budget_s=time_budget_s,
                inject_fail=_inject_fail, log=log,
            )
    return {"built": built, "cached": cached, "failed": failed,
            "shard": (shard_index, num_shards), "total": len(entries)}


def _run_pool(todo, store: ResultStore, *, jobs: int,
              time_budget_s: Optional[float],
              inject_fail: FrozenSet[str],
              log: Callable[[str], None]) -> tuple:
    """Drain ``todo`` through a spawn-context process pool; append each
    record as it completes (the store is hash-keyed and merge-sorted, so
    completion order never shows in merged bytes).  A worker that dies
    outright fails only its own cell."""
    import multiprocessing
    from concurrent.futures import ProcessPoolExecutor, as_completed

    tel = get_telemetry()
    built = failed = 0
    if not todo:
        return built, failed
    ctx = multiprocessing.get_context("spawn")
    with ProcessPoolExecutor(max_workers=min(jobs, len(todo)),
                             mp_context=ctx) as pool:
        futures = {
            pool.submit(_pool_cell, entry.spec.to_dict(), entry.n_steps,
                        time_budget_s, inject_fail): entry
            for entry in todo
        }
        for fut in as_completed(futures):
            entry = futures[fut]
            try:
                record = fut.result()
            except Exception as e:   # a worker process died outright
                record = {"hash": entry.hash,
                          "spec": entry.spec.to_dict(),
                          "n_steps": entry.n_steps, "status": "failed",
                          "error": f"{type(e).__name__}: {e}",
                          "wall_time_s": 0.0, "worker_id": None}
                log(f"[sweep] POOL-FAILED {entry.hash}: "
                    f"{record['error']}")
                if tel.enabled:
                    tel.event("sweep.cell.failed", hash=entry.hash,
                              error=record["error"])
            if record["status"] == "ok":
                built += 1
            else:
                failed += 1
            with tel.span("sweep.cell.store", hash=record["hash"]):
                store.append(record)
            log(_cell_log_line(record, entry))
    return built, failed
