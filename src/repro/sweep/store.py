"""Resumable result store: append-only JSONL keyed by a canonical spec hash.

One line = one completed (or failed) sweep cell:

    {"hash": "…", "spec": {…}, "n_steps": T, "status": "ok"|"failed",
     "metrics": {…full history incl. exact WireLedger ints…},
     "wall_time_s": 1.23, "worker_id": 4242, "error": "…"}

The **hash** is the identity of a cell: SHA-256 over the canonical JSON
of ``{"n_steps": T, "spec": spec.to_dict()}`` (sorted keys, no
whitespace), truncated to 16 hex chars.  It covers everything that
determines the numbers — the full :class:`~repro.api.ExperimentSpec`
(problem, seed, channels, aggregator, attack, …) *and* the round budget
— and nothing that doesn't, so the same cell planned on any host at any
time hashes identically.  ``tests/test_sweep.py`` pins a golden value;
changing the canonicalization is a store-format break and must bump
:data:`STORE_VERSION`.

Resumability: a :class:`ResultStore` opened on an existing file loads
its hashes, and the runner skips any cell whose hash is present —
re-running a finished sweep performs **zero** experiment builds.
``merge`` unions shard files from multiple hosts into one canonical
store: records are deduplicated by hash, **volatile** per-host fields
(wall time) are stripped, and lines are sorted by hash — so merging the
same set of cells always produces byte-identical output regardless of
which host ran which shard, or where a killed run was resumed.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Iterable, Optional

from ..api import ExperimentSpec

STORE_VERSION = 1

#: per-host / per-run diagnostics that must not affect merged-store bytes
#: (wall time and the executor-pool worker pid vary run to run; stripping
#: them is what keeps a ``--jobs N`` pool merge byte-identical to serial)
VOLATILE_KEYS = ("wall_time_s", "worker_id")


# ------------------------------------------------------------------ hash
def canonical_json(obj) -> str:
    """Deterministic JSON: sorted keys, minimal separators."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def spec_hash(spec, n_steps: int) -> str:
    """Canonical identity of one sweep cell (spec + round budget)."""
    if isinstance(spec, ExperimentSpec):
        spec = spec.to_dict()
    payload = canonical_json({"n_steps": int(n_steps), "spec": spec})
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def canonical_record(record: dict) -> dict:
    """A record with volatile per-host fields stripped (merge form)."""
    return {k: v for k, v in record.items() if k not in VOLATILE_KEYS}


# ------------------------------------------------------------------ store
class ResultStore:
    """Append-only JSONL result store; ``path=None`` keeps it in memory
    (benchmark thin-views that don't need resume across processes)."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._records: list[dict] = []
        self._by_hash: dict[str, dict] = {}
        if path is not None and os.path.exists(path):
            with open(path) as f:
                for line in f:
                    line = line.strip()
                    if line:
                        self._index(json.loads(line))

    def _index(self, rec: dict) -> None:
        h = rec["hash"]
        self._records.append(rec)
        # last-write-wins in-process (a retried failure overwrites), but
        # append-only on disk — merge dedups by first occurrence
        self._by_hash[h] = rec

    # -- writing ---------------------------------------------------------
    def append(self, record: dict) -> None:
        if "hash" not in record:
            raise ValueError("store records need a 'hash' key")
        if self.path is not None:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            with open(self.path, "a") as f:
                f.write(canonical_json(record) + "\n")
        self._index(record)

    # -- reading ---------------------------------------------------------
    def __contains__(self, h: str) -> bool:
        return h in self._by_hash

    def __len__(self) -> int:
        return len(self._by_hash)

    def get(self, h: str) -> Optional[dict]:
        return self._by_hash.get(h)

    def hashes(self) -> set:
        return set(self._by_hash)

    def records(self) -> list[dict]:
        """Deduplicated records (latest per hash), insertion order."""
        seen = set()
        out = []
        for rec in self._records:
            if rec["hash"] in seen:
                continue
            seen.add(rec["hash"])
            out.append(self._by_hash[rec["hash"]])
        return out

    def ok_records(self) -> list[dict]:
        return [r for r in self.records() if r.get("status") == "ok"]


# ------------------------------------------------------------------ merge
def merge(paths: Iterable[str], out_path: str) -> int:
    """Union shard stores into one canonical store (see module doc).

    Duplicate hashes keep the first occurrence in sorted-``paths`` order;
    the output is volatile-stripped, hash-sorted, canonical JSONL —
    byte-identical for the same set of cells however they were produced.
    Returns the number of merged records.  Every input path must exist —
    a typo'd shard file must not silently produce a half-empty store.
    """
    paths = sorted(paths)
    missing = [p for p in paths if not os.path.exists(p)]
    if missing:
        raise FileNotFoundError(f"shard store(s) not found: {missing}")
    by_hash: dict[str, dict] = {}
    for path in paths:
        for rec in ResultStore(path).records():
            by_hash.setdefault(rec["hash"], rec)
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        for h in sorted(by_hash):
            f.write(canonical_json(canonical_record(by_hash[h])) + "\n")
    return len(by_hash)
