"""repro.telemetry — zero-cost-when-disabled instrumentation.

Round-level tracing, wire/compile/aggregation metrics, and a
Perfetto-compatible timeline across both runtimes, the sweep engine,
and serving.  See README.md in this directory for the event schema and
the enabling story; the one-line version:

    REPRO_TELEMETRY_DIR=results/telemetry python examples/quickstart.py
    python -m repro.telemetry validate results/telemetry/events.jsonl \
        --trace results/telemetry/trace.json --check-wire
    # then load results/telemetry/trace.json at https://ui.perfetto.dev
"""
from .compile import (
    ANY,
    BACKEND_EVENT,
    TRACE_EVENT,
    CompileCounter,
    compile_scope,
    record_retrace,
)
from .core import ENV_DIR, Telemetry, device_event, get_telemetry
from .forensics import SuspicionTracker, planted_byzantine_ids
from .records import RoundRecord, rejected_from_keep
from .schema import (
    EVENT_SCHEMA,
    KINDS,
    SCHEMA_VERSION,
    validate_event,
    validate_stream,
)

__all__ = [
    "ANY",
    "BACKEND_EVENT",
    "TRACE_EVENT",
    "CompileCounter",
    "compile_scope",
    "record_retrace",
    "ENV_DIR",
    "Telemetry",
    "device_event",
    "get_telemetry",
    "RoundRecord",
    "rejected_from_keep",
    "SuspicionTracker",
    "planted_byzantine_ids",
    "EVENT_SCHEMA",
    "KINDS",
    "SCHEMA_VERSION",
    "validate_event",
    "validate_stream",
]
