"""CLI: ``python -m repro.telemetry validate <events.jsonl>``.

Validates every emitted event against the schema, optionally parses the
Chrome trace and cross-checks wire events against ledger totals — the
telemetry-smoke CI job's teeth.

    python -m repro.telemetry validate results/telemetry/events.jsonl \
        --trace results/telemetry/trace.json --check-wire
"""
from __future__ import annotations

import argparse
import json
import sys

from .schema import validate_stream


def _load_events(path: str) -> list:
    events = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events


def check_wire_exactness(events: list) -> list:
    """Every ledger snapshot's totals must equal the exact sum of the
    wire events from its ledger generation (the acceptance criterion:
    per-transmit bit events sum to the WireLedger's integer totals).

    **Order-insensitive by construction**: events are grouped by
    ``(pid, ledger_id)`` — ``ledger_id`` alone is only process-unique,
    and a parallel sweep pool's workers each restart the counter — and
    the check is a sum, invariant to interleaving/merge order.  When the
    stream carries the v3 per-record ``seq`` ids and the snapshot's
    ``n_records``, completeness is additionally asserted:
    ``sorted(seqs) == range(n_records)`` (missing and duplicated wire
    events are caught even when the sums coincidentally agree).
    v1/v2 streams (no seq/pid) still validate sum-only.

    Returns problem strings (empty ⇒ exact)."""
    sums: dict[tuple, dict] = {}
    for ev in events:
        if ev.get("kind") == "wire":
            gen = (ev.get("pid"), ev["ledger_id"])
            slot = sums.setdefault(gen, {"uplink": 0, "downlink": 0,
                                         "rounds": 0, "seqs": []})
            slot["uplink"] += ev["uplink"]
            slot["downlink"] += ev["downlink"]
            slot["rounds"] += ev["rounds"]
            if "seq" in ev:
                slot["seqs"].append(ev["seq"])
    problems = []
    n_checked = 0
    for ev in events:
        if ev.get("kind") != "ledger":
            continue
        n_checked += 1
        gen = (ev.get("pid"), ev["ledger_id"])
        label = (f"ledger {gen[1]}" if gen[0] is None
                 else f"ledger {gen[1]} (pid {gen[0]})")
        got = sums.get(gen, {"uplink": 0, "downlink": 0, "rounds": 0,
                             "seqs": []})
        for wire_key, ledger_key in (("uplink", "uplink_bits"),
                                     ("downlink", "downlink_bits"),
                                     ("rounds", "rounds")):
            if got[wire_key] != ev[ledger_key]:
                problems.append(
                    f"{label}: sum(wire.{wire_key}) = "
                    f"{got[wire_key]} but snapshot {ledger_key} = "
                    f"{ev[ledger_key]}"
                )
        n_records = ev.get("n_records")
        if n_records is not None and got["seqs"]:
            expected = list(range(n_records))
            seqs = sorted(got["seqs"])
            if seqs != expected:
                missing = sorted(set(expected) - set(seqs))
                extra = sorted(set(seqs) - set(expected))
                dupes = sorted({s for s in seqs if seqs.count(s) > 1})
                detail = ", ".join(filter(None, (
                    f"missing seqs {missing}" if missing else "",
                    f"unexpected seqs {extra}" if extra else "",
                    f"duplicated seqs {dupes}" if dupes else "",
                )))
                problems.append(
                    f"{label}: {len(seqs)} wire events vs n_records = "
                    f"{n_records} ({detail or 'seq mismatch'})"
                )
    if n_checked == 0:
        problems.append("--check-wire: no ledger snapshot events found")
    return problems


def check_chrome_trace(path: str) -> list:
    """``trace.json`` must parse and look like Chrome Trace Event
    Format (what Perfetto's JSON importer requires)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"trace {path}: {e}"]
    problems = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return [f"trace {path}: no 'traceEvents' array"]
    for i, ev in enumerate(events):
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                problems.append(f"trace event {i}: missing {key!r}")
                break
        else:
            if ev["ph"] == "X" and "dur" not in ev:
                problems.append(f"trace event {i}: complete event "
                                f"without 'dur'")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.telemetry")
    sub = ap.add_subparsers(dest="cmd", required=True)
    p_val = sub.add_parser("validate",
                           help="schema-validate an events.jsonl stream")
    p_val.add_argument("events", help="path to events.jsonl")
    p_val.add_argument("--trace", default=None,
                       help="also check this trace.json parses as "
                            "Chrome Trace Event Format")
    p_val.add_argument("--check-wire", action="store_true",
                       help="assert wire events sum exactly to each "
                            "ledger snapshot")
    args = ap.parse_args(argv)

    with open(args.events) as f:
        problems = [f"line {ln}: {msg}"
                    for ln, msg in validate_stream(f)]
    events = [] if problems else _load_events(args.events)
    if not problems:
        print(f"[telemetry] {args.events}: {len(events)} events, "
              f"schema-valid")
    if not problems and args.check_wire:
        problems += check_wire_exactness(events)
        if not problems:
            n = sum(1 for e in events if e.get("kind") == "ledger")
            print(f"[telemetry] wire events sum exactly to all "
                  f"{n} ledger snapshot(s)")
    if args.trace:
        trace_problems = check_chrome_trace(args.trace)
        if not trace_problems:
            print(f"[telemetry] {args.trace}: parses as Chrome trace "
                  f"(Perfetto-loadable)")
        problems += trace_problems
    for p in problems:
        print(f"[telemetry] INVALID: {p}", file=sys.stderr)
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
