"""Compile-counter: every JAX re-trace / backend compile is an event.

The ROADMAP's recompile-hygiene item needs *numbers*: adaptive-k
re-traces the jitted step on every k move, and every sweep cell builds
its own jit — compile time, not step time, dominates big grids.  This
module hooks :mod:`jax.monitoring`'s duration events (the instrumented
seam around JAX's compilation cache):

* ``…/jaxpr_trace_duration``       — one per re-trace,
* ``…/backend_compile_duration``   — one per actual XLA compile
  (a compilation-cache hit traces but does not backend-compile).

Attribution: the monitoring callback carries no function identity, so
runtimes label their compile sites with :func:`compile_scope` — a
contextvar the listener reads while the (synchronous) compile runs.
``DistributedCubicNewton.step`` runs under ``compile_scope
("newton.step")``, the mesh facade under ``"mesh.step"``, so
``counter.backend_compiles("newton.step")`` is exactly "how many times
did the paper runtime's step recompile" — the number the regression
pins assert.

One module-level listener dispatches to the active counters (JAX offers
no public unregister), registered lazily on first activation; with no
active counter it is a len()-check per *compile*, nothing per step.

Explicit re-trace triggers (an adaptive-k move rebuilding a jit) should
additionally call :func:`record_retrace` with their shape key, so the
event stream says *why* a re-trace happened, not just that it did.
"""
from __future__ import annotations

import contextvars
import threading
from contextlib import contextmanager
from typing import Optional

TRACE_EVENT = "/jax/core/compile/jaxpr_trace_duration"
BACKEND_EVENT = "/jax/core/compile/backend_compile_duration"
_WATCHED = {TRACE_EVENT: "jaxpr_trace", BACKEND_EVENT: "backend_compile"}

_scope: contextvars.ContextVar = contextvars.ContextVar(
    "repro_compile_scope", default=None)

_active: list = []
_listener_installed = False
_install_lock = threading.Lock()


@contextmanager
def compile_scope(label: str):
    """Attribute any compile that happens inside this block to ``label``.

    Purely host-side (a contextvar set/reset): it never enters a trace
    and costs ~100ns per use, so runtimes wrap every step call."""
    token = _scope.set(label)
    try:
        yield
    finally:
        _scope.reset(token)


def current_scope() -> Optional[str]:
    return _scope.get()


def _listener(event: str, duration_s: float, **kw) -> None:
    if not _active or event not in _WATCHED:
        return
    label = _scope.get()
    short = _WATCHED[event]
    for counter in list(_active):
        counter._record(short, duration_s, label)


def _ensure_listener() -> None:
    global _listener_installed
    with _install_lock:
        if _listener_installed:
            return
        import jax.monitoring

        jax.monitoring.register_event_duration_secs_listener(_listener)
        _listener_installed = True


class CompileCounter:
    """Count (and optionally emit) compiles while active.

    Use as a context manager for scoped assertions::

        with CompileCounter() as cc:
            run_something()
        assert cc.backend_compiles("newton.step") == 3

    or give the global telemetry handle one (``emit_to=tel``) so every
    compile becomes a schema'd ``compile`` event with its duration and
    attributed scope.
    """

    def __init__(self, emit_to=None):
        self._emit_to = emit_to
        self._lock = threading.Lock()
        # {(event_short, scope_label_or_None): [count, total_seconds]}
        self._by_key: dict[tuple, list] = {}

    # -- lifecycle -------------------------------------------------------
    def activate(self) -> "CompileCounter":
        _ensure_listener()
        if self not in _active:
            _active.append(self)
        return self

    def deactivate(self) -> None:
        try:
            _active.remove(self)
        except ValueError:
            pass

    def __enter__(self) -> "CompileCounter":
        return self.activate()

    def __exit__(self, *exc) -> None:
        self.deactivate()

    # -- recording -------------------------------------------------------
    def _record(self, event_short: str, duration_s: float,
                label: Optional[str]) -> None:
        with self._lock:
            slot = self._by_key.setdefault((event_short, label), [0, 0.0])
            slot[0] += 1
            slot[1] += duration_s
        if self._emit_to is not None:
            self._emit_to.compile_event(event=event_short,
                                        dur_s=duration_s, scope=label)

    # -- queries ---------------------------------------------------------
    def _count(self, event_short: str, scope) -> int:
        with self._lock:
            if scope is _ANY:
                return sum(v[0] for (e, _), v in self._by_key.items()
                           if e == event_short)
            return self._by_key.get((event_short, scope), [0, 0.0])[0]

    def backend_compiles(self, scope=None) -> int:
        """XLA backend compiles attributed to ``scope`` (``None`` counts
        unattributed compiles; pass ``scope=ANY`` for the grand total)."""
        return self._count("backend_compile", scope)

    def retraces(self, scope=None) -> int:
        """Jaxpr traces attributed to ``scope`` (cache hits retrace
        without backend-compiling; see module doc)."""
        return self._count("jaxpr_trace", scope)

    def compile_seconds(self, scope=None) -> float:
        """Total backend-compile seconds attributed to ``scope``
        (``ANY`` for the scope-blind total)."""
        with self._lock:
            return sum(v[1] for (e, s), v in self._by_key.items()
                       if e == "backend_compile"
                       and (scope is _ANY or s == scope))

    def snapshot(self) -> dict:
        """``{scope: {"backend_compiles": n, "retraces": n,
        "compile_s": s}}`` over every scope seen (None key =
        unattributed)."""
        out: dict = {}
        with self._lock:
            for (event, scope), (n, secs) in self._by_key.items():
                slot = out.setdefault(scope, {"backend_compiles": 0,
                                              "retraces": 0,
                                              "compile_s": 0.0})
                if event == "backend_compile":
                    slot["backend_compiles"] += n
                    slot["compile_s"] += secs
                else:
                    slot["retraces"] += n
        return out


class _Any:
    def __repr__(self):
        return "ANY"


#: pass to ``backend_compiles``/``retraces`` for the scope-blind total
ANY = _ANY = _Any()


def record_retrace(trigger: str, **shape_key) -> None:
    """Announce an *explicit* re-trace trigger (e.g. an adaptive-k move
    rebuilding its jit) on the global telemetry stream, with the shape
    key that caused it.  No-op when telemetry is disabled."""
    from .core import get_telemetry

    tel = get_telemetry()
    if not tel.enabled:
        return
    tel.event("compile.retrace", trigger=trigger, **shape_key)
