"""The process-global :class:`Telemetry` handle.

Design contract (the HLO-identity test pins it):

* **Disabled is the default and costs nothing.**  Every emit method
  checks one boolean and returns; ``span()`` hands back a shared no-op
  context manager; :func:`device_event` stages *nothing* into a trace —
  the lowered HLO with telemetry disabled is bit-identical to a build
  without the telemetry integration at all.
* **Instrumentation is host-side.**  Both runtimes already surface
  every per-round quantity as concrete metrics on the host, so round
  records, wire events, spans, and compile events are plain Python on
  the driver loop.  :func:`device_event` — a ``jax.debug.callback``
  staged only when telemetry is enabled *at trace time* — exists for
  the few values that genuinely live on the device (it changes the
  lowered program, which is exactly why it is opt-in per trace).
* **Two sinks**: a schema-versioned append-only JSONL event stream and
  a Chrome-trace/Perfetto ``trace.json`` (see :mod:`.sinks`).  Both are
  optional — ``enable()`` with no directory keeps metrics in memory
  (the serving path's latency histograms without file I/O).

Enable explicitly (``get_telemetry().enable(out_dir=…)``), per driver
flag (``--telemetry-dir``), or for unmodified entry points via the
environment: ``REPRO_TELEMETRY_DIR=results/telemetry`` enables the
global handle at first use.
"""
from __future__ import annotations

import atexit
import os
import threading
import time
from contextlib import contextmanager
from typing import Optional

from .records import RoundRecord
from .schema import SCHEMA_VERSION
from .sinks import ChromeTraceSink, JsonlSink

ENV_DIR = "REPRO_TELEMETRY_DIR"


class _NoopSpan:
    """Shared do-nothing context manager — the disabled ``span()``."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP_SPAN = _NoopSpan()


def _percentile(sorted_vals, q: float):
    """Nearest-rank percentile on a pre-sorted list (q in [0, 100])."""
    if not sorted_vals:
        return None
    idx = max(0, min(len(sorted_vals) - 1,
                     int(round(q / 100.0 * (len(sorted_vals) - 1)))))
    return sorted_vals[idx]


class Telemetry:
    """Counters, gauges, histograms, spans, and structured events.

    One instance is the process-global handle (:func:`get_telemetry`);
    fresh instances are cheap and used by tests.  All state is
    host-side; nothing here is ever traced.
    """

    def __init__(self):
        self._enabled = False
        self._lock = threading.Lock()
        self._local = threading.local()
        self._t0 = 0.0
        self._jsonl: Optional[JsonlSink] = None
        self._trace: Optional[ChromeTraceSink] = None
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._hists: dict[str, list] = {}
        self._compile_counter = None
        self.out_dir: Optional[str] = None

    # ------------------------------------------------------------ state
    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self, out_dir: Optional[str] = None, *,
               jsonl: bool = True, trace: bool = True) -> "Telemetry":
        """Turn the handle on.  With ``out_dir``, attach the JSONL sink
        (``events.jsonl``, append-only) and the Chrome-trace sink
        (``trace.json``, rewritten on flush); without it, metrics
        aggregate in memory only.  Idempotent; returns self."""
        with self._lock:
            if out_dir is not None:
                self.out_dir = out_dir
                if jsonl and self._jsonl is None:
                    self._jsonl = JsonlSink(os.path.join(out_dir,
                                                         "events.jsonl"))
                if trace and self._trace is None:
                    self._trace = ChromeTraceSink(os.path.join(out_dir,
                                                               "trace.json"))
            if not self._enabled:
                self._t0 = time.perf_counter()
                self._enabled = True
                atexit.register(self.flush)
        self._attach_compile_counter()
        return self

    def disable(self) -> None:
        """Flush and turn the handle off (sinks are kept for re-enable)."""
        self.flush()
        self._detach_compile_counter()
        self._enabled = False

    def _attach_compile_counter(self):
        from .compile import CompileCounter

        if self._compile_counter is None:
            self._compile_counter = CompileCounter(emit_to=self)
            self._compile_counter.activate()

    def _detach_compile_counter(self):
        if self._compile_counter is not None:
            self._compile_counter.deactivate()
            self._compile_counter = None

    # ------------------------------------------------------------- time
    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def _base(self, kind: str, name: str) -> dict:
        return {"v": SCHEMA_VERSION, "kind": kind, "name": name,
                "ts": round(self._now(), 6), "wall": round(time.time(), 6)}

    def _emit(self, event: dict) -> None:
        if self._jsonl is not None:
            self._jsonl.emit(event)

    # ------------------------------------------------------------ emits
    def event(self, name: str, **fields) -> None:
        """A free-form instant event (both sinks)."""
        if not self._enabled:
            return
        ev = self._base("event", name)
        ev.update(fields)
        self._emit(ev)
        if self._trace is not None:
            self._trace.instant(name, ev["ts"], fields or None)

    def count(self, name: str, n=1, **fields) -> None:
        """Increment a counter; the event carries the running total."""
        if not self._enabled:
            return
        with self._lock:
            total = self._counters.get(name, 0) + n
            self._counters[name] = total
        ev = self._base("counter", name)
        ev["value"] = total
        ev.update(fields)
        self._emit(ev)
        if self._trace is not None:
            self._trace.counter(name, ev["ts"], total)

    def gauge(self, name: str, value, **fields) -> None:
        """Set a gauge to its latest value."""
        if not self._enabled:
            return
        value = float(value)
        with self._lock:
            self._gauges[name] = value
        ev = self._base("gauge", name)
        ev["value"] = value
        ev.update(fields)
        self._emit(ev)
        if self._trace is not None:
            self._trace.counter(name, ev["ts"], value)

    def observe(self, name: str, value, **fields) -> None:
        """One histogram observation (p50/p99 via :meth:`histogram`)."""
        if not self._enabled:
            return
        value = float(value)
        with self._lock:
            self._hists.setdefault(name, []).append(value)
        ev = self._base("hist", name)
        ev["value"] = value
        ev.update(fields)
        self._emit(ev)

    def round(self, record: RoundRecord, name: str = "round") -> None:
        """Emit one :class:`RoundRecord` (kind ``round``)."""
        if not self._enabled:
            return
        ev = self._base("round", name)
        ev.update(record.to_fields())
        self._emit(ev)
        if self._trace is not None:
            self._trace.instant(
                f"{name}.saddle_escape" if record.saddle_escape else name,
                ev["ts"],
                {"step": ev["step"], "loss": ev.get("loss"),
                 "grad_norm": ev.get("grad_norm")},
            )

    def wire(self, *, ledger_id: int, uplink: int, downlink: int,
             rounds: int, label: Optional[str] = None,
             seq: Optional[int] = None, pid: Optional[int] = None) -> None:
        """One ledger-record call: exact integer bits on the wire.
        ``seq`` is the ledger's per-generation sequence id and ``pid``
        the emitting process — together they make validation
        order-insensitive across async channels and pool workers (v1/v2
        streams without them still validate sum-only)."""
        if not self._enabled:
            return
        ev = self._base("wire", "wire")
        ev.update(ledger_id=int(ledger_id), uplink=int(uplink),
                  downlink=int(downlink), rounds=int(rounds))
        if label:
            ev["label"] = label
        if seq is not None:
            ev["seq"] = int(seq)
        if pid is not None:
            ev["pid"] = int(pid)
        self._emit(ev)

    def ledger_snapshot(self, *, ledger_id: int, snapshot: dict,
                        n_records: Optional[int] = None,
                        pid: Optional[int] = None) -> None:
        """End-of-run ledger totals (must equal the sum of this
        ledger generation's wire events — the validator checks).
        ``n_records`` (the generation's record count) lets the
        validator assert seq completeness; ``pid`` disambiguates
        colliding per-process ledger_ids."""
        if not self._enabled:
            return
        ev = self._base("ledger", "ledger")
        ev["ledger_id"] = int(ledger_id)
        if n_records is not None:
            ev["n_records"] = int(n_records)
        if pid is not None:
            ev["pid"] = int(pid)
        ev.update({k: int(v) for k, v in snapshot.items()})
        self._emit(ev)

    def compile_event(self, *, event: str, dur_s: float,
                      scope: Optional[str] = None, **fields) -> None:
        """One JAX compilation-cache event (from the compile counter)."""
        if not self._enabled:
            return
        ev = self._base("compile", "compile")
        ev.update(event=event, dur_s=float(dur_s))
        if scope is not None:
            ev["scope"] = scope
        ev.update(fields)
        self._emit(ev)
        if self._trace is not None:
            now = ev["ts"]
            self._trace.span(f"compile.{event}", max(0.0, now - dur_s),
                             dur_s, {"scope": scope} if scope else None)

    # ------------------------------------------------------------ spans
    @contextmanager
    def _span_cm(self, name: str, attrs: dict):
        t0 = self._now()
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        stack.append(name)
        status = "ok"
        try:
            yield self
        except BaseException:
            status = "error"
            raise
        finally:
            stack.pop()
            dur = self._now() - t0
            ev = self._base("span", name)
            ev["ts"] = round(t0, 6)
            ev["dur_s"] = round(dur, 6)
            if attrs or status != "ok":
                ev["args"] = {**{k: str(v) for k, v in attrs.items()},
                              **({"status": status}
                                 if status != "ok" else {})}
            self._emit(ev)
            if self._trace is not None:
                self._trace.span(name, t0, dur, ev.get("args"))

    def span(self, name: str, **attrs):
        """``with tel.span("sweep.cell", hash=h): …`` — a timed scope
        emitted to both sinks.  Free when disabled."""
        if not self._enabled:
            return _NOOP_SPAN
        return self._span_cm(name, attrs)

    def current_span(self) -> Optional[str]:
        stack = getattr(self._local, "stack", None)
        return stack[-1] if stack else None

    # ---------------------------------------------------------- queries
    def counter_value(self, name: str):
        return self._counters.get(name)

    def gauge_value(self, name: str):
        return self._gauges.get(name)

    def histogram(self, name: str) -> Optional[dict]:
        """Summary of one histogram: count/min/max/mean/p50/p90/p99."""
        vals = sorted(self._hists.get(name, ()))
        if not vals:
            return None
        return {"count": len(vals), "min": vals[0], "max": vals[-1],
                "mean": sum(vals) / len(vals),
                "p50": _percentile(vals, 50), "p90": _percentile(vals, 90),
                "p99": _percentile(vals, 99)}

    def snapshot(self) -> dict:
        """All in-memory metrics as one plain dict."""
        with self._lock:
            return {"counters": dict(self._counters),
                    "gauges": dict(self._gauges),
                    "histograms": {k: self.histogram(k)
                                   for k in self._hists}}

    def flush(self) -> None:
        if self._trace is not None:
            self._trace.flush()
        if self._jsonl is not None:
            self._jsonl.flush()


# ----------------------------------------------------------- the global
_GLOBAL: Optional[Telemetry] = None
_GLOBAL_LOCK = threading.Lock()


def get_telemetry() -> Telemetry:
    """The process-global handle (created on first use; auto-enabled
    when ``REPRO_TELEMETRY_DIR`` is set, so unmodified entry points —
    the quickstart example, pytest runs — can opt in from the shell)."""
    global _GLOBAL
    if _GLOBAL is None:
        with _GLOBAL_LOCK:
            if _GLOBAL is None:
                tel = Telemetry()
                env_dir = os.environ.get(ENV_DIR)
                if env_dir:
                    tel.enable(env_dir)
                _GLOBAL = tel
    return _GLOBAL


def device_event(name: str, tel: Optional[Telemetry] = None, **arrays):
    """Stage a host callback that emits device values as an event.

    Call **at trace time** inside jitted code.  When telemetry is
    disabled this is a hard no-op — nothing is staged, the lowered HLO
    is bit-identical to code without the call (the HLO-identity test
    pins this).  When enabled, a ``jax.debug.callback`` ships the named
    arrays to the host and emits one ``event`` with their values —
    use it only for values that are not already surfaced as metrics.
    """
    tel = tel if tel is not None else get_telemetry()
    if not tel.enabled:
        return

    import jax
    import numpy as np

    names = tuple(arrays)

    def _cb(*vals):
        fields = {}
        for n, v in zip(names, vals):
            a = np.asarray(v)
            fields[n] = a.item() if a.ndim == 0 else a.tolist()
        tel.event(name, **fields)

    jax.debug.callback(_cb, *arrays.values())
