"""Per-worker suspicion scores — the forensic attribution layer.

:class:`SuspicionTracker` turns the per-round facts every runtime
already surfaces host-side (the aggregator's keep mask and the
per-worker update norms) into one EWMA **suspicion score** per worker,
following the history-based concentration idea of Allen-Zhu et al. 2020
(arXiv 2012.14368): a Byzantine worker betrays itself by *persistent*
deviation — it keeps getting rejected, or its update norm keeps leaving
the concentration band of its own past behaviour — while an honest
worker's occasional rejection (e.g. the β·m rank cut clipping the
largest honest norm once) decays away.

Two per-round signals, combined as a max and folded into the EWMA:

* **rejection** — ``1 − keep_i`` (soft keep masks contribute
  fractionally).  Skipped for selection-style rules (krum's one-hot
  keep rejects m−1 workers a round; rejection frequency carries no
  information there, detected as "more than half rejected");
* **norm z-score** — ``|norm_i − mean_i| / std_i`` against the worker's
  OWN running history (Welford, history *before* this round), clipped
  to [0, 1] at ``z_clip`` and then scaled by ``z_weight`` (< the 0.5
  default flag threshold).  Needs ≥ 3 prior observations.

The asymmetry is deliberate: an honest worker's norms drift as the run
converges, so the z-signal alone carries persistent low-level noise —
capping it at ``z_weight`` means z-evidence alone can never cross the
default 0.5 flag line (use a lower ``flagged`` threshold to hunt by
norms, e.g. under krum where rejection is uninformative), while a
worker the aggregator persistently rejects saturates toward 1.  A
non-finite norm is maximal evidence regardless.

The tracker is pure host-side bookkeeping: the runtimes construct one
only when telemetry is enabled and feed it concrete per-round values —
nothing here is ever traced, so the zero-cost-when-disabled contract is
untouched.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence


class SuspicionTracker:
    """EWMA suspicion per worker over one run (host-side, never traced).

    ``update(keep=…, norms=…)`` consumes one round and returns the m
    current scores (floats in [0, 1]).  ``None`` entries in ``keep`` /
    ``norms`` mean "worker did not participate this round" — its score
    and history are left untouched.
    """

    def __init__(self, m: int, *, ewma: float = 0.3, z_clip: float = 3.0,
                 z_weight: float = 0.4, min_history: int = 3):
        if not 0.0 < ewma <= 1.0:
            raise ValueError(f"ewma must be in (0, 1], got {ewma!r}")
        self.m = int(m)
        self.ewma = float(ewma)
        self.z_clip = float(z_clip)
        self.z_weight = float(z_weight)
        self.min_history = int(min_history)
        self.scores = [0.0] * self.m
        # Welford running stats of each worker's own update-norm history
        self._n = [0] * self.m
        self._mean = [0.0] * self.m
        self._m2 = [0.0] * self.m

    # -- the two signals -----------------------------------------------
    def _z_signal(self, i: int, norm: float) -> float:
        """Deviation of this round's norm from worker i's own history
        (computed BEFORE the norm enters the history)."""
        if self._n[i] < self.min_history:
            return 0.0
        var = self._m2[i] / (self._n[i] - 1)
        std = math.sqrt(var) if var > 0 else 0.0
        if std <= 0.0:
            # degenerate flat history: any deviation is maximal
            return 0.0 if norm == self._mean[i] else 1.0
        z = abs(norm - self._mean[i]) / std
        return min(1.0, z / self.z_clip)

    def _push_history(self, i: int, norm: float) -> None:
        self._n[i] += 1
        delta = norm - self._mean[i]
        self._mean[i] += delta / self._n[i]
        self._m2[i] += delta * (norm - self._mean[i])

    # -- one round ------------------------------------------------------
    def update(self, *, keep: Optional[Sequence] = None,
               norms: Optional[Sequence] = None) -> list:
        """Fold one round's keep mask / update norms into the scores.

        ``keep[i]`` is the aggregator's keep weight (1 kept, 0 rejected,
        fractional for soft masks), ``norms[i]`` the worker's update
        norm; ``None`` entries skip that worker.  Returns the m scores.
        """
        keep = list(keep) if keep is not None else [None] * self.m
        norms = list(norms) if norms is not None else [None] * self.m
        if len(keep) != self.m or len(norms) != self.m:
            raise ValueError(
                f"keep/norms must have length m={self.m}, got "
                f"{len(keep)}/{len(norms)}"
            )
        # a selection rule (krum) keeps one worker and "rejects" the
        # rest — rejection frequency is uninformative, use z-scores only
        live = [k for k in keep if k is not None]
        selection_rule = (
            live and sum(1.0 - min(1.0, max(0.0, float(k))) for k in live)
            > len(live) / 2
        )
        for i in range(self.m):
            k_i, n_i = keep[i], norms[i]
            if k_i is None and n_i is None:
                continue   # did not participate: score + history untouched
            signal = 0.0
            if k_i is not None and not selection_rule:
                signal = 1.0 - min(1.0, max(0.0, float(k_i)))
            if n_i is not None:
                n_i = float(n_i)
                if math.isfinite(n_i):
                    signal = max(signal,
                                 self.z_weight * self._z_signal(i, n_i))
                    self._push_history(i, n_i)
                else:
                    signal = 1.0   # non-finite update: maximally suspect
            self.scores[i] = ((1.0 - self.ewma) * self.scores[i]
                              + self.ewma * signal)
        return list(self.scores)

    def flagged(self, threshold: float = 0.5) -> list:
        """Worker ids whose current suspicion is ≥ ``threshold``."""
        return [i for i, s in enumerate(self.scores) if s >= threshold]


def planted_byzantine_ids(m: int, alpha: float) -> list:
    """The ground-truth Byzantine worker set the attack hook plants:
    :func:`repro.core.attacks.byzantine_mask` corrupts the FIRST
    ``int(alpha · m)`` workers, deterministically."""
    return list(range(int(float(alpha) * int(m))))
