"""Structured per-round record both runtimes emit.

A :class:`RoundRecord` is the telemetry view of ONE communication round:
the trajectory quantities the paper's claims are about (loss, gradient
norm, model decrease, saddle-escape), the wire quantities (measured δ̂,
the adaptive-k schedule's live k, exact per-round bits), and the
resilience quantities (which workers the aggregator rejected, what
attack was injected).  ``None`` fields are simply omitted from the
emitted event — the mesh runtime has no cheap global gradient norm, the
paper runtime has no staleness, and the schema stays one.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass
class RoundRecord:
    """One communication round, as seen from the host loop."""

    step: int                                  # 0-based round index
    runtime: str = "paper"                     # "paper" | "mesh" | "async"
    loss: Optional[float] = None
    grad_norm: Optional[float] = None
    model_decrease: Optional[float] = None     # f(w_t) − f(w_{t+1})
    uplink_delta: Optional[float] = None       # measured δ̂ this round
    k: Optional[int] = None                    # adaptive-k live k
    k_changed: bool = False                    # schedule moved this round
    saddle_escape: bool = False                # crossed below saddle_value
    rejected: Sequence[int] = ()               # aggregator-rejected workers
    attack: str = "none"
    alpha: float = 0.0
    wire_uplink_bits: Optional[int] = None     # exact bits this round
    wire_downlink_bits: Optional[int] = None
    center_bytes: Optional[int] = None         # center aggregation-path
                                               # bytes (O(m·k) sparse,
                                               # O(m·d) dense)
    agg_kernel: Optional[str] = None           # "sparse"|"fused"|"dense"
    # async-runtime fields (schema v3; None on synchronous runtimes):
    cohort_size: Optional[int] = None          # workers sampled this round
    n_arrivals: Optional[int] = None           # messages delivered this round
    queue_depth: Optional[int] = None          # still in flight after round
    participation: Optional[float] = None      # configured cohort fraction
    arrival_staleness: Optional[Sequence[int]] = None  # per-arrival ages
    # per-worker forensic fields (schema v4; every list is indexed by
    # worker id 0 … m−1 — None entries mean "did not participate/arrive
    # this round", a whole-field None means the runtime has no view):
    worker_bits: Optional[Sequence[int]] = None     # exact uplink bits paid
    worker_delta: Optional[Sequence] = None         # measured per-worker δ̂
    worker_keep: Optional[Sequence] = None          # aggregator keep weight
    worker_norms: Optional[Sequence] = None         # update norms
    worker_staleness: Optional[Sequence] = None     # arrival age (async)
    suspicion: Optional[Sequence[float]] = None     # EWMA suspicion ∈ [0, 1]
    byzantine_true: Optional[Sequence[int]] = None  # planted Byzantine ids

    def to_fields(self) -> dict:
        """Flatten to JSONL event fields (``None`` dropped, floats
        coerced so jnp scalars never leak into the JSON encoder)."""
        out = {"step": int(self.step), "runtime": self.runtime,
               "attack": self.attack, "alpha": float(self.alpha)}
        for key in ("loss", "grad_norm", "model_decrease", "uplink_delta"):
            v = getattr(self, key)
            if v is not None:
                out[key] = float(v)
        if self.k is not None:
            out["k"] = int(self.k)
        out["k_changed"] = bool(self.k_changed)
        out["saddle_escape"] = bool(self.saddle_escape)
        out["rejected"] = [int(i) for i in self.rejected]
        out["n_rejected"] = len(out["rejected"])
        if self.wire_uplink_bits is not None:
            out["wire_uplink_bits"] = int(self.wire_uplink_bits)
        if self.wire_downlink_bits is not None:
            out["wire_downlink_bits"] = int(self.wire_downlink_bits)
        if self.center_bytes is not None:
            out["center_bytes"] = int(self.center_bytes)
        if self.agg_kernel is not None:
            out["agg_kernel"] = str(self.agg_kernel)
        for key in ("cohort_size", "n_arrivals", "queue_depth"):
            v = getattr(self, key)
            if v is not None:
                out[key] = int(v)
        if self.participation is not None:
            out["participation"] = float(self.participation)
        if self.arrival_staleness is not None:
            out["arrival_staleness"] = [int(a) for a in self.arrival_staleness]
        if self.worker_bits is not None:
            out["worker_bits"] = [int(b) for b in self.worker_bits]
        for key in ("worker_delta", "worker_keep", "worker_norms"):
            v = getattr(self, key)
            if v is not None:
                out[key] = [None if x is None else float(x) for x in v]
        if self.worker_staleness is not None:
            out["worker_staleness"] = [None if a is None else int(a)
                                       for a in self.worker_staleness]
        if self.suspicion is not None:
            out["suspicion"] = [min(1.0, max(0.0, float(s)))
                                for s in self.suspicion]
        if self.byzantine_true is not None:
            out["byzantine_true"] = [int(i) for i in self.byzantine_true]
        return out


def rejected_from_keep(keep) -> list:
    """Worker indices the aggregator rejected, from its 0/1 keep mask
    (host-side; call on a concrete metrics value, never in a trace)."""
    return [i for i, kept in enumerate(keep) if not float(kept)]
