"""Event schema for the telemetry JSONL stream (schema-versioned).

Every line of ``events.jsonl`` is one JSON object.  The stream is
append-only and mergeable like the sweep store: concatenating two
streams yields a valid stream (events carry their own wall-clock
timestamps; no line references another line by position).

Base keys (every event):

* ``v``    — schema version (int, one of :data:`ACCEPTED_VERSIONS`;
  writers stamp :data:`SCHEMA_VERSION`)
* ``kind`` — one of :data:`KINDS`
* ``name`` — dotted event name (``"sweep.cell"``, ``"newton.round"``)
* ``ts``   — seconds since the process enabled telemetry (monotonic)
* ``wall`` — wall-clock unix seconds (for cross-process merge ordering)

Per-kind required keys (on top of the base):

* ``span``    — ``dur_s`` (float ≥ 0); optional ``args`` dict
* ``counter`` — ``value`` (number), the post-increment running total
* ``gauge``   — ``value`` (number)
* ``hist``    — ``value`` (number), one observation
* ``round``   — ``step`` (int ≥ 0); the flattened
  :class:`~repro.telemetry.RoundRecord` fields ride as optional keys
  (v2 adds ``center_bytes``, int ≥ 0, the center aggregation-path bytes,
  and ``agg_kernel``, one of ``"sparse"``/``"fused"``/``"dense"``; v3
  adds the async-runtime fields ``cohort_size``/``n_arrivals``/
  ``queue_depth`` (ints ≥ 0), ``participation`` (number), and
  ``arrival_staleness``, a list of ints ≥ 0 — per-arrival ages; v4 adds
  the per-worker forensic fields, every list indexed by worker id
  ``0 … m−1``: ``worker_bits`` (ints ≥ 0, exact uplink bits each worker
  paid this round), ``worker_delta`` (number-or-null, each worker's
  measured δ̂), ``worker_keep`` (number-or-null, the aggregator's keep
  weight — null when the worker did not participate/arrive),
  ``worker_norms`` (number-or-null, update norms), ``worker_staleness``
  (int ≥ 0 or null, arrival age), ``suspicion`` (numbers in [0, 1], the
  EWMA suspicion score), and ``byzantine_true`` (ints ≥ 0, the planted
  Byzantine worker ids the attack hook knows))
* ``wire``    — ``ledger_id`` (int), ``uplink`` (int ≥ 0),
  ``downlink`` (int ≥ 0), ``rounds`` (int ≥ 0): ONE ledger-record call,
  exact integer bits; v3 adds ``seq`` (int ≥ 0, the ledger generation's
  per-record sequence id) and ``pid`` (int ≥ 0, the emitting process)
* ``ledger``  — ``ledger_id``, ``uplink_bits``, ``downlink_bits``,
  ``total_bits``, ``rounds``: a ledger snapshot (end-of-run totals);
  the wire events from the same ledger generation — grouped
  ``(pid, ledger_id)`` — must sum to it exactly, and when the snapshot
  carries ``n_records`` (v3) their ``seq`` ids must cover exactly
  ``0 … n_records−1`` in ANY order (checked by
  ``python -m repro.telemetry validate --check-wire``)
* ``compile`` — ``event`` (the JAX monitoring event tail, e.g.
  ``backend_compile``), ``dur_s``; optional ``scope`` (the
  :func:`~repro.telemetry.compile_scope` label active during the
  compile) and ``trigger``/``shape_key`` on explicit re-trace events
* ``event``   — free-form (base keys only)

The validator is hand-rolled (no jsonschema dependency); the
:data:`EVENT_SCHEMA` dict is the same contract in JSON-Schema notation
for documentation and external tooling.
"""
from __future__ import annotations

from numbers import Number

#: version writers stamp on new events (4: per-worker forensic round
#: fields ``worker_bits``/``worker_delta``/``worker_keep``/
#: ``worker_norms``/``worker_staleness``/``suspicion``/
#: ``byzantine_true``)
SCHEMA_VERSION = 4
#: versions the validator accepts — each older version carries a strict
#: subset of the newer optional fields, so old streams stay valid forever
ACCEPTED_VERSIONS = (1, 2, 3, 4)

KINDS = ("event", "span", "counter", "gauge", "hist", "round", "wire",
         "ledger", "compile")

#: JSON-Schema rendering of the contract (documentation / external tools).
EVENT_SCHEMA = {
    "$schema": "http://json-schema.org/draft-07/schema#",
    "title": "repro.telemetry event",
    "type": "object",
    "required": ["v", "kind", "name", "ts", "wall"],
    "properties": {
        "v": {"enum": list(ACCEPTED_VERSIONS)},
        "kind": {"enum": list(KINDS)},
        "name": {"type": "string", "minLength": 1},
        "ts": {"type": "number", "minimum": 0},
        "wall": {"type": "number"},
        "dur_s": {"type": "number", "minimum": 0},
        "value": {"type": ["number", "integer"]},
        "step": {"type": "integer", "minimum": 0},
        "ledger_id": {"type": "integer", "minimum": 0},
        "uplink": {"type": "integer", "minimum": 0},
        "downlink": {"type": "integer", "minimum": 0},
        "rounds": {"type": "integer", "minimum": 0},
        "uplink_bits": {"type": "integer", "minimum": 0},
        "downlink_bits": {"type": "integer", "minimum": 0},
        "total_bits": {"type": "integer", "minimum": 0},
        "event": {"type": "string"},
        "args": {"type": "object"},
        "center_bytes": {"type": "integer", "minimum": 0},
        "agg_kernel": {"enum": ["sparse", "fused", "dense"]},
        "seq": {"type": "integer", "minimum": 0},
        "pid": {"type": "integer", "minimum": 0},
        "n_records": {"type": "integer", "minimum": 0},
        "cohort_size": {"type": "integer", "minimum": 0},
        "n_arrivals": {"type": "integer", "minimum": 0},
        "queue_depth": {"type": "integer", "minimum": 0},
        "participation": {"type": "number"},
        "arrival_staleness": {"type": "array",
                              "items": {"type": "integer", "minimum": 0}},
        "worker_bits": {"type": "array",
                        "items": {"type": "integer", "minimum": 0}},
        "worker_delta": {"type": "array",
                         "items": {"type": ["number", "null"]}},
        "worker_keep": {"type": "array",
                        "items": {"type": ["number", "null"]}},
        "worker_norms": {"type": "array",
                         "items": {"type": ["number", "null"]}},
        "worker_staleness": {"type": "array",
                             "items": {"type": ["integer", "null"],
                                       "minimum": 0}},
        "suspicion": {"type": "array",
                      "items": {"type": "number",
                                "minimum": 0, "maximum": 1}},
        "byzantine_true": {"type": "array",
                           "items": {"type": "integer", "minimum": 0}},
    },
    "allOf": [
        {"if": {"properties": {"kind": {"const": "span"}}},
         "then": {"required": ["dur_s"]}},
        {"if": {"properties": {"kind": {"enum": ["counter", "gauge", "hist"]}}},
         "then": {"required": ["value"]}},
        {"if": {"properties": {"kind": {"const": "round"}}},
         "then": {"required": ["step"]}},
        {"if": {"properties": {"kind": {"const": "wire"}}},
         "then": {"required": ["ledger_id", "uplink", "downlink", "rounds"]}},
        {"if": {"properties": {"kind": {"const": "ledger"}}},
         "then": {"required": ["ledger_id", "uplink_bits", "downlink_bits",
                               "total_bits", "rounds"]}},
        {"if": {"properties": {"kind": {"const": "compile"}}},
         "then": {"required": ["event", "dur_s"]}},
    ],
}

_REQUIRED_BY_KIND = {
    "span": ("dur_s",),
    "counter": ("value",),
    "gauge": ("value",),
    "hist": ("value",),
    "round": ("step",),
    "wire": ("ledger_id", "uplink", "downlink", "rounds"),
    "ledger": ("ledger_id", "uplink_bits", "downlink_bits",
               "total_bits", "rounds"),
    "compile": ("event", "dur_s"),
    "event": (),
}

_NONNEG_INTS = ("step", "ledger_id", "uplink", "downlink", "rounds",
                "uplink_bits", "downlink_bits", "total_bits",
                "center_bytes", "seq", "pid", "n_records",
                "cohort_size", "n_arrivals", "queue_depth")

_AGG_KERNELS = ("sparse", "fused", "dense")


def _is_number(v) -> bool:
    return isinstance(v, Number) and not isinstance(v, bool)


def _is_nonneg_int(v) -> bool:
    return isinstance(v, int) and not isinstance(v, bool) and v >= 0


#: v4 per-worker list fields → per-item predicate + description
_WORKER_LISTS = {
    "worker_bits": (_is_nonneg_int, "non-negative ints"),
    "worker_delta": (lambda v: v is None or _is_number(v),
                     "numbers or nulls"),
    "worker_keep": (lambda v: v is None or _is_number(v),
                    "numbers or nulls"),
    "worker_norms": (lambda v: v is None or _is_number(v),
                     "numbers or nulls"),
    "worker_staleness": (lambda v: v is None or _is_nonneg_int(v),
                         "non-negative ints or nulls"),
    "suspicion": (lambda v: _is_number(v) and 0 <= v <= 1,
                  "numbers in [0, 1]"),
    "byzantine_true": (_is_nonneg_int, "non-negative ints"),
}


def validate_event(obj) -> list:
    """Return a list of problem strings (empty ⇒ the event is valid)."""
    errors = []
    if not isinstance(obj, dict):
        return [f"event must be an object, got {type(obj).__name__}"]
    if obj.get("v") not in ACCEPTED_VERSIONS:
        errors.append(f"v must be one of {ACCEPTED_VERSIONS}, "
                      f"got {obj.get('v')!r}")
    kind = obj.get("kind")
    if kind not in KINDS:
        errors.append(f"kind must be one of {KINDS}, got {kind!r}")
    name = obj.get("name")
    if not isinstance(name, str) or not name:
        errors.append(f"name must be a non-empty string, got {name!r}")
    for key in ("ts", "wall"):
        if not isinstance(obj.get(key), Number) \
                or isinstance(obj.get(key), bool):
            errors.append(f"{key} must be a number, got {obj.get(key)!r}")
    for key in _REQUIRED_BY_KIND.get(kind, ()):
        if key not in obj:
            errors.append(f"kind={kind!r} requires key {key!r}")
    if "dur_s" in obj:
        if not isinstance(obj["dur_s"], Number) or isinstance(
                obj["dur_s"], bool) or obj["dur_s"] < 0:
            errors.append(f"dur_s must be a number ≥ 0, got {obj['dur_s']!r}")
    if "value" in obj:
        if not isinstance(obj["value"], Number) \
                or isinstance(obj["value"], bool):
            errors.append(f"value must be a number, got {obj['value']!r}")
    for key in _NONNEG_INTS:
        if key in obj and (not isinstance(obj[key], int)
                           or isinstance(obj[key], bool) or obj[key] < 0):
            errors.append(f"{key} must be a non-negative int, "
                          f"got {obj[key]!r}")
    if "args" in obj and not isinstance(obj["args"], dict):
        errors.append(f"args must be an object, got {type(obj['args'])}")
    if "agg_kernel" in obj and obj["agg_kernel"] not in _AGG_KERNELS:
        errors.append(f"agg_kernel must be one of {_AGG_KERNELS}, "
                      f"got {obj['agg_kernel']!r}")
    if "participation" in obj:
        if not isinstance(obj["participation"], Number) \
                or isinstance(obj["participation"], bool):
            errors.append(f"participation must be a number, "
                          f"got {obj['participation']!r}")
    if "arrival_staleness" in obj:
        ages = obj["arrival_staleness"]
        if not isinstance(ages, list) or any(
                not isinstance(a, int) or isinstance(a, bool) or a < 0
                for a in ages):
            errors.append("arrival_staleness must be a list of "
                          f"non-negative ints, got {ages!r}")
    for key, (ok, what) in _WORKER_LISTS.items():
        if key in obj:
            vals = obj[key]
            if not isinstance(vals, list) or not all(ok(v) for v in vals):
                errors.append(f"{key} must be a list of {what}, "
                              f"got {vals!r}")
    return errors


def validate_stream(lines) -> list:
    """Validate an iterable of JSONL lines; returns
    ``[(line_no, problem), …]`` (empty ⇒ the whole stream is valid).
    Blank lines are skipped; a truncated final line (a live writer) is
    reported so callers can choose to tolerate it."""
    import json

    problems = []
    for i, line in enumerate(lines, 1):
        line = line.strip()
        if not line:
            continue
        try:
            obj = json.loads(line)
        except json.JSONDecodeError as e:
            problems.append((i, f"not JSON: {e}"))
            continue
        for err in validate_event(obj):
            problems.append((i, err))
    return problems
