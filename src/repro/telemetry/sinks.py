"""Telemetry sinks: append-only JSONL events + a Chrome-trace timeline.

Two sinks, both host-side and flush-on-demand:

* :class:`JsonlSink` — one schema-versioned JSON object per line,
  appended (never rewritten), so sequential processes sharing a path
  interleave whole lines and streams merge by concatenation, like the
  sweep store's shard files.
* :class:`ChromeTraceSink` — accumulates Chrome Trace Event Format
  records and writes ``trace.json`` on flush: ``{"traceEvents": […]}``
  with ``ph: "X"`` complete events for spans, ``ph: "C"`` counter
  samples, and ``ph: "i"`` instants — the JSON flavour both
  ``chrome://tracing`` and Perfetto (https://ui.perfetto.dev) load
  directly.

Timestamps: spans carry microsecond ``ts``/``dur`` on the process-local
monotonic clock (Perfetto only needs self-consistency within one file);
JSONL events carry both the monotonic ``ts`` and unix ``wall`` seconds
so merged multi-process streams can still be ordered.
"""
from __future__ import annotations

import json
import os
import threading

from .schema import SCHEMA_VERSION


def _canonical(obj) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      default=str)


class JsonlSink:
    """Append-only JSONL event stream (one sink per path)."""

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        # touch so a zero-event run still leaves a valid (empty) stream
        with open(path, "a"):
            pass

    def emit(self, event: dict) -> None:
        line = _canonical(event) + "\n"
        with self._lock, open(self.path, "a") as f:
            f.write(line)

    def flush(self) -> None:  # appended per-event; nothing buffered
        pass


class ChromeTraceSink:
    """Chrome Trace Event Format accumulator → ``trace.json`` on flush.

    The file is rewritten whole on every flush (the format is one JSON
    document, not a log), so concurrent processes should use distinct
    paths — the CLI's ``--telemetry-dir`` does this per shard.
    """

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._pid = os.getpid()

    def _base(self, name: str, ts_s: float) -> dict:
        return {"name": name, "pid": self._pid,
                "tid": threading.get_ident() & 0xFFFF,
                "ts": round(ts_s * 1e6, 3)}

    def span(self, name: str, ts_s: float, dur_s: float,
             args: dict | None = None) -> None:
        ev = self._base(name, ts_s)
        ev.update(ph="X", dur=round(dur_s * 1e6, 3))
        if args:
            ev["args"] = {k: str(v) for k, v in args.items()}
        with self._lock:
            self._events.append(ev)

    def counter(self, name: str, ts_s: float, value) -> None:
        ev = self._base(name, ts_s)
        ev.update(ph="C", args={name.rpartition(".")[2] or name: value})
        with self._lock:
            self._events.append(ev)

    def instant(self, name: str, ts_s: float,
                args: dict | None = None) -> None:
        ev = self._base(name, ts_s)
        ev.update(ph="i", s="t")
        if args:
            ev["args"] = {k: str(v) for k, v in args.items()}
        with self._lock:
            self._events.append(ev)

    def flush(self) -> None:
        with self._lock:
            events = list(self._events)
        doc = {"traceEvents": events, "displayTimeUnit": "ms",
               "otherData": {"schema_version": SCHEMA_VERSION,
                             "producer": "repro.telemetry"}}
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        tmp = f"{self.path}.tmp.{self._pid}"
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, self.path)  # atomic: a reader never sees half a file
