import jax
import pytest

# Smoke tests must see the real (single-CPU) device topology — the 512-device
# XLA_FLAGS override lives ONLY inside launch/dryrun.py (see the brief).


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
