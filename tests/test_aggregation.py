"""Robust aggregation: norm-trim (the paper's rule) + baselines, with
hypothesis property tests on the invariants the Byzantine analysis needs."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import coordinate_median, norm_trim, norm_trim_tree, trimmed_mean


def test_norm_trim_drops_outliers():
    u = jnp.concatenate([jnp.ones((8, 5)), 1e6 * jnp.ones((2, 5))])
    agg, keep = norm_trim(u, beta=0.2)
    np.testing.assert_allclose(agg, jnp.ones(5))
    assert keep[-2:].sum() == 0


def test_norm_trim_keep_count():
    u = jnp.arange(40.0).reshape(10, 4)
    for beta, expected in [(0.1, 9), (0.3, 7), (0.5, 5)]:
        _, keep = norm_trim(u, beta)
        assert int(keep.sum()) == expected


@settings(max_examples=50, deadline=None)
@given(
    st.integers(min_value=4, max_value=12),  # m
    st.integers(min_value=1, max_value=6),   # d
    st.integers(min_value=0, max_value=10**6),
)
def test_norm_trim_bounded_by_kept_max(m, d, seed):
    """Post-trim, every surviving row's norm ≤ the (1−β)-quantile norm —
    the key lemma behind Theorem 2's attack bound."""
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.normal(size=(m, d)) * rng.exponential(5, size=(m, 1)))
    beta = 0.25
    agg, keep = norm_trim(u, beta)
    n_keep = max(1, int(round((1 - beta) * m)))
    norms = np.linalg.norm(np.asarray(u), axis=1)
    thresh = np.sort(norms)[n_keep - 1]
    kept_norms = norms[np.asarray(keep) > 0]
    assert (kept_norms <= thresh + 1e-6).all()
    # aggregate norm bounded by the threshold too (mean of vectors ≤ max norm)
    assert np.linalg.norm(np.asarray(agg)) <= thresh + 1e-6


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_norm_trim_permutation_invariant_aggregate(seed):
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.normal(size=(9, 7)))
    perm = rng.permutation(9)
    a1, _ = norm_trim(u, 0.3)
    a2, _ = norm_trim(u[perm], 0.3)
    np.testing.assert_allclose(a1, a2, atol=1e-5)


def test_norm_trim_tree_matches_flat():
    rng = np.random.default_rng(0)
    flat = jnp.asarray(rng.normal(size=(6, 10)))
    tree = {"a": flat[:, :4], "b": {"c": flat[:, 4:]}}
    agg_t, keep_t = norm_trim_tree(tree, 0.34)
    agg_f, keep_f = norm_trim(flat, 0.34)
    np.testing.assert_allclose(keep_t, keep_f)
    np.testing.assert_allclose(
        jnp.concatenate([agg_t["a"], agg_t["b"]["c"]]), agg_f, atol=1e-6
    )


def test_trimmed_mean_and_median_resist_outliers():
    u = jnp.concatenate([jnp.zeros((8, 3)), 1e9 * jnp.ones((2, 3))])
    assert float(jnp.abs(trimmed_mean(u, 0.2)).max()) == 0.0
    assert float(jnp.abs(coordinate_median(u)).max()) == 0.0


def test_mean_is_not_robust():
    u = jnp.concatenate([jnp.zeros((8, 3)), 1e9 * jnp.ones((2, 3))])
    assert float(jnp.abs(u.mean(0)).max()) > 1e8  # the contrast the paper draws


def test_krum_selects_inlier():
    from repro.core import krum
    import numpy as np
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    good = jnp.asarray(rng.normal(0, 0.1, size=(8, 6)) + 1.0)
    bad = jnp.asarray(rng.normal(50, 1.0, size=(2, 6)))
    u = jnp.concatenate([good, bad])
    sel = krum(u, n_byz=2)
    assert float(jnp.abs(sel - 1.0).max()) < 1.0  # picked a good worker
