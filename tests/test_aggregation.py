"""Robust aggregation: norm-trim (the paper's rule) + baselines.

The hypothesis property tests on the trimming invariants live in
test_properties.py behind its importorskip("hypothesis") guard, so this
module keeps running when hypothesis is absent."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coordinate_median, norm_trim, norm_trim_tree, trimmed_mean


def test_norm_trim_drops_outliers():
    u = jnp.concatenate([jnp.ones((8, 5)), 1e6 * jnp.ones((2, 5))])
    agg, keep = norm_trim(u, beta=0.2)
    np.testing.assert_allclose(agg, jnp.ones(5))
    assert keep[-2:].sum() == 0


def test_norm_trim_keep_count():
    u = jnp.arange(40.0).reshape(10, 4)
    for beta, expected in [(0.1, 9), (0.3, 7), (0.5, 5)]:
        _, keep = norm_trim(u, beta)
        assert int(keep.sum()) == expected


def test_norm_trim_tree_matches_flat():
    rng = np.random.default_rng(0)
    flat = jnp.asarray(rng.normal(size=(6, 10)))
    tree = {"a": flat[:, :4], "b": {"c": flat[:, 4:]}}
    agg_t, keep_t = norm_trim_tree(tree, 0.34)
    agg_f, keep_f = norm_trim(flat, 0.34)
    np.testing.assert_allclose(keep_t, keep_f)
    np.testing.assert_allclose(
        jnp.concatenate([agg_t["a"], agg_t["b"]["c"]]), agg_f, atol=1e-6
    )


def test_trimmed_mean_and_median_resist_outliers():
    u = jnp.concatenate([jnp.zeros((8, 3)), 1e9 * jnp.ones((2, 3))])
    assert float(jnp.abs(trimmed_mean(u, 0.2)).max()) == 0.0
    assert float(jnp.abs(coordinate_median(u)).max()) == 0.0


def test_mean_is_not_robust():
    u = jnp.concatenate([jnp.zeros((8, 3)), 1e9 * jnp.ones((2, 3))])
    assert float(jnp.abs(u.mean(0)).max()) > 1e8  # the contrast the paper draws


def test_krum_selects_inlier():
    from repro.core import krum
    import numpy as np
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    good = jnp.asarray(rng.normal(0, 0.1, size=(8, 6)) + 1.0)
    bad = jnp.asarray(rng.normal(50, 1.0, size=(2, 6)))
    u = jnp.concatenate([good, bad])
    sel = krum(u, n_byz=2)
    assert float(jnp.abs(sel - 1.0).max()) < 1.0  # picked a good worker
