"""repro.api facade: registry resolution, ExperimentSpec round-trip +
build-time validation, every registry aggregator running in BOTH runtimes
(mean-parity at α = 0 against the legacy hardcoded path), aggregator
resilience under the saddle/gaussian attacks at α = 0.2, and the
measured-δ feedback into the adaptive top-k schedule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    ExperimentSpec,
    SpecError,
    make_aggregator,
    make_attack,
    to_attack_config,
)
from repro.compression import AdaptiveTopK
from repro.core import DistributedCubicNewton, NewtonConfig
from repro.core.distributed import DistributedNewtonConfig, make_train_step

ALL_AGGREGATORS = ("mean", "norm_trim:0.25", "krum:2", "trimmed_mean:0.25",
                   "coordinate_median")


# ------------------------- registries --------------------------------------


def test_aggregator_registry_resolves_specs():
    for spec in ALL_AGGREGATORS:
        agg = make_aggregator(spec)
        assert agg.name == spec.partition(":")[0]
    assert make_aggregator("norm_trim:0.3").beta == pytest.approx(0.3)
    assert make_aggregator("krum:3").n_byz == 3
    # pass-through of an already-resolved instance
    agg = make_aggregator("mean")
    assert make_aggregator(agg) is agg


def test_aggregator_registry_rejects_bad_specs():
    with pytest.raises(SpecError, match="unknown aggregator"):
        make_aggregator("median_of_means")
    with pytest.raises(SpecError, match="β in \\(0, 1\\)"):
        make_aggregator("norm_trim:1.5")
    with pytest.raises(SpecError, match="trim fraction"):
        make_aggregator("trimmed_mean:0.7")
    with pytest.raises(SpecError, match="integer"):
        make_aggregator("krum:two")


def test_attack_registry_resolves_specs():
    atk = make_attack("gaussian:50.0", 0.2)
    assert atk.kind == "update" and atk.kwargs == {"sigma": 50.0}
    assert make_attack("negative", 0.2).kwargs == {"c": 0.9}  # default
    assert make_attack("saddle:7.5", 0.1).kwargs == {"scale": 7.5}
    assert make_attack("flip", 0.2).name == "flipped_label"  # alias
    assert make_attack("flipped_label", 0.2).kind == "label"
    assert make_attack("gaussian", 0.0).kind == "none"  # α = 0 disarms
    cfg = to_attack_config("gaussian:50.0", 0.2)
    assert cfg.name == "gaussian" and cfg.sigma == 50.0 and cfg.alpha == 0.2


def test_attack_registry_rejects_bad_specs():
    with pytest.raises(SpecError, match="unknown attack"):
        make_attack("dropout", 0.2)
    with pytest.raises(SpecError, match="no parameter"):
        make_attack("flipped_label:3", 0.2)
    with pytest.raises(SpecError, match="number"):
        make_attack("gaussian:big", 0.2)


def test_attack_hooks_corrupt_only_byzantine_rows():
    atk = make_attack("gaussian:100.0", 0.25)
    s = jnp.ones((8, 5))
    out = atk.update_hook(8)(jax.random.PRNGKey(0), s)
    np.testing.assert_array_equal(out[2:], s[2:])       # honest untouched
    assert float(jnp.abs(out[:2] - 1.0).max()) > 1.0    # byzantine moved


# ------------------------- ExperimentSpec serde ----------------------------


def test_spec_dict_roundtrip_exact():
    spec = ExperimentSpec(
        problem="w8a-robust", aggregator="norm_trim:0.25",
        attack="gaussian:50.0", alpha=0.2, compressor="topk:0.1",
        downlink_compressor="signnorm", error_feedback="ef21",
        exact_gradient=True, grad_compressor="topk:0.25",
        solver_iters=300, seed=7,
    )
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    with pytest.raises(SpecError, match="unknown ExperimentSpec fields"):
        ExperimentSpec.from_dict({**spec.to_dict(), "bogus_knob": 3})


# ------------------------- build-time validation ---------------------------


def test_validate_beta_leq_alpha_raises():
    spec = ExperimentSpec(problem="synthetic-logistic:400:10",
                          aggregator="norm_trim:0.2", attack="gaussian",
                          alpha=0.2)
    with pytest.raises(SpecError, match="β > α"):
        spec.validate()


def test_validate_unknown_specs_raise():
    base = ExperimentSpec(problem="synthetic-logistic:400:10")
    with pytest.raises(SpecError, match="unknown aggregator"):
        base.replace(aggregator="geometric_median").validate()
    with pytest.raises(SpecError, match="unknown attack"):
        base.replace(attack="bitflip").validate()
    with pytest.raises(SpecError, match="unknown compressor"):
        base.replace(compressor="gzip").validate()
    with pytest.raises(SpecError, match="unknown problem"):
        base.replace(problem="mnist").validate()


def test_validate_ef_without_compressor_raises():
    spec = ExperimentSpec(problem="synthetic-logistic:400:10",
                          error_feedback="ef21")
    with pytest.raises(SpecError, match="compressors are None"):
        spec.validate()
    # auto mode (None) quietly resolves instead of raising
    assert spec.replace(error_feedback=None).validate() \
        .resolved_error_feedback() == "none"
    assert spec.replace(error_feedback=None, compressor="topk:0.1") \
        .validate().resolved_error_feedback() == "ef21"


def test_validate_kernel_any_d_and_grad_round():
    base = ExperimentSpec(problem="synthetic-logistic:400:2000")
    # the old single-tile d ≤ 1408 rejection is GONE: the sharded launch
    # serves model-scale vectors, so the spec validates at any d
    base.replace(compressor="topk_kernel:0.1").validate()
    base.replace(compressor="topk_kernel:0.1",
                 downlink_compressor="topk_kernel:0.05").validate()
    with pytest.raises(SpecError, match="exact_gradient"):
        base.replace(grad_compressor="topk:0.1").validate()
    with pytest.raises(SpecError, match="label"):
        ExperimentSpec(runtime="mesh", problem="quadratic:8",
                       attack="flipped_label", alpha=0.2).validate()


def test_validate_fixed_cluster_size_for_paper_workloads():
    """Paper workloads pin m=20; a mismatched m_workers would make the
    resilience checks run against the wrong cluster size — reject it."""
    with pytest.raises(SpecError, match="m_workers=20"):
        ExperimentSpec(problem="a9a-robust", m_workers=10).validate()
    ExperimentSpec(problem="a9a-robust", m_workers=20).validate()


def test_make_problem_is_memoized():
    from repro.api import make_problem

    a = make_problem("synthetic-logistic:400:10", 4, seed=3)
    b = make_problem("synthetic-logistic:400:10", 4, seed=3)
    assert a is b  # sweeps share one materialization per (spec, m, seed)
    assert make_problem("synthetic-logistic:400:10", 4, seed=4) is not a


def test_validate_krum_and_trimmed_mean_strength():
    base = ExperimentSpec(problem="synthetic-logistic:400:10", m_workers=10,
                          attack="gaussian", alpha=0.2)
    with pytest.raises(SpecError, match="krum"):
        base.replace(aggregator="krum:1").validate()   # n_byz < α·m
    with pytest.raises(SpecError, match="trimmed_mean"):
        base.replace(aggregator="trimmed_mean:0.1").validate()
    base.replace(aggregator="krum:2").validate()
    base.replace(aggregator="trimmed_mean:0.25").validate()


# ------------------------- both runtimes, all aggregators ------------------


@pytest.fixture(scope="module")
def paper_spec():
    return ExperimentSpec(problem="synthetic-logistic:1200:12", m_workers=6)


@pytest.mark.parametrize("agg", ALL_AGGREGATORS)
def test_every_aggregator_runs_paper_runtime(paper_spec, agg):
    exp = paper_spec.replace(aggregator=agg).build()
    _, hist = exp.run(4)
    assert all(np.isfinite(hist["loss"]))
    assert hist["loss"][-1] < hist["loss"][0]


@pytest.mark.parametrize("agg", ALL_AGGREGATORS)
def test_every_aggregator_runs_mesh_runtime(agg):
    exp = ExperimentSpec(runtime="mesh", problem="quadratic:8", m_workers=6,
                         aggregator=agg, solver_iters=4).build()
    _, hist = exp.run(6)
    assert all(np.isfinite(hist["loss"]))
    assert hist["loss"][-1] < hist["loss"][0]


def test_mean_aggregator_parity_with_legacy_paper_runtime(paper_spec):
    """The registry "mean" must reproduce the legacy hardcoded β = 0 path
    bit-for-bit at α = 0 (identity-aggregator parity)."""
    exp = paper_spec.replace(aggregator="mean").build()
    w_new, h_new = exp.run(4)
    legacy = DistributedCubicNewton(
        exp.problem.loss_fn, NewtonConfig(M=10.0, eta=1.0, beta=0.0)
    )
    w_old, h_old = legacy.run(
        exp.problem.w0, exp.problem.X_workers, exp.problem.y_workers, 4
    )
    np.testing.assert_array_equal(np.asarray(w_new), np.asarray(w_old))
    assert h_new["loss"] == h_old["loss"]


def test_norm_trim_aggregator_parity_with_legacy_beta_field():
    """aggregator="norm_trim:β" ≡ the legacy beta-field path, mesh runtime
    (bit-identical params out of one jitted step)."""
    exp = ExperimentSpec(runtime="mesh", problem="quadratic:8", m_workers=4,
                         aggregator="norm_trim:0.25", solver_iters=3).build()
    legacy_cfg = DistributedNewtonConfig(M=10.0, beta=0.25, solver_iters=3)
    legacy_step = jax.jit(
        make_train_step(exp.problem.loss_fn, legacy_cfg, 4)
    )
    key = jax.random.PRNGKey(5)
    p_new, m_new = exp.step(exp.problem.w0, exp.problem.batch, key)
    p_old, m_old = legacy_step(exp.problem.w0, exp.problem.batch, key)
    for a, b in zip(jax.tree_util.tree_leaves(p_new),
                    jax.tree_util.tree_leaves(p_old)):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(m_new["kept"], m_old["kept"])


# ------------------------- resilience: escape the saddle -------------------


@pytest.mark.parametrize("agg", ["norm_trim:0.3", "krum:2",
                                 "trimmed_mean:0.2", "coordinate_median"])
@pytest.mark.parametrize("attack", ["saddle", "gaussian"])
def test_registry_aggregators_escape_saddle_under_attack(agg, attack):
    """Each robust registry rule escapes the strict saddle at α = 0.2
    under both the colluding saddle attack and gaussian noise."""
    spec = ExperimentSpec(
        problem="matrix-factor:10:2", m_workers=10, M=10.0,
        aggregator=agg, attack=attack, alpha=0.2, seed=0,
    )
    exp = spec.build()
    _, hist = exp.run(15)
    assert hist["loss"][-1] < 0.2 * exp.problem.saddle_value
    assert all(np.isfinite(hist["loss"]))


def test_mean_is_defeated_by_the_attacks_the_rules_survive():
    """The contrast: the non-robust baseline stays trapped near the
    saddle value under the colluding attack."""
    exp = ExperimentSpec(
        problem="matrix-factor:10:2", m_workers=10, M=10.0,
        aggregator="mean", attack="saddle:20.0", alpha=0.2, seed=0,
    ).build()
    _, hist = exp.run(15)
    assert hist["loss"][-1] > 0.2 * exp.problem.saddle_value


# ------------------------- model-scale topk_kernel through the facade ------


def test_topk_kernel_beyond_tile_limit_paper_runtime_bit_exact():
    """topk_kernel at d = 1500 > 1408 builds and runs through a full
    ExperimentSpec.build() round on the paper runtime, and the gridded
    kernel matches the XLA `topk` path BIT-exactly (same selected
    support ⇒ same EF21 states ⇒ same iterates and losses)."""
    base = ExperimentSpec(problem="synthetic-logistic:300:1500", m_workers=4,
                          solver_iters=5)
    exp_k = base.replace(compressor="topk_kernel:0.1").build()
    exp_x = base.replace(compressor="topk:0.1").build()
    w_k, h_k = exp_k.run(2)
    w_x, h_x = exp_x.run(2)
    np.testing.assert_array_equal(np.asarray(w_k), np.asarray(w_x))
    assert h_k["loss"] == h_x["loss"]
    assert h_k["uplink_bits"] == h_x["uplink_bits"]
    assert all(np.isfinite(h_k["loss"]))


def test_topk_kernel_beyond_tile_limit_mesh_runtime_bit_exact():
    """Same contract on the mesh runtime: a worker-stacked TreeChannel
    over the gridded launch, bit-identical to the XLA path."""
    base = ExperimentSpec(runtime="mesh", problem="quadratic:1500",
                          m_workers=4, solver_iters=2)
    exp_k = base.replace(compressor="topk_kernel:0.1").build()
    exp_x = base.replace(compressor="topk:0.1").build()
    _, h_k = exp_k.run(2)
    _, h_x = exp_x.run(2)
    assert h_k["loss"] == h_x["loss"]
    assert h_k["uplink_bits"] == h_x["uplink_bits"]
    assert all(np.isfinite(h_k["loss"]))


def test_topk_kernel_spec_64k_builds_and_runs():
    """The acceptance-bar spec: topk_kernel:0.1 at d = 65536 builds AND
    runs through a full ExperimentSpec.build() round (mesh runtime — the
    paper runtime's explicit d² Hessian is physically out of reach at
    this d), with bit-exact parity against the XLA `topk` compressor:
    same selected support ⇒ same losses, same wire bits."""
    spec = ExperimentSpec(problem="synthetic-logistic:64:65536",
                          m_workers=2, compressor="topk_kernel:0.1")
    spec.validate()                       # previously raised "single-tile"

    base = ExperimentSpec(runtime="mesh", problem="quadratic:65536",
                          m_workers=2, solver_iters=2)
    _, h_k = base.replace(compressor="topk_kernel:0.1").build().run(1)
    _, h_x = base.replace(compressor="topk:0.1").build().run(1)
    assert h_k["loss"] == h_x["loss"]
    assert h_k["uplink_bits"] == h_x["uplink_bits"]
    assert all(np.isfinite(h_k["loss"]))


# ------------------------- measured-δ feedback -----------------------------


def test_measured_delta_pins_k_trajectory():
    """δ-targeted control: measured δ̂ below target doubles k immediately
    (no patience window); at/above target the schedule holds — the exact
    k trajectory is pinned."""
    comp = AdaptiveTopK(100, 5, 80, delta_target=0.6)
    ks = []
    for delta in (0.2, 0.3, 0.5, 0.7, 0.9, 0.9):
        comp.schedule_update(grad_norm=1.0, measured_delta=delta)
        ks.append(comp.k)
    assert ks == [10, 20, 40, 40, 40, 40]
    # wire cost follows the live k; the δ guarantee stays the k_min floor
    assert comp.wire_bits(100) == 40 * (32 + 7)
    assert comp.delta_bound(100) == pytest.approx(0.05)


def test_measured_delta_pins_k_trajectory_gridded_kernel():
    """The d = 4096 mirror of the small-d pin above, over the GRIDDED
    kernel path: every δ-driven k move must re-trace the sharded launch
    (k is a static argument, so the payload shape — and parity with the
    XLA path — proves the fresh trace at each k)."""
    from repro.kernels.ref import topk_compress_ref

    d = 4096
    comp = AdaptiveTopK(d, 205, 3277, delta_target=0.6, use_kernel=True)
    assert comp.use_kernel
    x = jax.random.normal(jax.random.PRNGKey(3), (d,))
    ks = []
    for delta in (0.2, 0.3, 0.5, 0.7, 0.9, 0.9):
        comp.schedule_update(grad_norm=1.0, measured_delta=delta)
        ks.append(comp.k)
        v, i = comp.compress(x)
        assert v.shape == (comp.k,) and i.shape == (comp.k,)
        vr, ir = topk_compress_ref(x, comp.k)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
        np.testing.assert_array_equal(np.asarray(v), np.asarray(vr))
    assert ks == [410, 820, 1640, 1640, 1640, 1640]
    # wire cost follows the live k; the δ guarantee stays the k_min floor
    assert comp.wire_bits(d) == 1640 * (32 + 12)
    assert comp.delta_bound(d) == pytest.approx(205 / 4096)


def test_adaptive_topk_kernel_registry_spec():
    """adaptive_topk_kernel:<k_min>:<k_max> resolves to the kernel path
    with the same schedule bounds as adaptive_topk."""
    from repro.compression import make_compressor

    comp = make_compressor("adaptive_topk_kernel:0.05:0.5", 4096)
    assert isinstance(comp, AdaptiveTopK) and comp.use_kernel
    assert (comp.k_min, comp.k_max) == (205, 2048)
    plain = make_compressor("adaptive_topk:0.05:0.5", 4096)
    assert not plain.use_kernel
    assert comp.wire_bits(4096) == plain.wire_bits(4096)


def test_channel_surfaces_measured_delta_end_to_end(paper_spec):
    """The run history carries the uplink channel's per-round measured δ̂:
    exactly 1.0 on an identity wire, in (0, 1] and ≥ the k/d bound's
    energy floor under top-k."""
    exp = paper_spec.replace(aggregator="norm_trim:0.2").build()
    _, hist = exp.run(3)
    assert hist["uplink_delta"] == [1.0, 1.0, 1.0]  # full-precision wire
    exp_c = paper_spec.replace(aggregator="norm_trim:0.2",
                               compressor="topk:0.5").build()
    _, hist_c = exp_c.run(3)
    assert all(0.0 < d <= 1.0 + 1e-6 for d in hist_c["uplink_delta"])
    assert min(hist_c["uplink_delta"]) >= 0.5  # top-k keeps ≥ k/d energy


def test_adaptive_k_consumes_measured_delta(paper_spec):
    """An adaptive uplink whose target δ exceeds the initial k/d bound
    must grow k during a run (the measured-δ feedback loop closing)."""
    exp = paper_spec.replace(
        aggregator="norm_trim:0.2", compressor="adaptive_topk:0.1:1.0",
        error_feedback="none",
    ).build()
    comp = None
    _, hist = exp.run(6)
    comp = exp.algo.uplink.compressor
    comp.delta_target = 0.99  # force the δ-grow path on the next updates
    k0 = comp.k
    exp.algo._maybe_adapt(1.0, measured_delta=0.1)
    assert comp.k == min(2 * k0, comp.k_max)


# ------------------------- facade misc -------------------------------------


def test_experiment_bits_per_step_and_config_views():
    spec = ExperimentSpec(problem="synthetic-logistic:400:10", m_workers=4,
                          compressor="topk:0.5")
    exp = spec.build()
    bps = exp.bits_per_step()
    assert bps["uplink"] == 4 * exp.algo.uplink.compressor.wire_bits(10)
    ncfg = spec.to_newton_config()
    assert ncfg.compressor == "topk:0.5" and ncfg.error_feedback == "ef21"
    dcfg = spec.replace(runtime="mesh", problem="quadratic:8",
                        error_feedback="ef21").to_distributed_config()
    assert dcfg.error_feedback == "ef21"
