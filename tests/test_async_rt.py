"""repro.async_rt + the sweep executor pool.

The subsystem's acceptance criteria as tests: degenerate-config
bit-exactness with the synchronous runtime (BOTH center layouts),
deterministic event scheduling and arrival ordering, EF-state versioning
per arrival (dropped packets never advance the center's belief), exact
wire accounting under drops/duplicates, spec-axis validation and serde
hash-compatibility, the ``staleness`` sweep preset, pool-vs-serial
byte-identical merged stores with failure isolation, the
order-insensitive wire validator, and the schema-v3 async fields.
(Hypothesis cohort properties live in ``test_properties.py`` — the
unit-test modules stay hypothesis-free by repo convention.)
"""
import dataclasses
import json
import random

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec, SpecError
from repro.api.aggregators import make_aggregator
from repro.async_rt import (
    AsyncConfig,
    AsyncCubicNewton,
    EventScheduler,
    Message,
    MessageQueue,
    StalenessWeighted,
    cohort_size,
    sample_cohort,
)
from repro.sweep import ResultStore, merge, plan_grid, run_plan
from repro.sweep.grids import staleness_grid
from repro.telemetry import Telemetry, validate_event
from repro.telemetry.__main__ import check_wire_exactness

# tiny shared scenarios (jit caches stay warm across the module)
DENSE_KW = dict(problem="synthetic-logistic:80:6", m_workers=5, M=10.0,
                alpha=0.2, attack="gaussian", aggregator="norm_trim:0.4",
                compressor="topk:0.5", seed=0)      # EF21 auto ⇒ dense center
SPARSE_KW = dict(problem="synthetic-logistic:80:6", m_workers=5, M=10.0,
                 attack="none", aggregator="mean", compressor="topk:0.5",
                 error_feedback="none", seed=0)     # sparse center auto


# ------------------------------------------------------------ scheduler
def test_full_participation_cohort_is_every_worker():
    np.testing.assert_array_equal(sample_cohort(3, 9, 7, 1.0), np.arange(7))
    assert cohort_size(7, 1.0) == 7


def test_cohort_size_floors_at_one():
    assert cohort_size(10, 0.01) == 1       # a round is never a no-op
    assert cohort_size(5, 0.5) == 2         # round(2.5) banker's-rounds down


def test_cohort_deterministic_sorted_without_replacement():
    c = sample_cohort(0, 4, 10, 0.5)
    np.testing.assert_array_equal(c, sample_cohort(0, 4, 10, 0.5))
    ids = c.tolist()
    assert len(ids) == 5 and len(set(ids)) == 5 and ids == sorted(ids)
    assert all(0 <= i < 10 for i in ids)


def test_scheduler_fault_probability_extremes():
    s = EventScheduler(0, 4, staleness=0, drop=1.0, duplicate=1.0)
    assert s.lag(0, 0) == 0
    assert s.dropped(0, 0) and s.duplicated(0, 0)
    q = EventScheduler(0, 4, staleness=3)
    assert not q.dropped(5, 2) and not q.duplicated(5, 2)
    assert all(0 <= q.lag(t, i) <= 3 for t in range(6) for i in range(4))


def test_message_queue_drains_due_in_deterministic_order():
    q = MessageQueue()
    mk = lambda w, t, c=0: Message(worker=w, send_round=t, version=t,
                                   copy=c, payload=None)
    q.push(1, mk(2, 0))          # lagged send from round 0
    q.push(0, mk(1, 0))
    q.push(0, mk(1, 0, c=1))     # its duplicate
    q.push(2, mk(0, 1))          # not due yet
    assert q.depth == 4
    due = q.pop_due(0)
    assert [(m.worker, m.copy) for m in due] == [(1, 0), (1, 1)]
    assert q.depth == 2
    # round 1 drains the round-0 straggler BEFORE the round-1 send
    assert [(m.send_round, m.worker) for m in q.pop_due(1)] == [(0, 2)]
    assert [(m.send_round, m.worker) for m in q.pop_due(2)] == [(1, 0)]
    assert q.depth == 0


# ---------------------------------------------- staleness-weighted agg
def test_staleness_weighted_fresh_arrivals_match_base_rule():
    agg = make_aggregator("norm_trim:0.4")
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.normal(size=(6, 4)).astype(np.float32))
    base_a, base_k = agg(u)
    a, k = StalenessWeighted(agg, decay=0.5)(u, [0] * 6)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(base_k))
    np.testing.assert_allclose(np.asarray(a), np.asarray(base_a), rtol=1e-5)


def test_staleness_weighted_discounts_by_age():
    agg = make_aggregator("mean")
    u = jnp.asarray([[3.0, 0.0], [0.0, 3.0]], jnp.float32)
    a, k = StalenessWeighted(agg, decay=0.5)(u, [0, 1])
    expected = (u[0] + 0.5 * u[1]) / 1.5          # weights decay**age
    np.testing.assert_allclose(np.asarray(a), np.asarray(expected),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(k), np.ones(2))


def test_staleness_weighted_single_arrival_never_screened():
    agg = make_aggregator("norm_trim:0.4")
    u = jnp.asarray([[2.0, -1.0, 0.5]], jnp.float32)
    a, k = StalenessWeighted(agg, decay=1.0)(u, [4])
    np.testing.assert_array_equal(np.asarray(k), np.ones(1))
    np.testing.assert_allclose(np.asarray(a), np.asarray(u[0]), rtol=1e-6)


def test_staleness_weighted_rejects_bad_decay():
    agg = make_aggregator("mean")
    for decay in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError, match="decay"):
            StalenessWeighted(agg, decay=decay)
    with pytest.raises(ValueError, match="norm_guard"):
        StalenessWeighted(agg, norm_guard=0.0)


def test_staleness_norm_guard_rejects_lone_byzantine_packet():
    """Regression: under low participation a round where ONLY a Byzantine
    packet lands must not become the center update — the guard screens a
    lone arrival against the last screened aggregate's norm."""
    sw = StalenessWeighted(make_aggregator("norm_trim:0.4"), decay=1.0)
    rng = np.random.default_rng(1)
    # honest gradients share a direction (like real descent directions),
    # so a lone honest arrival has the same scale as the aggregate
    honest = jnp.asarray(
        (np.array([2.0, -1.0, 0.5, 1.0])
         + 0.1 * rng.normal(size=(5, 4))).astype(np.float32))
    sw(honest, [0] * 5)                       # screened round → reference

    bomb = jnp.asarray([[1e4, -1e4, 1e4, -1e4]], jnp.float32)
    a, k = sw(bomb, [0])
    np.testing.assert_array_equal(np.asarray(k), np.zeros(1))
    np.testing.assert_array_equal(np.asarray(a), np.zeros(4))

    # a rejected round must not move the reference: the bomb still
    # bounces on the next lone-arrival round
    _, k2 = sw(bomb, [1])
    np.testing.assert_array_equal(np.asarray(k2), np.zeros(1))

    # an honest-scale lone arrival still passes
    a3, k3 = sw(honest[:1], [0])
    np.testing.assert_array_equal(np.asarray(k3), np.ones(1))
    np.testing.assert_allclose(np.asarray(a3), np.asarray(honest[0]),
                               rtol=1e-6)


def test_async_low_participation_saddle_attack_stays_bounded():
    """End-to-end: participation so low that single-arrival rounds are
    common, with saddle-attack Byzantine workers — the guard keeps the
    trajectory finite and bounded."""
    spec = ExperimentSpec(
        runtime="async", participation=0.2, staleness=3, drop=0.3,
        problem="synthetic-logistic:80:6", m_workers=10, M=10.0,
        alpha=0.2, attack="saddle:50.0", aggregator="norm_trim:0.4",
        seed=0,
    )
    w, h = spec.build().run(12)
    # the scenario actually exercises the guard: some rounds deliver a
    # single packet, and with α=0.2 some of those are Byzantine
    assert any(n == 1 for n in h["n_arrivals"])
    assert bool(jnp.all(jnp.isfinite(w)))
    assert np.isfinite(h["loss"]).all()
    assert h["loss"][-1] < 10 * h["loss"][0] + 1.0


# --------------------------------------- degenerate-config bit-exactness
@pytest.fixture(scope="module")
def dense_pair():
    w_s, h_s = ExperimentSpec(**DENSE_KW).build().run(3)
    w_a, h_a = ExperimentSpec(runtime="async", **DENSE_KW).build().run(3)
    return (w_s, h_s), (w_a, h_a)


def test_degenerate_async_bit_exact_with_paper_dense_layout(dense_pair):
    (w_s, h_s), (w_a, h_a) = dense_pair
    assert bool(jnp.all(w_s == w_a))              # bit-exact iterates
    assert h_a["loss"] == h_s["loss"]             # exact float trajectories
    assert h_a["uplink_bits"] == h_s["uplink_bits"]
    assert h_a["downlink_bits"] == h_s["downlink_bits"]
    assert h_a["async_degenerate"] is True
    assert "async_degenerate" not in h_s


def test_degenerate_async_bit_exact_with_paper_sparse_layout():
    sync = ExperimentSpec(**SPARSE_KW).build()
    w_s, h_s = sync.run(3)
    deg = ExperimentSpec(runtime="async", **SPARSE_KW).build()
    w_a, h_a = deg.run(3)
    # the degenerate path delegates to the synchronous program, so the
    # sparse-domain center stays selected — and stays bit-exact
    assert sync.algo._use_sparse_center and deg.algo._use_sparse_center
    assert bool(jnp.all(w_s == w_a))
    assert h_a["loss"] == h_s["loss"]
    assert h_a["uplink_bits"] == h_s["uplink_bits"]
    assert h_a["async_degenerate"] is True


# ----------------------------------------------------- buffered rounds
@pytest.fixture(scope="module")
def buffered():
    exp = ExperimentSpec(runtime="async", participation=0.5, staleness=2,
                         **DENSE_KW).build()
    w, h = exp.run(4)
    return exp, w, h


def test_buffered_round_series_and_wire_accounting(buffered):
    exp, _, h = buffered
    assert h["async_degenerate"] is False
    assert h["cohort_size"] == [2] * 4            # round(0.5·5) = 2, every round
    assert len(h["loss"]) == 4 and h["rounds"] == 4
    total_sends = sum(h["cohort_size"])
    assert sum(h["n_arrivals"]) + h["queue_depth"][-1] == total_sends
    msg_bits = exp.algo.bits_per_step()["uplink"] // 5
    assert h["uplink_bits"] == msg_bits * total_sends   # billed at send time
    for mean_age in h["staleness_mean"]:
        assert mean_age is None or 0 <= mean_age <= 2
    assert all(d >= 0 for d in h["queue_depth"])


def test_buffered_run_is_reproducible(buffered):
    exp2 = ExperimentSpec(runtime="async", participation=0.5, staleness=2,
                          **DENSE_KW).build()
    _, h2 = exp2.run(4)
    _, _, h = buffered
    assert h2["loss"] == h["loss"]
    assert h2["n_arrivals"] == h["n_arrivals"]
    assert h2["uplink_bits"] == h["uplink_bits"]


def test_drop_everything_freezes_iterate_and_center_ef_state():
    kw = dict(problem="synthetic-logistic:80:6", m_workers=5, M=10.0,
              attack="none", aggregator="mean", compressor="topk:0.5",
              error_feedback="ef21", seed=0)
    exp = ExperimentSpec(runtime="async", drop=1.0, **kw).build()
    _, h = exp.run(3)
    assert h["n_arrivals"] == [0, 0, 0]
    assert h["downlink_bits"] == 0                # nothing ever broadcast
    assert len(set(h["loss"])) == 1               # w never moved
    msg_bits = exp.algo.bits_per_step()["uplink"] // 5
    assert h["uplink_bits"] == msg_bits * 5 * 3   # drops still pay the wire
    # EF versioning: no arrival ⇒ the center's per-worker channel state
    # never advances ⇒ the (deterministic) transmit is identical each
    # round.  If drops advanced the state this δ̂ series would move.
    assert len(set(h["uplink_delta"])) == 1


def test_duplicates_pay_twice_and_deliver_twice():
    kw = dict(problem="synthetic-logistic:80:6", m_workers=5, M=10.0,
              attack="none", aggregator="mean", compressor="topk:0.5",
              error_feedback="ef21", seed=0)
    exp = ExperimentSpec(runtime="async", duplicate=1.0, **kw).build()
    _, h = exp.run(3)
    msg_bits = exp.algo.bits_per_step()["uplink"] // 5
    assert h["uplink_bits"] == 2 * msg_bits * 5 * 3     # every packet twice
    assert h["n_arrivals"] == [10, 10, 10]              # delivered twice
    # EF-committed ONCE per send + equal-weight mean over the doubled
    # stack ⇒ the trajectory tracks the duplicate-free (degenerate) run
    _, h_ref = ExperimentSpec(runtime="async", **kw).build().run(3)
    np.testing.assert_allclose(h["loss"], h_ref["loss"], rtol=5e-3)


def test_sparse_center_demand_rejected_on_buffered_path():
    exp = ExperimentSpec(runtime="async", participation=0.5,
                         **SPARSE_KW).build()
    cfg = dataclasses.replace(exp.config, sparse_center=True)
    algo = AsyncCubicNewton(exp.problem.loss_fn, cfg,
                            exp.spec.to_attack_config(),
                            AsyncConfig(participation=0.5))
    with pytest.raises(ValueError, match="sparse_center"):
        algo.run(exp.problem.w0, exp.problem.X_workers,
                 exp.problem.y_workers, 1)


def test_sparse_capable_channel_falls_back_to_dense_when_buffered():
    exp = ExperimentSpec(runtime="async", participation=0.5,
                         **SPARSE_KW).build()
    _, h = exp.run(2)
    assert exp.algo._use_sparse_center is False   # auto resolved: dense
    assert h["async_degenerate"] is False
    assert len(h["loss"]) == 2


# ------------------------------------------------- spec axes and serde
def test_async_axes_validate_ranges():
    good = ExperimentSpec(runtime="async", participation=0.5, staleness=3,
                          drop=0.1, duplicate=0.1, staleness_decay=0.9)
    good.validate()
    bad = [dict(participation=0.0), dict(participation=1.5),
           dict(staleness=-1), dict(drop=1.5), dict(duplicate=-0.1),
           dict(staleness_decay=0.0)]
    for kw in bad:
        with pytest.raises(SpecError):
            ExperimentSpec(runtime="async", **kw).validate()


def test_non_default_axes_require_async_runtime():
    with pytest.raises(SpecError, match="runtime"):
        ExperimentSpec(participation=0.5).validate()
    with pytest.raises(SpecError, match="runtime"):
        ExperimentSpec(runtime="mesh", problem="quadratic:8",
                       staleness=2).validate()


def test_async_rejects_two_round_mode():
    with pytest.raises(SpecError, match="async"):
        ExperimentSpec(runtime="async", exact_gradient=True).validate()


def test_to_dict_omits_default_axes_and_round_trips():
    plain = ExperimentSpec(**DENSE_KW)
    d = plain.to_dict()
    for axis in ("participation", "staleness", "drop", "duplicate",
                 "staleness_decay"):
        assert axis not in d          # pre-async spec dicts stay byte-stable
    assert ExperimentSpec.from_dict(d) == plain
    stale = ExperimentSpec(runtime="async", staleness=3, drop=0.25)
    d2 = stale.to_dict()
    assert d2["staleness"] == 3 and d2["drop"] == 0.25
    assert "participation" not in d2  # still-default axes stay omitted
    assert ExperimentSpec.from_dict(d2) == stale
    assert ExperimentSpec.from_json(stale.to_json()) == stale


def test_staleness_grid_preset_plans_all_cells():
    axes, base = staleness_grid(n_steps=2)
    plan = plan_grid(axes, base)
    assert len(plan.entries) == 12 and not plan.skipped   # 3 × 2 × 2
    assert all(e.spec.runtime == "async" for e in plan.entries)
    degen = [e for e in plan.entries
             if e.spec.staleness == 0 and e.spec.participation == 1.0
             and e.spec.alpha == 0.0]
    assert len(degen) == 1            # the paper-runtime bit-exact anchor
    d = degen[0].spec.to_dict()
    assert "staleness" not in d and "participation" not in d


# ------------------------------------------------------- executor pool
POOL_AXES = {"aggregator": ["mean", "norm_trim"]}
POOL_BASE = {"problem": "synthetic-logistic:200:8", "m_workers": 10,
             "alpha": 0.2, "attack": "gaussian", "seed": 0, "n_steps": 2}


@pytest.fixture(scope="module")
def pool_stores(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("pool")
    plan = plan_grid(POOL_AXES, POOL_BASE)
    s_sum = run_plan(plan, ResultStore(str(tmp / "serial.jsonl")), jobs=1)
    p_sum = run_plan(plan, ResultStore(str(tmp / "pool.jsonl")), jobs=2)
    return tmp, plan, s_sum, p_sum


def test_pool_builds_every_cell(pool_stores):
    _, plan, s_sum, p_sum = pool_stores
    assert s_sum["built"] == p_sum["built"] == len(plan.entries) == 2
    assert s_sum["failed"] == p_sum["failed"] == 0


def test_pool_merge_byte_identical_to_serial(pool_stores):
    tmp, _, _, _ = pool_stores
    merge([str(tmp / "serial.jsonl")], str(tmp / "m_serial.jsonl"))
    merge([str(tmp / "pool.jsonl")], str(tmp / "m_pool.jsonl"))
    a = (tmp / "m_serial.jsonl").read_bytes()
    assert a and a == (tmp / "m_pool.jsonl").read_bytes()
    # volatile diagnostics present per-run, stripped by merge
    raw = [json.loads(ln) for ln
           in (tmp / "pool.jsonl").read_text().splitlines()]
    assert all("wall_time_s" in r and "worker_id" in r for r in raw)
    merged = [json.loads(ln) for ln
              in (tmp / "m_pool.jsonl").read_text().splitlines()]
    assert all("wall_time_s" not in r and "worker_id" not in r
               for r in merged)


def test_pool_failure_isolation_and_retry(tmp_path):
    plan = plan_grid(POOL_AXES, POOL_BASE)
    bad = plan.entries[0].hash
    store = ResultStore(str(tmp_path / "s.jsonl"))
    first = run_plan(plan, store, jobs=2, _inject_fail=frozenset({bad}))
    assert first["built"] == 1 and first["failed"] == 1
    rec = store.get(bad)
    assert rec["status"] == "failed" and "injected" in rec["error"]
    again = run_plan(plan, store, jobs=2, retry_failed=True)
    assert again == {"built": 1, "cached": 1, "failed": 0,
                     "shard": (0, 1), "total": 2}
    assert store.get(bad)["status"] == "ok"


# ------------------------------------------------ wire validator (v3)
def _wire(pid, lid, seq, up=8, down=0, rounds=0):
    return {"v": 3, "kind": "wire", "name": "ledger.record", "ts": 0.0,
            "wall": 0.0, "ledger_id": lid, "uplink": up, "downlink": down,
            "rounds": rounds, "seq": seq, "pid": pid}


def _snap(pid, lid, n, up, down=0, rounds=0):
    return {"v": 3, "kind": "ledger", "name": "ledger.snapshot", "ts": 0.0,
            "wall": 0.0, "ledger_id": lid, "uplink_bits": up,
            "downlink_bits": down,
            "total_bits": up + down, "rounds": rounds,
            "n_records": n, "pid": pid}


def test_wire_validator_is_order_insensitive():
    events = ([_wire(11, 0, s) for s in range(4)]
              + [_snap(11, 0, 4, up=32)]
              + [_wire(22, 0, s, up=4) for s in range(3)]   # pid-colliding id
              + [_snap(22, 0, 3, up=12)])
    for seed in range(5):
        shuffled = list(events)
        random.Random(seed).shuffle(shuffled)
        assert check_wire_exactness(shuffled) == []


def test_wire_validator_groups_generations_by_pid():
    # same ledger_id from two pool workers: a pid-blind validator would
    # pool their sums and fail both snapshots
    events = [_wire(1, 7, 0, up=10), _snap(1, 7, 1, up=10),
              _wire(2, 7, 0, up=99), _snap(2, 7, 1, up=99)]
    assert check_wire_exactness(events) == []
    assert any("sum(wire.uplink)" in p for p in check_wire_exactness(
        [_wire(1, 7, 0, up=10), _snap(1, 7, 1, up=11)]))


def test_wire_validator_detects_missing_and_duplicated_seqs():
    # sums agree (the lost record carried 0 bits) but seq 2 never arrived
    missing = [_wire(5, 0, 0), _wire(5, 0, 1), _wire(5, 0, 3, up=0),
               _snap(5, 0, 4, up=16)]
    assert any("missing seqs [2]" in p
               for p in check_wire_exactness(missing))
    duped = [_wire(5, 0, 0), _wire(5, 0, 1), _wire(5, 0, 1),
             _snap(5, 0, 2, up=16)]
    assert any("duplicated seqs [1]" in p
               for p in check_wire_exactness(duped))


def test_wire_validator_accepts_pre_v3_streams_sum_only():
    legacy = [{"v": 1, "kind": "wire", "name": "ledger.record", "ts": 0.0,
               "ledger_id": 3, "uplink": 6, "downlink": 2, "rounds": 1},
              {"v": 1, "kind": "ledger", "name": "ledger.snapshot",
               "ts": 0.0, "ledger_id": 3, "uplink_bits": 6,
               "downlink_bits": 2, "total_bits": 8, "rounds": 1}]
    assert check_wire_exactness(legacy) == []


# --------------------------------------------------------- schema v3
def test_schema_v3_async_round_fields():
    base = {"v": 3, "kind": "round", "name": "newton.round", "ts": 0.1,
            "wall": 1.0, "step": 0}
    good = {**base, "cohort_size": 3, "n_arrivals": 2, "queue_depth": 1,
            "participation": 0.5, "arrival_staleness": [0, 2]}
    assert validate_event(good) == []
    assert any("arrival_staleness" in p for p in
               validate_event({**base, "arrival_staleness": [0, -1]}))
    assert any("participation" in p for p in
               validate_event({**base, "participation": "half"}))
    assert any("cohort_size" in p for p in
               validate_event({**base, "cohort_size": -1}))
    assert validate_event(_wire(1234, 0, 0)) == []
    assert validate_event(_snap(1234, 0, 1, up=8)) == []


def test_async_run_emits_valid_rounds_histograms_and_exact_wire(
        tmp_path, monkeypatch):
    from repro.telemetry import core

    t = Telemetry()
    t.enable(str(tmp_path / "telemetry"))
    monkeypatch.setattr(core, "_GLOBAL", t)
    try:
        exp = ExperimentSpec(runtime="async", participation=0.5,
                             staleness=2, **DENSE_KW).build()
        _, hist = exp.run(3)
        t.flush()
        with open(str(tmp_path / "telemetry" / "events.jsonl")) as f:
            events = [json.loads(ln) for ln in f if ln.strip()]
        for ev in events:
            assert validate_event(ev) == [], ev
        assert check_wire_exactness(events) == []
        rounds = [e for e in events if e["kind"] == "round"]
        assert len(rounds) == 3
        for r in rounds:
            assert r["runtime"] == "async"
            assert r["cohort_size"] == 2 and r["participation"] == 0.5
            assert r["n_arrivals"] == len(r["arrival_staleness"])
            assert all(0 <= a <= 2 for a in r["arrival_staleness"])
            assert r["queue_depth"] >= 0
        assert t.histogram("async.queue_depth")["count"] == 3
        assert (t.histogram("async.staleness") or {"count": 0})["count"] \
            == sum(hist["n_arrivals"])
    finally:
        t.disable()
