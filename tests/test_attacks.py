import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LABEL_ATTACKS, UPDATE_ATTACKS, byzantine_mask


def test_mask_fraction():
    assert int(byzantine_mask(20, 0.2).sum()) == 4
    assert int(byzantine_mask(20, 0.0).sum()) == 0


def test_update_attacks_touch_only_byzantine():
    key = jax.random.PRNGKey(0)
    u = jnp.ones((10, 6))
    mask = byzantine_mask(10, 0.3)
    for name in ("gaussian", "negative", "saddle"):
        out = UPDATE_ATTACKS[name](key, u, mask)
        np.testing.assert_allclose(out[3:], u[3:])  # good workers untouched
        assert not np.allclose(out[:3], u[:3])


def test_negative_update_direction():
    key = jax.random.PRNGKey(0)
    u = jnp.ones((4, 3))
    out = UPDATE_ATTACKS["negative"](key, u, byzantine_mask(4, 0.5), c=0.9)
    np.testing.assert_allclose(out[0], -0.9 * u[0])


def test_label_attacks():
    key = jax.random.PRNGKey(0)
    y = jnp.ones((6, 20))
    mask = byzantine_mask(6, 0.34)
    flipped = LABEL_ATTACKS["flipped_label"](key, y, mask, num_classes=2)
    np.testing.assert_allclose(flipped[:2], 0.0)
    np.testing.assert_allclose(flipped[2:], 1.0)
    rnd = LABEL_ATTACKS["random_label"](key, y, mask, num_classes=2)
    np.testing.assert_allclose(rnd[2:], 1.0)
    assert 0.2 < float(rnd[:2].mean()) < 0.8  # actually randomized
