"""ByzantinePGD baseline [YCKB19]: converges, and needs many more
communication rounds than the cubic-Newton method (the Table-1 claim)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    AttackConfig,
    ByzantinePGD,
    DistributedCubicNewton,
    NewtonConfig,
    PGDConfig,
)
from repro.data import make_classification, shard_to_workers


def logistic_loss(w, X, y):
    z = X @ w
    yy = 2.0 * y - 1.0
    return jnp.mean(jnp.log1p(jnp.exp(-yy * z))) + 0.5e-3 * w @ w


@pytest.fixture(scope="module")
def data():
    X, y, _ = make_classification(jax.random.PRNGKey(3), 2000, 15)
    Xm, ym = shard_to_workers(X, y, 10)
    return Xm, ym


def test_pgd_converges(data):
    Xm, ym = data
    pgd = ByzantinePGD(logistic_loss, PGDConfig(lr=1.0, grad_th=1e-3))
    w, hist = pgd.run(jnp.zeros(15), Xm, ym, max_rounds=300, grad_tol=0.05)
    assert hist["grad_norm"][-1] <= 0.05 or hist["rounds"] == 300
    assert hist["loss"][-1] < hist["loss"][0]


def test_newton_uses_fewer_rounds(data):
    """The communication-efficiency claim (§6: 36× fewer rounds)."""
    Xm, ym = data
    tol = 0.05
    newton = DistributedCubicNewton(logistic_loss, NewtonConfig(M=10.0, beta=0.1))
    _, h_newton = newton.run(jnp.zeros(15), Xm, ym, 50, grad_tol=tol)
    pgd = ByzantinePGD(logistic_loss, PGDConfig(lr=1.0))
    _, h_pgd = pgd.run(jnp.zeros(15), Xm, ym, max_rounds=400, grad_tol=tol)
    assert h_newton["rounds"] < h_pgd["rounds"]
    assert h_newton["rounds"] * 3 <= h_pgd["rounds"]  # conservative 3× floor


def test_pgd_with_attack(data):
    Xm, ym = data
    pgd = ByzantinePGD(
        logistic_loss,
        PGDConfig(lr=1.0, trim_frac=0.3),
        AttackConfig(name="gaussian", alpha=0.2),
    )
    w, hist = pgd.run(jnp.zeros(15), Xm, ym, max_rounds=120, grad_tol=0.05)
    assert hist["loss"][-1] < hist["loss"][0]
