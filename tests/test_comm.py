"""repro.comm channel layer: exact ledger accounting, identity-channel
parity with the uncompressed step in BOTH runtimes, EF-state pytrees under
jit donation, compressed downlink/two-round-gradient/mesh-EF coverage, and
the adaptive-k schedule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import TreeChannel, VectorChannel, WireLedger
from repro.compression import AdaptiveTopK, make_compressor
from repro.core import AttackConfig, DistributedCubicNewton, NewtonConfig
from repro.core.distributed import (
    DistributedNewtonConfig,
    make_stateful_train_step,
    make_train_step,
)
from repro.data import make_classification, shard_to_workers


def logistic_loss(w, X, y):
    z = X @ w
    yy = 2.0 * y - 1.0
    return jnp.mean(jnp.log1p(jnp.exp(-yy * z))) + 1e-3 * w @ w


@pytest.fixture(scope="module")
def logistic_data():
    X, y, _ = make_classification(jax.random.PRNGKey(0), 1200, 20, margin=3.0)
    Xm, ym = shard_to_workers(X, y, 10)
    return Xm, ym


def _quad_setup(rng, m=4, n=32, din=8):
    wstar = jax.random.normal(rng, (din,))
    X = jax.random.normal(jax.random.fold_in(rng, 1), (m, n, din))
    Y = X @ wstar + 0.01 * jax.random.normal(jax.random.fold_in(rng, 2), (m, n))

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    params0 = {"w": jnp.zeros(din), "b": jnp.zeros(())}
    return loss_fn, params0, {"x": X, "y": Y}


# ------------------------- ledger ----------------------------------------


def test_wire_ledger_exact_beyond_float32():
    """The accumulator is a host-side Python int: totals far beyond the
    float32 mantissa (the seed's lossy metric) stay exact to the bit."""
    ledger = WireLedger()
    big = 32 * 405_000_000_000  # 405B params at fp32, > 2**43
    for _ in range(1000):
        ledger.record(uplink=big + 1, downlink=3)
    assert ledger.uplink_bits == 1000 * (big + 1)  # off-by-one survives
    assert float(np.float32(ledger.uplink_bits)) != ledger.uplink_bits
    assert ledger.downlink_bits == 3000
    assert ledger.total_bits == ledger.uplink_bits + 3000
    assert ledger.rounds == 1000
    snap = ledger.snapshot()
    assert snap["uplink_bits"] == ledger.uplink_bits
    ledger.reset()
    assert ledger.total_bits == 0 and ledger.rounds == 0


def test_ledger_blocked_kernel_payload_bits_exact():
    """The gridded topk_kernel's blocked payload layout must not change
    accounted wire cost: WireLedger uplink bits for a topk_kernel
    transmit equal the single-tile/XLA topk bits for the same (d, k),
    at small d (single-tile launch) and model-scale d (sharded launch)."""
    for d, m in ((1000, 4), (4096, 4), (65536, 2)):
        ch_k = VectorChannel("uplink", "topk_kernel:0.1", d, m)
        ch_x = VectorChannel("uplink", "topk:0.1", d, m)
        assert ch_k.bits_per_round() == ch_x.bits_per_round()
        led_k, led_x = WireLedger(), WireLedger()
        for _ in range(3):
            ch_k.record(led_k)
            ch_x.record(led_x)
        assert led_k.uplink_bits == led_x.uplink_bits
        assert isinstance(led_k.uplink_bits, int)
    # the accounted payload is what actually crosses the wire: k values
    # + k int32-indexed coordinates out of the kernel's blocked pack
    d = 4096
    ch_k = VectorChannel("uplink", "topk_kernel:0.1", d, 1)
    vals, idx = ch_k.compressor.compress(jax.random.normal(
        jax.random.PRNGKey(0), (d,)))
    k = ch_k.compressor.k
    assert vals.shape == (k,) and idx.shape == (k,)
    assert ch_k.bits_per_round() == k * (32 + 12)  # 12 index bits at 4096


def test_vector_channel_bits_per_round():
    up = VectorChannel("uplink", "topk:0.5", 10, 4)
    down = VectorChannel("downlink", None, 10, 1)
    assert up.bits_per_round() == 4 * make_compressor("topk:0.5", 10).wire_bits(10)
    assert down.bits_per_round() == 32 * 10  # broadcast counted once
    ledger = WireLedger()
    up.record(ledger)
    down.record(ledger, rounds=1)
    assert ledger.uplink_bits == up.bits_per_round()
    assert ledger.downlink_bits == down.bits_per_round()


# ------------------------- identity-channel parity ------------------------


def test_identity_channel_parity_paper_runtime(logistic_data):
    """compressor="none" (an Identity channel) must reproduce the
    uncompressed (channel-less wire) step — paper-faithful runtime."""
    Xm, ym = logistic_data
    w0 = jnp.zeros(20)
    plain = DistributedCubicNewton(logistic_loss, NewtonConfig(M=10.0, beta=0.1))
    ident = DistributedCubicNewton(
        logistic_loss, NewtonConfig(M=10.0, beta=0.1, compressor="none",
                                    downlink_compressor="none"))
    w_p, h_p = plain.run(w0, Xm, ym, 5)
    w_i, h_i = ident.run(w0, Xm, ym, 5)
    np.testing.assert_allclose(np.asarray(w_p), np.asarray(w_i),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(h_p["loss"], h_i["loss"], rtol=1e-6)
    # identity payload is full precision: ledgers must agree exactly
    assert h_p["uplink_bits"] == h_i["uplink_bits"]
    assert h_p["downlink_bits"] == h_i["downlink_bits"]


def test_identity_channel_parity_mesh_runtime(rng):
    """Same contract on the mesh step (bit-identical, not just allclose)."""
    loss_fn, params0, batch = _quad_setup(rng)
    cfg = DistributedNewtonConfig(M=10.0, beta=0.25, solver_iters=4)
    plain = jax.jit(make_train_step(loss_fn, cfg, 4))
    ident = jax.jit(make_train_step(loss_fn, cfg, 4, compressor="none"))
    key = jax.random.PRNGKey(3)
    p1, m1 = plain(params0, batch, key)
    p2, m2 = ident(params0, batch, key)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(m1["update_norms"], m2["update_norms"])


# ------------------------- EF state under jit donation --------------------


def test_newton_comm_state_roundtrips_through_donation(logistic_data):
    """The channel-state pytree survives donated jit buffers across steps
    (structure and shapes stable, old buffers safely invalidated)."""
    Xm, ym = logistic_data
    algo = DistributedCubicNewton(
        logistic_loss,
        NewtonConfig(M=10.0, beta=0.1, compressor="topk:0.3",
                     downlink_compressor="topk:0.3", exact_gradient=True,
                     grad_compressor="topk:0.3"),
    )
    w = jnp.zeros(20)
    algo._ensure_channels(20, 10)
    donated = jax.jit(algo._step_impl, donate_argnums=(2,))
    v = jnp.zeros_like(w)
    state = algo.init_comm_state()
    tdef0 = jax.tree_util.tree_structure(state)
    key = jax.random.PRNGKey(0)
    for _ in range(4):
        key, sub = jax.random.split(key)
        w, v, state, _ = donated(w, v, state, Xm, ym, sub)
    assert jax.tree_util.tree_structure(state) == tdef0
    assert state["uplink"].shape == (10, 20)
    assert state["downlink"].shape == (20,)
    assert state["grad"].shape == (10, 20)
    # EF21 memory is live (the tracker moved off its zero init)
    assert float(jnp.abs(state["uplink"]).sum()) > 0
    assert jnp.all(jnp.isfinite(w))


def test_mesh_comm_state_roundtrips_through_donation(rng):
    loss_fn, params0, batch = _quad_setup(rng)
    cfg = DistributedNewtonConfig(
        M=10.0, beta=0.25, solver_iters=4, compressor="topk:0.5",
        downlink_compressor="topk:0.5", error_feedback="ef21",
    )
    step, init_state = make_stateful_train_step(loss_fn, cfg, 4)
    jstep = jax.jit(step, donate_argnums=(3,))
    state = init_state(params0)
    tdef0 = jax.tree_util.tree_structure(state)
    params, key = params0, jax.random.PRNGKey(0)
    losses = []
    for _ in range(12):
        key, sub = jax.random.split(key)
        params, metrics, state = jstep(params, batch, sub, state)
        losses.append(float(metrics["loss"]))
    assert jax.tree_util.tree_structure(state) == tdef0
    assert state["uplink"]["w"].shape == (4, 8)   # (m, d) worker-stacked
    assert state["downlink"]["w"].shape == (8,)   # center-side memory
    assert losses[-1] < 0.5 * losses[0]
    assert all(np.isfinite(losses))


# ------------------------- downlink compression ---------------------------


def test_downlink_compressed_escapes_saddle_under_byzantine_attack():
    """The byzantine saddle smoke test with a compressed broadcast: the
    downlink channel (EF21 at the center) must not re-trap the iterate at
    the strict saddle the colluding workers pull toward."""
    from benchmarks.saddle_escape import factor_loss, make_problem

    key = jax.random.PRNGKey(0)
    d, r, m = 10, 2, 10
    X, _ = make_problem(key, d=d, r=r, m=m)
    y = jnp.zeros(X.shape[:2])
    w0 = 1e-3 * jax.random.normal(jax.random.fold_in(key, 2), (d * r,))
    saddle_val = float(factor_loss(jnp.zeros(d * r), X.reshape(-1, d), None))

    algo = DistributedCubicNewton(
        factor_loss,
        NewtonConfig(M=10.0, eta=1.0, beta=0.2 + 2.0 / m,
                     downlink_compressor="topk:0.5"),
        AttackConfig(name="saddle", alpha=0.2),
    )
    _, hist = algo.run(w0, X, y, 15)
    assert hist["loss"][-1] < 0.1 * saddle_val
    # the broadcast was actually compressed (fewer downlink than fp32 bits)
    assert hist["downlink_bits"] < hist["rounds"] * 32 * d * r


def test_mesh_downlink_compression_converges(rng):
    loss_fn, params0, batch = _quad_setup(rng)
    cfg = DistributedNewtonConfig(
        M=10.0, beta=0.25, solver_iters=4, downlink_compressor="topk:0.5",
    )
    step = jax.jit(make_train_step(loss_fn, cfg, 4))
    raw = make_train_step(loss_fn, cfg, 4)
    wb = raw.wire_bits(params0)
    assert wb["downlink"] < 32 * 9  # broadcast is compressed
    assert wb["uplink"] == 4 * 32 * 9  # uplink untouched
    params, key = params0, jax.random.PRNGKey(0)
    losses = []
    for _ in range(12):
        key, sub = jax.random.split(key)
        params, metrics = step(params, batch, sub)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < 0.5 * losses[0]


# ------------------------- compressed two-round gradients -----------------


def test_compressed_two_round_gradients(logistic_data):
    """Remark-5 mode with the gradient round on its own compressed channel
    (own EF21 state): converges, and the wire no longer pays full
    precision for ε_g = 0."""
    Xm, ym = logistic_data
    w0 = jnp.zeros(20)
    full = DistributedCubicNewton(
        logistic_loss, NewtonConfig(M=10.0, beta=0.1, exact_gradient=True))
    comp = DistributedCubicNewton(
        logistic_loss,
        NewtonConfig(M=10.0, beta=0.1, exact_gradient=True,
                     grad_compressor="topk:0.25"))
    _, h_full = full.run(w0, Xm, ym, 8)
    _, h_comp = comp.run(w0, Xm, ym, 8)
    assert h_comp["rounds"] == 16  # still two rounds per step
    assert h_comp["uplink_bits"] < h_full["uplink_bits"]
    assert h_comp["grad_norm"][-1] < 0.1
    # the gradient channel keeps its own EF21 memory, separate from uplink
    assert comp.grad_uplink is not comp.uplink
    assert comp.grad_uplink.feedback is not None


# ------------------------- adaptive top-k ---------------------------------


def test_adaptive_topk_registry_and_schedule():
    comp = make_compressor("adaptive_topk:0.1:0.5", 100)
    assert isinstance(comp, AdaptiveTopK)
    assert comp.k == 10 and comp.k_min == 10 and comp.k_max == 50
    assert comp.delta_bound(100) == pytest.approx(0.1)
    # plateau ⇒ grow toward k_max
    changed = [comp.schedule_update(grad_norm=1.0) for _ in range(comp.patience + 1)]
    assert any(changed) and comp.k == 20
    comp.schedule_update(grad_norm=1.0)  # window restarts after a change
    for _ in range(comp.patience + 1):
        comp.schedule_update(grad_norm=1.0)
    assert comp.k == 40
    # fast progress ⇒ shrink back toward k_min
    for gn in (1.0, 0.5, 0.2, 0.05, 0.01, 0.001, 1e-4, 1e-5):
        comp.schedule_update(grad_norm=gn)
    assert comp.k < 40
    # wire cost follows the live k; the δ guarantee stays the k_min floor
    assert comp.wire_bits(100) == comp.k * (32 + 7)
    assert comp.delta_bound(100) == pytest.approx(0.1)


def test_adaptive_topk_end_to_end(logistic_data):
    """Adaptive-k run converges; the ledger's cumulative series reflects
    the re-traced k changes exactly (strictly increasing, exact ints)."""
    Xm, ym = logistic_data
    algo = DistributedCubicNewton(
        logistic_loss,
        NewtonConfig(M=10.0, beta=0.1, compressor="adaptive_topk:0.1:1.0"))
    w, hist = algo.run(jnp.zeros(20), Xm, ym, 10)
    assert hist["grad_norm"][-1] < 0.5 * hist["grad_norm"][0]
    series = hist["bits_cumulative"]
    assert all(isinstance(b, int) for b in series)
    assert all(b2 > b1 for b1, b2 in zip(series, series[1:]))
    assert hist["total_bits"] == series[-1]
    k_now = algo.uplink.compressor.k
    assert algo.uplink.compressor.k_min <= k_now <= algo.uplink.compressor.k_max


# ------------------------- channel hygiene --------------------------------


def test_channels_resolved_once_not_per_trace(logistic_data):
    """Compressor/EF construction happens at channel build time, not in
    the traced step (the seed rebuilt them on every trace)."""
    Xm, ym = logistic_data
    algo = DistributedCubicNewton(
        logistic_loss, NewtonConfig(M=10.0, beta=0.1, compressor="topk:0.3"))
    algo._ensure_channels(20, 10)
    up = algo.uplink
    algo.step(jnp.zeros(20), Xm, ym, jax.random.PRNGKey(0))
    assert algo.uplink is up                       # same channel object
    assert algo.uplink.compressor is up.compressor  # same compressor
    # same dims ⇒ no rebuild on subsequent steps either
    algo.step(jnp.ones(20), Xm, ym, jax.random.PRNGKey(1))
    assert algo.uplink is up


def test_tree_channel_stateless_matches_stateful_none(rng):
    """error_feedback="none" stateful step ≡ the stateless step (trivial
    carry), so the two builders can't drift."""
    loss_fn, params0, batch = _quad_setup(rng)
    cfg = DistributedNewtonConfig(M=10.0, beta=0.25, solver_iters=3,
                                  compressor="topk:0.5")
    stateless = jax.jit(make_train_step(loss_fn, cfg, 4))
    step, init_state = make_stateful_train_step(loss_fn, cfg, 4)
    state = init_state(params0)
    assert jax.tree_util.tree_leaves(state) == []  # no EF ⇒ empty carry
    key = jax.random.PRNGKey(7)
    p1, m1 = stateless(params0, batch, key)
    p2, m2, state2 = jax.jit(step)(params0, batch, key, state)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(a, b)


# ------------------------- sparse receive path ----------------------------


def test_transmit_sparse_payload_matches_transmit(rng):
    """The payload-shaped receive is the same wire: densifying the
    (vals, idx) payloads reproduces transmit's reconstruction exactly,
    δ̂ agrees, and bits_per_round is untouched."""
    d, m = 600, 5
    ch = VectorChannel("uplink", "topk:0.1", d, m, error_feedback="none")
    assert ch.supports_sparse_receive
    x = jax.random.normal(rng, (m, d))
    state = ch.init_state()
    (vals, idx), _, delta_s = ch.transmit_sparse(x, state, measure=True)
    xhat, _, delta_d = ch.transmit(x, state, measure=True)
    assert idx.dtype == jnp.int32
    dense = jnp.zeros((m, d)).at[jnp.arange(m)[:, None], idx].set(vals)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(xhat))
    np.testing.assert_allclose(float(delta_s), float(delta_d), atol=1e-6)
    # wire accounting identical: the payload is what crosses, either way
    assert ch.bits_per_round() == \
        VectorChannel("uplink", "topk:0.1", d, m,
                      error_feedback="none").bits_per_round()


def test_transmit_sparse_single_sender(rng):
    """n_senders == 1 still hands back worker-stacked (1, k) payloads."""
    ch = VectorChannel("uplink", "topk:0.25", 40, 1, error_feedback="none")
    (vals, idx), _ = ch.transmit_sparse(jax.random.normal(rng, (40,)),
                                        ch.init_state())
    assert vals.shape == (1, 10) and idx.shape == (1, 10)


def test_supports_sparse_receive_gate():
    """The gate demands: uplink direction, a sparse (value, index)
    compressor, no EF state to densify against, no update attack."""
    ok = VectorChannel("uplink", "topk:0.1", 100, 4, error_feedback="none")
    assert ok.supports_sparse_receive
    down = VectorChannel("downlink", "topk:0.1", 100, 1,
                         error_feedback="none")
    assert not down.supports_sparse_receive
    ef = VectorChannel("uplink", "topk:0.1", 100, 4, error_feedback="ef21")
    assert not ef.supports_sparse_receive
    dense_comp = VectorChannel("uplink", "int8", 100, 4,
                               error_feedback="none")
    assert not dense_comp.supports_sparse_receive
    attacked = VectorChannel("uplink", "topk:0.1", 100, 4,
                             error_feedback="none",
                             attack_hook=lambda k, x: x)
    assert not attacked.supports_sparse_receive
    with pytest.raises(AssertionError, match="transmit_sparse"):
        ef.transmit_sparse(jnp.zeros((4, 100)), ef.init_state())


# ------------------------- sparse-domain center ---------------------------


def _sparse_center_cfg(**kw):
    base = dict(M=10.0, compressor="topk:0.2", error_feedback="none",
                aggregator="mean", solver_iters=50)
    base.update(kw)
    return NewtonConfig(**base)


@pytest.mark.parametrize("aggregator", ["mean", "norm_trim:0.25"])
def test_sparse_center_matches_dense_trajectory(aggregator, logistic_data):
    """ISSUE acceptance: the sparse-domain center is the SAME algorithm —
    sparse_center auto (on) vs forced dense agree along the whole
    trajectory, for the mean and norm-trim rules."""
    Xm, ym = logistic_data
    w0 = jnp.zeros(20)
    runs = {}
    for forced, label in ((None, "sparse"), (False, "dense")):
        algo = DistributedCubicNewton(
            logistic_loss,
            _sparse_center_cfg(aggregator=aggregator, sparse_center=forced))
        w, hist = algo.run(w0, Xm, ym, 4, key=jax.random.PRNGKey(3))
        runs[label] = (w, hist, algo)
    assert runs["sparse"][2]._use_sparse_center
    assert not runs["dense"][2]._use_sparse_center
    np.testing.assert_allclose(np.asarray(runs["sparse"][0]),
                               np.asarray(runs["dense"][0]), atol=1e-5)
    np.testing.assert_allclose(runs["sparse"][1]["loss"],
                               runs["dense"][1]["loss"], atol=1e-5)
    np.testing.assert_allclose(runs["sparse"][1]["uplink_delta"],
                               runs["dense"][1]["uplink_delta"], atol=1e-5)
    # identical wire: the receive-side representation is not the payload
    assert runs["sparse"][1]["total_bits"] == runs["dense"][1]["total_bits"]


def test_sparse_center_auto_gates_off():
    """Auto mode must fall back to dense whenever any gate condition
    fails — EF, a non-sparse compressor, an update attack, or an
    aggregator without a sparse path."""
    for cfg, attack in [
        (_sparse_center_cfg(error_feedback="ef21"), AttackConfig()),
        (_sparse_center_cfg(compressor="int8"), AttackConfig()),
        (_sparse_center_cfg(compressor=None), AttackConfig()),
        (_sparse_center_cfg(aggregator="krum:1"), AttackConfig()),
        (_sparse_center_cfg(), AttackConfig(name="gaussian", alpha=0.25)),
    ]:
        algo = DistributedCubicNewton(logistic_loss, cfg, attack)
        algo._ensure_channels(20, 10)
        assert not algo._use_sparse_center, (cfg, attack)
        assert algo._agg_kernel_label() == "dense"


def test_sparse_center_demand_raises_when_unsupported():
    algo = DistributedCubicNewton(
        logistic_loss,
        _sparse_center_cfg(error_feedback="ef21", sparse_center=True))
    with pytest.raises(ValueError, match="sparse_center=True"):
        algo._ensure_channels(20, 10)


def test_center_bytes_per_round_and_label():
    """center_bytes: O(m·k) + the (d,) aggregate sparse, O(m·d) dense."""
    algo = DistributedCubicNewton(logistic_loss, _sparse_center_cfg())
    algo._ensure_channels(20, 10)
    k = algo.uplink.compressor.k
    assert algo._use_sparse_center
    assert algo._agg_kernel_label() == "sparse"
    assert algo.center_bytes_per_round() == 10 * k * 8 + 4 * 20
    dense = DistributedCubicNewton(
        logistic_loss, _sparse_center_cfg(sparse_center=False))
    dense._ensure_channels(20, 10)
    assert dense.center_bytes_per_round() == 10 * 20 * 4 + 4 * 20
    fused = DistributedCubicNewton(
        logistic_loss, NewtonConfig(aggregator="krum_kernel:2"))
    fused._ensure_channels(20, 10)
    assert fused._agg_kernel_label() == "fused"


def _center_avals(fn, *args):
    """Every intermediate aval a traced center function materializes."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    avals = []
    for eqn in jaxpr.jaxpr.eqns:
        avals.extend(v.aval for v in eqn.outvars)
    return avals


def test_sparse_center_never_materializes_m_by_d():
    """ISSUE acceptance shape probe: tracing the receive side — wire
    payloads in, aggregate out — shows NO intermediate of m·d elements
    (the dense worker matrix), for mean and norm-trim, at scatter and
    gridded scale.  The dense center, traced the same way, DOES."""
    from repro.api.aggregators import make_aggregator

    m, k = 6, 32
    for d in (2048, 16384):        # scatter path, gridded kernel path
        vals = jnp.ones((m, k))
        idx = jnp.tile(jnp.arange(k, dtype=jnp.int32), (m, 1))
        for spec in ("mean", "norm_trim:0.25"):
            agg = make_aggregator(spec)
            avals = _center_avals(
                lambda pv, pidx: agg.sparse(pv, pidx, d), vals, idx)
            big = [a for a in avals
                   if getattr(a, "size", 0) >= m * d]
            assert not big, (spec, d, big)
    # contrast: the XLA center path this replaces — scatter the payloads
    # to dense, then aggregate — DOES materialize the (m, d) matrix
    dense_agg = make_aggregator("mean")

    def dense_center(pv, pidx):
        dense = jnp.zeros((m, 16384)).at[
            jnp.arange(m)[:, None], pidx].set(pv)
        return dense_agg(dense)

    avals = _center_avals(dense_center, jnp.ones((m, k)),
                          jnp.tile(jnp.arange(k, dtype=jnp.int32), (m, 1)))
    assert any(getattr(a, "size", 0) >= m * 16384 for a in avals)
