"""δ-approximate compression subsystem: contraction guarantees, wire-bit
accounting, error feedback on the quadratic-with-saddle problem, and
end-to-end parity/convergence of the compressed mesh train step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression import (
    EF21,
    ErrorFeedback,
    Identity,
    TopK,
    TreeCompressor,
    index_bits,
    make_compressor,
    make_error_feedback,
)
from repro.core import DistributedCubicNewton, NewtonConfig
from repro.core.distributed import (
    DistributedNewtonConfig,
    make_train_step,
)

SPECS = ["topk:0.1", "topk:0.5", "signnorm", "int8", "int8:32"]


# ------------------------- δ-contraction ----------------------------------


@pytest.mark.parametrize("spec", SPECS)
@pytest.mark.parametrize("d", [7, 123, 300])
def test_delta_contraction(spec, d, rng):
    """Definition 2: ‖x − C(x)‖² ≤ (1 − δ)‖x‖² at the measured δ, and the
    measured δ respects the compressor's guaranteed bound."""
    x = jax.random.normal(rng, (d,)) * jnp.exp(
        jax.random.normal(jax.random.fold_in(rng, 1), (d,))
    )
    comp = make_compressor(spec, d)
    r = comp.roundtrip(x)
    sq = float(jnp.sum(x * x))
    err = float(jnp.sum((x - r) ** 2))
    delta = float(comp.delta(x))
    assert err <= (1.0 - delta) * sq + 1e-4 * sq  # measured δ is exact
    assert delta >= comp.delta_bound(d) - 1e-6    # and above the guarantee


def test_randk_delta_in_expectation(rng):
    d, k = 200, 20
    comp = make_compressor("randk:0.1", d)
    x = jax.random.normal(rng, (d,))
    deltas = jnp.stack(
        [comp.delta(x, key=jax.random.fold_in(rng, i)) for i in range(300)]
    )
    assert abs(float(deltas.mean()) - k / d) < 0.02


def test_topk_lossless_at_full_k(rng):
    x = jax.random.normal(rng, (64,))
    comp = make_compressor("topk:1.0", 64)
    assert comp.k == 64
    assert bool(jnp.all(comp.roundtrip(x) == x))


# ------------------------- wire accounting --------------------------------


def test_wire_bits_accounting():
    d = 300
    assert Identity().wire_bits(d) == 32 * d
    assert make_compressor("signnorm", d).wire_bits(d) == d + 32
    topk = make_compressor("topk:0.1", d)
    assert topk.wire_bits(d) == 30 * (32 + index_bits(d))
    assert index_bits(d) == 9  # 2^9 = 512 ≥ 300
    int8 = make_compressor("int8", d)
    assert int8.wire_bits(d) == d * 8 + 3 * 32  # ⌈300/128⌉ = 3 blocks
    # every compressor beats full precision
    for spec in SPECS:
        assert make_compressor(spec, d).wire_bits(d) < 32 * d


def test_newton_run_accumulates_wire_bits(rng):
    from repro.data import make_classification, shard_to_workers
    from benchmarks.problems import logistic_loss

    X, y, _ = make_classification(rng, 400, 10)
    Xm, ym = shard_to_workers(X, y, 4)
    algo = DistributedCubicNewton(
        logistic_loss, NewtonConfig(M=10.0, beta=0.0, compressor="topk:0.5")
    )
    _, hist = algo.run(jnp.zeros(10), Xm, ym, 3)
    per_step = algo.bits_per_step()
    assert per_step["uplink"] == 4 * 5 * (32 + index_bits(10))
    assert per_step["downlink"] == 32 * 10  # uncompressed fp32 broadcast
    assert hist["uplink_bits"] == 3 * per_step["uplink"]
    assert hist["downlink_bits"] == 3 * per_step["downlink"]
    assert hist["total_bits"] == hist["uplink_bits"] + hist["downlink_bits"]
    assert hist["bits_cumulative"][-1] == hist["total_bits"]


# ------------------------- error feedback ---------------------------------


def test_feedback_lossless_passthrough(rng):
    """Both EF schemes are exact when the compressor is lossless."""
    x = jax.random.normal(rng, (32,))
    for wrap in (ErrorFeedback, EF21):
        ef = wrap(TopK(32), damping=0.75)
        e = ef.init(32)
        for _ in range(3):
            xhat, e = ef.apply(x, e)
            np.testing.assert_allclose(xhat, x, atol=1e-6)


def test_make_error_feedback_variants():
    base = TopK(4)
    assert make_error_feedback("none", base) is None
    assert isinstance(make_error_feedback("ef", base), ErrorFeedback)
    assert isinstance(make_error_feedback("ef21", base), EF21)
    with pytest.raises(ValueError):
        make_error_feedback("bogus", base)


def test_error_feedback_escapes_saddle():
    """Compressed cubic Newton still escapes the strict saddle of the
    low-rank factorization problem (the quadratic-with-saddle workload of
    benchmarks.saddle_escape) — the EF convergence smoke test."""
    from benchmarks.saddle_escape import factor_loss, make_problem

    key = jax.random.PRNGKey(0)
    d, r, m = 10, 2, 10
    X, _ = make_problem(key, d=d, r=r, m=m)
    y = jnp.zeros(X.shape[:2])
    w0 = 1e-3 * jax.random.normal(jax.random.fold_in(key, 2), (d * r,))
    saddle_val = float(factor_loss(jnp.zeros(d * r), X.reshape(-1, d), None))

    algo = DistributedCubicNewton(
        factor_loss,
        NewtonConfig(M=10.0, eta=1.0, beta=0.1, compressor="topk:0.25"),
    )
    _, hist = algo.run(w0, X, y, 15)
    assert hist["loss"][-1] < 0.1 * saddle_val
    # without any feedback the same budget stalls closer to the saddle
    algo_nofb = DistributedCubicNewton(
        factor_loss,
        NewtonConfig(
            M=10.0, eta=1.0, beta=0.1, compressor="topk:0.25",
            error_feedback="none",
        ),
    )
    _, hist_nofb = algo_nofb.run(w0, X, y, 15)
    assert hist["loss"][-1] < hist_nofb.get("loss")[-1] + 1e-6


# ------------------------- tree compressor --------------------------------


def test_tree_compressor_shapes_dtypes(rng):
    tc = TreeCompressor("topk:0.5")
    tree = {
        "w": jax.random.normal(rng, (4, 6, 3), jnp.float32),
        "b": jax.random.normal(jax.random.fold_in(rng, 1), (4, 5), jnp.bfloat16),
    }
    out = tc.roundtrip_worker_tree(tree, rng, 4)
    assert jax.tree_util.tree_structure(out) == jax.tree_util.tree_structure(tree)
    for a, b in zip(jax.tree_util.tree_leaves(out), jax.tree_util.tree_leaves(tree)):
        assert a.shape == b.shape and a.dtype == b.dtype
    # per-worker bits: leaves of size 18 and 5 at ratio 0.5 → k = 9, 2
    assert tc.wire_bits_tree(tree, 4) == 9 * (32 + index_bits(18)) + 2 * (
        32 + index_bits(5)
    )
    assert 0 < tc.delta_bound_tree(tree, 4) <= 1.0


# ------------------------- mesh train step --------------------------------


def _quad_setup(rng, m=4, n=32, din=8):
    wstar = jax.random.normal(rng, (din,))
    X = jax.random.normal(jax.random.fold_in(rng, 1), (m, n, din))
    Y = X @ wstar + 0.01 * jax.random.normal(jax.random.fold_in(rng, 2), (m, n))

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    params0 = {"w": jnp.zeros(din), "b": jnp.zeros(())}
    return loss_fn, params0, {"x": X, "y": Y}


def test_train_step_compression_parity_at_full_k(rng):
    """make_train_step(compressor=topk) at k = d is bit-identical to the
    uncompressed step — the end-to-end parity contract."""
    loss_fn, params0, batch = _quad_setup(rng)
    cfg = DistributedNewtonConfig(M=10.0, beta=0.25, solver_iters=4)
    plain = jax.jit(make_train_step(loss_fn, cfg, 4))
    full = jax.jit(make_train_step(loss_fn, cfg, 4, compressor="topk:1.0"))
    key = jax.random.PRNGKey(3)
    p1, m1 = plain(params0, batch, key)
    p2, m2 = full(params0, batch, key)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(m1["update_norms"], m2["update_norms"])


def test_train_step_compressed_converges_and_counts_bits(rng):
    loss_fn, params0, batch = _quad_setup(rng)
    cfg = DistributedNewtonConfig(
        M=10.0, beta=0.25, solver_iters=4, compressor="topk:0.5"
    )
    step = jax.jit(make_train_step(loss_fn, cfg, 4))
    params, key = params0, jax.random.PRNGKey(0)
    losses = []
    for _ in range(12):
        key, sub = jax.random.split(key)
        params, metrics = step(params, batch, sub)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < 0.5 * losses[0]
    assert all(np.isfinite(losses))
    # d = 9 (w:8 + b:1) at ratio 0.5 → k = 4 on w, 1 on b; exact static
    # ints come from the channels (step.wire_bits), never a traced metric
    payload = 4 * (32 + index_bits(8)) + 1 * (32 + index_bits(1))
    raw = make_train_step(loss_fn, cfg, 4)
    assert raw.wire_bits(params0) == {"uplink": 4 * payload,
                                      "downlink": 32 * 9}
    plain = make_train_step(loss_fn, DistributedNewtonConfig(), 4)
    assert plain.wire_bits(params0) == {"uplink": 4 * 32 * 9,
                                        "downlink": 32 * 9}
    # two_round adds the full-precision gradient round (m uplink payloads
    # + the averaged-gradient broadcast)
    two = make_train_step(loss_fn, DistributedNewtonConfig(two_round=True), 4)
    assert two.wire_bits(params0) == {"uplink": 2 * 4 * 32 * 9,
                                      "downlink": 2 * 32 * 9}


def test_train_step_compressed_trims_attacker(rng):
    loss_fn, params0, batch = _quad_setup(rng)
    cfg = DistributedNewtonConfig(
        M=10.0, beta=0.25, solver_iters=2, compressor="signnorm"
    )
    step = jax.jit(
        make_train_step(loss_fn, cfg, 4, attack_name="gaussian", attack_alpha=0.25)
    )
    _, metrics = step(params0, batch, jax.random.PRNGKey(0))
    assert float(metrics["kept"][0]) == 0.0  # Byzantine worker 0 trimmed
