"""Cubic sub-problem solvers: exact oracle vs Algorithm 2 vs HVP variant,
plus the Lemma-4 optimality conditions the paper's analysis leans on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    cubic_model_value,
    cubic_residual,
    solve_cubic_exact,
    solve_cubic_gd,
    solve_cubic_hvp,
)


def _problem(seed, d=24, scale=1.0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    A = jax.random.normal(k1, (d, d)) * scale
    H = (A + A.T) / 2  # symmetric, indefinite
    g = jax.random.normal(k2, (d,)) * scale
    return g, H


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("M,gamma", [(10.0, 1.0), (20.0, 0.5), (5.0, 2.0)])
def test_exact_matches_gd(seed, M, gamma):
    g, H = _problem(seed)
    s_ex = solve_cubic_exact(g, H, M, gamma)
    s_gd = solve_cubic_gd(g, H, M, gamma, tol=1e-9, max_iters=50000)
    np.testing.assert_allclose(s_ex, s_gd, atol=2e-3, rtol=1e-2)


@pytest.mark.parametrize("seed", [0, 5])
def test_first_order_condition(seed):
    """Lemma 4 Eq. (16): g + γHs + (Mγ²/2)‖s‖s = 0 at the solution."""
    g, H = _problem(seed)
    s = solve_cubic_exact(g, H)
    assert float(cubic_residual(s, g, H)) < 1e-4


@pytest.mark.parametrize("seed", [0, 7])
def test_second_order_condition(seed):
    """Lemma 4 Eq. (17): γH + (Mγ²/2)‖s‖ I ⪰ 0."""
    g, H = _problem(seed)
    M, gamma = 10.0, 1.0
    s = solve_cubic_exact(g, H, M, gamma)
    lam_min = float(jnp.linalg.eigvalsh(H)[0])
    assert gamma * lam_min + 0.5 * M * gamma**2 * float(jnp.linalg.norm(s)) >= -1e-3


@pytest.mark.parametrize("seed", [0, 3])
def test_descent_value(seed):
    """Lemma 4 Eq. (18) implies m(s*) ≤ −(M/12)γ²‖s‖³ < 0 = m(0)."""
    g, H = _problem(seed)
    s = solve_cubic_exact(g, H)
    val = float(cubic_model_value(s, g, H))
    assert val < 0.0


def test_negative_curvature_escape():
    """Near a strict saddle (tiny g, λ_min(H) < 0) the solution is O(|λ_min|)
    along the negative-curvature direction — the saddle-escape mechanism.
    (g exactly 0 is the classic 'hard case'; any perturbation resolves it,
    which is also how the iterative solvers behave in practice.)"""
    d = 10
    evals = jnp.array([-2.0] + [1.0] * (d - 1))
    H = jnp.diag(evals)
    g = jnp.zeros(d).at[0].set(1e-4)  # infinitesimal component on e_min
    s = solve_cubic_exact(g, H, 10.0, 1.0)
    # ‖s‖ → 2|λ_min|/(Mγ) as g → 0
    np.testing.assert_allclose(float(jnp.linalg.norm(s)), 2 * 2.0 / 10.0, rtol=5e-2)
    # and the step is along the negative-curvature eigenvector
    assert abs(float(s[0])) > 0.9 * float(jnp.linalg.norm(s))


def test_hvp_solver_matches_explicit():
    """Matrix-free Algorithm 2 == explicit Algorithm 2 on a quadratic loss."""
    d = 16
    g, H = _problem(11, d=d, scale=0.3)

    def loss(w, X, y):
        del X, y
        return 0.5 * w @ (H @ w) + g @ w

    w0 = jnp.zeros(d)
    hvp = lambda v: jax.jvp(jax.grad(lambda w: loss(w, None, None)), (w0,), (v,))[1]
    lr = float(1.0 / (jnp.linalg.norm(H, "fro") + 10.0))
    s_hvp = solve_cubic_hvp(g, hvp, M=10.0, gamma=1.0, lr=lr, n_iters=3000)
    s_gd = solve_cubic_gd(g, H, 10.0, 1.0, lr=lr, tol=1e-10, max_iters=3000)
    np.testing.assert_allclose(s_hvp, s_gd, atol=1e-4)
