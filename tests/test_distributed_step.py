"""Mesh-scale train step (repro.core.distributed): convergence, trimming
behavior under injected attacks, Remark-5 two-round mode, and the
first-order robust baseline."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.distributed import (
    DistributedNewtonConfig,
    make_robust_sgd_step,
    make_train_step,
)
from repro.data import WorkerBatcher
from repro.models import build_model


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_config("codeqwen1.5-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _run(cfg, model, params, step, m, n=10, seq=64):
    batcher = WorkerBatcher(cfg, m, 2 * m, seq, 0)
    key = jax.random.PRNGKey(1)
    losses = []
    for it in range(n):
        key, sub = jax.random.split(key)
        params, metrics = step(params, batcher(it), sub)
        losses.append(float(metrics["loss"]))
    return losses, metrics


def test_newton_step_converges(tiny_lm):
    cfg, model, params = tiny_lm
    ncfg = DistributedNewtonConfig(M=10.0, beta=0.25, solver_iters=4)
    step = jax.jit(make_train_step(model.loss_fn, ncfg, 4))
    losses, _ = _run(cfg, model, params, step, 4)
    assert losses[-1] < 0.85 * losses[0]
    assert all(jnp.isfinite(jnp.asarray(losses)))


def test_newton_step_two_round(tiny_lm):
    cfg, model, params = tiny_lm
    ncfg = DistributedNewtonConfig(M=10.0, beta=0.25, solver_iters=4, two_round=True)
    step = jax.jit(make_train_step(model.loss_fn, ncfg, 4))
    losses, _ = _run(cfg, model, params, step, 4)
    assert losses[-1] < 0.85 * losses[0]


def test_gaussian_attacker_is_trimmed(tiny_lm):
    cfg, model, params = tiny_lm
    ncfg = DistributedNewtonConfig(M=10.0, beta=0.25, solver_iters=2)
    step = jax.jit(
        make_train_step(
            model.loss_fn, ncfg, 4, attack_name="gaussian", attack_alpha=0.25
        )
    )
    losses, metrics = _run(cfg, model, params, step, 4, n=6)
    # worker 0 is Byzantine (mask = first ⌊αm⌋) and must be trimmed
    assert float(metrics["kept"][0]) == 0.0
    assert losses[-1] < losses[0]


def test_converges_under_negative_attack(tiny_lm):
    cfg, model, params = tiny_lm
    ncfg = DistributedNewtonConfig(M=10.0, beta=0.25, solver_iters=2)
    step = jax.jit(
        make_train_step(
            model.loss_fn, ncfg, 4, attack_name="negative", attack_alpha=0.25
        )
    )
    losses, _ = _run(cfg, model, params, step, 4, n=10)
    # the negative attack preserves norms so norm-trim cannot filter it; the
    # paper's Fig. 1 shows slowed-but-monotone convergence — assert that.
    assert losses[-1] < 0.97 * losses[0]


def test_robust_sgd_baseline(tiny_lm):
    cfg, model, params = tiny_lm
    step = jax.jit(make_robust_sgd_step(model.loss_fn, 0.1, 4, beta=0.25))
    losses, _ = _run(cfg, model, params, step, 4)
    assert losses[-1] < losses[0]


def test_update_norm_metrics_shape(tiny_lm):
    cfg, model, params = tiny_lm
    ncfg = DistributedNewtonConfig(M=10.0, beta=0.25, solver_iters=1)
    step = jax.jit(make_train_step(model.loss_fn, ncfg, 4))
    batcher = WorkerBatcher(cfg, 4, 8, 64, 0)
    _, metrics = step(params, batcher(0), jax.random.PRNGKey(0))
    assert metrics["update_norms"].shape == (4,)
    assert metrics["kept"].shape == (4,)
    assert int(metrics["kept"].sum()) == 3  # (1-β)·m = 3
