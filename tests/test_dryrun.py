"""Dry-run machinery tests.

Sharding-spec construction runs in-process (pure metadata, no devices); the
actual 512-device lower+compile runs in a subprocess because the XLA
host-device-count flag must be set before jax initializes (and the rest of
the suite needs the real 1-CPU topology).
"""
import json
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, INPUT_SHAPES
from repro.launch.specs import skip_reason
from repro.models import build_model

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_skip_policy():
    long = INPUT_SHAPES["long_500k"]
    assert skip_reason(ARCHS["llama3-405b"], long) is not None
    assert skip_reason(ARCHS["mamba2-780m"], long) is None
    assert skip_reason(ARCHS["recurrentgemma-9b"], long) is None
    assert skip_reason(ARCHS["gemma3-27b"], long) is None
    assert skip_reason(ARCHS["whisper-medium"], long) is not None
    for s in ("train_4k", "prefill_32k", "decode_32k"):
        for a in ARCHS.values():
            assert skip_reason(a, INPUT_SHAPES[s]) is None


def test_param_specs_cover_all_leaves():
    """Every param leaf of every arch gets a valid, shape-compatible spec."""
    from repro.launch.sharding import param_specs

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    for name, cfg in ARCHS.items():
        model = build_model(cfg)
        shapes = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
        specs = param_specs(shapes, FakeMesh())
        flat_s, _ = jax.tree_util.tree_flatten(
            specs, is_leaf=lambda x: isinstance(x, P)
        )
        flat_p, _ = jax.tree_util.tree_flatten(shapes)
        assert len(flat_s) == len(flat_p), name
        for spec, leaf in zip(flat_s, flat_p):
            assert len(spec) <= leaf.ndim, (name, spec, leaf.shape)
            # divisibility of sharded dims
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = 1
                for a in axes:
                    n *= FakeMesh.shape[a]
                assert dim % n == 0, (name, spec, leaf.shape)


@pytest.mark.slow
def test_dryrun_subprocess_single_pair():
    """One real 512-host-device lower+compile through the CLI."""
    env = {**os.environ, "PYTHONPATH": SRC}
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-780m", "--shape", "decode_32k",
         "--json", "/tmp/test_dryrun.jsonl"],
        env=env, capture_output=True, text=True, timeout=560,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.loads(open("/tmp/test_dryrun.jsonl").readlines()[-1])
    assert rec["status"] == "ok"
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert rec["flops_per_device"] > 0
