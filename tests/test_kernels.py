"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (mandated by the
brief), executed in interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import attention_bshd, cubic_step, flash_attention, rmsnorm
from repro.kernels.cubic_step import cubic_solve_fused
from repro.kernels.ref import cubic_step_ref, flash_attention_ref, rmsnorm_ref
from repro.core import solve_cubic_exact


@pytest.mark.parametrize("B,H,S,Dh", [(1, 1, 128, 64), (2, 3, 256, 64), (1, 2, 256, 128)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, S, Dh, causal, dtype, rng):
    kq, kk, kv = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (B, H, S, Dh), dtype)
    k = jax.random.normal(kk, (B, H, S, Dh), dtype)
    v = jax.random.normal(kv, (B, H, S, Dh), dtype)
    o = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    r = flash_attention_ref(q, k, v, causal=causal)
    atol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        o.astype(jnp.float32), r.astype(jnp.float32), atol=atol
    )


@pytest.mark.parametrize("window", [64, 128, 192])
def test_flash_attention_window(window, rng):
    B, H, S, Dh = 1, 2, 384, 64
    kq, kk, kv = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (B, H, S, Dh))
    k = jax.random.normal(kk, (B, H, S, Dh))
    v = jax.random.normal(kv, (B, H, S, Dh))
    o = flash_attention(q, k, v, causal=True, window=window, block_q=64, block_k=64)
    r = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(o, r, atol=2e-6)


def test_flash_attention_block_shape_invariance(rng):
    B, H, S, Dh = 1, 2, 256, 64
    q = jax.random.normal(rng, (B, H, S, Dh))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, H, S, Dh))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, H, S, Dh))
    o1 = flash_attention(q, k, v, block_q=64, block_k=64)
    o2 = flash_attention(q, k, v, block_q=128, block_k=32)
    np.testing.assert_allclose(o1, o2, atol=2e-6)


def test_attention_bshd_gqa(rng):
    """ops.py wrapper: (B,S,H,Dh) layout + GQA kv repetition."""
    B, S, H, Hkv, Dh = 2, 128, 4, 2, 64
    q = jax.random.normal(rng, (B, S, H, Dh))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, Hkv, Dh))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, Hkv, Dh))
    o = attention_bshd(q, k, v, causal=True, block_q=64, block_k=64)
    from repro.models.attention import reference_attention

    r = reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(o, r, atol=2e-6)


@pytest.mark.parametrize("d", [64, 123, 300])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_cubic_step_sweep(d, dtype, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    A = jax.random.normal(k1, (d, d), dtype)
    H = (A + A.T) / 2
    g = jax.random.normal(k2, (d,), dtype)
    s = jax.random.normal(k3, (d,), dtype)
    o = cubic_step(s, g, H, M=10.0, gamma=1.0, lr=1e-2)
    r = cubic_step_ref(s, g, H, M=10.0, gamma=1.0, lr=1e-2)
    np.testing.assert_allclose(o, r, atol=1e-5)


def test_cubic_solve_fused_matches_exact(rng):
    d = 64
    A = jax.random.normal(rng, (d, d))
    H = (A + A.T) / 2
    g = jax.random.normal(jax.random.fold_in(rng, 1), (d,))
    s = cubic_solve_fused(g, H, n_iters=4000)
    s_ex = solve_cubic_exact(g, H)
    np.testing.assert_allclose(s, s_ex, atol=1e-3)


@pytest.mark.parametrize("N,d", [(128, 256), (256, 512), (64, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(N, d, dtype, rng):
    x = jax.random.normal(rng, (N, d), dtype)
    w = 0.1 * jax.random.normal(jax.random.fold_in(rng, 1), (d,), jnp.float32)
    o = rmsnorm(x, w, block_rows=64)
    r = rmsnorm_ref(x, w)
    np.testing.assert_allclose(
        o.astype(jnp.float32), r.astype(jnp.float32), atol=1e-2 if dtype == jnp.bfloat16 else 1e-5
    )
