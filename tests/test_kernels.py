"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (mandated by the
brief), executed in interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    DEFAULT_BLOCK,
    DENSE_FUSED_MAX_M,
    SINGLE_TILE_MAX_D,
    SPARSE_SCATTER_MAX_D,
    agg_kernel_plan,
    aggregate_sparse,
    aggregate_sparse_gridded,
    aggregate_sparse_scatter,
    attention_bshd,
    coordinate_median_fused,
    cubic_step,
    flash_attention,
    kernel_plan,
    krum_scores_fused,
    krum_select_fused,
    rmsnorm,
    sort_workers_fused,
    topk_compress,
    topk_compress_sharded,
    topk_decompress,
    trimmed_mean_fused,
)
from repro.kernels.cubic_step import cubic_solve_fused
from repro.kernels.ref import (
    cubic_step_ref,
    flash_attention_ref,
    krum_scores_ref,
    rmsnorm_ref,
    sparse_aggregate_ref,
    topk_compress_ref,
    topk_compress_sharded_ref,
)
from repro.core import solve_cubic_exact
from repro.core.aggregation import (
    coordinate_median,
    krum_select,
    trimmed_mean,
)


@pytest.mark.parametrize("B,H,S,Dh", [(1, 1, 128, 64), (2, 3, 256, 64), (1, 2, 256, 128)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, S, Dh, causal, dtype, rng):
    kq, kk, kv = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (B, H, S, Dh), dtype)
    k = jax.random.normal(kk, (B, H, S, Dh), dtype)
    v = jax.random.normal(kv, (B, H, S, Dh), dtype)
    o = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    r = flash_attention_ref(q, k, v, causal=causal)
    atol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        o.astype(jnp.float32), r.astype(jnp.float32), atol=atol
    )


@pytest.mark.parametrize("window", [64, 128, 192])
def test_flash_attention_window(window, rng):
    B, H, S, Dh = 1, 2, 384, 64
    kq, kk, kv = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (B, H, S, Dh))
    k = jax.random.normal(kk, (B, H, S, Dh))
    v = jax.random.normal(kv, (B, H, S, Dh))
    o = flash_attention(q, k, v, causal=True, window=window, block_q=64, block_k=64)
    r = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(o, r, atol=2e-6)


def test_flash_attention_block_shape_invariance(rng):
    B, H, S, Dh = 1, 2, 256, 64
    q = jax.random.normal(rng, (B, H, S, Dh))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, H, S, Dh))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, H, S, Dh))
    o1 = flash_attention(q, k, v, block_q=64, block_k=64)
    o2 = flash_attention(q, k, v, block_q=128, block_k=32)
    np.testing.assert_allclose(o1, o2, atol=2e-6)


def test_attention_bshd_gqa(rng):
    """ops.py wrapper: (B,S,H,Dh) layout + GQA kv repetition."""
    B, S, H, Hkv, Dh = 2, 128, 4, 2, 64
    q = jax.random.normal(rng, (B, S, H, Dh))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, Hkv, Dh))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, Hkv, Dh))
    o = attention_bshd(q, k, v, causal=True, block_q=64, block_k=64)
    from repro.models.attention import reference_attention

    r = reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(o, r, atol=2e-6)


@pytest.mark.parametrize("d", [64, 123, 300])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_cubic_step_sweep(d, dtype, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    A = jax.random.normal(k1, (d, d), dtype)
    H = (A + A.T) / 2
    g = jax.random.normal(k2, (d,), dtype)
    s = jax.random.normal(k3, (d,), dtype)
    o = cubic_step(s, g, H, M=10.0, gamma=1.0, lr=1e-2)
    r = cubic_step_ref(s, g, H, M=10.0, gamma=1.0, lr=1e-2)
    np.testing.assert_allclose(o, r, atol=1e-5)


def test_cubic_solve_fused_matches_exact(rng):
    d = 64
    A = jax.random.normal(rng, (d, d))
    H = (A + A.T) / 2
    g = jax.random.normal(jax.random.fold_in(rng, 1), (d,))
    s = cubic_solve_fused(g, H, n_iters=4000)
    s_ex = solve_cubic_exact(g, H)
    np.testing.assert_allclose(s, s_ex, atol=1e-3)


@pytest.mark.parametrize("d", [64, 123, 300, 512])
@pytest.mark.parametrize("ratio", [0.05, 0.1, 0.5, 1.0])
def test_topk_compress_sweep(d, ratio, rng):
    """Fused threshold-select + pack vs the lax.top_k oracle: identical
    packed payload (index-ascending) on dense random vectors."""
    k = max(1, int(round(ratio * d)))
    x = jax.random.normal(jax.random.fold_in(rng, d * 1000 + k), (d,))
    v, i = topk_compress(x, k)
    vr, ir = topk_compress_ref(x, k)
    np.testing.assert_array_equal(i, ir)
    np.testing.assert_allclose(v, vr, atol=1e-6)
    np.testing.assert_allclose(
        topk_decompress(v, i, d), topk_decompress(vr, ir, d), atol=1e-6
    )


def test_topk_compress_edge_cases():
    # constant and zero vectors: ties keep the lowest indices
    for x in (jnp.zeros(130), jnp.ones(130)):
        v, i = topk_compress(x, 5)
        np.testing.assert_array_equal(i, jnp.arange(5))
        np.testing.assert_allclose(v, x[:5])


def test_topk_compress_ties_keep_large_magnitudes(rng):
    # threshold ties at low indices must not evict strictly larger values
    # at high indices (regression: first-k-by-index over the raw mask)
    x = jnp.array([2.0, 2.0, 2.0, -7.0])
    v, i = topk_compress(x, 2)
    vr, ir = topk_compress_ref(x, 2)
    np.testing.assert_array_equal(i, ir)
    np.testing.assert_allclose(v, vr)
    # sparse input, fewer nonzeros than k, nonzero at a high index
    xs = jnp.zeros(10).at[7].set(5.0)
    v, i = topk_compress(xs, 3)
    np.testing.assert_array_equal(i, topk_compress_ref(xs, 3)[1])
    # heavy-tie sweep: quantized magnitudes, random (d, k)
    for t in range(25):
        kk = jax.random.fold_in(rng, t)
        d = int(jax.random.randint(kk, (), 4, 60))
        xq = jnp.round(jax.random.normal(jax.random.fold_in(kk, 1), (d,)) * 3) / 3
        k = int(jax.random.randint(jax.random.fold_in(kk, 2), (), 1, d + 1))
        v, i = topk_compress(xq, k)
        vr, ir = topk_compress_ref(xq, k)
        np.testing.assert_array_equal(i, ir)
        np.testing.assert_allclose(v, vr, atol=1e-6)


def test_topk_compress_vmap(rng):
    xs = jax.random.normal(rng, (4, 300))
    vs, idxs = jax.jit(jax.vmap(lambda z: topk_compress(z, 30)))(xs)
    assert vs.shape == (4, 30) and idxs.shape == (4, 30)
    ref = jax.vmap(lambda z: topk_compress_ref(z, 30)[0])(xs)
    np.testing.assert_allclose(vs, ref, atol=1e-6)


# ------------------- sharded (gridded) top-k kernel -----------------------

_B = DEFAULT_BLOCK


def _assert_payload_parity(x, k, **kw):
    """Gridded kernel == lax.top_k oracle == blocked two-pass oracle,
    bit-for-bit (selected support, packed order, values)."""
    v, i = topk_compress_sharded(x, k, **kw)
    vr, ir = topk_compress_ref(x, k)
    np.testing.assert_array_equal(i, ir)
    np.testing.assert_array_equal(v, vr)
    vb, ib = topk_compress_sharded_ref(x, k, kw.get("block", _B))
    np.testing.assert_array_equal(ib, ir)
    np.testing.assert_array_equal(vb, vr)


@pytest.mark.parametrize("d", [_B - 1, _B, _B + 1, 1408, 1409, 4096, 65536])
@pytest.mark.parametrize("kind", ["first", "tenth", "last"])
def test_topk_sharded_oracle_sweep(d, kind, rng):
    """ISSUE sweep: gridded kernel parity at the block boundaries, the
    single-tile limit and beyond, k at both extremes and in between."""
    k = {"first": 1, "tenth": max(1, d // 10), "last": d - 1}[kind]
    x = jax.random.normal(jax.random.fold_in(rng, d * 7 + k), (d,))
    _assert_payload_parity(x, k)


@pytest.mark.parametrize("d", [_B - 1, _B + 1, 3000])
def test_topk_sharded_duplicate_magnitudes(d, rng):
    """Quantized magnitudes force many threshold ties; the tie class must
    fill lowest-index-first ACROSS blocks (lax.top_k's rule)."""
    x = jnp.round(jax.random.normal(jax.random.fold_in(rng, d), (d,)) * 2) / 2
    for k in (1, d // 3, d - 1):
        _assert_payload_parity(x, k)


def test_topk_sharded_all_zero_and_constant():
    # all-zero: every coordinate ties at t = 0 → keep the lowest indices
    for x in (jnp.zeros(3 * _B + 5), jnp.ones(3 * _B + 5)):
        v, i = topk_compress_sharded(x, 7)
        np.testing.assert_array_equal(i, jnp.arange(7))
        np.testing.assert_array_equal(v, x[:7])


def test_topk_sharded_negative_heavy(rng):
    """Values carry their sign through the pack; magnitude ordering only."""
    x = -jnp.abs(jax.random.normal(rng, (2 * _B + 17,))) - 0.5
    _assert_payload_parity(x, _B // 2)
    assert float(topk_compress_sharded(x, 5)[0].max()) < 0


def test_topk_sharded_sparse_high_index_survivors():
    # fewer nonzeros than k, the nonzero far from block 0: zero-ties fill
    # from index 0, the lone survivor keeps its global index
    d = 4 * _B
    xs = jnp.zeros(d).at[d - 3].set(9.0)
    _assert_payload_parity(xs, 3)


def test_topk_sharded_block_width_invariance(rng):
    """The packed payload must not depend on the launch's block width."""
    x = jax.random.normal(rng, (3000,))
    v1, i1 = topk_compress_sharded(x, 300, block=128)
    v2, i2 = topk_compress_sharded(x, 300, block=1024)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_array_equal(v1, v2)


def test_topk_auto_select_dispatch(rng):
    """topk_compress routes by d: single-tile to the limit, gridded past
    it — and both sides of the boundary agree with the oracle."""
    assert kernel_plan(SINGLE_TILE_MAX_D)[0] == "single_tile"
    assert kernel_plan(SINGLE_TILE_MAX_D + 1)[0] == "gridded"
    for d in (SINGLE_TILE_MAX_D, SINGLE_TILE_MAX_D + 1):
        x = jax.random.normal(jax.random.fold_in(rng, d), (d,))
        v, i = topk_compress(x, 140)
        vr, ir = topk_compress_ref(x, 140)
        np.testing.assert_array_equal(i, ir)
        np.testing.assert_array_equal(v, vr)


def test_topk_sharded_vmap(rng):
    """Worker-stacked compression (the TreeChannel layout) over the
    gridded launch."""
    xs = jax.random.normal(rng, (3, 2000))
    vs, idxs = jax.jit(jax.vmap(lambda z: topk_compress_sharded(z, 64)))(xs)
    assert vs.shape == (3, 64) and idxs.shape == (3, 64)
    for b in range(3):
        vr, ir = topk_compress_ref(xs[b], 64)
        np.testing.assert_array_equal(idxs[b], ir)
        np.testing.assert_array_equal(vs[b], vr)


def test_kernel_plan_rejects_bad_blocks():
    with pytest.raises(ValueError, match="multiple of 128"):
        kernel_plan(4096, block=100)
    with pytest.raises(ValueError, match="VMEM"):
        kernel_plan(4096, block=4096)


# ------------------- sparse-domain aggregation kernel ---------------------


def _int_payload(m, k, d, seed, duplicates=False):
    """Integer-valued float payloads: every partial sum is exactly
    representable in f32, so dense/sparse/kernel paths must agree
    bit-for-bit regardless of summation order."""
    r = np.random.default_rng(seed)
    vals = r.integers(-8, 9, size=(m, k)).astype(np.float32)
    if duplicates:
        idx = r.integers(0, d, size=(m, k)).astype(np.int32)
    else:
        idx = np.stack([np.sort(r.choice(d, size=k, replace=False))
                        for _ in range(m)]).astype(np.int32)
    return jnp.asarray(vals), jnp.asarray(idx)


def _assert_sparse_parity(vals, idx, d, weights=None, exact=True):
    """Auto-dispatch, gridded kernel and scatter fallback all equal the
    numpy segmented-merge oracle."""
    ref = sparse_aggregate_ref(np.asarray(vals), np.asarray(idx), d,
                               None if weights is None else np.asarray(weights))
    check = (np.testing.assert_array_equal if exact else
             lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5,
                                                     atol=1e-6))
    check(np.asarray(aggregate_sparse(vals, idx, d, weights)), ref)
    check(np.asarray(aggregate_sparse_gridded(vals, idx, d, weights)), ref)
    check(np.asarray(aggregate_sparse_scatter(vals, idx, d, weights)), ref)


@pytest.mark.parametrize("m,k,d", [
    (1, 1, 8),            # degenerate single worker, single entry
    (1, 16, 2048),        # m=1 at scatter scale
    (4, 32, 1408),        # scatter path, non-multiple-of-block d
    (8, 64, 8192),        # gridded path
    (3, 16, 65537),       # gridded, d past the ISSUE's 65536 + odd edge
    (4, 32, 65536),       # the ISSUE's sparse-path scale floor
])
def test_sparse_agg_integer_sweep(m, k, d):
    """Segmented merge vs the numpy oracle, bit-exact on integer-valued
    payloads across the scatter/gridded boundary (ISSUE: d up to 65536)."""
    vals, idx = _int_payload(m, k, d, seed=m * 10007 + k * 101 + d)
    _assert_sparse_parity(vals, idx, d)


@pytest.mark.parametrize("d", [512, 8192])
def test_sparse_agg_duplicate_indices(d):
    """Duplicate coordinates — within a worker and across workers — merge
    lowest-index-first; the dedup prepass keeps the kernel exact."""
    vals, idx = _int_payload(6, 40, min(d, 50), seed=d, duplicates=True)
    _assert_sparse_parity(vals, idx, d)


@pytest.mark.parametrize("d", [1024, 9000])
def test_sparse_agg_all_zero_payload(d):
    vals = jnp.zeros((5, 12), jnp.float32)
    idx = jnp.tile(jnp.arange(12, dtype=jnp.int32), (5, 1))
    _assert_sparse_parity(vals, idx, d)
    np.testing.assert_array_equal(
        np.asarray(aggregate_sparse(vals, idx, d)), np.zeros(d, np.float32))


@pytest.mark.parametrize("d", [2048, 16384])
def test_sparse_agg_weighted(d):
    """Per-worker weights (the norm-trim keep mask) fold into the merge;
    0/1 and small-integer weights stay exact."""
    vals, idx = _int_payload(7, 24, d, seed=d + 1)
    w01 = jnp.asarray([1, 0, 1, 1, 0, 1, 0], jnp.float32)
    _assert_sparse_parity(vals, idx, d, weights=w01)
    w_int = jnp.asarray([2, 1, 3, 1, 2, 1, 4], jnp.float32)
    _assert_sparse_parity(vals, idx, d, weights=w_int)


def test_sparse_agg_float_payloads(rng):
    """Dense random floats with distinct per-worker coordinates: every
    coordinate receives its contributions in the same (worker) order on
    every path, so parity holds to float tolerance."""
    for d in (3000, 20000):
        k1 = jax.random.fold_in(rng, d)
        vals = jax.random.normal(k1, (5, 64))
        idx = jnp.asarray(np.stack([
            np.sort(np.random.default_rng(d + i).choice(d, 64, replace=False))
            for i in range(5)]).astype(np.int32))
        _assert_sparse_parity(vals, idx, d, exact=False)


def test_sparse_agg_block_width_invariance():
    """The aggregate must not depend on the gridded launch's block."""
    vals, idx = _int_payload(6, 48, 40000, seed=3)
    o1 = aggregate_sparse_gridded(vals, idx, 40000, block=512)
    o2 = aggregate_sparse_gridded(vals, idx, 40000, block=1024)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_agg_kernel_plan_dispatch_and_rejects():
    """kernel_plan-style auto-dispatch boundaries + build-time ValueError
    on blocks the TPU tiling cannot serve."""
    assert agg_kernel_plan(8, SPARSE_SCATTER_MAX_D, k=64)[0] == "scatter"
    assert agg_kernel_plan(8, SPARSE_SCATTER_MAX_D + 1, k=64)[0] \
        == "sparse_gridded"
    assert agg_kernel_plan(DENSE_FUSED_MAX_M, 4096)[0] == "fused"
    assert agg_kernel_plan(DENSE_FUSED_MAX_M + 1, 4096)[0] == "dense"
    plan, P = agg_kernel_plan(10, 4096)
    assert plan == "fused" and P == 16   # m padded to a power of two
    with pytest.raises(ValueError, match="multiple of 128"):
        agg_kernel_plan(8, 65536, k=64, block=100)
    with pytest.raises(ValueError, match="VMEM"):
        agg_kernel_plan(8, 65536, k=64, block=8192)
    with pytest.raises(ValueError, match="multiple of 128"):
        agg_kernel_plan(8, 4096, block=100)


# ------------------- fused distance kernels (krum / row sort) -------------


@pytest.mark.parametrize("m,d", [(4, 64), (6, 600), (10, 1024), (13, 1500)])
@pytest.mark.parametrize("n_byz", [1, 2])
def test_krum_scores_vs_naive_ref(m, d, n_byz, rng):
    """Fused krum scores equal the naive O(m²) double-loop oracle and the
    selection equals the registry's krum_select."""
    flat = jax.random.normal(jax.random.fold_in(rng, m * 1000 + d), (m, d))
    scores = krum_scores_fused(flat, n_byz)
    ref = krum_scores_ref(np.asarray(flat), n_byz)
    np.testing.assert_allclose(np.asarray(scores), ref, rtol=2e-5)
    assert int(krum_select_fused(flat, n_byz)) == int(krum_select(flat, n_byz))


def test_krum_integer_payload_exact(rng):
    """Integer-valued stacks: squared distances and partial sums are
    exact in f32, so the fused scores match the oracle bit-for-bit."""
    r = np.random.default_rng(11)
    flat = jnp.asarray(r.integers(-5, 6, size=(8, 700)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(krum_scores_fused(flat, 2)),
        krum_scores_ref(np.asarray(flat), 2).astype(np.float32))


@pytest.mark.parametrize("m,d", [(2, 100), (5, 512), (8, 513), (16, 2000)])
def test_sort_workers_fused_exact(m, d, rng):
    """The tiled bitonic network is a pure permutation: bit-equal to
    jnp.sort over the worker axis, including +inf row padding."""
    x = jax.random.normal(jax.random.fold_in(rng, m * 31 + d), (m, d))
    np.testing.assert_array_equal(
        np.asarray(sort_workers_fused(x)), np.asarray(jnp.sort(x, axis=0)))


@pytest.mark.parametrize("m", [3, 4, 9, 12])
@pytest.mark.parametrize("trim_frac", [0.0, 0.2, 0.4])
def test_trimmed_mean_fused_matches_registry(m, trim_frac, rng):
    x = jax.random.normal(jax.random.fold_in(rng, m), (m, 777))
    np.testing.assert_array_equal(
        np.asarray(trimmed_mean_fused(x, trim_frac)),
        np.asarray(trimmed_mean(x, trim_frac)))


@pytest.mark.parametrize("m", [2, 3, 6, 11])
def test_coordinate_median_fused_matches_registry(m, rng):
    """Odd and even m (jnp.median's (low + high) / 2 midpoint)."""
    x = jax.random.normal(jax.random.fold_in(rng, 100 + m), (m, 640))
    np.testing.assert_array_equal(
        np.asarray(coordinate_median_fused(x)),
        np.asarray(coordinate_median(x)))


def test_fused_rules_reject_oversized_m(rng):
    big = jnp.zeros((DENSE_FUSED_MAX_M + 1, 256))
    with pytest.raises(ValueError, match="registry path"):
        krum_scores_fused(big, 2)
    with pytest.raises(ValueError, match="registry path"):
        sort_workers_fused(big)


@pytest.mark.parametrize("N,d", [(128, 256), (256, 512), (64, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(N, d, dtype, rng):
    x = jax.random.normal(rng, (N, d), dtype)
    w = 0.1 * jax.random.normal(jax.random.fold_in(rng, 1), (d,), jnp.float32)
    o = rmsnorm(x, w, block_rows=64)
    r = rmsnorm_ref(x, w)
    np.testing.assert_allclose(
        o.astype(jnp.float32), r.astype(jnp.float32), atol=1e-2 if dtype == jnp.bfloat16 else 1e-5
    )
