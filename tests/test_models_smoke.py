"""Per-architecture smoke tests (mandated by the brief): a REDUCED variant
of each assigned architecture runs one forward/train step on CPU with shape
and finiteness assertions, plus decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, VARIANTS
from repro.models import build_model

ALL = sorted(ARCHS)


def _batch(cfg, key, B=2, S=32):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["prefix_emb"] = jax.random.normal(
            key, (B, cfg.num_prefix_tokens, cfg.d_model)
        )
    if cfg.family == "audio":
        batch["enc_emb"] = jax.random.normal(key, (B, cfg.encoder_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL)
def test_forward_and_loss(arch, rng):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(rng)
    B, S = 2, 32
    batch = _batch(cfg, rng, B, S)
    logits, aux = model.forward(
        params,
        batch["tokens"],
        prefix_emb=batch.get("prefix_emb"),
        enc_emb=batch.get("enc_emb"),
    )
    S_total = S + (cfg.num_prefix_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_total, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    loss = jax.jit(model.loss_fn)(params, batch)
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ALL)
def test_one_train_grad_step(arch, rng):
    """One SGD step on the reduced config: gradients finite, loss drops."""
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(rng)
    batch = _batch(cfg, rng)
    loss0, grads = jax.jit(jax.value_and_grad(model.loss_fn))(params, batch)
    assert bool(jnp.isfinite(loss0))
    finite = jax.tree_util.tree_map(lambda g: bool(jnp.isfinite(g).all()), grads)
    assert all(jax.tree_util.tree_leaves(finite))
    # MoE needs a smaller probe step: top-k routing flips make the loss
    # piecewise and non-monotone at large steps.
    lr = 0.003 if cfg.num_experts else 0.05
    params2 = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    loss1 = jax.jit(model.loss_fn)(params2, batch)
    assert float(loss1) < float(loss0)


@pytest.mark.parametrize("arch", ALL)
def test_decode_step_shapes(arch, rng):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(rng)
    B = 2
    cache = model.init_cache(B, 64)
    logits, cache2 = jax.jit(model.decode_step)(
        params, cache, jnp.zeros((B,), jnp.int32), jnp.int32(0)
    )
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize(
    "arch",
    ["llama3-405b", "mamba2-780m", "gemma3-27b", "recurrentgemma-9b",
     "deepseek-moe-16b"],
)
def test_decode_matches_forward(arch, rng):
    """Token-by-token decode reproduces the training-path logits — the
    KV-cache/rolling-window/SSM-state plumbing is exact."""
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(rng)
    B, T = 2, 12
    toks = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
    logits_fwd, _ = model.forward(params, toks)
    cache = model.init_cache(B, 32)
    step = jax.jit(model.decode_step)
    for t in range(T):
        lg, cache = step(params, cache, toks[:, t], jnp.int32(t))
        err = float(jnp.abs(lg - logits_fwd[:, t]).max())
        assert err < 2e-4, (t, err)


def test_swa_variant_exists():
    assert "llama3-405b-swa" in VARIANTS
    assert VARIANTS["llama3-405b-swa"].supports_long_context
