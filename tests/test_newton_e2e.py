"""Algorithm 1 end-to-end on the paper's two problems (synthetic LIBSVM
twins): non-Byzantine convergence + robustness under all four attacks, and
the robust-vs-naive contrast that motivates norm thresholding."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import AttackConfig, DistributedCubicNewton, NewtonConfig
from repro.data import make_classification, make_regression, shard_to_workers


def logistic_loss(w, X, y):
    z = X @ w
    yy = 2.0 * y - 1.0
    return jnp.mean(jnp.log1p(jnp.exp(-yy * z))) + 0.5e-3 * w @ w


def robust_regression_loss(w, X, y):
    r = y - X @ w
    return jnp.mean(jnp.log(r * r / 2.0 + 1.0))


@pytest.fixture(scope="module")
def logistic_data():
    # margin=4 ⇒ near-separable (low Bayes floor) so loss-ratio assertions
    # measure the optimizer, not the noise floor.
    X, y, _ = make_classification(
        jax.random.PRNGKey(0), 2000, 20, margin=4.0, label_noise=0.01
    )
    Xm, ym = shard_to_workers(X, y, 10)
    return Xm, ym, X, y


@pytest.fixture(scope="module")
def regression_data():
    X, y, w_star = make_regression(jax.random.PRNGKey(1), 2000, 20)
    Xm, ym = shard_to_workers(X, y, 10)
    return Xm, ym, X, y, w_star


def test_nonbyzantine_convergence(logistic_data):
    Xm, ym, X, y = logistic_data
    algo = DistributedCubicNewton(logistic_loss, NewtonConfig(M=10.0, beta=0.0))
    w, hist = algo.run(jnp.zeros(20), Xm, ym, 15)
    assert hist["loss"][-1] < 0.55 * hist["loss"][0]
    assert hist["grad_norm"][-1] < 0.1


def test_fast_gradient_decay(logistic_data):
    """The second-order signature: large early progress (the 1/T^{2/3} rate
    shows up as few-round convergence in the paper's Table 1)."""
    Xm, ym, X, y = logistic_data
    algo = DistributedCubicNewton(logistic_loss, NewtonConfig(M=10.0, beta=0.0))
    w, hist = algo.run(jnp.zeros(20), Xm, ym, 8)
    assert hist["grad_norm"][-1] < 0.45 * hist["grad_norm"][0]


@pytest.mark.parametrize("attack", ["gaussian", "negative", "flipped_label", "random_label"])
def test_byzantine_robustness(logistic_data, attack):
    """All four §6 attacks at α=20%, β=α+2/m (the paper's setting)."""
    Xm, ym, X, y = logistic_data
    algo = DistributedCubicNewton(
        logistic_loss,
        NewtonConfig(M=10.0, beta=0.2 + 2 / 10),
        AttackConfig(name=attack, alpha=0.2),
    )
    w, hist = algo.run(jnp.zeros(20), Xm, ym, 15)
    assert hist["loss"][-1] < 0.75 * hist["loss"][0]
    acc = float(((X @ w > 0) == (y > 0.5)).mean())
    assert acc > 0.75


def test_robust_beats_naive_mean_under_gaussian_attack(logistic_data):
    Xm, ym, X, y = logistic_data
    atk = AttackConfig(name="gaussian", alpha=0.2, sigma=100.0)
    naive = DistributedCubicNewton(logistic_loss, NewtonConfig(beta=0.0), atk)
    robust = DistributedCubicNewton(logistic_loss, NewtonConfig(beta=0.4), atk)
    w_n, h_n = naive.run(jnp.zeros(20), Xm, ym, 10)
    w_r, h_r = robust.run(jnp.zeros(20), Xm, ym, 10)
    assert h_r["loss"][-1] < h_n["loss"][-1] - 0.05


def test_nonconvex_robust_regression(regression_data):
    Xm, ym, X, y, w_star = regression_data
    algo = DistributedCubicNewton(
        robust_regression_loss, NewtonConfig(M=10.0, beta=0.1)
    )
    w, hist = algo.run(jnp.zeros(20), Xm, ym, 25)
    assert hist["loss"][-1] < hist["loss"][0]
    # recovered the planted parameter despite outliers (the non-convex loss's
    # whole point)
    assert float(jnp.linalg.norm(w - w_star)) < 0.5 * float(jnp.linalg.norm(w_star))


def test_two_round_exact_gradient(logistic_data):
    """Remark 5: ε_g = 0 variant converges and counts 2 rounds per step."""
    Xm, ym, X, y = logistic_data
    algo = DistributedCubicNewton(
        logistic_loss, NewtonConfig(M=10.0, beta=0.1, exact_gradient=True)
    )
    w, hist = algo.run(jnp.zeros(20), Xm, ym, 10)
    assert hist["rounds"] == 20
    assert hist["grad_norm"][-1] < 0.1


def test_momentum_variant(logistic_data):
    """Beyond-paper: CR-with-momentum [WZLL20] converges at least as fast
    in early rounds as the paper's momentum-free Algorithm 1."""
    Xm, ym, X, y = logistic_data
    base = DistributedCubicNewton(logistic_loss, NewtonConfig(M=10.0, beta=0.1))
    mom = DistributedCubicNewton(
        logistic_loss,
        dataclasses.replace(NewtonConfig(M=10.0, beta=0.1), momentum=0.5),
    )
    _, h_b = base.run(jnp.zeros(20), Xm, ym, 10)
    _, h_m = mom.run(jnp.zeros(20), Xm, ym, 10)
    assert h_m["loss"][-1] <= h_b["loss"][-1] + 1e-3
    assert all(jnp.isfinite(jnp.asarray(h_m["loss"])))
