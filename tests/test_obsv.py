"""repro.obsv: Byzantine forensics, the run-health doctor, bench ledger.

Pins the PR's acceptance criteria:

* **attribution is exact where it should be** — on a w8a gaussian run at
  α = 0.2 with a matched trim (β slightly above α), the doctor's
  flagged-worker set equals the planted Byzantine ids: precision =
  recall = 1.0, exactly;
* **forensics stays zero-cost when disabled** — the per-sender δ̂ and
  update norms are staged into the traced round ONLY when telemetry is
  enabled at trace time (the info dict pins the gate);
* **the suspicion score's semantics** — rejection evidence saturates,
  z-evidence alone stays below the default flag line, selection rules
  (krum) fall back to z-only, non-finite norms are maximal evidence;
* **the bench ledger gates** — ``bench-compare`` exits 0 against an
  identical baseline and 1 against an injected 2× bits regression.
"""
import json
import math
import os

import pytest

from repro.obsv import (
    analyze_events,
    append_ledger,
    augment_trace,
    compare_ledgers,
    detection_metrics,
    extract_scalars,
    fingerprint,
    flagged_workers,
    group_runs,
    load_events,
    run_anomalies,
)
from repro.obsv.__main__ import main as obsv_cli
from repro.telemetry import (
    SuspicionTracker,
    Telemetry,
    planted_byzantine_ids,
)
from repro.telemetry.__main__ import (
    check_chrome_trace,
    main as telemetry_cli,
)


@pytest.fixture
def tel(tmp_path, monkeypatch):
    from repro.telemetry import core

    t = Telemetry()
    t.enable(str(tmp_path / "telemetry"))
    monkeypatch.setattr(core, "_GLOBAL", t)
    yield t
    t.disable()


# ------------------------------------------------ suspicion semantics


def test_suspicion_rejection_saturates_and_decays():
    tr = SuspicionTracker(4)
    for _ in range(3):
        scores = tr.update(keep=[0.0, 1.0, 1.0, 1.0])
    # 3 consecutive rejections cross the default 0.5 flag line
    assert scores[0] == pytest.approx(1 - 0.7 ** 3)
    assert tr.flagged() == [0]
    # …and decay once the worker behaves again
    for _ in range(10):
        scores = tr.update(keep=[1.0, 1.0, 1.0, 1.0])
    assert scores[0] < 0.05 and tr.flagged() == []


def test_suspicion_z_evidence_alone_stays_below_default_threshold():
    """Honest norm drift must not cross the 0.5 line by itself."""
    tr = SuspicionTracker(2)
    for i in range(50):
        tr.update(keep=[1.0, 1.0], norms=[1.0 + 0.01 * i, 1e9 * (i + 1)])
    assert max(tr.scores) < 0.5


def test_suspicion_selection_rule_uses_z_only():
    """A krum-style one-hot keep rejects m−1 workers a round — rejection
    frequency carries no information, so it must not raise scores."""
    tr = SuspicionTracker(5)
    keep = [1.0, 0.0, 0.0, 0.0, 0.0]
    for _ in range(10):
        scores = tr.update(keep=keep, norms=[1.0] * 5)
    assert max(scores) < 0.5


def test_suspicion_nonfinite_norm_is_maximal_evidence():
    tr = SuspicionTracker(2)
    scores = tr.update(keep=[1.0, 1.0], norms=[1.0, float("nan")])
    assert scores[1] == pytest.approx(tr.ewma)  # one round at signal 1.0
    assert scores[0] == 0.0


def test_suspicion_none_means_no_participation():
    tr = SuspicionTracker(3)
    tr.update(keep=[0.0, None, 1.0], norms=[1.0, None, 1.0])
    assert tr.scores[1] == 0.0 and tr._n[1] == 0
    with pytest.raises(ValueError):
        tr.update(keep=[1.0])


def test_planted_ids_match_attack_mask():
    import numpy as np

    from repro.core import byzantine_mask

    for m, alpha in ((20, 0.2), (10, 0.25), (7, 0.5), (4, 0.0)):
        ids = planted_byzantine_ids(m, alpha)
        mask = np.asarray(byzantine_mask(m, alpha))
        assert ids == [i for i in range(m) if mask[i]]


# ------------------------------------------------ doctor unit pieces


def _round(step, runtime="paper", attack="gaussian", alpha=0.2, **kw):
    ev = {"kind": "round", "name": f"{runtime}.round", "ts": 0.1 * step,
          "wall": 1.0, "v": 4, "step": step, "runtime": runtime,
          "attack": attack, "alpha": alpha, "pid": 1}
    ev.update(kw)
    return ev


def test_group_runs_splits_on_step_reset_and_identity():
    events = (
        [_round(t) for t in range(3)]                       # run 1
        + [_round(t) for t in range(2)]                     # step reset
        + [_round(t + 2, runtime="async") for t in range(2)]  # new identity
    )
    runs = group_runs(events)
    assert [len(r["rounds"]) for r in runs] == [3, 2, 2]
    assert [r["runtime"] for r in runs] == ["paper", "paper", "async"]


def test_flagged_workers_v4_and_legacy_fallback():
    v4 = {"rounds": [_round(0, suspicion=[0.9, 0.1, 0.6])]}
    assert flagged_workers(v4) == ([0, 2], "suspicion")
    legacy = {"rounds": [_round(t, rejected=[0] if t < 3 else [1])
                         for t in range(4)]}
    for ev in legacy["rounds"]:
        del ev["v"]
    assert flagged_workers(legacy) == ([0], "rejection_frequency")


def test_detection_metrics_edges():
    perfect = detection_metrics([0, 1], [0, 1])
    assert perfect["precision"] == 1.0 and perfect["recall"] == 1.0
    nothing = detection_metrics([], [])
    assert nothing["precision"] == 1.0 and nothing["recall"] == 1.0
    assert detection_metrics([0, 5], [0, 1])["precision"] == 0.5
    assert detection_metrics([0], [0, 1])["recall"] == 0.5
    assert detection_metrics([3], [])["precision"] == 0.0


def test_run_anomaly_flags():
    saddle_stuck = {"attack": "saddle:5.0", "rounds":
                    [_round(t, attack="saddle:5.0", saddle_escape=False)
                     for t in range(4)]}
    assert [a["flag"] for a in run_anomalies(saddle_stuck)] \
        == ["no_saddle_escape"]
    saddle_ok = {"attack": "saddle:5.0", "rounds":
                 [_round(0, attack="saddle:5.0", saddle_escape=True)]}
    assert run_anomalies(saddle_ok) == []
    diverged = {"attack": "none", "rounds":
                [_round(0, loss=float("inf")),
                 _round(1, uplink_delta=-0.2)]}
    flags = [a["flag"] for a in run_anomalies(diverged)]
    assert flags == ["loss_divergence", "ef_divergence"]


# ------------------------------------ the acceptance pin: exact recovery


def test_doctor_w8a_gaussian_recovers_planted_set_exactly(tel):
    """w8a at α = 0.2 (m = 20 ⇒ Byzantine {0,1,2,3}), β = 0.22 ⇒ the
    trim rejects exactly 4 workers/round: the doctor's flagged set must
    equal the planted ids — precision = recall = 1.0, pinned."""
    from repro.api import ExperimentSpec

    exp = ExperimentSpec(
        problem="w8a-logistic", m_workers=20, M=10.0,
        aggregator="norm_trim:0.22", attack="gaussian", alpha=0.2, seed=0,
    ).build()
    exp.run(n_steps=5)
    tel.flush()

    events, problems = load_events(tel.out_dir)
    assert problems == []
    report = analyze_events(events)
    assert report["n_runs"] == 1
    run = report["runs"][0]
    assert run["byzantine_true"] == [0, 1, 2, 3]
    assert run["flagged"] == [0, 1, 2, 3]
    assert run["method"] == "suspicion"
    det = run["detection"]
    assert det["precision"] == 1.0 and det["recall"] == 1.0
    assert report["wire_ledger_mismatch"] == []
    # the doctor CLI agrees, with teeth
    rc = obsv_cli(["doctor", tel.out_dir, "--expect-precision", "1.0",
                   "--expect-recall", "1.0"])
    assert rc == 0


def test_doctor_cli_fails_on_missed_recall(tmp_path):
    events = [_round(t, suspicion=[0.0] * 4, byzantine_true=[0])
              for t in range(3)]
    p = tmp_path / "events.jsonl"
    p.write_text("".join(json.dumps(e) + "\n" for e in events))
    assert obsv_cli(["doctor", str(p), "--expect-recall", "1.0"]) == 1
    assert obsv_cli(["doctor", str(p)]) == 0  # no expectation, no failure


def test_doctor_augments_trace_with_worker_tracks(tel, tmp_path):
    from repro.api import ExperimentSpec

    exp = ExperimentSpec(
        problem="synthetic-logistic:120:12", m_workers=4,
        aggregator="norm_trim:0.3", attack="gaussian", alpha=0.25,
    ).build()
    exp.run(n_steps=3)
    tel.flush()
    trace = os.path.join(tel.out_dir, "trace.json")
    events, _ = load_events(tel.out_dir)
    out = augment_trace(trace, events,
                        out_path=str(tmp_path / "augmented.json"))
    assert check_chrome_trace(out) == []
    with open(out) as f:
        doc = json.load(f)
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e.get("ph") == "M"}
    assert any(n.startswith("worker 0 [paper/gaussian") for n in names)
    counters = [e for e in doc["traceEvents"]
                if e.get("ph") == "C" and e["name"].startswith("suspicion.")]
    assert len(counters) == 3 * 4  # rounds × workers


# ------------------------------------------- zero-cost trace-time gate


def test_forensic_outputs_gated_on_telemetry(tel, monkeypatch):
    """The per-sender δ̂ / update norms are staged only when telemetry
    was enabled at trace time — the disabled program is the pre-v4 one."""
    import jax

    from repro.solvers.sgd import CompressedSGD, SGDParams

    def make():
        from repro.api.problems import make_problem
        prob = make_problem("synthetic-logistic:120:12", m_workers=4)
        s = CompressedSGD(prob.loss_fn, SGDParams(lr=0.5, compressor="topk:4",
                                                  error_feedback="ef21"))
        s._ensure_channels(prob.dim, 4)
        key = jax.random.PRNGKey(0)
        w, v, st, info = s._round_impl(
            prob.w0, jax.numpy.zeros_like(prob.w0), s.init_comm_state(),
            prob.X_workers, prob.y_workers, key)
        return info

    info_on = make()
    assert "worker_delta" in info_on and "update_norms" in info_on
    assert info_on["worker_delta"].shape == (4,)

    from repro.telemetry import core
    monkeypatch.setattr(core, "_GLOBAL", Telemetry())  # disabled
    info_off = make()
    assert "worker_delta" not in info_off
    assert "update_norms" not in info_off


# --------------------------- satellite: solver streams validate exactly


def test_pgd_and_sgd_streams_validate_with_wire_check(tel, capsys):
    """byzantine_pgd (incl. escape-probe rounds) and compressed_sgd
    telemetry streams pass `python -m repro.telemetry validate
    --check-wire`."""
    from repro.api import ExperimentSpec

    pgd = ExperimentSpec(
        problem="matrix-factor:6:2", m_workers=4, eta=0.05,
        solver="byzantine_pgd", aggregator="norm_trim:0.3",
        attack="gaussian", alpha=0.25, seed=0,
    ).build()
    # a tight grad_tol arms the Escape subroutine, so probe rounds are
    # in the stream (billed with label="escape")
    pgd.run(12, grad_tol=1.0)
    sgd = ExperimentSpec(
        problem="synthetic-logistic:120:12", m_workers=4, eta=0.5,
        solver="compressed_sgd", compressor="topk:4",
        error_feedback="ef21", aggregator="norm_trim:0.3",
        attack="gaussian", alpha=0.25, seed=0,
    ).build()
    sgd.run(6)
    tel.flush()
    events_path = os.path.join(tel.out_dir, "events.jsonl")
    assert telemetry_cli(["validate", events_path, "--check-wire",
                          "--trace",
                          os.path.join(tel.out_dir, "trace.json")]) == 0
    events, _ = load_events(tel.out_dir)
    runtimes = {e.get("runtime") for e in events if e.get("kind") == "round"}
    assert {"pgd", "sgd"} <= runtimes
    for e in events:
        if e.get("kind") == "round":
            assert "suspicion" in e and "worker_keep" in e
            assert e["byzantine_true"] == [0]


# ----------------------------------------------- soft keep (satellite)


def test_trimmed_mean_soft_keep_exposes_fully_trimmed_worker():
    import jax.numpy as jnp
    import numpy as np

    from repro.api.aggregators import make_aggregator
    from repro.telemetry import rejected_from_keep

    u = jnp.array(np.random.default_rng(0).normal(size=(5, 16)),
                  jnp.float32)
    u = u.at[0].set(1e6)  # largest in every coordinate → always trimmed
    for spec in ("trimmed_mean:0.2", "trimmed_mean_kernel:0.2"):
        agg, keep = make_aggregator(spec)(u)
        keep = np.asarray(keep)
        assert keep[0] == 0.0
        assert 0.0 < keep[1:].min() and keep.max() <= 1.0
        assert rejected_from_keep(keep) == [0]
    agg, keep = make_aggregator("coordinate_median")(u)
    keep = np.asarray(keep)
    assert keep[0] == 0.0 and keep[1:].sum() > 0


def test_staleness_weighting_binarizes_soft_keep():
    """A soft keep is forensic signal, not an aggregation weight: only
    fully rejected arrivals are excluded from the async center mean."""
    import jax.numpy as jnp
    import numpy as np

    from repro.api.aggregators import make_aggregator
    from repro.async_rt.aggregate import StalenessWeighted

    u = jnp.array(np.random.default_rng(1).normal(size=(5, 8)), jnp.float32)
    u = u.at[0].set(1e6)
    sw = StalenessWeighted(make_aggregator("trimmed_mean:0.2"), decay=1.0)
    agg, keep = sw(u, [0] * 5)
    np.testing.assert_allclose(np.asarray(agg),
                               np.asarray(u[1:].mean(0)), rtol=1e-6)


# ------------------------------------------------------- bench ledger


def _fake_results():
    return {
        "table1": [{"attack": "gaussian", "alpha": 0.2,
                    "newton_rounds": 7, "pgd_rounds": 40,
                    "newton_uplink_bits": 1000,
                    "newton_downlink_bits": 500, "speedup": 5.7}],
        "bits_to_eps": [{"compressor": "topk:4",
                         "bits_to_eps": {0.3: 2048, 0.1: None}}],
        "topk_kernel_timing": [{"d": 1408, "kernel_us": 11.0,
                                "xla_topk_us": 25.0, "plan": "grid"}],
    }


def test_extract_scalars_classifies_and_skips_nones():
    res = _fake_results()
    t1 = extract_scalars("table1", res["table1"])
    assert t1["gaussian.alpha=0.2.newton_uplink_bits"] == 1000
    assert "gaussian.alpha=0.2.speedup" not in t1  # not a ledger class
    be = extract_scalars("bits_to_eps", res["bits_to_eps"])
    assert be == {"topk:4.bits@eps=0.3": 2048}  # None ε-miss dropped
    assert extract_scalars("unknown_entry", {"x": 1}) == {}


def test_bench_compare_passes_identical_and_fails_on_2x_bits(tmp_path):
    meta = fingerprint()
    assert set(meta) == {"git_sha", "jax", "jaxlib", "platform",
                         "python", "timestamp_utc"}
    base_dir, cur_dir = str(tmp_path / "base"), str(tmp_path / "cur")
    for name, entry in _fake_results().items():
        scalars = extract_scalars(name, entry)
        if scalars:
            append_ledger(base_dir, name, scalars, meta)
            append_ledger(cur_dir, name, scalars, meta)

    problems, warnings, n = compare_ledgers(cur_dir, base_dir)
    assert problems == [] and n > 0
    assert obsv_cli(["bench-compare", cur_dir, "--baseline", base_dir]) == 0

    # inject a 2× wire regression into the current table1 ledger
    path = os.path.join(cur_dir, "BENCH_table1.json")
    with open(path) as f:
        records = json.load(f)
    for k in records[-1]["scalars"]:
        if "bits" in k:
            records[-1]["scalars"][k] *= 2
    with open(path, "w") as f:
        json.dump(records, f)
    problems, _, _ = compare_ledgers(cur_dir, base_dir)
    assert any("REGRESSION" in p for p in problems)
    assert obsv_cli(["bench-compare", cur_dir, "--baseline", base_dir]) == 1


def test_bench_compare_times_skipped_unless_asked(tmp_path):
    meta = fingerprint()
    base_dir, cur_dir = str(tmp_path / "b"), str(tmp_path / "c")
    append_ledger(base_dir, "topk_kernel_timing",
                  {"d=1408.kernel_us": 10.0}, meta)
    append_ledger(cur_dir, "topk_kernel_timing",
                  {"d=1408.kernel_us": 1000.0}, meta)
    problems, _, n = compare_ledgers(cur_dir, base_dir)
    assert problems == [] and n == 0          # times not gated by default
    problems, _, n = compare_ledgers(cur_dir, base_dir, check_times=True)
    assert len(problems) == 1 and n == 1      # 100× > the 5× time ratio


def test_bench_ledger_appends_and_missing_is_warning(tmp_path):
    meta = fingerprint()
    d = str(tmp_path / "led")
    p1 = append_ledger(d, "table1", {"a_bits": 1}, meta)
    append_ledger(d, "table1", {"a_bits": 2}, meta)
    with open(p1) as f:
        records = json.load(f)
    assert [r["scalars"]["a_bits"] for r in records] == [1, 2]
    # baseline has an entry the current run lacks → warning, not failure
    cur = str(tmp_path / "cur")
    os.makedirs(cur)
    problems, warnings, _ = compare_ledgers(cur, d)
    assert problems == [] and len(warnings) == 1
    problems, _, _ = compare_ledgers(cur, d, strict=True)
    assert len(problems) == 1
