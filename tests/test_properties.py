"""Hypothesis property tests on the system's numerical invariants.

ALL hypothesis-based tests live in this module (the unit-test modules
stay hypothesis-free), behind an importorskip so the suite degrades
gracefully when the dependency is absent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.compression import make_compressor
from repro.core import norm_trim, solve_cubic_exact, cubic_model_value
from repro.core.aggregation import (
    coordinate_median,
    krum_select,
    mean as agg_mean,
    trimmed_mean,
)
from repro.kernels import (
    aggregate_sparse,
    coordinate_median_fused,
    krum_scores_fused,
    krum_select_fused,
    trimmed_mean_fused,
)
from repro.kernels.ref import krum_scores_ref, sparse_aggregate_ref
from repro.core.tree_util import tree_dot, tree_randn_like
from repro.models.attention import chunked_attention, reference_attention
from repro.models.mamba2 import ssd_chunked, ssd_reference


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=1, max_value=3),    # batch
    st.sampled_from([16, 32, 48]),            # seq
    st.integers(min_value=1, max_value=3),    # heads
    st.sampled_from([4, 8]),                  # head dim P
    st.sampled_from([3, 5]),                  # state N
    st.integers(min_value=0, max_value=10**6),
)
def test_ssd_chunked_equals_recurrence(b, S, H, P, N, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    B = jax.random.normal(ks[1], (b, S, N)) * 0.5
    C = jax.random.normal(ks[2], (b, S, N)) * 0.5
    log_a = -jax.nn.softplus(jax.random.normal(ks[3], (b, S, H)))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (b, S, H)))
    y1 = ssd_chunked(x, B, C, log_a, dt, 16)
    y2 = ssd_reference(x, B, C, log_a, dt)
    np.testing.assert_allclose(y1, y2, atol=5e-5)


@settings(max_examples=15, deadline=None)
@given(
    st.sampled_from([32, 48, 64]),            # seq
    st.sampled_from([8, 16]),                 # q chunk
    st.sampled_from([8, 16]),                 # kv chunk
    st.booleans(),                            # causal
    st.integers(min_value=0, max_value=10**6),
)
def test_chunked_attention_equals_reference(S, qc, kc, causal, seed):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (1, S, 2, 8))
    k = jax.random.normal(kk, (1, S, 1, 8))
    v = jax.random.normal(kv, (1, S, 1, 8))
    a = chunked_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    b = reference_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(a, b, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_cubic_solution_never_increases_model(seed):
    """m(s*) ≤ m(0) = 0 for the sub-problem — the descent lemma's engine."""
    key = jax.random.PRNGKey(seed)
    d = 12
    A = jax.random.normal(key, (d, d))
    H = (A + A.T) / 2
    g = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    s = solve_cubic_exact(g, H)
    assert float(cubic_model_value(s, g, H)) <= 1e-5


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=4, max_value=16),
    st.integers(min_value=0, max_value=10**6),
)
def test_norm_trim_scale_equivariant(m, seed):
    """norm_trim(c·U) = c·norm_trim(U) for c > 0 (the rule only ranks).
    Rows are given well-separated norms: with near-tied norms the float
    ranking can legitimately flip under scaling (a boundary condition of
    any float-based rank rule, found by hypothesis)."""
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.normal(size=(m, 5)))
    u = u / jnp.linalg.norm(u, axis=1, keepdims=True)  # unit rows…
    u = u * (1.0 + jnp.arange(m, dtype=jnp.float32))[rng.permutation(m), None]
    a1, k1 = norm_trim(u, 0.25)
    a2, k2 = norm_trim(3.5 * u, 0.25)
    np.testing.assert_allclose(3.5 * a1, a2, rtol=1e-5)
    np.testing.assert_array_equal(k1, k2)


@settings(max_examples=50, deadline=None)
@given(
    st.integers(min_value=4, max_value=12),  # m
    st.integers(min_value=1, max_value=6),   # d
    st.integers(min_value=0, max_value=10**6),
)
def test_norm_trim_bounded_by_kept_max(m, d, seed):
    """Post-trim, every surviving row's norm ≤ the (1−β)-quantile norm —
    the key lemma behind Theorem 2's attack bound."""
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.normal(size=(m, d)) * rng.exponential(5, size=(m, 1)))
    beta = 0.25
    agg, keep = norm_trim(u, beta)
    n_keep = max(1, int(round((1 - beta) * m)))
    norms = np.linalg.norm(np.asarray(u), axis=1)
    thresh = np.sort(norms)[n_keep - 1]
    kept_norms = norms[np.asarray(keep) > 0]
    assert (kept_norms <= thresh + 1e-6).all()
    # aggregate norm bounded by the threshold too (mean of vectors ≤ max norm)
    assert np.linalg.norm(np.asarray(agg)) <= thresh + 1e-6


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_norm_trim_permutation_invariant_aggregate(seed):
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.normal(size=(9, 7)))
    perm = rng.permutation(9)
    a1, _ = norm_trim(u, 0.3)
    a2, _ = norm_trim(u[perm], 0.3)
    np.testing.assert_allclose(a1, a2, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_tree_dot_matches_flat(seed):
    key = jax.random.PRNGKey(seed)
    t1 = {"a": jax.random.normal(key, (3, 4)), "b": jax.random.normal(key, (5,))}
    t2 = tree_randn_like(jax.random.fold_in(key, 1), t1)
    flat1 = jnp.concatenate([t1["a"].ravel(), t1["b"]])
    flat2 = jnp.concatenate([t2["a"].ravel(), t2["b"]])
    np.testing.assert_allclose(tree_dot(t1, t2), flat1 @ flat2, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=2200),     # d (spans >4 blocks at 512)
    st.floats(min_value=0.0, max_value=1.0),      # k as a fraction of d
    st.sampled_from(["dense", "quantized", "negative", "zero", "spiky"]),
    st.integers(min_value=0, max_value=10**6),
)
def test_topk_sharded_kernel_matches_oracle(d, kfrac, family, seed):
    """Gridded-kernel parity, hypothesis-driven (the ISSUE's oracle
    harness): random (d, k) across block boundaries and adversarial value
    families — duplicate magnitudes (tie-at-threshold fills
    lowest-index-first across blocks), all-zero, negative-heavy, and
    spiky (most coordinates tied at zero) — must match ``lax.top_k``
    bit-for-bit through the two-pass sharded launch."""
    from repro.kernels import topk_compress_sharded
    from repro.kernels.ref import topk_compress_ref

    k = max(1, min(d, int(round(kfrac * d))))
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (d,))
    if family == "quantized":
        x = jnp.round(x * 2) / 2                  # heavy magnitude ties
    elif family == "negative":
        x = -jnp.abs(x) - 0.25
    elif family == "zero":
        x = jnp.zeros_like(x)
    elif family == "spiky":
        x = jnp.where(jnp.abs(x) > 1.5, x, 0.0)   # mass ties at |x| = 0
    v, i = topk_compress_sharded(x, k, block=512)
    vr, ir = topk_compress_ref(x, k)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(vr))


@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=1, max_value=1500),
    st.integers(min_value=0, max_value=10**6),
)
def test_topk_sharded_blocked_oracle_matches_flat_oracle(d, seed):
    """The blocked two-pass reference (explicit per-block tie budgets and
    pack offsets) is a pure re-arrangement of lax.top_k — the contract
    that makes the gridded wire payload cost exactly the same bits."""
    from repro.kernels.ref import topk_compress_ref, topk_compress_sharded_ref

    rng = np.random.default_rng(seed)
    x = jnp.asarray(np.round(rng.normal(size=(d,)) * 3) / 3, jnp.float32)
    k = int(rng.integers(1, d + 1))
    block = int(rng.choice([128, 256, 512]))
    vb, ib = topk_compress_sharded_ref(x, k, block)
    vr, ir = topk_compress_ref(x, k)
    np.testing.assert_array_equal(np.asarray(ib), np.asarray(ir))
    np.testing.assert_array_equal(np.asarray(vb), np.asarray(vr))


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=4, max_value=12),     # m
    st.integers(min_value=0, max_value=10**6),
)
def test_sort_based_rules_permutation_invariant(m, seed):
    """Trimmed-mean and coordinate-median only see the per-coordinate
    sorted stack, so permuting workers changes NOTHING — exact equality,
    on the registry path and the fused-kernel path alike."""
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.normal(size=(m, 50)).astype(np.float32))
    up = u[jnp.asarray(rng.permutation(m))]
    np.testing.assert_array_equal(np.asarray(trimmed_mean(u, 0.2)),
                                  np.asarray(trimmed_mean(up, 0.2)))
    np.testing.assert_array_equal(np.asarray(coordinate_median(u)),
                                  np.asarray(coordinate_median(up)))
    np.testing.assert_array_equal(np.asarray(trimmed_mean_fused(u, 0.2)),
                                  np.asarray(trimmed_mean_fused(up, 0.2)))
    np.testing.assert_array_equal(np.asarray(coordinate_median_fused(u)),
                                  np.asarray(coordinate_median_fused(up)))


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=4, max_value=10),     # m
    st.integers(min_value=0, max_value=10**6),
)
def test_mean_and_norm_trim_permutation_invariant(m, seed):
    """Mean/norm-trim aggregates are worker-order free (float summation
    order moves, so allclose; norms are distinct w.p. 1 on normals)."""
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.normal(size=(m, 30)).astype(np.float32))
    perm = jnp.asarray(rng.permutation(m))
    np.testing.assert_allclose(np.asarray(agg_mean(u)),
                               np.asarray(agg_mean(u[perm])), atol=1e-6)
    a1, _ = norm_trim(u, 0.25)
    a2, _ = norm_trim(u[perm], 0.25)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), atol=1e-6)


@settings(max_examples=12, deadline=None)
@given(
    st.integers(min_value=4, max_value=12),     # m
    st.integers(min_value=1, max_value=2),      # n_byz
    st.integers(min_value=0, max_value=10**6),
)
def test_krum_permutation_equivariant(m, n_byz, seed):
    """Krum selects the same WORKER under any permutation (scores are
    distinct w.p. 1): perm[selected(permuted)] == selected(original) —
    registry and fused-kernel paths."""
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.normal(size=(m, 40)).astype(np.float32))
    perm = rng.permutation(m)
    up = u[jnp.asarray(perm)]
    sel = int(krum_select(u, n_byz))
    assert perm[int(krum_select(up, n_byz))] == sel
    assert int(krum_select_fused(u, n_byz)) == sel
    assert perm[int(krum_select_fused(up, n_byz))] == sel


@settings(max_examples=12, deadline=None)
@given(
    st.integers(min_value=4, max_value=12),     # m
    st.sampled_from([32, 300]),                 # d
    st.integers(min_value=1, max_value=2),      # n_byz
    st.integers(min_value=0, max_value=10**6),
)
def test_krum_fused_score_equals_naive_double_loop(m, d, n_byz, seed):
    """ISSUE invariant: the fused kernel's on-chip scores equal the naive
    O(m²) double-loop definition, and the selections agree."""
    rng = np.random.default_rng(seed)
    flat = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(krum_scores_fused(flat, n_byz)),
                               krum_scores_ref(np.asarray(flat), n_byz),
                               rtol=2e-5)
    assert int(krum_select_fused(flat, n_byz)) == int(krum_select(flat, n_byz))


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=2, max_value=12),     # m
    st.integers(min_value=0, max_value=10**6),
)
def test_trimmed_mean_zero_trim_is_mean(m, seed):
    """ISSUE invariant: trim_frac = 0 degenerates to the plain mean —
    registry and fused paths (sort-then-mean vs mean: allclose)."""
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.normal(size=(m, 64)).astype(np.float32))
    ref = np.asarray(agg_mean(u))
    np.testing.assert_allclose(np.asarray(trimmed_mean(u, 0.0)), ref,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(trimmed_mean_fused(u, 0.0)), ref,
                               atol=1e-6)


@settings(max_examples=12, deadline=None)
@given(
    st.integers(min_value=2, max_value=8),      # m
    st.sampled_from([1500, 9000]),              # d (scatter + gridded)
    st.integers(min_value=0, max_value=10**6),
)
def test_sparse_aggregate_permutation_invariant(m, d, seed):
    """The sparse-domain center (kernel-backed mean path) is exactly
    permutation invariant on integer-valued payloads — duplicate
    coordinates included — and equals the numpy oracle."""
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.integers(-6, 7, size=(m, 24)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, d, size=(m, 24)).astype(np.int32))
    perm = jnp.asarray(rng.permutation(m))
    out = aggregate_sparse(vals, idx, d)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(aggregate_sparse(vals[perm], idx[perm], d)))
    np.testing.assert_array_equal(
        np.asarray(out),
        sparse_aggregate_ref(np.asarray(vals), np.asarray(idx), d))


@settings(max_examples=50, deadline=None)
@given(
    st.integers(min_value=1, max_value=24),      # m
    st.floats(min_value=0.01, max_value=1.0),    # participation
    st.integers(min_value=0, max_value=10**4),   # round
    st.integers(min_value=0, max_value=10**6),   # seed
)
def test_cohort_sampling_reproducible_without_replacement(m, p, t, seed):
    """Async-runtime cohorts are a pure function of ``(seed, round)``:
    re-sampling yields the identical sorted, duplicate-free subset of
    ``range(m)`` with exactly ``cohort_size(m, p)`` members."""
    from repro.async_rt import cohort_size, sample_cohort

    c1 = sample_cohort(seed, t, m, p)
    c2 = sample_cohort(seed, t, m, p)
    np.testing.assert_array_equal(c1, c2)        # key-pure, not call-order
    ids = c1.tolist()
    assert len(ids) == cohort_size(m, p) == max(1, int(round(p * m)))
    assert len(set(ids)) == len(ids)             # without replacement
    assert ids == sorted(ids)
    assert all(0 <= i < m for i in ids)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=2, max_value=16),      # m
    st.integers(min_value=0, max_value=100),     # round
    st.integers(min_value=1, max_value=7),       # staleness cap
    st.integers(min_value=0, max_value=10**6),   # seed
)
def test_scheduler_decision_streams_independent(m, t, k, seed):
    """Distinct decision kinds never share an RNG stream: turning faults
    and staleness on cannot change who participates, and every sampled
    lag respects the configured cap."""
    from repro.async_rt import EventScheduler

    quiet = EventScheduler(seed, m, participation=0.5)
    noisy = EventScheduler(seed, m, participation=0.5, staleness=k,
                           drop=0.3, duplicate=0.3)
    np.testing.assert_array_equal(quiet.cohort(t), noisy.cohort(t))
    for i in range(m):
        assert quiet.lag(t, i) == 0
        assert 0 <= noisy.lag(t, i) <= k
        assert noisy.lag(t, i) == noisy.lag(t, i)          # deterministic
        assert noisy.dropped(t, i) == noisy.dropped(t, i)
        assert noisy.duplicated(t, i) == noisy.duplicated(t, i)


@settings(max_examples=40, deadline=None)
@given(
    st.sampled_from(["topk:0.1", "topk:0.5", "signnorm", "int8", "int8:32"]),
    st.integers(min_value=2, max_value=400),   # d
    st.integers(min_value=0, max_value=10**6),
)
def test_compressor_delta_contraction(spec, d, seed):
    """Definition 2: ‖x − C(x)‖² ≤ (1 − δ)‖x‖² with the compressor's
    guaranteed δ, on arbitrary inputs (the deterministic compressors)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(
        rng.normal(size=(d,)) * rng.exponential(3, size=(d,)), jnp.float32
    )
    comp = make_compressor(spec, d)
    r = comp.roundtrip(x)
    err = float(jnp.sum((x - r) ** 2))
    sq = float(jnp.sum(x * x))
    assert err <= (1.0 - comp.delta_bound(d)) * sq + 1e-5 * max(sq, 1.0)
    assert float(comp.delta(x)) >= comp.delta_bound(d) - 1e-5
