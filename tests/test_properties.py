"""Hypothesis property tests on the system's numerical invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import norm_trim, solve_cubic_exact, cubic_model_value
from repro.models.attention import chunked_attention, reference_attention
from repro.models.mamba2 import ssd_chunked, ssd_reference


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=1, max_value=3),    # batch
    st.sampled_from([16, 32, 48]),            # seq
    st.integers(min_value=1, max_value=3),    # heads
    st.sampled_from([4, 8]),                  # head dim P
    st.sampled_from([3, 5]),                  # state N
    st.integers(min_value=0, max_value=10**6),
)
def test_ssd_chunked_equals_recurrence(b, S, H, P, N, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    B = jax.random.normal(ks[1], (b, S, N)) * 0.5
    C = jax.random.normal(ks[2], (b, S, N)) * 0.5
    log_a = -jax.nn.softplus(jax.random.normal(ks[3], (b, S, H)))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (b, S, H)))
    y1 = ssd_chunked(x, B, C, log_a, dt, 16)
    y2 = ssd_reference(x, B, C, log_a, dt)
    np.testing.assert_allclose(y1, y2, atol=5e-5)


@settings(max_examples=15, deadline=None)
@given(
    st.sampled_from([32, 48, 64]),            # seq
    st.sampled_from([8, 16]),                 # q chunk
    st.sampled_from([8, 16]),                 # kv chunk
    st.booleans(),                            # causal
    st.integers(min_value=0, max_value=10**6),
)
def test_chunked_attention_equals_reference(S, qc, kc, causal, seed):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (1, S, 2, 8))
    k = jax.random.normal(kk, (1, S, 1, 8))
    v = jax.random.normal(kv, (1, S, 1, 8))
    a = chunked_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    b = reference_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(a, b, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_cubic_solution_never_increases_model(seed):
    """m(s*) ≤ m(0) = 0 for the sub-problem — the descent lemma's engine."""
    key = jax.random.PRNGKey(seed)
    d = 12
    A = jax.random.normal(key, (d, d))
    H = (A + A.T) / 2
    g = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    s = solve_cubic_exact(g, H)
    assert float(cubic_model_value(s, g, H)) <= 1e-5


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=4, max_value=16),
    st.integers(min_value=0, max_value=10**6),
)
def test_norm_trim_scale_equivariant(m, seed):
    """norm_trim(c·U) = c·norm_trim(U) for c > 0 (the rule only ranks).
    Rows are given well-separated norms: with near-tied norms the float
    ranking can legitimately flip under scaling (a boundary condition of
    any float-based rank rule, found by hypothesis)."""
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.normal(size=(m, 5)))
    u = u / jnp.linalg.norm(u, axis=1, keepdims=True)  # unit rows…
    u = u * (1.0 + jnp.arange(m, dtype=jnp.float32))[rng.permutation(m), None]
    a1, k1 = norm_trim(u, 0.25)
    a2, k2 = norm_trim(3.5 * u, 0.25)
    np.testing.assert_allclose(3.5 * a1, a2, rtol=1e-5)
    np.testing.assert_array_equal(k1, k2)
