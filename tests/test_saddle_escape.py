"""The paper's headline claim, tested directly: cubic Newton escapes strict
saddles that stall first-order methods, even under the saddle-point attack."""
from benchmarks.saddle_escape import run


def test_saddle_escape():
    r = run(T=15)
    saddle_val = r["newton"]["saddle_value"]
    # the saddle is strict
    assert r["second_order"]["saddle_lambda_min"] < -1.0
    # cubic Newton escapes to (near) the global minimum…
    assert r["newton"]["loss"][-1] < 0.05 * saddle_val
    # …while first-order robust GD is still near the saddle plateau
    assert r["gd"]["loss"][-1] > 0.5 * saddle_val
    # and the saddle-point attack does not trap the trimmed Newton iterate
    assert r["newton_saddle_attack"]["loss"][-1] < 0.05 * saddle_val
