"""The solver axis: spec grammar, degenerate-parity pins, and per-solver
ledger exactness (escape-probe rounds included).

The two parity pins are the contracts the first-order baselines are
allowed to claim comparability under:

* ``compressed_sgd`` with ``compressor=None``, ``aggregator="mean"``,
  α = 0 IS plain robust SGD — bit for bit, not allclose;
* ``byzantine_pgd`` through the facade is the same loop as the legacy
  ``repro.core.ByzantinePGD`` surface (now a shim): identical round
  count AND identical iterates on the w8a problem.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.api import ExperimentSpec, SpecError
from repro.solvers import FIRST_ORDER_SOLVERS, parse_solver_spec


# ------------------------- spec grammar ------------------------------------


def test_parse_solver_spec_grammar():
    assert parse_solver_spec(None) == ("cubic_newton", {})
    assert parse_solver_spec("cubic_newton") == ("cubic_newton", {})
    assert parse_solver_spec("byzantine_pgd") == \
        ("byzantine_pgd", {"R": 10, "Q": 10})
    assert parse_solver_spec("byzantine_pgd:3:5") == \
        ("byzantine_pgd", {"R": 3, "Q": 5})
    assert parse_solver_spec("compressed_sgd") == \
        ("compressed_sgd", {"perturb_radius": 0.0, "perturb_gtol": 0.0})
    assert parse_solver_spec("compressed_sgd:1.5:0.1") == \
        ("compressed_sgd",
         {"perturb_radius": 1.5, "perturb_gtol": 0.1})


@pytest.mark.parametrize("bad", [
    "sgd",                      # unknown head
    "cubic_newton:3",           # newton takes no parameters
    "byzantine_pgd:3",          # wrong arity
    "byzantine_pgd:three:5",    # non-numeric
    "byzantine_pgd:-1:5",       # R < 0
    "byzantine_pgd:3:0",        # Q < 1
    "compressed_sgd:1.0",       # wrong arity
    "compressed_sgd:-1.0:0.1",  # radius < 0
    3,                          # not a string
])
def test_parse_solver_spec_rejects(bad):
    with pytest.raises(SpecError):
        parse_solver_spec(bad)


def test_validate_rejects_newton_only_axes():
    base = dict(problem="synthetic-logistic:200:10", m_workers=4)
    # first-order solvers are paper-runtime only
    with pytest.raises(SpecError, match="runtime='paper' only"):
        ExperimentSpec(solver="compressed_sgd", runtime="async",
                       **base).validate()
    # exact_gradient is the Newton Remark-5 two-round mode
    with pytest.raises(SpecError, match="exact_gradient"):
        ExperimentSpec(solver="byzantine_pgd", exact_gradient=True,
                       **base).validate()
    # Yin et al.'s PGD has no momentum term
    with pytest.raises(SpecError, match="momentum"):
        ExperimentSpec(solver="byzantine_pgd", momentum=0.5,
                       **base).validate()
    # ... but momentum-SGD is exactly what compressed_sgd offers
    ExperimentSpec(solver="compressed_sgd", momentum=0.5, **base).validate()
    # bad grammar surfaces at validate time too
    with pytest.raises(SpecError):
        ExperimentSpec(solver="byzantine_pgd:3", **base).validate()


def test_default_solver_omitted_from_dict():
    """Pre-existing spec dicts (and sweep-store hashes) must not change:
    the default solver is omitted exactly like the default async axes."""
    d = ExperimentSpec(problem="synthetic-logistic:200:10").to_dict()
    assert "solver" not in d
    spec = ExperimentSpec(problem="synthetic-logistic:200:10",
                          solver="byzantine_pgd:3:5")
    assert spec.to_dict()["solver"] == "byzantine_pgd:3:5"
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec
    assert "byzantine_pgd" in FIRST_ORDER_SOLVERS


# ------------------------- degenerate parity -------------------------------


def test_degenerate_compressed_sgd_is_plain_sgd_bit_exact():
    """compressed_sgd(mean, α=0, identity wire, momentum 0, radius 0)
    compiles to the plain-SGD round — same floats, not allclose."""
    exp = ExperimentSpec(
        solver="compressed_sgd", problem="synthetic-logistic:1000:20",
        m_workers=10, eta=1.0, seed=0,
    ).build()
    prob = exp.problem
    w_sgd, hist = exp.run(5)

    grads = jax.vmap(jax.grad(prob.loss_fn), in_axes=(None, 0, 0))

    # reference with the data as jit ARGUMENTS, matching the solver's
    # round signature — closure-constant data compiles to different
    # float rounding, so this is part of the contract
    @jax.jit
    def sgd_round(w, X, y):
        return w - 1.0 * jnp.mean(grads(w, X, y), axis=0)

    w_ref = prob.w0
    for _ in range(5):
        w_ref = sgd_round(w_ref, prob.X_workers, prob.y_workers)
    assert bool(jnp.all(w_sgd == w_ref))
    assert hist["rounds"] == 5


def test_pgd_facade_matches_legacy_shim_on_w8a():
    """Channel-routed byzantine_pgd through the facade reproduces the
    legacy ByzantinePGD surface exactly: same rounds, same iterates."""
    from repro.core import AttackConfig, ByzantinePGD, PGDConfig

    exp = ExperimentSpec(
        problem="w8a-robust", m_workers=20, eta=1.0,
        solver="byzantine_pgd", aggregator="trimmed_mean:0.2",
        attack="gaussian:10.0", alpha=0.2, seed=0,
    ).build()
    w_api, h_api = exp.run(25, grad_tol=0.05)

    prob = exp.problem
    legacy = ByzantinePGD(
        prob.loss_fn, PGDConfig(lr=1.0),
        AttackConfig(name="gaussian", alpha=0.2, sigma=10.0),
    )
    w_leg, h_leg = legacy.run(prob.w0, prob.X_workers, prob.y_workers,
                              max_rounds=25, grad_tol=0.05)
    assert h_api["rounds"] == h_leg["rounds"]
    assert h_api["uplink_bits"] == h_leg["uplink_bits"]
    assert bool(jnp.all(w_api == w_leg))


# ------------------------- ledger exactness --------------------------------


def _ledger_exact(h, bps):
    assert isinstance(h["uplink_bits"], int)
    assert isinstance(h["downlink_bits"], int)
    assert h["uplink_bits"] == bps["uplink"] * h["rounds"]
    assert h["downlink_bits"] == bps["downlink"] * h["rounds"]
    assert h["total_bits"] == h["uplink_bits"] + h["downlink_bits"]


def test_sgd_ledger_exact_compressed_wire():
    exp = ExperimentSpec(
        solver="compressed_sgd", problem="synthetic-logistic:500:16",
        m_workers=8, eta=1.0, compressor="topk:0.25",
        aggregator="norm_trim:0.5", attack="gaussian:10.0", alpha=0.25,
        seed=1,
    ).build()
    _, h = exp.run(12)
    bps = exp.bits_per_step()
    assert bps["uplink"] < 8 * 32 * 16        # the top-k wire is compressed
    _ledger_exact(h, bps)


def test_pgd_escape_probes_billed_and_budget_capped():
    """Forced escape: probe rounds are billed on the ledger, counted in
    hist["rounds"], and NEVER overshoot n_steps (unlike the legacy
    loop)."""
    from repro.solvers import ChannelByzantinePGD, PGDParams
    from repro.data import make_classification, shard_to_workers

    def loss(w, X, y):
        z = X @ w
        yy = 2.0 * y - 1.0
        return jnp.mean(jnp.log1p(jnp.exp(-yy * z))) + 0.5e-3 * w @ w

    X, y, _ = make_classification(jax.random.PRNGKey(0), 400, 12)
    Xm, ym = shard_to_workers(X, y, 8)

    # grad_tol so loose the very first round triggers Escape; f_th so
    # strict every attempt is rejected → loop certifies and stops
    solver = ChannelByzantinePGD(
        loss, PGDParams(lr=1.0, R=2, Q=3, f_th=1e9, grad_th=1e-4)
    )
    _, h = solver.run(jnp.zeros(12), Xm, ym, n_steps=50, grad_tol=1e9)
    assert h["escape_rounds"] == 2 * 3
    assert h["rounds"] == 1 + 2 * 3           # one main round + all probes
    _ledger_exact(h, solver.bits_per_step())
    assert solver.bits_per_step()["uplink"] == 8 * 32 * 12

    # budget cap: probes stop mid-attempt at the round budget
    solver = ChannelByzantinePGD(
        loss, PGDParams(lr=1.0, R=5, Q=10, f_th=1e9, grad_th=1e-4)
    )
    _, h = solver.run(jnp.zeros(12), Xm, ym, n_steps=4, grad_tol=1e9)
    assert h["rounds"] == 4                   # == n_steps, never over
    assert h["escape_rounds"] == 3
    _ledger_exact(h, solver.bits_per_step())


def test_solver_history_schema_matches_newton():
    """Sweep/report pivots consume the same keys across the solver axis."""
    exp = ExperimentSpec(
        solver="byzantine_pgd:2:2", problem="synthetic-logistic:300:8",
        m_workers=4, eta=1.0, seed=0,
    ).build()
    _, h = exp.run(6)
    for key in ("loss", "grad_norm", "rounds", "bits_cumulative",
                "uplink_delta", "k_trajectory", "saddle_escape_step",
                "truncated", "uplink_bits", "downlink_bits", "total_bits"):
        assert key in h, key
    assert len(h["bits_cumulative"]) == len(h["loss"])
    assert h["bits_cumulative"][-1] <= h["total_bits"]
