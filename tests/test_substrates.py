"""Data pipeline, optimizers, checkpointing, tree utils, HLO analyzer.

The hypothesis property test on tree_dot lives in test_properties.py
behind its importorskip("hypothesis") guard, so this module keeps
running when hypothesis is absent."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.core.tree_util import (
    tree_axpy,
    tree_norm,
    tree_size,
    tree_zeros_like,
)
from repro.data import TokenStream, WorkerBatcher, make_classification, paper_dataset
from repro.launch.hlo import analyze_hlo
from repro.optim import adam, apply_updates, cosine_schedule, sgd


# ------------------------------ data --------------------------------------


def test_token_stream_deterministic():
    s = TokenStream(1000, seed=3)
    a1, b1 = s.batch(5, 4, 16)
    a2, b2 = s.batch(5, 4, 16)
    np.testing.assert_array_equal(a1, a2)
    assert a1.shape == (4, 16) and b1.shape == (4, 16)
    assert int(a1.max()) < 1000


def test_token_stream_learnable_structure():
    """Odd positions are a deterministic shift of their predecessor."""
    s = TokenStream(1000, seed=0)
    toks, targets = s.batch(0, 2, 33)
    np.testing.assert_array_equal(np.asarray(toks[:, 1:]), np.asarray(targets[:, :-1]))


def test_worker_batcher_shapes():
    cfg = get_config("internvl2-76b").reduced()
    b = WorkerBatcher(cfg, 4, 8, 32, 0)
    batch = b(0)
    assert batch["tokens"].shape == (4, 2, 32 - cfg.num_prefix_tokens)
    assert batch["prefix_emb"].shape == (4, 2, cfg.num_prefix_tokens, cfg.d_model)


def test_paper_dataset_shapes():
    from repro.configs import PAPER_WORKLOADS

    d = paper_dataset(PAPER_WORKLOADS["a9a-logistic"])
    assert d["X_workers"].shape[0] == 20
    assert d["X_workers"].shape[2] == 123
    assert d["X_test"].shape == (9600, 123)


# ------------------------------ optim --------------------------------------


def _quad_loss(w):
    return 0.5 * jnp.sum(w * w)


@pytest.mark.parametrize("opt", [sgd(0.2), sgd(0.2, momentum=0.9), adam(0.2)])
def test_optimizers_descend(opt):
    w = {"a": jnp.ones(5), "b": {"c": 2.0 * jnp.ones(3)}}
    state = opt.init(w)
    for _ in range(50):
        g = jax.grad(lambda p: _quad_loss(jnp.concatenate([p["a"], p["b"]["c"]])))(w)
        upd, state = opt.update(g, state, w)
        w = apply_updates(w, upd)
    assert float(tree_norm(w)) < 0.2


def test_cosine_schedule():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(100)) < 0.01


# ------------------------------ checkpoint ---------------------------------


def test_checkpoint_roundtrip(tmp_path):
    params = {
        "w": jnp.arange(6.0).reshape(2, 3),
        "nested": {"b": jnp.ones(4, jnp.bfloat16)},
    }
    save_checkpoint(str(tmp_path / "ck"), params, 7, {"loss": 1.0})
    restored, step = load_checkpoint(str(tmp_path / "ck"), params)
    assert step == 7
    np.testing.assert_array_equal(restored["w"], params["w"])
    assert restored["nested"]["b"].dtype == jnp.bfloat16


# ------------------------------ tree utils ---------------------------------


def test_tree_axpy_size_zeros():
    t = {"a": jnp.ones((2, 2)), "b": jnp.ones(3)}
    assert tree_size(t) == 7
    z = tree_zeros_like(t)
    out = tree_axpy(2.0, t, z)
    np.testing.assert_allclose(out["a"], 2.0)


# ------------------------------ HLO analyzer -------------------------------


def test_hlo_analyzer_scan_flops():
    """Loop-aware flop counting: a scan of n matmuls counts n×, not 1×."""
    n, d = 8, 16

    def f(xs, w):
        def body(c, x):
            return c @ w + x, ()
        c, _ = jax.lax.scan(body, xs[0], xs)
        return c

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, d, d), jnp.float32),
        jax.ShapeDtypeStruct((d, d), jnp.float32),
    ).compile()
    a = analyze_hlo(comp.as_text())
    expected = n * 2 * d**3
    assert expected <= a["flops"] <= 1.5 * expected
    assert a["unknown_loops"] == 0


def test_hlo_analyzer_simple_matmul():
    f = lambda x, w: x @ w
    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
    ).compile()
    a = analyze_hlo(comp.as_text())
    assert abs(a["flops"] - 2 * 32 * 64 * 128) / (2 * 32 * 64 * 128) < 0.1
