"""repro.sweep: planner pruning, shard determinism, resumability,
byte-identical merges, failure isolation, wall-time budgets, report
pivots, and the serving downlink broadcast."""
import json

import jax.numpy as jnp
import pytest

from repro.api import ExperimentSpec
from repro.sweep import (
    ResultStore,
    merge,
    plan_grid,
    report,
    run_plan,
    shard_entries,
    smoke_grid,
    spec_hash,
)
from repro.sweep import runner as runner_mod

# one tiny grid shared across the run-based tests (jit caches stay warm)
AXES = {"aggregator": ["mean", "norm_trim"],
        "compressor": [None, "topk:0.5"]}
BASE = {"problem": "synthetic-logistic:200:8", "m_workers": 10,
        "alpha": 0.2, "attack": "gaussian", "seed": 0, "n_steps": 2}


@pytest.fixture(scope="module")
def plan():
    return plan_grid(AXES, BASE)


# ------------------------------------------------------------- planning
def test_spec_hash_golden_value():
    """The canonical cell hash is pinned: changing the canonicalization
    (field set, key order, n_steps inclusion) is a store-format break."""
    spec = ExperimentSpec(
        problem="synthetic-logistic:400:16", m_workers=10, M=10.0,
        alpha=0.2, attack="gaussian", aggregator="norm_trim:0.4",
    )
    assert spec_hash(spec, 2) == "5952ea3508ba31ae"


def test_plan_is_deterministic(plan):
    again = plan_grid(AXES, BASE)
    assert [e.hash for e in again.entries] == [e.hash for e in plan.entries]
    assert len(plan.entries) == 4 and not plan.skipped


def test_plan_resolves_paper_strengths(plan):
    aggs = {e.spec.aggregator for e in plan.entries}
    assert aggs == {"mean", "norm_trim:0.4"}   # β = α + 2/m at plan time


def test_invalid_combos_skipped_with_reason_not_crashed():
    sweep = plan_grid(
        axes={
            "attack": ["gaussian", "flipped_label"],
            "runtime": ["paper", "mesh"],
            "error_feedback": [None, "ef21"],
        },
        base={"problem": "synthetic-logistic:200:8", "m_workers": 10,
              "alpha": 0.2, "aggregator": "norm_trim:0.4", "n_steps": 2},
        prune=(lambda p: "pruned by hook" if p.get("runtime") == "mesh"
               and p.get("attack") == "gaussian" else None),
    )
    reasons = " ".join(s["reason"] for s in sweep.skipped)
    # mesh + label attack: facade SpecError recorded, not raised
    assert "label" in reasons
    # paper runtime + mesh problem mismatch / ef21-without-compressor
    assert "error_feedback" in reasons
    # the custom prune hook fired too
    assert "pruned by hook" in reasons
    # and the valid paper-runtime combos survived
    assert len(sweep.entries) >= 2
    for e in sweep.entries:
        assert e.spec.runtime == "paper"


def test_duplicate_cells_collapse():
    sweep = plan_grid(
        axes={"aggregator": ["mean", "mean"]},
        base=dict(BASE, compressor=None),
    )
    assert len(sweep.entries) == 1
    assert any("duplicate" in s["reason"] for s in sweep.skipped)


# ------------------------------------------------------------- sharding
@pytest.mark.parametrize("num_shards", [1, 2, 3, 5, 8, 11])
def test_shards_disjoint_and_covering(num_shards):
    axes, base = smoke_grid()
    entries = plan_grid(axes, base).entries
    seen = []
    for i in range(num_shards):
        seen.extend(e.hash for e in shard_entries(entries, i, num_shards))
    assert len(seen) == len(entries)                      # covering, no dup
    assert sorted(seen) == sorted(e.hash for e in entries)


def test_shard_index_validated():
    with pytest.raises(ValueError):
        shard_entries([], 2, 2)


# -------------------------------------------------- resume + merge bytes
def test_kill_and_resume_merge_byte_identical(plan, tmp_path):
    # reference: the full sweep in one uninterrupted run
    full = ResultStore(str(tmp_path / "full.jsonl"))
    s = run_plan(plan, full)
    assert s == {"built": 4, "cached": 0, "failed": 0,
                 "shard": (0, 1), "total": 4}
    merge([full.path], str(tmp_path / "full_merged.jsonl"))
    golden = (tmp_path / "full_merged.jsonl").read_bytes()

    # killed mid-sweep (limit= simulates the kill) and re-run
    part = ResultStore(str(tmp_path / "part.jsonl"))
    assert run_plan(plan, part, limit=2)["built"] == 2
    resumed = ResultStore(part.path)          # fresh open, as a new process
    s = run_plan(plan, resumed)
    assert s["built"] == 2 and s["cached"] == 2
    merge([part.path], str(tmp_path / "part_merged.jsonl"))
    assert (tmp_path / "part_merged.jsonl").read_bytes() == golden

    # a finished sweep re-runs with ZERO builds
    assert run_plan(plan, resumed)["built"] == 0


def test_two_shard_merge_equals_single_host(plan, tmp_path):
    s0 = ResultStore(str(tmp_path / "s0.jsonl"))
    s1 = ResultStore(str(tmp_path / "s1.jsonl"))
    run_plan(plan, s0, shard_index=0, num_shards=2)
    run_plan(plan, s1, shard_index=1, num_shards=2)
    assert not (s0.hashes() & s1.hashes())
    merge([s0.path, s1.path], str(tmp_path / "m2.jsonl"))

    one = ResultStore(str(tmp_path / "one.jsonl"))
    run_plan(plan, one)
    merge([one.path], str(tmp_path / "m1.jsonl"))
    assert (tmp_path / "m2.jsonl").read_bytes() == \
        (tmp_path / "m1.jsonl").read_bytes()


def test_store_records_carry_exact_wire_ints(plan, tmp_path):
    store = ResultStore(str(tmp_path / "w.jsonl"))
    run_plan(plan, store)
    for rec in store.ok_records():
        m = rec["metrics"]
        assert isinstance(m["uplink_bits"], int)
        assert isinstance(m["downlink_bits"], int)
        assert m["total_bits"] == m["uplink_bits"] + m["downlink_bits"]
        assert m["bits_cumulative"][-1] == m["total_bits"]


# ------------------------------------------------- isolation + budgets
def test_failure_isolation_and_retry(plan, tmp_path, monkeypatch):
    doomed = plan.entries[1].hash
    real = runner_mod._build_and_run

    def flaky(entry, deadline):
        if entry.hash == doomed:
            raise RuntimeError("diverged (injected)")
        return real(entry, deadline)

    monkeypatch.setattr(runner_mod, "_build_and_run", flaky)
    store = ResultStore(str(tmp_path / "f.jsonl"))
    s = run_plan(plan, store)
    assert s["built"] == 3 and s["failed"] == 1      # sweep survived
    rec = store.get(doomed)
    assert rec["status"] == "failed" and "diverged" in rec["error"]

    # failed cells count as done unless retry_failed is set
    assert run_plan(plan, store)["built"] == 0
    monkeypatch.setattr(runner_mod, "_build_and_run", real)
    s = run_plan(plan, store, retry_failed=True)
    assert s["built"] == 1 and store.get(doomed)["status"] == "ok"


def test_wall_time_budget_truncates_not_kills(tmp_path):
    sweep = plan_grid({}, dict(BASE, aggregator="mean", n_steps=50))
    store = ResultStore(str(tmp_path / "b.jsonl"))
    s = run_plan(sweep, store, time_budget_s=1e-6)
    assert s == {"built": 1, "cached": 0, "failed": 0,
                 "shard": (0, 1), "total": 1}
    (rec,) = store.ok_records()
    m = rec["metrics"]
    assert m["truncated"] is True
    assert 1 <= len(m["loss"]) < 50       # at least one round, then stopped

    # truncated counts as done by default, but retry_truncated re-runs it
    assert run_plan(sweep, store)["built"] == 0
    s = run_plan(sweep, store, retry_truncated=True)
    assert s["built"] == 1
    (rec,) = store.ok_records()
    assert rec["metrics"]["truncated"] is False
    assert len(rec["metrics"]["loss"]) == 50


def test_merge_refuses_missing_shard_file(plan, tmp_path):
    store = ResultStore(str(tmp_path / "ok.jsonl"))
    run_plan(plan, store, limit=1)
    with pytest.raises(FileNotFoundError, match="typo"):
        merge([store.path, str(tmp_path / "typo.jsonl")],
              str(tmp_path / "out.jsonl"))


# -------------------------------------------------------------- report
def test_report_tables_render(plan, tmp_path, capsys):
    store = ResultStore(str(tmp_path / "r.jsonl"))
    run_plan(plan, store)
    tables = report(store)
    out = capsys.readouterr().out
    assert "resilience frontier" in out and "norm_trim" in out
    assert len(tables["resilience"]) >= 1
    assert len(tables["eps"]) == 4
    row = tables["resilience"][0]
    assert {"problem", "alpha", "compressor", "attack"} <= set(row)


def test_cli_plan_and_report_roundtrip(plan, tmp_path, capsys):
    from repro.sweep.__main__ import main

    store_path = str(tmp_path / "cli.jsonl")
    run_plan(plan, ResultStore(store_path))
    assert main(["plan", "--preset", "smoke"]) == 0
    assert main(["report", store_path]) == 0
    out = capsys.readouterr().out
    assert "cells planned" in out and "sweep report" in out


def test_report_plots_render_panels(plan, tmp_path, capsys):
    """--plots renders the Fig. 1-3 panels from the same store the
    tables pivot (matplotlib-gated — skipped when it is absent)."""
    pytest.importorskip("matplotlib")
    import os

    from repro.sweep.report import plots

    store = ResultStore(str(tmp_path / "p.jsonl"))
    run_plan(plan, store)
    out_dir = str(tmp_path / "plots")
    written = plots(store, out_dir)
    assert written and all(os.path.exists(p) for p in written)
    names = {os.path.basename(p) for p in written}
    # the grid is all-attacked with grad_norm/bits series: Figs. 1-2 and
    # the bits-to-ε panel must render; Fig. 3 needs attack-free cells
    assert "fig12_resilience.png" in names
    assert "fig_bits_to_eps.png" in names
    assert capsys.readouterr().out.count("wrote")
    # CLI path: the flag drives the same renderer after the tables
    from repro.sweep.__main__ import main

    assert main(["report", store.path, "--plots",
                 str(tmp_path / "plots2")]) == 0
    assert os.path.exists(str(tmp_path / "plots2" / "fig12_resilience.png"))


# ------------------------------------------------- benchmark thin views
def test_fig12_thin_view_pivots_only_its_plan(tmp_path):
    """A reused store may hold other grids (other T, other compressors);
    the figure must render exactly its own plan's cells."""
    from benchmarks import fig12_byzantine

    path = str(tmp_path / "fig12.jsonl")
    kw = dict(datasets=("a9a",), attacks=("gaussian",), alphas=(0.2,),
              aggregators=("norm_trim",), store_path=path)
    keys = {"fig2/a9a/gaussian/alpha=0.2/norm_trim",
            "fig1/a9a/gaussian/alpha=0.2/norm_trim"}
    r1 = fig12_byzantine.run(T=2, **kw)
    assert set(r1) == keys
    # second grid against the SAME store: T=3 cells join the T=2 ones,
    # but each view pivots only its own round budget
    r2 = fig12_byzantine.run(T=3, **kw)
    assert set(r2) == keys
    assert len(r2["fig1/a9a/gaussian/alpha=0.2/norm_trim"]["loss"]) == 3
    assert len(r1["fig1/a9a/gaussian/alpha=0.2/norm_trim"]["loss"]) == 2


def test_fig12_raises_on_failed_cells(monkeypatch):
    from benchmarks import fig12_byzantine

    calls = {"n": 0}

    def boom(entry, deadline):
        calls["n"] += 1
        raise RuntimeError("injected divergence")

    monkeypatch.setattr(runner_mod, "_build_and_run", boom)
    with pytest.raises(RuntimeError, match="failed"):
        fig12_byzantine.run(T=2, datasets=("a9a",), attacks=("gaussian",),
                            alphas=(0.2,), aggregators=("norm_trim",))
    assert calls["n"] > 0


def test_fig12_raises_on_uncoverable_grid():
    """Plan-time skips in the figure's own grid are loud (the old
    SpecError behaviour), not silently missing keys."""
    from benchmarks import fig12_byzantine

    with pytest.raises(RuntimeError, match="skipped at plan time"):
        fig12_byzantine.run(T=2, datasets=("a9a",), attacks=("gaussian",),
                            alphas=(0.45,), aggregators=("krum",))


# ------------------------------------------------- serving downlink bits
def test_serve_broadcast_params_int8_bits_and_accuracy():
    from repro.launch.serve import broadcast_params

    params = {"w": jnp.linspace(-1.0, 1.0, 100), "b": jnp.zeros((3,))}
    out, info = broadcast_params(params, "int8")
    # exact ledger bits: 8/coord + one fp32 scale per 128-block per leaf
    assert info["downlink_bits"] == (100 * 8 + 32) + (3 * 8 + 32)
    assert info["full_precision_bits"] == 32 * 103
    # int8 per-coordinate error ≤ max|x|/254
    assert float(jnp.max(jnp.abs(out["w"] - params["w"]))) <= 1.0 / 254 + 1e-6
    assert out["b"].shape == (3,)

    out, info = broadcast_params(params, None)
    assert info["downlink_bits"] == info["full_precision_bits"]
    assert jnp.array_equal(out["w"], params["w"])
