"""End-to-end behaviour tests for the paper's system.

The full story in one place: the paper-faithful pipeline (synthetic LIBSVM
twin → 20 workers → Algorithm 1 under attack → robust convergence) and the
framework pipeline (train driver on a reduced assigned arch, serve driver
decode), exactly as the examples run them.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import PAPER_WORKLOADS
from repro.core import AttackConfig, DistributedCubicNewton, NewtonConfig
from repro.data import paper_dataset
from repro.launch.serve import run_serving
from repro.launch.train import run_training


def logistic_loss(w, X, y):
    z = X @ w
    yy = 2.0 * y - 1.0
    return jnp.mean(jnp.log1p(jnp.exp(-yy * z))) + 0.5 / X.shape[0] * (w @ w)


def test_paper_pipeline_a9a_twin():
    """The §6 protocol end-to-end at reduced rounds: m=20 machines,
    β = α + 2/m, flipped-label attack, accuracy recovers."""
    wl = PAPER_WORKLOADS["a9a-logistic"]
    data = paper_dataset(wl, seed=0)
    m = wl.m_workers
    alpha = 0.15
    algo = DistributedCubicNewton(
        logistic_loss,
        NewtonConfig(M=wl.M, eta=wl.eta, beta=alpha + 2 / m),
        AttackConfig(name="flipped_label", alpha=alpha),
    )
    w0 = jnp.zeros(wl.dim)
    w, hist = algo.run(w0, data["X_workers"], data["y_workers"], 8)
    acc = float(((data["X_test"] @ w > 0) == (data["y_test"] > 0.5)).mean())
    assert acc > 0.8
    assert hist["loss"][-1] < hist["loss"][0]


def test_train_driver_end_to_end():
    _, hist = run_training(
        arch="deepseek-moe-16b", preset="smoke", steps=6, m_workers=4,
        per_worker_batch=2, seq_len=64, solver_iters=2, log_every=5,
    )
    assert hist[-1] < hist[0]


def test_train_driver_under_attack():
    _, hist = run_training(
        arch="codeqwen1.5-7b", preset="smoke", steps=6, m_workers=4,
        per_worker_batch=2, seq_len=64, solver_iters=2,
        attack="gaussian", alpha=0.25, beta=0.5, log_every=5,
    )
    assert hist[-1] < hist[0]


def test_serve_driver_end_to_end():
    toks = run_serving(arch="gemma3-27b", preset="smoke", batch=2,
                       prompt_len=8, gen=8)
    assert toks.shape == (2, 8)
