"""repro.telemetry: zero-cost-when-disabled instrumentation.

Pins the subsystem's three contracts:

* **disabled is free** — every emit early-returns, ``span()`` is a shared
  no-op, and :func:`device_event` stages nothing: the lowered HLO with
  telemetry disabled is bit-identical to code without the call;
* **enabled is exact** — events are schema-valid JSONL, the Chrome trace
  parses, per-transmit wire events sum to the WireLedger's integer
  totals, and round records mirror the histories both runtimes return;
* **observation does not perturb results** — a sweep run with telemetry
  on produces a byte-identical merged store to one with telemetry off,
  and the compile-counter pins the expected number of XLA compiles
  (recompile hygiene: 3 for the adaptive-k ladder's 3 distinct k,
  exactly 1 per sweep cell).
"""
import json
import os
from functools import partial

import jax
import jax.numpy as jnp
import pytest

from repro.api import ExperimentSpec
from repro.compression import AdaptiveTopK
from repro.sweep import runner
from repro.sweep import store as store_mod
from repro.sweep.grid import plan_grid
from repro.sweep.report import telemetry_report, wire_table
from repro.telemetry import (
    CompileCounter,
    RoundRecord,
    Telemetry,
    compile_scope,
    device_event,
    get_telemetry,
    rejected_from_keep,
    validate_event,
    validate_stream,
)
from repro.telemetry.__main__ import (
    check_chrome_trace,
    check_wire_exactness,
    main as telemetry_cli,
)
from repro.telemetry.core import _NOOP_SPAN


@pytest.fixture
def tel(tmp_path, monkeypatch):
    """A fresh, sink-backed Telemetry installed as the process global
    (so the runtimes' ``get_telemetry()`` calls see it), detached after
    the test."""
    from repro.telemetry import core

    t = Telemetry()
    t.enable(str(tmp_path / "telemetry"))
    monkeypatch.setattr(core, "_GLOBAL", t)
    yield t
    t.disable()


def _events(t):
    t.flush()
    path = os.path.join(t.out_dir, "events.jsonl")
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


PAPER_KW = dict(problem="synthetic-logistic:120:12", m_workers=4,
                aggregator="norm_trim:0.3", attack="gaussian", alpha=0.25)


# ------------------------------------------------------------- disabled
def test_disabled_is_noop(tmp_path):
    t = Telemetry()
    assert not t.enabled
    t.event("x", a=1)
    t.count("c")
    t.gauge("g", 2.0)
    t.observe("h", 3.0)
    t.wire(ledger_id=0, uplink=1, downlink=2, rounds=1)
    t.round(RoundRecord(step=0))
    assert t.span("s") is _NOOP_SPAN          # shared object, no allocation
    assert t.span("other") is _NOOP_SPAN
    assert t.counter_value("c") is None
    assert t.histogram("h") is None
    assert list(tmp_path.iterdir()) == []     # nothing ever touches disk


def test_device_event_hlo_identity():
    """Disabled device_event stages NOTHING: the lowered HLO is
    bit-identical to a build without the call, and contains no host
    callback; enabled, it differs and carries one."""
    t_off = Telemetry()
    t_on = Telemetry().enable()               # memory-only; no sinks needed
    x = jnp.arange(8.0)

    def step(z):                               # instrumented body
        device_event("probe", tel=t_off, s=jnp.sum(z))
        return z * 2.0 + 1.0

    instrumented = jax.jit(step).lower(x).as_text()

    def step(z):                               # same name ⇒ same HLO module
        return z * 2.0 + 1.0

    bare = jax.jit(step).lower(x).as_text()
    assert instrumented == bare
    assert "callback" not in instrumented

    def step(z):
        device_event("probe", tel=t_on, s=jnp.sum(z))
        return z * 2.0 + 1.0

    enabled = jax.jit(step).lower(x).as_text()
    assert enabled != bare
    assert "callback" in enabled
    t_on.disable()


# -------------------------------------------------------------- enabled
def test_emits_are_schema_valid_and_trace_parses(tel):
    tel.event("e", foo="bar")
    tel.count("n", 2)
    tel.gauge("g", 1.5)
    tel.observe("lat", 0.25)
    with tel.span("outer", label="x"):
        with tel.span("inner"):
            assert tel.current_span() == "inner"
    tel.wire(ledger_id=7, uplink=10, downlink=4, rounds=1)
    tel.ledger_snapshot(ledger_id=7, snapshot={
        "uplink_bits": 10, "downlink_bits": 4, "total_bits": 14,
        "rounds": 1})
    tel.round(RoundRecord(step=0, loss=1.0, rejected=[2]))
    tel.flush()
    events = _events(tel)
    for ev in events:
        assert validate_event(ev) == [], ev
    assert check_wire_exactness(events) == []
    assert check_chrome_trace(os.path.join(tel.out_dir, "trace.json")) == []


def test_histogram_percentiles():
    t = Telemetry().enable()
    for v in range(1, 101):
        t.observe("lat", float(v))
    h = t.histogram("lat")
    assert h["count"] == 100 and h["min"] == 1.0 and h["max"] == 100.0
    assert h["p50"] == pytest.approx(50.0, abs=1)
    assert h["p99"] == pytest.approx(99.0, abs=1)
    t.disable()


# -------------------------------------------------- runtimes emit rounds
def test_paper_run_round_records_and_wire_exactness(tel):
    spec = ExperimentSpec(compressor="adaptive_topk:0.25:0.9", **PAPER_KW)
    exp = spec.build()
    _, hist = exp.run(4)
    events = _events(tel)
    rounds = [e for e in events if e["kind"] == "round"]
    assert len(rounds) == 4
    for i, r in enumerate(rounds):
        assert r["step"] == i and r["runtime"] == "paper"
        assert r["attack"] == "gaussian" and r["alpha"] == 0.25
        assert r["loss"] == pytest.approx(hist["loss"][i])
        assert r["grad_norm"] == pytest.approx(hist["grad_norm"][i])
        assert r["uplink_delta"] == pytest.approx(hist["uplink_delta"][i])
        assert r["k"] == hist["k_trajectory"][i]
        assert isinstance(r["rejected"], list)
        assert r["model_decrease"] is not None
    # acceptance criterion (a): wire events sum EXACTLY to ledger totals
    assert check_wire_exactness(events) == []
    run_wire = [e for e in events
                if e["kind"] == "wire" and e.get("label") == "round"]
    assert sum(e["uplink"] for e in run_wire) == hist["uplink_bits"]
    assert sum(e["downlink"] for e in run_wire) == hist["downlink_bits"]


def test_mesh_run_round_records_and_device_event(tel):
    spec = ExperimentSpec(problem="quadratic:16", runtime="mesh",
                          m_workers=4, aggregator="norm_trim:0.3",
                          attack="gaussian", alpha=0.25,
                          compressor="topk:0.5")
    exp = spec.build()
    _, hist = exp.run(3)
    events = _events(tel)
    rounds = [e for e in events if e["kind"] == "round"]
    assert len(rounds) == 3
    assert all(r["runtime"] == "mesh" for r in rounds)
    assert hist["uplink_delta"] and len(hist["uplink_delta"]) == 3
    # the staged jax.debug.callback shipped the device-side keep mask out
    aggs = [e for e in events
            if e["kind"] == "event" and e["name"] == "mesh.aggregate"]
    assert len(aggs) == 3
    assert len(aggs[0]["keep"]) == 4
    assert check_wire_exactness(events) == []


def test_saddle_escape_flag_and_step():
    """matrix-factor carries a known saddle value; the run must flag the
    first round whose loss drops below it (paper's headline claim)."""
    from repro.telemetry import core

    t = Telemetry()
    spec = ExperimentSpec(problem="matrix-factor:6:2", m_workers=4,
                          aggregator="mean", M=5.0)
    exp = spec.build()
    saved = core._GLOBAL
    core._GLOBAL = t
    try:
        _, hist = exp.run(25)
    finally:
        core._GLOBAL = saved
    sv = exp.problem.saddle_value
    esc = hist["saddle_escape_step"]
    below = [i for i, l in enumerate(hist["loss"]) if l < sv]
    assert esc == (below[0] if below else None)


# -------------------------------------------- observation ≠ perturbation
def _run_sweep(store_path, n_cells=2):
    plan = plan_grid({"seed": list(range(n_cells))},
                     {**PAPER_KW, "compressor": "topk:0.25", "n_steps": 3})
    st = store_mod.ResultStore(store_path)
    summary = runner.run_plan(plan, st)
    assert summary["failed"] == 0
    return st


def test_sweep_store_byte_identical_with_telemetry_on_off(
        tmp_path, monkeypatch):
    """Telemetry is an observer: the merged (volatile-stripped,
    hash-sorted) store bytes are identical with it on and off."""
    from repro.telemetry import core

    off = tmp_path / "off.jsonl"
    monkeypatch.setattr(core, "_GLOBAL", Telemetry())   # decidedly off
    _run_sweep(str(off))
    on = tmp_path / "on.jsonl"
    t = Telemetry().enable(str(tmp_path / "tel"))
    monkeypatch.setattr(core, "_GLOBAL", t)
    _run_sweep(str(on))
    t.disable()
    store_mod.merge([str(off)], str(tmp_path / "off_m.jsonl"))
    store_mod.merge([str(on)], str(tmp_path / "on_m.jsonl"))
    assert (tmp_path / "off_m.jsonl").read_bytes() \
        == (tmp_path / "on_m.jsonl").read_bytes()
    # and the telemetry-on run actually observed: spans for every phase
    t.flush()
    names = {e["name"] for e in _events(t) if e["kind"] == "span"}
    assert {"sweep.shard", "sweep.cell", "sweep.cell.build",
            "sweep.cell.run", "sweep.cell.store"} <= names


def test_sweep_store_persists_wire_adaptivity_columns(tmp_path):
    """Satellite: per-round uplink_delta and the adaptive-k trajectory
    land in the stored cell metrics, and sweep.report can pivot them."""
    plan = plan_grid({"seed": [0]},
                     {**PAPER_KW, "compressor": "adaptive_topk:0.25:0.9",
                      "n_steps": 3})
    st = store_mod.ResultStore(str(tmp_path / "s.jsonl"))
    assert runner.run_plan(plan, st)["failed"] == 0
    (rec,) = st.ok_records()
    m = rec["metrics"]
    assert len(m["uplink_delta"]) == 3
    assert len(m["k_trajectory"]) == 3
    assert m["k_trajectory"][0] == 3    # ceil-free int(0.25·12)
    (row,) = wire_table([rec])
    assert row["k_start"] == m["k_trajectory"][0]
    assert row["k_final"] == m["k_trajectory"][-1]
    assert row["delta_mean"] == pytest.approx(
        sum(m["uplink_delta"]) / 3)


# ------------------------------------------------- compile-count pins
def test_compile_pin_adaptive_topk_d4096():
    """Recompile hygiene: the pinned d=4096 δ̂ ladder moves k three times
    (410→820→1640, then holds), so a k-static jitted consumer compiles
    EXACTLY 3 times — one XLA compile per distinct k, none for the holds."""
    from repro.kernels.ref import topk_compress_ref

    d = 4096
    comp = AdaptiveTopK(d, 205, 3277, delta_target=0.6)
    x = jax.random.normal(jax.random.PRNGKey(0), (d,))
    f = jax.jit(partial(topk_compress_ref), static_argnums=1)
    ks = []
    cc = CompileCounter()
    with cc, compile_scope("pin.adaptive"):
        for delta in (0.2, 0.3, 0.5, 0.7, 0.9, 0.9):
            comp.schedule_update(grad_norm=1.0, measured_delta=delta)
            ks.append(comp.k)
            f(x, comp.k)
    assert ks == [410, 820, 1640, 1640, 1640, 1640]
    assert cc.backend_compiles("pin.adaptive") == len(set(ks)) == 3


def test_compile_pin_sweep_one_compile_per_cell(tmp_path):
    """A 2-cell sweep differing only in seed compiles the paper step
    EXACTLY twice — once per cell (each Experiment owns a fresh jit),
    never per round.  Guards against per-step retrace regressions."""
    cc = CompileCounter()
    with cc:
        _run_sweep(str(tmp_path / "s.jsonl"), n_cells=2)
    assert cc.backend_compiles("newton.step") == 2


# --------------------------------------------------------- CLI / report
def test_validate_cli_exit_codes(tel, tmp_path, capsys):
    spec = ExperimentSpec(compressor="topk:0.25", **PAPER_KW)
    spec.build().run(2)
    tel.flush()
    events_path = os.path.join(tel.out_dir, "events.jsonl")
    trace_path = os.path.join(tel.out_dir, "trace.json")
    assert telemetry_cli([
        "validate", events_path, "--trace", trace_path, "--check-wire",
    ]) == 0
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"v": 1, "kind": "wire", "name": "wire"}\n')
    assert telemetry_cli(["validate", str(bad)]) == 1
    capsys.readouterr()


def test_validate_stream_catches_missing_fields():
    good = json.dumps({"v": 1, "kind": "event", "name": "x",
                       "ts": 0.0, "wall": 0.0})
    bad = json.dumps({"v": 1, "kind": "span", "name": "x",
                      "ts": 0.0, "wall": 0.0})    # span without dur_s
    problems = validate_stream([good, bad])
    assert [ln for ln, _ in problems] == [2]


def test_telemetry_report_aggregates(tel, tmp_path):
    _run_sweep(str(tmp_path / "s.jsonl"))
    tel.flush()
    lines = []
    rep = telemetry_report(os.path.join(tel.out_dir, "events.jsonl"),
                           printer=lines.append)
    assert rep["cells"]["ok"] == 2 and rep["cells"]["failed"] == 0
    assert rep["rounds"] == 6                      # 2 cells × 3 rounds
    assert rep["wire"]["uplink"] > 0
    span_names = {r["span"] for r in rep["spans"]}
    assert "sweep.cell.run" in span_names
    assert any("sweep report" not in ln and "cells:" in ln for ln in lines)


# ----------------------------------------------- schema v2 (center path)


def test_round_records_carry_center_path_fields(tel):
    """Round records carry the v2 center-path fields (center_bytes +
    agg_kernel) at the current schema version, and the
    newton.center_bytes gauge mirrors them — sparse and dense paths."""
    from repro.telemetry.schema import SCHEMA_VERSION

    spec = ExperimentSpec(problem="synthetic-logistic:120:12", m_workers=4,
                          aggregator="mean", compressor="topk:0.25",
                          error_feedback="none")
    exp = spec.build()
    exp.run(2)
    events = _events(tel)
    rounds = [e for e in events if e["kind"] == "round"]
    assert rounds and all(e["v"] == SCHEMA_VERSION for e in rounds)
    d, m = 12, 4
    k = max(1, round(0.25 * d))
    for r in rounds:
        assert r["agg_kernel"] == "sparse"
        assert r["center_bytes"] == m * k * 8 + 4 * d
    gauges = [e for e in events if e["kind"] == "gauge"
              and e["name"] == "newton.center_bytes"]
    assert len(gauges) == len(rounds)
    assert all(g["value"] == rounds[0]["center_bytes"] for g in gauges)
    assert validate_stream(json.dumps(e) for e in events) == []


def test_round_record_dense_path_fields(tel):
    spec = ExperimentSpec(**PAPER_KW)   # norm_trim + gaussian attack ⇒ dense
    exp = spec.build()
    exp.run(2)
    rounds = [e for e in _events(tel) if e["kind"] == "round"]
    d, m = 12, 4
    for r in rounds:
        assert r["agg_kernel"] == "dense"
        assert r["center_bytes"] == m * d * 4 + 4 * d


def test_schema_v2_validator_coverage():
    """v1-v3 events stay valid forever; per-version field constraints
    enforced; unknown versions rejected."""
    from repro.telemetry.schema import ACCEPTED_VERSIONS, SCHEMA_VERSION

    assert SCHEMA_VERSION == 4 and ACCEPTED_VERSIONS == (1, 2, 3, 4)
    base = {"kind": "round", "name": "newton.round", "ts": 0.1,
            "wall": 1.0, "step": 0}
    assert validate_event({**base, "v": 1}) == []          # v1 round: valid
    assert validate_event({**base, "v": 2, "center_bytes": 128,
                           "agg_kernel": "sparse"}) == []
    assert validate_event({**base, "v": 5})                # unknown version
    assert any("agg_kernel" in p for p in
               validate_event({**base, "v": 2, "agg_kernel": "vectorized"}))
    assert any("center_bytes" in p for p in
               validate_event({**base, "v": 2, "center_bytes": -4}))
    assert any("center_bytes" in p for p in
               validate_event({**base, "v": 2, "center_bytes": 3.5}))


def test_schema_v4_worker_field_validation():
    """The per-worker forensic lists: typed entries, null participation
    holes where allowed, suspicion clamped to [0, 1]."""
    base = {"kind": "round", "name": "newton.round", "ts": 0.1,
            "wall": 1.0, "step": 0, "v": 4}
    ok = {**base, "worker_bits": [64, 0], "worker_delta": [0.9, None],
          "worker_keep": [1.0, None], "worker_norms": [0.5, None],
          "worker_staleness": [0, None], "suspicion": [0.0, 1.0],
          "byzantine_true": [0]}
    assert validate_event(ok) == []
    assert any("worker_bits" in p for p in
               validate_event({**base, "worker_bits": [-1]}))
    assert any("worker_bits" in p for p in
               validate_event({**base, "worker_bits": [None]}))
    assert any("suspicion" in p for p in
               validate_event({**base, "suspicion": [1.5]}))
    assert any("suspicion" in p for p in
               validate_event({**base, "suspicion": [None]}))
    assert any("byzantine_true" in p for p in
               validate_event({**base, "byzantine_true": [0.5]}))
    assert any("worker_staleness" in p for p in
               validate_event({**base, "worker_staleness": [1.5]}))
    assert any("worker_keep" in p for p in
               validate_event({**base, "worker_keep": "all"}))
